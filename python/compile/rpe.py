"""Relative positional encoders (RPEs) — time-domain and frequency-domain.

Three parameterisations of the stationary (non-SPD) kernel
``k_l(i-j)`` that generates the Toeplitz mixing matrices:

1. :func:`time_rpe` — the baseline TNN's MLP over normalised relative
   position, multiplied by the explicit decay bias ``λ^{|t|}``
   (Qin et al. 2023, reproduced as the comparison baseline).
2. :func:`fd_rpe` — FD-TNN's MLP over normalised frequency
   ``ω/π ∈ [0,1]`` modelling the kernel's frequency response directly;
   real-only for causal models (imaginary part recovered with the
   Hilbert transform), complex (2d outputs) for bidirectional models.
   Smoothness of the chosen activation sets the implied time-domain
   decay (paper Theorems 2–4): GeLU ⇒ super-exponential, SiLU ⇒
   super-polynomial, ReLU ⇒ square-summable.
3. SKI's RPE is *not* an MLP at all: Proposition 1 shows a scalar ReLU
   MLP is just a piecewise-linear function, so SKI-TNO learns the
   piecewise-linear function directly — a value table over the
   inverse-time-warped axis, read by :func:`ski_taps` (paper §3.2.2).

MLPs follow the paper's structure: hidden layers are
``act(LayerNorm(W h + b))``, the output layer is linear.
"""

import jax
import jax.numpy as jnp

_ACTS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def layer_norm(x, g, b, eps=1e-5):
    """LayerNorm over the trailing axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def mlp_init(key, sizes, out_scale=1.0):
    """Init an MLP ``sizes[0] -> ... -> sizes[-1]`` with LN on hiddens.

    Returns a dict of parameters; hidden layers carry LN gain/bias.
    """
    params = {}
    n_layers = len(sizes) - 1
    keys = jax.random.split(key, n_layers)
    for i in range(n_layers):
        fan_in, fan_out = sizes[i], sizes[i + 1]
        scale = (1.0 / max(fan_in, 1)) ** 0.5
        if i == n_layers - 1:
            scale *= out_scale
        kw, kb = jax.random.split(keys[i])
        params[f"w{i}"] = scale * jax.random.normal(kw, (fan_in, fan_out))
        # Random (not zero) bias: with b = 0 the first hidden layer is
        # x·w and LayerNorm turns it into a sign-like function with a
        # transition of width ~sqrt(eps) at x = 0 — a spectral spike at
        # ω = 0 that destroys the smoothness⇒decay behaviour of §4.2.
        # PyTorch-style U(-1/√fan_in, 1/√fan_in) keeps the per-unit
        # spread positive everywhere.
        params[f"b{i}"] = (1.0 / max(fan_in, 1)) ** 0.5 * jax.random.uniform(
            kb, (fan_out,), minval=-1.0, maxval=1.0
        )
        if i < n_layers - 1:
            params[f"g{i}"] = jnp.ones((fan_out,))
            params[f"h{i}"] = jnp.zeros((fan_out,))
    return params


def mlp_apply(params, x, act="relu"):
    """Apply the MLP; ``x`` is ``(..., sizes[0])``."""
    f = _ACTS[act]
    n_layers = len([k for k in params if k.startswith("w")])
    h = x
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = f(layer_norm(h, params[f"g{i}"], params[f"h{i}"]))
    return h


def rpe_sizes(hidden: int, layers: int, out: int):
    """MLP shape for an RPE with `layers` hidden layers."""
    return [1] + [hidden] * layers + [out]


# ---------------------------------------------------------------------------
# Baseline time-domain RPE (TNN)
# ---------------------------------------------------------------------------


def time_rpe(params, n: int, d: int, lam: float, causal: bool, act: str = "relu"):
    """Kernel values at all 2n-1 relative positions, with decay bias.

    Returns ``(k_neg, k_zero, k_pos)``:
      k_neg ``(n-1, d)`` at lags ``-1..-(n-1)``, k_zero ``(d,)``,
      k_pos ``(n-1, d)`` at lags ``1..n-1``.  For causal models the
      negative lags are zeroed (upper triangle of T removed).
    """
    t = jnp.arange(-(n - 1), n, dtype=jnp.float32)  # (2n-1,)
    feats = (t / n)[:, None]
    k = mlp_apply(params, feats, act=act)  # (2n-1, d)
    k = k * (lam ** jnp.abs(t))[:, None]
    k_neg_rev = k[: n - 1]  # lags -(n-1)..-1
    k_zero = k[n - 1]
    k_pos = k[n:]  # lags 1..n-1
    k_neg = k_neg_rev[::-1]  # lags -1..-(n-1)
    if causal:
        k_neg = jnp.zeros_like(k_neg)
    return k_neg, k_zero, k_pos


# ---------------------------------------------------------------------------
# Frequency-domain RPE (FD-TNN)
# ---------------------------------------------------------------------------


def fd_rpe_real(params, n: int, act: str = "relu"):
    """Real frequency response on the rFFT grid ``ω_m = mπ/n``, m=0..n.

    Used by the causal FD-TNO: the response is interpreted as the real
    (even) part of the causal kernel's spectrum.  Returns ``(n+1, d)``.
    """
    w = jnp.arange(n + 1, dtype=jnp.float32) / n  # ω/π in [0, 1]
    return mlp_apply(params, w[:, None], act=act)


def fd_rpe_complex(params, n: int, d: int, act: str = "relu"):
    """Complex frequency response for the bidirectional FD-TNO.

    The MLP emits ``2d`` outputs per frequency — real and imaginary
    halves — and the imaginary part is forced to zero at ``ω = 0`` and
    ``ω = π`` so the time-domain kernel is real (paper §3.3.2).
    Returns ``(kr, ki)`` each ``(n+1, d)``.
    """
    w = jnp.arange(n + 1, dtype=jnp.float32) / n
    out = mlp_apply(params, w[:, None], act=act)  # (n+1, 2d)
    kr, ki = out[:, :d], out[:, d:]
    edge = jnp.ones((n + 1, 1), out.dtype).at[0, 0].set(0.0).at[n, 0].set(0.0)
    return kr, ki * edge


# ---------------------------------------------------------------------------
# SKI RPE: piecewise-linear table over the inverse time warp
# ---------------------------------------------------------------------------


def inverse_time_warp(t, lam: float):
    """``x(t) = sign(t) λ^{|t|}`` — maps all of R into [-1, 1].

    Long lags compress towards 0, so extending to unseen sequence
    lengths *interpolates* the table near its centre instead of
    extrapolating an MLP (paper §3.2.2).
    """
    return jnp.sign(t) * lam ** jnp.abs(t)


def table_lookup(table, x):
    """Linear interpolation of a ``(tbl, d)`` table on the axis [-1, 1].

    The centre entry is structurally zeroed so that ``k(0) = 0`` and
    ``k(±∞) → 0`` (the warp sends both to the table centre — this *is*
    the implicit decay bias of SKI-TNO).
    """
    tbl = table.shape[0]
    assert tbl % 2 == 1, "table size must be odd so the centre pins zero"
    centre = tbl // 2
    mask = jnp.ones((tbl, 1), table.dtype).at[centre, 0].set(0.0)
    tab = table * mask
    g = (x + 1.0) * 0.5 * (tbl - 1)  # fractional grid coordinate
    lo = jnp.clip(jnp.floor(g).astype(jnp.int32), 0, tbl - 2)
    frac = (g - lo.astype(x.dtype))[:, None]
    return (1.0 - frac) * jnp.take(tab, lo, axis=0) + frac * jnp.take(
        tab, lo + 1, axis=0
    )


def ski_taps(table, r: int, h: float, lam: float):
    """Inducing-point Gram taps ``a_q = k(τ_q)``, ``τ_q = (q-(r-1))·h``.

    ``h`` is the inducing-point spacing ``(n-1)/(r-1)``; the kernel is
    the warped table read, so only ``2r-1`` evaluations are needed per
    layer instead of ``2n-1`` MLP calls (the paper's headline RPE-cost
    reduction).  Returns ``(2r-1, d)``.
    """
    tau = (jnp.arange(2 * r - 1, dtype=jnp.float32) - (r - 1)) * h
    return table_lookup(table, inverse_time_warp(tau, lam))


__all__ = [
    "layer_norm",
    "mlp_init",
    "mlp_apply",
    "rpe_sizes",
    "time_rpe",
    "fd_rpe_real",
    "fd_rpe_complex",
    "inverse_time_warp",
    "table_lookup",
    "ski_taps",
]
