"""Fused train step (fwd + bwd + Adam) lowered as a single artifact.

The Rust coordinator drives training by repeatedly executing this one
compiled computation; Python never runs after `make artifacts`.  The
whole optimizer state lives in the artifact's input/output signature:

    step(*params, *m, *v, t, *batch) -> (*params', *m', *v', t', loss)

Gradients are clipped to a global norm, the learning rate follows a
linear warmup into a constant (the TNN repo's default schedule shape),
and Adam uses bias correction.
"""

import jax
import jax.numpy as jnp

from . import model
from .configs import ModelCfg

B1, B2, EPS = 0.9, 0.98, 1e-8


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def train_step(params, m, v, t, batch, cfg: ModelCfg):
    """One fused optimization step. ``t`` is the f32 step counter."""

    def loss_of(p):
        loss, _metric = model.loss_fn(p, batch, cfg)
        return loss

    loss, grads = jax.value_and_grad(loss_of)(params)

    # Global-norm clip.
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, cfg.clip / (gnorm + 1e-6))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    t1 = t + 1.0
    lr = cfg.lr * jnp.minimum(1.0, t1 / float(cfg.warmup))
    bc1 = 1.0 - B1**t1
    bc2 = 1.0 - B2**t1

    def upd(p, mi, vi, g):
        mi = B1 * mi + (1.0 - B1) * g
        vi = B2 * vi + (1.0 - B2) * g * g
        p = p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + EPS)
        return p, mi, vi

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    flat_g = jax.tree_util.tree_leaves(grads)
    out_p, out_m, out_v = [], [], []
    for p, mi, vi, g in zip(flat_p, flat_m, flat_v, flat_g):
        p, mi, vi = upd(p, mi, vi, g)
        out_p.append(p)
        out_m.append(mi)
        out_v.append(vi)
    unf = jax.tree_util.tree_unflatten
    return unf(treedef, out_p), unf(treedef, out_m), unf(treedef, out_v), t1, loss


__all__ = ["adam_init", "train_step", "B1", "B2", "EPS"]
