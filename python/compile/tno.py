"""Toeplitz Neural Operators — the four token-mixing variants.

- :func:`tno_base`      — baseline TNN (Qin et al. 2023): MLP RPE at all
  2n-1 lags × explicit decay bias, applied via 2n-circulant FFT.
- :func:`tno_ski`       — paper §3.2: sparse (depthwise conv) + low-rank
  (asymmetric SKI, ``W A Wᵀ``) with the inverse-time-warp table RPE.
  Bidirectional only: Appendix B shows causal masking turns the SKI
  apply into a sequential cumulative sum that forfeits the speedup
  (reproduced in the Rust substrate, `toeplitz::causal_ski_scan`).
- :func:`tno_fd_causal` — paper §3.3.1: real frequency-response RPE,
  imaginary part via the discrete Hilbert transform ⇒ causal kernel,
  no explicit decay bias, one fewer kernel FFT than the baseline.
- :func:`tno_fd_bidir`  — paper §3.3.2: complex frequency response
  (2d-wide RPE), again skipping the kernel FFT and decay bias.

All operate on ``(b, n, e)`` activations channel-wise.  FFTs stay in
XLA (jnp.fft); the per-bin complex modulation, the depthwise conv and
the SKI apply run as Pallas kernels (L1).
"""

import jax.numpy as jnp

from . import rpe as rpe_mod
from .kernels import conv1d, fdmod, ski_lowrank
from .kernels.ski import interp_matrix


def _rfft_pad(x, n):
    """rFFT of x zero-padded to length 2n along the sequence axis."""
    xh = jnp.fft.rfft(x, n=2 * n, axis=1)
    return jnp.real(xh), jnp.imag(xh)


def _irfft_take(yr, yi, n):
    y = jnp.fft.irfft(yr + 1j * yi, n=2 * n, axis=1)
    return y[:, :n]


def tno_base(x, params, *, lam: float, causal: bool, act: str = "relu"):
    """Baseline TNN TNO: circulant-FFT action of T built from the MLP RPE."""
    b, n, d = x.shape
    k_neg, k_zero, k_pos = rpe_mod.time_rpe(params["rpe"], n, d, lam, causal, act)
    zero = jnp.zeros_like(k_zero)[None]
    # circulant first column: [k_0, k_1..k_{n-1}, 0, k_{-(n-1)}..k_{-1}]
    c = jnp.concatenate([k_zero[None], k_pos, zero, k_neg[::-1]], axis=0)  # (2n, d)
    ch = jnp.fft.rfft(c, axis=0)
    xr, xi = _rfft_pad(x, n)
    yr, yi = fdmod(jnp.real(ch), jnp.imag(ch), xr, xi)
    return _irfft_take(yr, yi, n)


def tno_ski(
    x,
    params,
    *,
    lam: float,
    r: int,
    lowrank_only: bool = False,
):
    """SKI-TNO: depthwise-conv sparse branch + fused W A Wᵀ low-rank branch.

    ``params`` carries ``filt`` (m, d) and ``table`` (tbl, d).  ``W`` is
    a structural constant built in-graph from iotas.  ``lowrank_only``
    drops the sparse branch (the fig11 ablation).
    """
    b, n, d = x.shape
    h = (n - 1) / (r - 1)
    taps = rpe_mod.ski_taps(params["table"], r, h, lam)  # (2r-1, d)
    W = interp_matrix(n, r, x.dtype)
    y = ski_lowrank(x, W, taps)
    if not lowrank_only:
        y = y + conv1d(x, params["filt"], False)
    return y


def fd_causal_spectrum(khat_r, n: int):
    """Causal spectrum ``k̂ - i·H{k̂}`` from the real response (Algorithm 2).

    irFFT the even real response to the (even, real) time kernel, keep
    the non-negative-time half (double the strictly-positive lags, keep
    t=0 and t=n once), and rFFT back: the result's imaginary part is
    exactly the discrete Hilbert transform of its real part, and its
    inverse transform is causal.
    """
    kt = jnp.fft.irfft(khat_r, n=2 * n, axis=0)  # (2n, d), real even
    w = jnp.concatenate(
        [
            jnp.ones((1,), kt.dtype),
            2.0 * jnp.ones((n - 1,), kt.dtype),
            jnp.ones((1,), kt.dtype),
            jnp.zeros((n - 1,), kt.dtype),
        ]
    )
    kh = jnp.fft.rfft(kt * w[:, None], axis=0)  # (n+1, d)
    return jnp.real(kh), jnp.imag(kh)


def tno_fd_causal(x, params, *, act: str = "relu"):
    """Causal FD-TNO (Algorithm 2): Hilbert-transform-enforced causality."""
    b, n, d = x.shape
    khat_r = rpe_mod.fd_rpe_real(params["rpe"], n, act=act)  # (n+1, d)
    kr, ki = fd_causal_spectrum(khat_r, n)
    xr, xi = _rfft_pad(x, n)
    yr, yi = fdmod(kr, ki, xr, xi)
    return _irfft_take(yr, yi, n)


def tno_fd_bidir(x, params, *, act: str = "relu"):
    """Bidirectional FD-TNO: complex response, no Hilbert constraint."""
    b, n, d = x.shape
    kr, ki = rpe_mod.fd_rpe_complex(params["rpe"], n, d, act=act)
    xr, xi = _rfft_pad(x, n)
    yr, yi = fdmod(kr, ki, xr, xi)
    return _irfft_take(yr, yi, n)


def tno_apply(x, params, cfg, causal: bool):
    """Dispatch on the config's variant. ``cfg`` is a ModelCfg."""
    if cfg.variant == "base":
        return tno_base(x, params, lam=cfg.lam, causal=causal, act=cfg.rpe_act)
    if cfg.variant == "ski":
        if causal:
            raise ValueError(
                "SKI-TNO is bidirectional-only (paper Appendix B: causal "
                "masking negates SKI's benefits)"
            )
        return tno_ski(
            x, params, lam=cfg.lam, r=cfg.r, lowrank_only=cfg.ski_lowrank_only
        )
    if cfg.variant == "fd":
        if causal:
            return tno_fd_causal(x, params, act=cfg.rpe_act)
        return tno_fd_bidir(x, params, act=cfg.rpe_act)
    raise ValueError(f"unknown TNO variant {cfg.variant}")


__all__ = [
    "tno_base",
    "tno_ski",
    "tno_fd_causal",
    "tno_fd_bidir",
    "fd_causal_spectrum",
    "tno_apply",
]
