"""Standalone inducing-point Toeplitz matvec ``v = A u``.

The inner ``A``-apply of the SKI factorization, exposed on its own for
tests, the fig11 micro-benchmarks, and as a building block for users of
the library who want the ``O(r log r)``-sized Gram action without the
interpolation stages.  ``A`` is carried as its ``2r-1`` per-channel taps
(lag ``-(r-1) … r-1``); the kernel grids over (batch, channel-tiles) and
materialises ``A`` in VMEM only (r ≤ 64 ⇒ ≤ 2 MiB at dt = 128).

Backward: ``du = Aᵀ dv`` is the same kernel with reversed taps; tap
gradients are an anti-diagonal segment-sum of ``dv uᵀ``.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, d_tile


def _toep_kernel(t_ref, u_ref, o_ref, *, r: int):
    taps = t_ref[...]  # (2r-1, dt)
    u = u_ref[0]  # (r, dt)
    ii = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
    A = jnp.take(taps, ii - jj + r - 1, axis=0)  # (r, r, dt)
    o_ref[0] = jnp.einsum("ijl,jl->il", A, u)


def _toep_call(taps, u):
    b, r, d = u.shape
    dt = d_tile(d)
    return pl.pallas_call(
        partial(_toep_kernel, r=r),
        grid=(b, d // dt),
        in_specs=[
            pl.BlockSpec((2 * r - 1, dt), lambda i, c: (0, c)),
            pl.BlockSpec((1, r, dt), lambda i, c: (i, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, r, dt), lambda i, c: (i, 0, c)),
        out_shape=jax.ShapeDtypeStruct((b, r, d), u.dtype),
        interpret=INTERPRET,
    )(taps, u)


@jax.custom_vjp
def toeplitz_av(taps, u):
    """Per-channel Toeplitz matvec ``v[b,:,l] = A_l u[b,:,l]``.

    Args:
      taps: ``(2r-1, d)`` Toeplitz taps, ``A_ij = taps[i-j+r-1]``.
      u: ``(b, r, d)`` f32.

    Returns:
      ``(b, r, d)`` f32.
    """
    return _toep_call(taps, u)


def _toep_fwd(taps, u):
    return _toep_call(taps, u), (taps, u)


def _toep_bwd(res, dv):
    taps, u = res
    r = u.shape[1]
    d = u.shape[2]
    du = _toep_call(taps[::-1], dv)
    dA = jnp.einsum("bid,bjd->ijd", dv, u)  # (r, r, d)
    ii = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
    seg = (ii - jj + r - 1).reshape(-1)
    dtaps = jax.ops.segment_sum(dA.reshape(r * r, d), seg, num_segments=2 * r - 1)
    return dtaps, du


toeplitz_av.defvjp(_toep_fwd, _toep_bwd)

__all__ = ["toeplitz_av"]
