"""Fused asymmetric-SKI low-rank apply ``y = W A Wᵀ x`` (paper §3.2.1).

``W ∈ R^{n×r}`` is the sparse linear-interpolation matrix of structured
kernel interpolation (hat-function rows, ≤2 non-zeros each); ``A ∈
R^{r×r}`` is the *asymmetric* inducing-point Gram matrix, which for a
stationary kernel on a uniform inducing grid is itself Toeplitz and is
therefore carried as its ``2r-1`` taps per channel.

One Pallas block fuses the whole low-rank branch for a
``(batch, channel-tile)`` cell:

    u = Wᵀ x        (r×n · n×dt matmul — MXU-shaped)
    A = gather(taps)  ((r,r,dt) built from the 2r-1 taps)
    v = A ⋄ u       (per-channel r×r matvec, batched over the tile)
    y = W v         (n×r · r×dt matmul — MXU-shaped)

so the sequence tile is read from HBM exactly once and the tiny
(r ≤ 64) intermediates never leave VMEM.  This is the practical
"batched dense matmul" realisation the paper lands on (their §3.2.1
note about sparse tensors being slower than dense for n ≤ 512); the
mathematically-O(n + r log r) sparse path is implemented and measured
in the Rust substrate (``rust/src/toeplitz``) for the fig10/fig11
comparisons.

Backward: ``dx = W Aᵀ Wᵀ dy`` is the *same* kernel with the tap vector
reversed (Toeplitz transpose); ``dA = (Wᵀdy)(Wᵀx)ᵀ`` reduces to tap
gradients with an anti-diagonal segment-sum.  ``W`` is a structural
constant (it never trains), so its cotangent is zero.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, d_tile


def interp_matrix(n: int, r: int, dtype=jnp.float32):
    """Dense hat-function interpolation matrix ``W`` (n, r).

    Observation points ``i = 0..n-1`` are mapped onto ``r`` uniformly
    spaced inducing points covering ``[0, n-1]`` (spacing ``h``);
    row ``i`` holds the linear-interpolation weights
    ``W_ij = max(0, 1 - |i/h - j|)`` (≤ 2 adjacent non-zeros, rows sum
    to 1).  Built from iotas so it lowers to a tiny HLO expression
    rather than an (n·r) literal.
    """
    h = (n - 1) / (r - 1)
    i = jax.lax.broadcasted_iota(dtype, (n, r), 0)
    j = jax.lax.broadcasted_iota(dtype, (n, r), 1)
    return jnp.maximum(0.0, 1.0 - jnp.abs(i / h - j))


def _ski_kernel(x_ref, w_ref, t_ref, o_ref, *, r: int):
    x = x_ref[0]  # (n, dt)
    W = w_ref[...]  # (n, r)
    taps = t_ref[...]  # (2r-1, dt)
    # u = Wᵀ x : (r, dt)
    u = W.T @ x
    # A[i, j, l] = taps[i - j + r - 1, l]
    ii = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
    A = jnp.take(taps, ii - jj + r - 1, axis=0)  # (r, r, dt)
    # v[i, l] = sum_j A[i, j, l] u[j, l]
    v = jnp.einsum("ijl,jl->il", A, u)
    # y = W v : (n, dt)
    o_ref[0] = W @ v


def _ski_call(x, W, taps):
    b, n, d = x.shape
    r = W.shape[1]
    dt = d_tile(d)
    return pl.pallas_call(
        partial(_ski_kernel, r=r),
        grid=(b, d // dt),
        in_specs=[
            pl.BlockSpec((1, n, dt), lambda i, c: (i, 0, c)),
            pl.BlockSpec((n, r), lambda i, c: (0, 0)),
            pl.BlockSpec((2 * r - 1, dt), lambda i, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, n, dt), lambda i, c: (i, 0, c)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        interpret=INTERPRET,
    )(x, W, taps)


@jax.custom_vjp
def ski_lowrank(x, W, taps):
    """Apply the SKI low-rank Toeplitz approximation ``y = W A Wᵀ x``.

    Args:
      x: ``(b, n, d)`` f32 sequence.
      W: ``(n, r)`` interpolation matrix (see :func:`interp_matrix`);
         structurally constant — receives a zero cotangent.
      taps: ``(2r-1, d)`` per-channel Toeplitz taps of the inducing Gram
        matrix ``A`` (``A_ij = taps[i-j+r-1]``), ordered from lag
        ``-(r-1)`` to ``r-1``.

    Returns:
      ``(b, n, d)`` f32.
    """
    return _ski_call(x, W, taps)


def _ski_fwd(x, W, taps):
    return _ski_call(x, W, taps), (x, W, taps)


def _ski_bwd(res, dy):
    x, W, taps = res
    r = W.shape[1]
    d = x.shape[2]
    # dx = W Aᵀ Wᵀ dy; Aᵀ has taps reversed along the lag axis.
    dx = _ski_call(dy, W, taps[::-1])
    # dA = (Wᵀ dy)(Wᵀ x)ᵀ per channel; reduce anti-diagonals to taps.
    p = jnp.einsum("nr,bnd->brd", W, x)  # Wᵀ x
    q = jnp.einsum("nr,bnd->brd", W, dy)  # Wᵀ dy
    dA = jnp.einsum("bid,bjd->ijd", q, p)  # (r, r, d)
    ii = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
    seg = (ii - jj + r - 1).reshape(-1)
    dtaps = jax.ops.segment_sum(dA.reshape(r * r, d), seg, num_segments=2 * r - 1)
    return dx, jnp.zeros_like(W), dtaps


ski_lowrank.defvjp(_ski_fwd, _ski_bwd)

__all__ = ["ski_lowrank", "interp_matrix"]
