"""Depthwise short 1-D convolution — the action of ``T_sparse``.

Paper §3.2 / Algorithm 1: applying the sparse component of the Toeplitz
decomposition (``m`` non-zero diagonals) is exactly a depthwise 1-D
convolution with filter size ``m``.  For bidirectional models the filter
is centred (diagonals ``-⌈m/2⌉+1 … ⌊m/2⌋``); for causal models it covers
diagonals ``0 … m-1`` only.

The kernel grids over ``(batch, channel-tiles)``; one block loads an
``(n, d_tile)`` sequence tile plus the ``(m, d_tile)`` filter into VMEM
and produces the output tile with ``m`` shifted fused multiply-adds —
the natural VPU schedule (no im2col, no matmul detour).

Backward: ``dx`` is the same Pallas kernel run in the *adjoint* padding
mode with the time-reversed filter; ``dw`` is an ``m``-term reduction
done with jnp slices (``m ≤ 33``, negligible).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, d_tile

# Padding modes: how the filter taps are aligned against the sequence.
_CAUSAL = "causal"  # y[i] = sum_t w[t] x[i-t]            (lags 0..m-1)
_SYM = "sym"  # y[i] = sum_t w[t] x[i-(t-c)], c=m//2      (lags -(m-1-c)..c)
_ANTI = "anti"  # y[i] = sum_t w[t] x[i+t]                (adjoint of causal)


def _pads(mode: str, m: int):
    if mode == _CAUSAL:
        return (m - 1, 0)
    if mode == _ANTI:
        return (0, m - 1)
    if mode == _SYM:
        c = m // 2
        return (m - 1 - c, c)
    raise ValueError(f"bad conv mode {mode}")


def _conv_kernel(x_ref, w_ref, o_ref, *, mode: str):
    x = x_ref[0]  # (n, dt)
    w = w_ref[...]  # (m, dt)
    m = w.shape[0]
    n = x.shape[0]
    lo, hi = _pads(mode, m)
    xp = jnp.pad(x, ((lo, hi), (0, 0)))
    acc = jnp.zeros_like(x)
    # m shifted FMAs over the (n, dt) tile; unrolled at trace time.
    for t in range(m):
        if mode == _ANTI:
            # y[i] = sum_t w[t] x[i+t]  -> slice starting at t
            acc = acc + w[t] * jax.lax.dynamic_slice_in_dim(xp, t, n, axis=0)
        else:
            # y[i] = sum_t w[t] x[i-t(+c)] -> reversed tap order over slices
            acc = acc + w[m - 1 - t] * jax.lax.dynamic_slice_in_dim(xp, t, n, axis=0)
    o_ref[0] = acc


def _conv_call(x, w, mode: str):
    b, n, d = x.shape
    m = w.shape[0]
    dt = d_tile(d)
    return pl.pallas_call(
        partial(_conv_kernel, mode=mode),
        grid=(b, d // dt),
        in_specs=[
            pl.BlockSpec((1, n, dt), lambda i, c: (i, 0, c)),
            pl.BlockSpec((m, dt), lambda i, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, n, dt), lambda i, c: (i, 0, c)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        interpret=INTERPRET,
    )(x, w)


def _conv_ref_slices(x, w, mode: str):
    """jnp (non-Pallas) equivalent used for the dw reduction in bwd."""
    b, n, d = x.shape
    m = w.shape[0]
    lo, hi = _pads(mode, m)
    xp = jnp.pad(x, ((0, 0), (lo, hi), (0, 0)))
    acc = jnp.zeros_like(x)
    for t in range(m):
        if mode == _ANTI:
            acc = acc + w[t] * jax.lax.dynamic_slice_in_dim(xp, t, n, axis=1)
        else:
            acc = acc + w[m - 1 - t] * jax.lax.dynamic_slice_in_dim(xp, t, n, axis=1)
    return acc


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def conv1d(x, w, causal: bool = True):
    """Depthwise 1-D convolution ``y = T_sparse x``.

    Args:
      x: ``(b, n, d)`` f32 sequence.
      w: ``(m, d)`` depthwise filter (one length-``m`` filter per channel).
      causal: causal (lags ``0..m-1``) vs centred/bidirectional taps.

    Returns:
      ``(b, n, d)`` f32.
    """
    return _conv_call(x, w, _CAUSAL if causal else _SYM)


def _conv1d_fwd(x, w, causal):
    return conv1d(x, w, causal), (x, w)


def _conv1d_bwd(causal, res, dy):
    x, w = res
    m, _ = w.shape
    n = x.shape[1]
    if causal:
        # Adjoint of causal conv: dx[j] = sum_t w[t] dy[j+t] (anticausal).
        dx = _conv_call(dy, w, _ANTI)
        xp = jnp.pad(x, ((0, 0), (m - 1, 0), (0, 0)))
        dw = jnp.stack(
            [
                jnp.sum(
                    jax.lax.dynamic_slice_in_dim(xp, m - 1 - t, n, axis=1) * dy,
                    axis=(0, 1),
                )
                for t in range(m)
            ]
        )
    else:
        c = m // 2
        # Adjoint of centred conv = centred conv with time-reversed taps,
        # with the centre mirrored for even m (lag set -(m-1-c)..c flips).
        lo, hi = m - 1 - c, c
        dyp = jnp.pad(dy, ((0, 0), (hi, lo), (0, 0)))
        dx = jnp.zeros_like(x)
        for t in range(m):
            dx = dx + w[t] * jax.lax.dynamic_slice_in_dim(dyp, t, n, axis=1)
        xp = jnp.pad(x, ((0, 0), (lo, hi), (0, 0)))
        dw = jnp.stack(
            [
                jnp.sum(
                    jax.lax.dynamic_slice_in_dim(xp, m - 1 - t, n, axis=1) * dy,
                    axis=(0, 1),
                )
                for t in range(m)
            ]
        )
    return dx, dw


conv1d.defvjp(_conv1d_fwd, _conv1d_bwd)

__all__ = ["conv1d"]
