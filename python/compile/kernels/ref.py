"""Pure-jnp oracles for every Pallas kernel and TNO building block.

These are the CORE correctness signal of the build path: every kernel
in this package is asserted ``allclose`` against its oracle over shape /
hyper-parameter sweeps in ``python/tests``, and the L2 TNO compositions
are asserted against dense ``O(n²)`` Toeplitz matrix products built
here.  Nothing in this module is ever lowered into an artifact.
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Kernel oracles
# ---------------------------------------------------------------------------


def conv1d_ref(x, w, causal=True):
    """Depthwise conv oracle: explicit lag sum, same alignment as conv1d."""
    b, n, d = x.shape
    m = w.shape[0]
    c = 0 if causal else m // 2
    out = jnp.zeros_like(x)
    for t in range(m):
        lag = t - c  # y[i] += w[t] x[i - lag]
        if lag >= 0:
            seg = jnp.pad(x[:, : n - lag if lag else n], ((0, 0), (lag, 0), (0, 0)))
        else:
            seg = jnp.pad(x[:, -lag:], ((0, 0), (0, -lag), (0, 0)))
        out = out + w[t] * seg
    return out


def toeplitz_dense(taps):
    """Dense per-channel Toeplitz matrix from taps.

    Args:
      taps: ``(2r-1, d)`` with ``A_ij = taps[i-j+r-1]``.
    Returns:
      ``(r, r, d)``.
    """
    r = (taps.shape[0] + 1) // 2
    ii = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
    return jnp.take(taps, ii - jj + r - 1, axis=0)


def toeplitz_av_ref(taps, u):
    A = toeplitz_dense(taps)  # (r, r, d)
    return jnp.einsum("ijl,bjl->bil", A, u)


def ski_lowrank_ref(x, W, taps):
    """Dense W A Wᵀ x."""
    A = toeplitz_dense(taps)  # (r, r, d)
    u = jnp.einsum("nr,bnd->brd", W, x)
    v = jnp.einsum("ijl,bjl->bil", A, u)
    return jnp.einsum("nr,brd->bnd", W, v)


def ski_dense_matrix(W, taps):
    """The full dense low-rank approximation ``T̃ = W A Wᵀ`` (n, n, d)."""
    A = toeplitz_dense(taps)
    return jnp.einsum("ir,rsl,js->ijl", W, A, W)


def fdmod_ref(kr, ki, xr, xi):
    k = kr + 1j * ki
    x = xr + 1j * xi
    y = k[None] * x
    return jnp.real(y), jnp.imag(y)


# ---------------------------------------------------------------------------
# TNO oracles (dense O(n^2) Toeplitz action)
# ---------------------------------------------------------------------------


def tno_dense_ref(x, k_neg, k_zero, k_pos):
    """Apply the dense per-channel Toeplitz matrix T to x.

    Args:
      x: ``(b, n, d)``.
      k_neg: ``(n-1, d)`` kernel at lags ``-1 .. -(n-1)`` (k_neg[j] = k[-(j+1)]).
      k_zero: ``(d,)`` kernel at lag 0.
      k_pos: ``(n-1, d)`` kernel at lags ``1 .. n-1``.

    Returns:
      ``(b, n, d)`` with ``y[b,i,l] = sum_j k_l[i-j] x[b,j,l]``.
    """
    n = x.shape[1]
    # full lag vector indexed by (i - j + n - 1) in 0..2n-2
    full = jnp.concatenate([k_neg[::-1], k_zero[None], k_pos], axis=0)  # (2n-1, d)
    ii = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    T = jnp.take(full, ii - jj + n - 1, axis=0)  # (n, n, d)
    return jnp.einsum("ijl,bjl->bil", T, x)


def toeplitz_fft_ref(x, k_neg, k_zero, k_pos):
    """Same action as :func:`tno_dense_ref` via the 2n circulant embedding."""
    n = x.shape[1]
    zero = jnp.zeros_like(k_zero)[None]
    # circulant first column: [k0, k1.., k_{n-1}, 0, k_{-(n-1)}, .., k_{-1}]
    c = jnp.concatenate([k_zero[None], k_pos, zero, k_neg[::-1]], axis=0)  # (2n, d)
    ch = jnp.fft.rfft(c, axis=0)
    xh = jnp.fft.rfft(x, n=2 * n, axis=1)
    y = jnp.fft.irfft(ch[None] * xh, n=2 * n, axis=1)
    return y[:, :n]


def causal_spectrum_ref(khat_r, n):
    """Causal kernel spectrum from a real (even) frequency response.

    Implements Algorithm 2's Hilbert-transform step directly: the real
    samples ``khat_r(ω_m)``, ``ω_m = mπ/n`` for ``m = 0..n``, define an
    even real kernel of period ``2n``; zeroing its negative-time half
    (half-weighting the self-conjugate t=0 and t=n samples) yields the
    causal kernel whose spectrum is ``k̂ - i·H{k̂}``.

    Returns the complex ``(n+1, d)`` causal spectrum.
    """
    kt = jnp.fft.irfft(khat_r.astype(jnp.complex64), n=2 * n, axis=0)  # (2n, d)
    w = jnp.concatenate(
        [
            jnp.ones((1,)),
            2.0 * jnp.ones((n - 1,)),
            jnp.ones((1,)),
            jnp.zeros((n - 1,)),
        ]
    )
    kc = kt * w[:, None]
    return jnp.fft.rfft(kc, axis=0)  # (n+1, d)


def hilbert_definition_ref(khat_r):
    """Discrete Hilbert transform by Definition 1 (convolution with h).

    ``h[l] = 2/(πl)`` for odd ``l``, 0 for even ``l``; the frequency
    samples are treated as a periodic sequence of length ``2n`` (the
    even extension of the ``n+1`` rFFT samples), matching the DFT-based
    window construction up to the finite-length wrap-around.

    Used as an *independent* check that :func:`causal_spectrum_ref`'s
    imaginary part is the discrete Hilbert transform of its real part.
    """
    nf = khat_r.shape[0]  # n + 1
    n = nf - 1
    # Even periodic extension over the full 2n DFT grid.
    ext = jnp.concatenate([khat_r, khat_r[1:-1][::-1]], axis=0)  # (2n, d)
    ll = jnp.arange(2 * n)
    # periodic Hilbert kernel for even length: h[l] = 2/ (N tan(pi l / N)) on odd l
    # (the finite-N form of 2/(pi l); tends to 2/(pi l) as N->inf)
    denom = jnp.tan(jnp.pi * ll / (2 * n))
    h = jnp.where(ll % 2 == 1, 2.0 / (2 * n) / jnp.where(ll % 2 == 1, denom, 1.0), 0.0)
    # circular convolution over the frequency index
    H = jnp.real(
        jnp.fft.ifft(
            jnp.fft.fft(ext, axis=0) * jnp.fft.fft(h)[:, None],
            axis=0,
        )
    )
    return H[:nf]


__all__ = [
    "conv1d_ref",
    "toeplitz_dense",
    "toeplitz_av_ref",
    "ski_lowrank_ref",
    "ski_dense_matrix",
    "fdmod_ref",
    "tno_dense_ref",
    "toeplitz_fft_ref",
    "causal_spectrum_ref",
    "hilbert_definition_ref",
]
