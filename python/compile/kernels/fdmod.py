"""Frequency-domain modulation ``ŷ = k̂ ⊙ x̂`` over real/imag pairs.

The elementwise hot-spot of FD-TNO (paper §3.3, Algorithm 2): after the
rFFT of the (zero-padded) input and the construction of the causal or
bidirectional kernel frequency response, every output frequency bin is
one complex multiply per channel.  Complex numbers are carried as
separate real/imag planes — the layout a TPU VPU wants (no complex
dtype in Mosaic) — and the kernel grids over (batch, channel-tiles)
with full ``(n_freq, d_tile)`` blocks.

Backward: the input cotangent is the same kernel with the conjugate
response (``k̂ → k̂*``); the response cotangent is a batch reduction of
``x̂* ⊙ dŷ`` done in jnp.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, d_tile


def _fdmod_kernel(kr_ref, ki_ref, xr_ref, xi_ref, yr_ref, yi_ref):
    kr = kr_ref[...]  # (f, dt)
    ki = ki_ref[...]
    xr = xr_ref[0]  # (f, dt)
    xi = xi_ref[0]
    yr_ref[0] = kr * xr - ki * xi
    yi_ref[0] = kr * xi + ki * xr


def _fdmod_call(kr, ki, xr, xi):
    b, f, d = xr.shape
    dt = d_tile(d)
    return pl.pallas_call(
        _fdmod_kernel,
        grid=(b, d // dt),
        in_specs=[
            pl.BlockSpec((f, dt), lambda i, c: (0, c)),
            pl.BlockSpec((f, dt), lambda i, c: (0, c)),
            pl.BlockSpec((1, f, dt), lambda i, c: (i, 0, c)),
            pl.BlockSpec((1, f, dt), lambda i, c: (i, 0, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, f, dt), lambda i, c: (i, 0, c)),
            pl.BlockSpec((1, f, dt), lambda i, c: (i, 0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, f, d), xr.dtype),
            jax.ShapeDtypeStruct((b, f, d), xr.dtype),
        ],
        interpret=INTERPRET,
    )(kr, ki, xr, xi)


@jax.custom_vjp
def fdmod(kr, ki, xr, xi):
    """Complex modulation ``ŷ = k̂ ⊙ x̂`` on real/imag planes.

    Args:
      kr, ki: ``(f, d)`` kernel frequency response (shared over batch).
      xr, xi: ``(b, f, d)`` input spectrum.

    Returns:
      ``(yr, yi)`` each ``(b, f, d)``.
    """
    return _fdmod_call(kr, ki, xr, xi)


def _fdmod_fwd(kr, ki, xr, xi):
    return _fdmod_call(kr, ki, xr, xi), (kr, ki, xr, xi)


def _fdmod_bwd(res, dys):
    kr, ki, xr, xi = res
    dyr, dyi = dys
    # dx = conj(k) ⊙ dy  — same kernel, conjugate response.
    dxr, dxi = _fdmod_call(kr, -ki, dyr, dyi)
    # dk = sum_b conj(x) ⊙ dy
    dkr = jnp.sum(xr * dyr + xi * dyi, axis=0)
    dki = jnp.sum(xr * dyi - xi * dyr, axis=0)
    return dkr, dki, dxr, dxi


fdmod.defvjp(_fdmod_fwd, _fdmod_bwd)

__all__ = ["fdmod"]
