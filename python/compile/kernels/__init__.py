"""Layer-1 Pallas kernels for the SKI-TNN / FD-TNN reproduction.

Every kernel here is the compute hot-spot of one TNO variant from
"SKI to go Faster" (Moreno, Mei & Walters, 2023):

- :mod:`conv1d`    — depthwise short 1-D convolution: the action of the
  *sparse* component ``T_sparse`` of the sparse+low-rank Toeplitz
  decomposition (paper §3.2, Algorithm 1).
- :mod:`ski`       — the fused asymmetric-SKI low-rank apply
  ``y = W A Wᵀ x`` (paper §3.2.1), with ``A`` built in-kernel from its
  ``2r-1`` Toeplitz taps.
- :mod:`toeplitz`  — standalone inducing-point Toeplitz matvec
  ``v = A u`` used by tests and micro-benchmarks.
- :mod:`fdmod`     — frequency-domain complex modulation ``ŷ = k̂ ⊙ x̂``
  expressed over real/imag pairs (paper §3.3, Algorithm 2).

All kernels are written with explicit ``BlockSpec`` tilings (batch ×
channel-tile grids) so the HBM↔VMEM schedule is what a real TPU lowering
would use; in this environment they are lowered with ``interpret=True``
(the CPU PJRT plugin cannot execute Mosaic custom-calls) and checked
against the pure-jnp oracles in :mod:`ref`.

Each kernel carries a ``jax.custom_vjp`` so that the *backward* pass of
the train step also runs through Pallas kernels where the transpose is
itself one of our kernels (conv ↔ flipped conv, ``W A Wᵀ`` ↔ reversed
taps, ``k̂ ⊙`` ↔ conjugate ``k̂ ⊙``); small reductions (filter/tap
gradients) use jnp segment-sums.
"""

from .conv1d import conv1d
from .ski import ski_lowrank
from .toeplitz import toeplitz_av
from .fdmod import fdmod
from . import ref

__all__ = ["conv1d", "ski_lowrank", "toeplitz_av", "fdmod", "ref"]
