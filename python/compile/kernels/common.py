"""Shared helpers for the Pallas kernels.

Tiling policy
-------------
All kernels grid over ``(batch, channel-tiles)``.  The channel tile is
``d_tile = min(d, 128)`` — 128 lanes is the native TPU vector width and
keeps every block's VMEM working set far below the ~16 MiB budget even
at the longest LRA sequence length we lower (n = 4096: a full
``(n, 128)`` f32 sequence tile is 2 MiB, leaving room for double
buffering).  Sequence-length tiling (with halos for the conv) is the
next refinement documented in DESIGN.md §Perf; at the shapes this paper
evaluates it is not needed to fit VMEM.

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.  The kernels are
still written against the Pallas block model so the same code targets
TPU unchanged.
"""

import jax

# The CPU plugin cannot run Mosaic custom-calls; interpret mode lowers the
# kernels to plain HLO so the AOT artifacts execute on the Rust PJRT client.
INTERPRET = True


def d_tile(d: int) -> int:
    """Channel tile width: full channel dim up to one TPU lane-width."""
    for cand in (128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= d and d % cand == 0:
            return cand
    return 1


def vmem_bytes_conv(n: int, dt: int, m: int) -> int:
    """Analytic VMEM footprint of one conv1d block (f32)."""
    return 4 * (n * dt + m * dt + n * dt)  # x tile + filter + out tile


def vmem_bytes_ski(n: int, dt: int, r: int) -> int:
    """Analytic VMEM footprint of one ski_lowrank block (f32)."""
    # x tile, W (n,r), taps, A (r,r,dt), u/v (r,dt), out tile
    return 4 * (n * dt + n * r + (2 * r - 1) * dt + r * r * dt + 2 * r * dt + n * dt)


def vmem_bytes_fdmod(f: int, dt: int) -> int:
    """Analytic VMEM footprint of one fdmod block (f32)."""
    return 4 * (2 * f * dt + 4 * f * dt)  # k pair + x pair + y pair


__all__ = [
    "INTERPRET",
    "d_tile",
    "vmem_bytes_conv",
    "vmem_bytes_ski",
    "vmem_bytes_fdmod",
]
