"""AOT lowering driver: JAX → HLO text artifacts + manifest.json.

Python's only job in this system is to run once, here, at build time
(`make artifacts`).  For every config in :mod:`configs` it lowers:

  * ``init``    — ``(seed u32) → (*params)``
  * ``step``    — ``(*params, *m, *v, t, *batch) → (*params', *m', *v', t', loss)``
  * ``fwd``     — ``(*params, *batch) → (loss, metric)``
  * ``logits``  — ``(*params, ids) → (logits)``  (serving entry)
  * ``fwd_n{L}``— extra eval-only lowerings at other sequence lengths
                  (perplexity-vs-inference-length, paper Fig 7a)

HLO **text** is the interchange format: jax ≥ 0.5 emits HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
`xla` Rust crate binds) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Lowering is embarrassingly parallel across configs; ``--jobs N`` forks
workers (default: up to 8).
"""

import argparse
import dataclasses
import json
import os
import sys
from concurrent.futures import ProcessPoolExecutor

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, train
from .configs import CONFIGS, CORE, ModelCfg, batch_spec

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "u32": jnp.uint32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is MANDATORY: the default printer elides big
    # array literals as ``constant({...})`` and the HLO text parser then
    # silently materialises them as ZEROS — any graph that multiplies a
    # computed value by a large constant (the Hilbert causal window, the
    # SKI table centre mask, the FD edge mask) would run as a zero
    # operator on the Rust side while every python-side jit test passes.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # ...and metadata must be OFF: the new printer emits attribute keys
    # (source_end_line, …) the 0.5.1 text parser rejects outright.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, _DTYPES[dtype])


def param_specs(cfg: ModelCfg):
    """Flattened (name, shape) list + treedef of the model parameters."""
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), cfg))
    paths, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    names, leaves = [], []
    for path, leaf in paths:
        names.append(jax.tree_util.keystr(path, simple=True, separator="."))
        leaves.append(leaf)
    return names, leaves, treedef


def _io_desc(name, leaf):
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32", jnp.uint32.dtype: "u32"}[
        leaf.dtype
    ]
    return {"name": name, "shape": list(leaf.shape), "dtype": dt}


def lower_config(cfg: ModelCfg, out_dir: str):
    """Lower all entries for one config; return its manifest fragment."""
    names, leaves, treedef = param_specs(cfg)
    unf = lambda flat: jax.tree_util.tree_unflatten(treedef, list(flat))
    nparams = len(leaves)
    bspec = batch_spec(cfg)
    batch_leaves = [_spec(shape, dt) for (_n, shape, dt) in bspec]

    entries = {}

    def emit(entry_name, fn, arg_specs, in_desc, out_desc):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}.{entry_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries[entry_name] = {
            "file": fname,
            "inputs": in_desc,
            "outputs": out_desc,
        }

    # ---- init ----
    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        p = model.init(key, cfg)
        return tuple(jax.tree_util.tree_leaves(p))

    emit(
        "init",
        init_fn,
        [_spec((), "u32")],
        [{"name": "seed", "shape": [], "dtype": "u32"}],
        [_io_desc(n, l) for n, l in zip(names, leaves)],
    )

    # ---- step ----
    def step_fn(*args):
        p = unf(args[:nparams])
        m = unf(args[nparams : 2 * nparams])
        v = unf(args[2 * nparams : 3 * nparams])
        t = args[3 * nparams]
        batch = args[3 * nparams + 1 :]
        p, m, v, t, loss = train.train_step(p, m, v, t, batch, cfg)
        fl = jax.tree_util.tree_leaves
        return tuple(fl(p)) + tuple(fl(m)) + tuple(fl(v)) + (t, loss)

    step_in = (
        [_io_desc(n, l) for n, l in zip(names, leaves)]
        + [_io_desc(f"m.{n}", l) for n, l in zip(names, leaves)]
        + [_io_desc(f"v.{n}", l) for n, l in zip(names, leaves)]
        + [{"name": "t", "shape": [], "dtype": "f32"}]
        + [{"name": bn, "shape": list(bs), "dtype": bd} for bn, bs, bd in bspec]
    )
    step_out = (
        [_io_desc(n, l) for n, l in zip(names, leaves)]
        + [_io_desc(f"m.{n}", l) for n, l in zip(names, leaves)]
        + [_io_desc(f"v.{n}", l) for n, l in zip(names, leaves)]
        + [
            {"name": "t", "shape": [], "dtype": "f32"},
            {"name": "loss", "shape": [], "dtype": "f32"},
        ]
    )
    emit(
        "step",
        step_fn,
        leaves + leaves + leaves + [_spec((), "f32")] + batch_leaves,
        step_in,
        step_out,
    )

    # ---- fwd (loss + metric on one batch) ----
    def fwd_fn(*args):
        p = unf(args[:nparams])
        batch = args[nparams:]
        loss, metric = model.loss_fn(p, batch, cfg)
        return loss, metric

    emit(
        "fwd",
        fwd_fn,
        leaves + batch_leaves,
        [_io_desc(n, l) for n, l in zip(names, leaves)]
        + [{"name": bn, "shape": list(bs), "dtype": bd} for bn, bs, bd in bspec],
        [
            {"name": "loss", "shape": [], "dtype": "f32"},
            {"name": "metric", "shape": [], "dtype": "f32"},
        ],
    )

    # ---- logits (serving) ----
    ids_shape = (cfg.batch, cfg.n)
    out_shape = (
        (cfg.batch, cfg.num_classes)
        if cfg.task == "cls"
        else (cfg.batch, cfg.vocab)
    )

    def logits_fn(*args):
        p = unf(args[:nparams])
        return (model.logits_entry(p, args[nparams], cfg),)

    emit(
        "logits",
        logits_fn,
        leaves + [_spec(ids_shape, "i32")],
        [_io_desc(n, l) for n, l in zip(names, leaves)]
        + [{"name": "ids", "shape": list(ids_shape), "dtype": "i32"}],
        [{"name": "logits", "shape": list(out_shape), "dtype": "f32"}],
    )

    # ---- extra eval lengths (Fig 7a) ----
    for L in cfg.eval_lens:
        ecfg = dataclasses.replace(cfg, n=L, eval_lens=())
        ebspec = batch_spec(ecfg)
        ebatch = [_spec(shape, dt) for (_n, shape, dt) in ebspec]

        def fwd_L(*args, _ecfg=ecfg):
            p = unf(args[:nparams])
            loss, metric = model.loss_fn(p, args[nparams:], _ecfg)
            return loss, metric

        emit(
            f"fwd_n{L}",
            fwd_L,
            leaves + ebatch,
            [_io_desc(n, l) for n, l in zip(names, leaves)]
            + [{"name": bn, "shape": list(bs), "dtype": bd} for bn, bs, bd in ebspec],
            [
                {"name": "loss", "shape": [], "dtype": "f32"},
                {"name": "metric", "shape": [], "dtype": "f32"},
            ],
        )

    frag = cfg.to_dict()
    frag["params"] = [_io_desc(n, l) for n, l in zip(names, leaves)]
    frag["param_count"] = int(sum(int(jnp.prod(jnp.array(l.shape))) for l in leaves))
    frag["entries"] = entries
    return cfg.name, frag


def _worker(args):
    name, out_dir = args
    return lower_config(CONFIGS[name], out_dir)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", nargs="*", help="subset of config names")
    ap.add_argument("--core", action="store_true", help="lower only the CORE set")
    ap.add_argument("--jobs", type=int, default=min(8, os.cpu_count() or 1))
    args = ap.parse_args()

    names = args.only or (CORE if args.core else list(CONFIGS))
    for n in names:
        if n not in CONFIGS:
            sys.exit(f"unknown config {n!r}; have {list(CONFIGS)}")
    os.makedirs(args.out, exist_ok=True)

    work = [(n, args.out) for n in names]
    frags = {}
    if args.jobs > 1 and len(work) > 1:
        with ProcessPoolExecutor(max_workers=args.jobs) as ex:
            for name, frag in ex.map(_worker, work):
                frags[name] = frag
                print(f"lowered {name}: {list(frag['entries'])}", flush=True)
    else:
        for w in work:
            name, frag = _worker(w)
            frags[name] = frag
            print(f"lowered {name}: {list(frag['entries'])}", flush=True)

    # Merge with any existing manifest so partial lowering is additive.
    mpath = os.path.join(args.out, "manifest.json")
    manifest = {"configs": {}}
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)
    manifest["configs"].update(frags)
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {mpath} with {len(manifest['configs'])} configs")


if __name__ == "__main__":
    main()
