"""The Toeplitz Neural Network (L2): GTU + GLU blocks around the TNOs.

Architecture follows Qin et al. (2023) Figure 3 (reproduced in the
paper's Appendix A): each sequence-modeling block is

    x ← x + GTU(LN(x))          # token + channel mixing
    x ← x + GLU(LN(x))          # channel mixing

with GTU(u) = (φ(uW_u) ⊙ TNO(φ(uW_v))) W_o and GLU per Shazeer (2020).
The TNO variant (base / ski / fd) is the only thing that differs across
the paper's comparisons; everything else is shared so speed and quality
deltas isolate the token-mixing change.

Heads:
  * ``lm_causal`` — next-token cross-entropy (perplexity experiments),
  * ``lm_bidir``  — masked-token cross-entropy (RoBERTa-style
    pre-training, the paper's bidirectional setting),
  * ``cls``       — mean-pool + linear head (LRA tasks).

Parameters are nested dicts; the AOT manifest records the flattened
(jax tree) order so the Rust coordinator addresses buffers by index.
"""

import jax
import jax.numpy as jnp

from . import rpe as rpe_mod
from . import tno as tno_mod
from .configs import ModelCfg, MASK

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in, fan_out, scale=1.0):
    return scale * (1.0 / fan_in) ** 0.5 * jax.random.normal(key, (fan_in, fan_out))


def tno_params_init(key, cfg: ModelCfg):
    e = cfg.e
    if cfg.variant == "base":
        sizes = rpe_mod.rpe_sizes(cfg.rpe_hidden, cfg.rpe_layers, e)
        return {"rpe": rpe_mod.mlp_init(key, sizes, out_scale=0.3)}
    if cfg.variant == "ski":
        k1, k2 = jax.random.split(key)
        return {
            "table": 0.3 * jax.random.normal(k1, (cfg.tbl, e)),
            "filt": 0.3 * (1.0 / cfg.m) ** 0.5 * jax.random.normal(k2, (cfg.m, e)),
        }
    if cfg.variant == "fd":
        out = e if cfg.causal else 2 * e
        sizes = rpe_mod.rpe_sizes(cfg.rpe_hidden, cfg.rpe_layers, out)
        return {"rpe": rpe_mod.mlp_init(key, sizes, out_scale=0.3)}
    raise ValueError(cfg.variant)


def block_init(key, cfg: ModelCfg):
    d, e = cfg.d, cfg.e
    f = cfg.glu_mult * d
    ks = jax.random.split(key, 8)
    return {
        "ln1_g": jnp.ones((d,)),
        "ln1_b": jnp.zeros((d,)),
        "gtu": {
            "wu": _dense_init(ks[0], d, e),
            "wv": _dense_init(ks[1], d, e),
            "wo": _dense_init(ks[2], e, d),
            "tno": tno_params_init(ks[3], cfg),
        },
        "ln2_g": jnp.ones((d,)),
        "ln2_b": jnp.zeros((d,)),
        "glu": {
            "w1": _dense_init(ks[4], d, f),
            "w2": _dense_init(ks[5], d, f),
            "w3": _dense_init(ks[6], f, d),
        },
    }


def init(key, cfg: ModelCfg):
    ks = jax.random.split(key, cfg.blocks + 3)
    head_out = cfg.num_classes if cfg.task == "cls" else cfg.vocab
    return {
        "emb": 0.02 * jax.random.normal(ks[0], (cfg.vocab, cfg.d)),
        "blocks": [block_init(ks[1 + i], cfg) for i in range(cfg.blocks)],
        "lnf_g": jnp.ones((cfg.d,)),
        "lnf_b": jnp.zeros((cfg.d,)),
        "head": _dense_init(ks[-1], cfg.d, head_out),
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _ln(x, g, b):
    return rpe_mod.layer_norm(x, g, b)


def gtu(x, p, cfg: ModelCfg, causal: bool):
    u = jax.nn.silu(x @ p["wu"])
    v = jax.nn.silu(x @ p["wv"])
    t = tno_mod.tno_apply(v, p["tno"], cfg, causal)
    return (u * t) @ p["wo"]


def glu(x, p):
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w2"])) @ p["w3"]


def backbone(params, ids, cfg: ModelCfg):
    """Token ids ``(b, n)`` → features ``(b, n, d)``."""
    causal = cfg.causal
    x = jnp.take(params["emb"], ids, axis=0)
    for bp in params["blocks"]:
        x = x + gtu(_ln(x, bp["ln1_g"], bp["ln1_b"]), bp["gtu"], cfg, causal)
        x = x + glu(_ln(x, bp["ln2_g"], bp["ln2_b"]), bp["glu"])
    return _ln(x, params["lnf_g"], params["lnf_b"])


def logits_fn(params, ids, cfg: ModelCfg):
    h = backbone(params, ids, cfg)
    if cfg.task == "cls":
        return jnp.mean(h, axis=1) @ params["head"]  # (b, C)
    return h @ params["head"]  # (b, n, V)


def _xent(logits, targets):
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return lse - tgt


def loss_fn(params, batch, cfg: ModelCfg):
    """Returns ``(loss, metric)``.

    metric: summed token count for LM tasks (so perplexity aggregates
    exactly across batches) and correct-prediction count for cls.
    """
    if cfg.task == "lm_causal":
        (tokens,) = batch
        ids, tgt = tokens[:, :-1], tokens[:, 1:]
        lg = logits_fn(params, ids, cfg)
        nll = _xent(lg, tgt)
        return jnp.mean(nll), jnp.float32(nll.size) * 1.0
    if cfg.task == "lm_bidir":
        ids, tgt, mask = batch
        lg = logits_fn(params, ids, cfg)
        nll = _xent(lg, tgt) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(nll) / denom, denom
    if cfg.task == "cls":
        ids, labels = batch
        lg = logits_fn(params, ids, cfg)
        nll = _xent(lg, labels)
        correct = jnp.sum((jnp.argmax(lg, axis=-1) == labels).astype(jnp.float32))
        return jnp.mean(nll), correct
    raise ValueError(cfg.task)


def logits_entry(params, batch_ids, cfg: ModelCfg):
    """Serving entrypoint: class logits, or last-position LM logits."""
    lg = logits_fn(params, batch_ids, cfg)
    if cfg.task == "cls":
        return lg
    return lg[:, -1, :]


def mask_batch_tokens(ids, key, rate=0.15):
    """Reference MLM masking (mirrors rust/src/data/lm.rs; used in tests)."""
    m = jax.random.bernoulli(key, rate, ids.shape)
    masked = jnp.where(m, MASK, ids)
    return masked, ids, m.astype(jnp.float32)


__all__ = [
    "init",
    "backbone",
    "logits_fn",
    "loss_fn",
    "logits_entry",
    "gtu",
    "glu",
    "mask_batch_tokens",
]
