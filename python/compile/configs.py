"""Model / lowering configurations and the artifact manifest schema.

Every entry in :data:`CONFIGS` becomes a family of AOT artifacts
(`init`, `step`, `fwd`, `logits`, plus fixed extra eval lengths for the
perplexity-vs-length experiment).  The Rust coordinator consumes
``artifacts/manifest.json`` and never re-derives any of these shapes.

Scaling note (documented in DESIGN.md): the paper trains on A100s at
n=512 (Wikitext-103) and n=1024–4096 (LRA).  On the CPU PJRT substrate
we keep the same *structure* (block counts, RPE depths, r/m ratios,
sequence-length sweeps 512→2048) with reduced widths so that full
train-eval cycles complete in CI time.  All comparisons are
within-substrate, matching how the paper reports *relative* speedups.
"""

from dataclasses import dataclass, field, asdict


@dataclass
class ModelCfg:
    name: str
    task: str  # 'lm_causal' | 'lm_bidir' | 'cls'
    variant: str  # 'base' | 'ski' | 'fd'
    vocab: int = 259  # 256 bytes + PAD + MASK + CLS
    n: int = 256
    d: int = 128
    blocks: int = 2
    expand: int = 1  # GTU expansion factor (TNO channel count = d*expand)
    glu_mult: int = 2  # GLU hidden multiplier
    rpe_layers: int = 3
    rpe_hidden: int = 32
    rpe_act: str = "relu"
    lam: float = 0.99
    r: int = 64  # SKI rank (inducing points)
    m: int = 32  # SKI sparse filter size
    tbl: int = 65  # SKI table grid points (odd; centre pinned to 0)
    num_classes: int = 10
    batch: int = 8
    lr: float = 1e-3
    warmup: int = 100
    clip: float = 1.0
    ski_lowrank_only: bool = False
    eval_lens: tuple = ()  # extra fwd-only lowerings at other seq lens

    @property
    def causal(self) -> bool:
        return self.task == "lm_causal"

    @property
    def e(self) -> int:
        return self.d * self.expand

    def to_dict(self):
        d = asdict(self)
        d["eval_lens"] = list(self.eval_lens)
        return d


PAD, MASK, CLS = 256, 257, 258


def _lm(name, variant, task="lm_causal", **kw):
    kw.setdefault("n", 256)
    kw.setdefault("d", 128)
    kw.setdefault("blocks", 2)
    kw.setdefault("batch", 8)
    return ModelCfg(name=name, task=task, variant=variant, **kw)


def _timing(name, variant, n, **kw):
    # fig10/fig11 step-time configs: structure of the paper's 512/2048
    # sweep, thin width so CPU steps stay sub-second.
    return ModelCfg(
        name=name,
        task="lm_bidir",
        variant=variant,
        n=n,
        d=64,
        blocks=2,
        batch=2,
        rpe_layers=6 if variant == "base" else 3,
        **kw,
    )


def _lra(task_name, variant, n, ncls, **kw):
    return ModelCfg(
        name=f"lra_{task_name}_{variant}",
        task="cls",
        variant=variant,
        n=n,
        d=64,
        blocks=2,
        batch=4,
        num_classes=ncls,
        r=kw.pop("r", 64),
        m=kw.pop("m", 32),
        **kw,
    )


def build_configs():
    cfgs = [
        # --- Table 1 / Fig 1b / Fig 7: causal LM pre-training ----------
        _lm("lm_base_3l", "base", rpe_layers=3, eval_lens=(64, 128, 384, 512)),
        _lm("lm_fd_3l", "fd", rpe_layers=3, eval_lens=(64, 128, 384, 512)),
        _lm("lm_base_6l", "base", rpe_layers=6),
        _lm("lm_fd_6l", "fd", rpe_layers=6),
        # --- Fig 1b / Fig 8 / Fig 9: bidirectional pre-training --------
        _lm("lm_bidir_base_3l", "base", task="lm_bidir", rpe_layers=3),
        _lm("lm_bidir_fd_3l", "fd", task="lm_bidir", rpe_layers=3),
        _lm("lm_bidir_base_6l", "base", task="lm_bidir", rpe_layers=6),
        _lm("lm_bidir_fd_6l", "fd", task="lm_bidir", rpe_layers=6),
        _lm("lm_bidir_ski", "ski", task="lm_bidir"),
        # --- Fig 10 / Fig 11: sequence-length scaling ------------------
        _timing("t512_base6", "base", 512),
        _timing("t512_ski", "ski", 512),
        _timing("t2048_base6", "base", 2048),
        _timing("t2048_ski", "ski", 2048),
        _timing("t512_ski_lronly", "ski", 512, ski_lowrank_only=True),
        _timing("t2048_ski_lronly", "ski", 2048, ski_lowrank_only=True),
    ]
    # --- Table 2 / Fig 1a: LRA tasks (5 tasks × 3 variants) ------------
    # 1-D tasks use the paper's r=64, m=32; 2-D tasks r=32, m=16.
    lra = [
        ("text", 1024, 2, dict()),
        ("listops", 1024, 10, dict()),
        ("retrieval", 1024, 2, dict()),
        ("pathfinder", 1024, 2, dict(r=32, m=16)),
        ("image", 1024, 10, dict(r=32, m=16, rpe_act="relu")),
    ]
    for tname, n, ncls, extra in lra:
        for variant in ("base", "ski", "fd"):
            cfgs.append(_lra(tname, variant, n, ncls, **dict(extra)))
    return {c.name: c for c in cfgs}


CONFIGS = build_configs()

# The cheap subset used by `make artifacts-core` and the python tests.
CORE = [
    "lm_base_3l",
    "lm_fd_3l",
    "lm_bidir_ski",
    "lm_bidir_fd_3l",
]


def batch_spec(cfg: ModelCfg):
    """Input specs (name, shape, dtype) of one training batch."""
    b, n = cfg.batch, cfg.n
    if cfg.task == "lm_causal":
        return [("tokens", (b, n + 1), "i32")]
    if cfg.task == "lm_bidir":
        return [
            ("ids", (b, n), "i32"),
            ("tgt", (b, n), "i32"),
            ("mask", (b, n), "f32"),
        ]
    if cfg.task == "cls":
        return [("ids", (b, n), "i32"), ("labels", (b,), "i32")]
    raise ValueError(cfg.task)


__all__ = ["ModelCfg", "CONFIGS", "CORE", "batch_spec", "PAD", "MASK", "CLS"]
