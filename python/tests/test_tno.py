"""Layer-2 TNO variants vs dense O(n²) oracles + causality invariants.

The heart of the reproduction: each TNO (base / SKI / FD-causal /
FD-bidir) must equal the dense Toeplitz-matrix action it claims to
accelerate, and the causal variants must be *exactly* causal.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import rpe as rpe_mod
from compile import tno as tno_mod
from compile.configs import ModelCfg
from compile.kernels import ref
from compile.kernels.ski import interp_matrix

KEY = jax.random.PRNGKey(7)


def allclose(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


def small_cfg(variant, task="lm_bidir", **kw):
    return ModelCfg(name="t", task=task, variant=variant, n=32, d=8, rpe_hidden=8,
                    rpe_layers=2, r=8, m=5, tbl=9, **kw)


def tno_params(cfg, key=KEY):
    from compile import model

    return model.tno_params_init(key, cfg)


# ---------------------------------------------------------------------------
# Base TNO
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
def test_tno_base_matches_dense_toeplitz(causal):
    cfg = small_cfg("base", task="lm_causal" if causal else "lm_bidir")
    p = tno_params(cfg)
    x = jax.random.normal(KEY, (2, cfg.n, cfg.d))
    got = tno_mod.tno_base(x, p, lam=cfg.lam, causal=causal, act="relu")
    k_neg, k_zero, k_pos = rpe_mod.time_rpe(p["rpe"], cfg.n, cfg.d, cfg.lam, causal, "relu")
    want = ref.tno_dense_ref(x, k_neg, k_zero, k_pos)
    allclose(got, want)


def test_tno_base_fft_ref_matches_dense_ref():
    n, d = 16, 3
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    k_neg = jax.random.normal(k1, (n - 1, d))
    k_zero = jax.random.normal(k2, (d,))
    k_pos = jax.random.normal(k3, (n - 1, d))
    x = jax.random.normal(k4, (2, n, d))
    allclose(
        ref.toeplitz_fft_ref(x, k_neg, k_zero, k_pos),
        ref.tno_dense_ref(x, k_neg, k_zero, k_pos),
    )


def test_tno_base_causal_ignores_future():
    cfg = small_cfg("base", task="lm_causal")
    p = tno_params(cfg)
    x = jax.random.normal(KEY, (1, cfg.n, cfg.d))
    y0 = tno_mod.tno_base(x, p, lam=cfg.lam, causal=True, act="relu")
    x2 = x.at[:, 20:].set(1e3)
    y1 = tno_mod.tno_base(x2, p, lam=cfg.lam, causal=True, act="relu")
    allclose(y0[:, :20], y1[:, :20], 1e-3)


def test_decay_bias_applied():
    """The λ^{|t|} bias must shrink far-lag kernel values."""
    cfg = small_cfg("base", lam=0.5)
    p = tno_params(cfg)
    k_neg, _, k_pos = rpe_mod.time_rpe(p["rpe"], cfg.n, cfg.d, 0.5, False, "relu")
    # raw MLP values at the same positions, no bias
    r_neg, _, r_pos = rpe_mod.time_rpe(p["rpe"], cfg.n, cfg.d, 1.0, False, "relu")
    t = np.arange(1, cfg.n)
    np.testing.assert_allclose(
        np.asarray(k_pos), np.asarray(r_pos) * (0.5 ** t)[:, None], rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(k_neg), np.asarray(r_neg) * (0.5 ** t)[:, None], rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# SKI TNO
# ---------------------------------------------------------------------------


def test_tno_ski_equals_conv_plus_lowrank_dense():
    cfg = small_cfg("ski")
    p = tno_params(cfg)
    x = jax.random.normal(KEY, (2, cfg.n, cfg.d))
    got = tno_mod.tno_ski(x, p, lam=cfg.lam, r=cfg.r)
    # oracle: dense W A Wᵀ x + centred depthwise conv
    h = (cfg.n - 1) / (cfg.r - 1)
    taps = rpe_mod.ski_taps(p["table"], cfg.r, h, cfg.lam)
    W = interp_matrix(cfg.n, cfg.r)
    want = ref.ski_lowrank_ref(x, W, taps) + ref.conv1d_ref(x, p["filt"], causal=False)
    allclose(got, want)


def test_tno_ski_lowrank_only_ablation():
    cfg = small_cfg("ski")
    p = tno_params(cfg)
    x = jax.random.normal(KEY, (1, cfg.n, cfg.d))
    both = tno_mod.tno_ski(x, p, lam=cfg.lam, r=cfg.r, lowrank_only=False)
    lr = tno_mod.tno_ski(x, p, lam=cfg.lam, r=cfg.r, lowrank_only=True)
    conv = ref.conv1d_ref(x, p["filt"], causal=False)
    allclose(both, lr + conv)


def test_tno_ski_rejects_causal_dispatch():
    cfg = small_cfg("ski", task="lm_causal")
    p = tno_params(cfg)
    x = jnp.zeros((1, cfg.n, cfg.d))
    with pytest.raises(ValueError, match="bidirectional-only"):
        tno_mod.tno_apply(x, p, cfg, causal=True)


def test_inverse_time_warp_properties():
    lam = 0.9
    t = jnp.array([-1e6, -10.0, -1.0, 0.0, 1.0, 10.0, 1e6])
    x = rpe_mod.inverse_time_warp(t, lam)
    xs = np.asarray(x)
    assert np.all(np.abs(xs) <= 1.0), "warp maps into [-1, 1]"
    # signs match wherever the warp has not underflowed to ±0
    nz = np.abs(xs) > 0
    assert np.all(np.sign(xs[nz]) == np.sign(np.asarray(t)[nz]))
    # long lags compress toward 0 — extrapolation becomes interpolation
    assert abs(xs[0]) < 1e-6 and abs(xs[-1]) < 1e-6
    # |x| decreases with |t| and the warp is odd-symmetric
    assert abs(xs[1]) < abs(xs[2])
    assert np.isclose(abs(xs[2]), abs(xs[4]))
    assert abs(xs[5]) < abs(xs[4])


def test_table_lookup_centre_pinned_and_interpolates():
    tbl, d = 9, 3
    table = jax.random.normal(KEY, (tbl, d))
    # centre is structurally zero → k(0) = 0 and warp(±∞) → 0
    out = rpe_mod.table_lookup(table, jnp.zeros((1,)))
    allclose(out, jnp.zeros((1, d)), 1e-6)
    # exact at grid points (except pinned centre)
    grid = jnp.linspace(-1.0, 1.0, tbl)
    vals = rpe_mod.table_lookup(table, grid)
    centre = tbl // 2
    mask = jnp.ones((tbl, 1)).at[centre, 0].set(0.0)
    allclose(vals, table * mask, 1e-5)


# ---------------------------------------------------------------------------
# FD TNO (causal + bidirectional)
# ---------------------------------------------------------------------------


def test_fd_causal_spectrum_hilbert_pair():
    """Imag part of the causal spectrum = discrete Hilbert transform of
    the real part (Definition 1), checked against the independent
    convolution-form implementation."""
    n, d = 64, 4
    khat_r = jax.random.normal(KEY, (n + 1, d))
    kr, ki = tno_mod.fd_causal_spectrum(khat_r, n)
    allclose(kr, khat_r, 1e-4)  # real part preserved
    want_im = -ref.hilbert_definition_ref(khat_r)
    allclose(ki, want_im, 1e-3)


def test_fd_causal_spectrum_time_kernel_is_causal():
    n, d = 32, 2
    khat_r = jax.random.normal(KEY, (n + 1, d))
    kr, ki = tno_mod.fd_causal_spectrum(khat_r, n)
    kt = jnp.fft.irfft(kr + 1j * ki, n=2 * n, axis=0)
    # negative-time half (t = n+1 .. 2n-1) must vanish
    np.testing.assert_allclose(np.asarray(kt[n + 1 :]), 0.0, atol=1e-5)


def test_tno_fd_causal_ignores_future():
    cfg = small_cfg("fd", task="lm_causal")
    p = tno_params(cfg)
    x = jax.random.normal(KEY, (1, cfg.n, cfg.d))
    y0 = tno_mod.tno_fd_causal(x, p, act="relu")
    x2 = x.at[:, 20:].set(1e3)
    y1 = tno_mod.tno_fd_causal(x2, p, act="relu")
    allclose(y0[:, :20], y1[:, :20], 1e-3)


def test_tno_fd_causal_matches_dense_toeplitz():
    """The FD-causal TNO is the action of the causal Toeplitz matrix
    built from its own time-domain kernel."""
    cfg = small_cfg("fd", task="lm_causal")
    p = tno_params(cfg)
    n, d = cfg.n, cfg.d
    x = jax.random.normal(KEY, (2, n, d))
    got = tno_mod.tno_fd_causal(x, p, act="relu")
    khat_r = rpe_mod.fd_rpe_real(p["rpe"], n, act="relu")
    kr, ki = tno_mod.fd_causal_spectrum(khat_r, n)
    kt = jnp.fft.irfft(kr + 1j * ki, n=2 * n, axis=0)  # causal kernel, lags 0..n
    k_pos = kt[1:n]
    k_zero = kt[0]
    k_neg = jnp.zeros_like(k_pos)
    want = ref.tno_dense_ref(x, k_neg, k_zero, k_pos)
    allclose(got, want, 1e-3)


def test_tno_fd_bidir_matches_dense_toeplitz():
    """The bidirectional FD TNO applies the (generally asymmetric) real
    Toeplitz operator defined by its complex frequency response."""
    cfg = small_cfg("fd", task="lm_bidir")
    p = tno_params(cfg)
    n, d = cfg.n, cfg.d
    x = jax.random.normal(KEY, (1, n, d))
    got = tno_mod.tno_fd_bidir(x, p, act="relu")
    kr, ki = rpe_mod.fd_rpe_complex(p["rpe"], n, d, act="relu")
    kt = jnp.fft.irfft(kr + 1j * ki, n=2 * n, axis=0)  # (2n, d) real kernel
    k_zero = kt[0]
    k_pos = kt[1:n]  # positive lags
    k_neg = kt[2 * n - 1 : n : -1]  # lags -1 .. -(n-1)
    want = ref.tno_dense_ref(x, k_neg, k_zero, k_pos)
    allclose(got, want, 1e-3)


def test_fd_rpe_complex_real_edges():
    """Imag response must vanish at ω = 0 and ω = π so the time kernel
    is real (§3.3.2)."""
    cfg = small_cfg("fd", task="lm_bidir")
    p = tno_params(cfg)
    kr, ki = rpe_mod.fd_rpe_complex(p["rpe"], cfg.n, cfg.d, act="relu")
    np.testing.assert_allclose(np.asarray(ki[0]), 0.0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(ki[-1]), 0.0, atol=1e-7)


def test_fd_bidir_time_kernel_is_real():
    cfg = small_cfg("fd", task="lm_bidir")
    p = tno_params(cfg)
    n, d = cfg.n, cfg.d
    kr, ki = rpe_mod.fd_rpe_complex(p["rpe"], n, d, act="relu")
    # build the full 2n DFT spectrum the irfft implies and check it is
    # Hermitian (equivalent: irfft output exactly reproduces rfft input)
    kt = jnp.fft.irfft(kr + 1j * ki, n=2 * n, axis=0)
    back = jnp.fft.rfft(kt, axis=0)
    allclose(jnp.real(back), kr, 1e-4)
    allclose(jnp.imag(back), ki, 1e-4)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "variant,task",
    [("base", "lm_causal"), ("base", "lm_bidir"), ("ski", "lm_bidir"),
     ("fd", "lm_causal"), ("fd", "lm_bidir")],
)
def test_tno_apply_dispatch_shapes(variant, task):
    cfg = small_cfg(variant, task=task)
    p = tno_params(cfg)
    x = jax.random.normal(KEY, (2, cfg.n, cfg.d))
    y = tno_mod.tno_apply(x, p, cfg, causal=cfg.causal)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_tno_apply_unknown_variant():
    cfg = dataclasses.replace(small_cfg("base"), variant="nope")
    with pytest.raises(ValueError):
        tno_mod.tno_apply(jnp.zeros((1, 8, 4)), {}, cfg, causal=False)
