"""Layer-1 Pallas kernels vs their pure-jnp oracles.

Every kernel is swept over shapes/hyper-parameters with hypothesis and
asserted allclose against `kernels.ref`; the custom_vjp backward passes
are asserted against jax autodiff *of the oracle* so both the forward
kernel and its hand-written transpose are covered.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import conv1d, fdmod, ref, ski_lowrank, toeplitz_av
from compile.kernels.ski import interp_matrix

KEY = jax.random.PRNGKey(0)


def keys(n):
    return jax.random.split(KEY, n)


def allclose(a, b, tol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# conv1d
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 3),
    n=st.sampled_from([8, 17, 64, 128]),
    d=st.sampled_from([1, 3, 8, 128]),
    m=st.integers(1, 9),
    causal=st.booleans(),
)
def test_conv1d_matches_ref(b, n, d, m, causal):
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (b, n, d))
    w = jax.random.normal(k2, (m, d))
    allclose(conv1d(x, w, causal), ref.conv1d_ref(x, w, causal))


@given(causal=st.booleans(), m=st.integers(1, 7))
def test_conv1d_grads_match_ref(causal, m):
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (2, 24, 4))
    w = jax.random.normal(k2, (m, 4))

    def loss_kernel(x, w):
        return jnp.sum(jnp.sin(conv1d(x, w, causal)))

    def loss_ref(x, w):
        return jnp.sum(jnp.sin(ref.conv1d_ref(x, w, causal)))

    gx, gw = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    allclose(gx, rx, 1e-4)
    allclose(gw, rw, 1e-4)


def test_conv1d_causal_ignores_future():
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (1, 32, 2))
    w = jax.random.normal(k2, (5, 2))
    y0 = conv1d(x, w, True)
    x2 = x.at[:, 20:].set(99.0)
    y1 = conv1d(x2, w, True)
    allclose(y0[:, :20], y1[:, :20])


# ---------------------------------------------------------------------------
# toeplitz_av
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 3),
    r=st.sampled_from([2, 5, 16, 64]),
    d=st.sampled_from([1, 4, 32]),
)
def test_toeplitz_av_matches_ref(b, r, d):
    k1, k2 = keys(2)
    taps = jax.random.normal(k1, (2 * r - 1, d))
    u = jax.random.normal(k2, (b, r, d))
    allclose(toeplitz_av(taps, u), ref.toeplitz_av_ref(taps, u))


def test_toeplitz_av_grads_match_ref():
    k1, k2 = keys(2)
    r, d = 8, 4
    taps = jax.random.normal(k1, (2 * r - 1, d))
    u = jax.random.normal(k2, (2, r, d))

    gt, gu = jax.grad(lambda t, u: jnp.sum(toeplitz_av(t, u) ** 2), argnums=(0, 1))(taps, u)
    rt, ru = jax.grad(lambda t, u: jnp.sum(ref.toeplitz_av_ref(t, u) ** 2), argnums=(0, 1))(
        taps, u
    )
    allclose(gt, rt, 1e-4)
    allclose(gu, ru, 1e-4)


def test_toeplitz_av_identity_taps():
    r, d = 6, 2
    taps = jnp.zeros((2 * r - 1, d)).at[r - 1].set(1.0)  # lag-0 tap = 1 ⇒ A = I
    u = jax.random.normal(KEY, (1, r, d))
    allclose(toeplitz_av(taps, u), u)


# ---------------------------------------------------------------------------
# ski_lowrank
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 2),
    n=st.sampled_from([16, 65, 128]),
    r=st.sampled_from([4, 16, 64]),
    d=st.sampled_from([1, 8, 128]),
)
def test_ski_lowrank_matches_ref(b, n, r, d):
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (b, n, d))
    taps = jax.random.normal(k2, (2 * r - 1, d))
    W = interp_matrix(n, r)
    allclose(ski_lowrank(x, W, taps), ref.ski_lowrank_ref(x, W, taps), 2e-5)


def test_ski_lowrank_grads_match_ref():
    k1, k2 = keys(2)
    n, r, d = 32, 8, 4
    x = jax.random.normal(k1, (2, n, d))
    taps = jax.random.normal(k2, (2 * r - 1, d))
    W = interp_matrix(n, r)

    gx, gt = jax.grad(lambda x, t: jnp.sum(ski_lowrank(x, W, t) ** 2), argnums=(0, 1))(x, taps)
    rx, rt = jax.grad(lambda x, t: jnp.sum(ref.ski_lowrank_ref(x, W, t) ** 2), argnums=(0, 1))(
        x, taps
    )
    allclose(gx, rx, 1e-4)
    allclose(gt, rt, 1e-4)


def test_interp_matrix_rows_sum_to_one():
    for n, r in [(16, 4), (128, 64), (100, 7)]:
        W = interp_matrix(n, r)
        np.testing.assert_allclose(np.asarray(jnp.sum(W, axis=1)), np.ones(n), rtol=1e-6)
        # ≤ 2 nonzeros per row (linear interpolation)
        assert int(jnp.max(jnp.sum((W > 0).astype(jnp.int32), axis=1))) <= 2
        # interpolation is exact at inducing points: W @ e_j hits 1
        assert np.isclose(float(jnp.max(W)), 1.0, atol=1e-6)


def test_ski_is_exact_when_r_equals_n():
    """With one inducing point per observation, W = I and the SKI
    factorisation reproduces the dense Toeplitz action exactly."""
    n = d = 16
    k1, k2 = keys(2)
    x = jax.random.normal(k1, (1, n, d))
    taps = jax.random.normal(k2, (2 * n - 1, d))
    W = interp_matrix(n, n)
    got = ski_lowrank(x, W, taps)
    want = ref.toeplitz_av_ref(taps, x)
    allclose(got, want, 1e-4)


# ---------------------------------------------------------------------------
# fdmod
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 3),
    f=st.sampled_from([4, 65, 129]),
    d=st.sampled_from([1, 8, 128]),
)
def test_fdmod_matches_ref(b, f, d):
    k1, k2, k3, k4 = keys(4)
    kr = jax.random.normal(k1, (f, d))
    ki = jax.random.normal(k2, (f, d))
    xr = jax.random.normal(k3, (b, f, d))
    xi = jax.random.normal(k4, (b, f, d))
    got = fdmod(kr, ki, xr, xi)
    want = ref.fdmod_ref(kr, ki, xr, xi)
    allclose(got[0], want[0])
    allclose(got[1], want[1])


def test_fdmod_grads_match_ref():
    k1, k2, k3, k4 = keys(4)
    f, d = 16, 4
    args = (
        jax.random.normal(k1, (f, d)),
        jax.random.normal(k2, (f, d)),
        jax.random.normal(k3, (2, f, d)),
        jax.random.normal(k4, (2, f, d)),
    )

    def loss(fn):
        def inner(*a):
            yr, yi = fn(*a)
            return jnp.sum(yr**2) + jnp.sum(yr * yi)

        return inner

    got = jax.grad(loss(fdmod), argnums=(0, 1, 2, 3))(*args)
    want = jax.grad(loss(ref.fdmod_ref), argnums=(0, 1, 2, 3))(*args)
    for g, w in zip(got, want):
        allclose(g, w, 1e-4)


def test_fdmod_unit_response_is_identity():
    f, d = 9, 3
    kr, ki = jnp.ones((f, d)), jnp.zeros((f, d))
    xr = jax.random.normal(KEY, (1, f, d))
    xi = jax.random.normal(keys(2)[1], (1, f, d))
    yr, yi = fdmod(kr, ki, xr, xi)
    allclose(yr, xr)
    allclose(yi, xi)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_kernels_preserve_dtype(dtype):
    x = jnp.ones((1, 8, 4), dtype)
    w = jnp.ones((3, 4), dtype)
    assert conv1d(x, w, True).dtype == dtype
    taps = jnp.ones((7, 4), dtype)
    u = jnp.ones((1, 4, 4), dtype)
    assert toeplitz_av(taps, u).dtype == dtype
