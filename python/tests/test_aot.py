"""The AOT bridge itself: HLO-text lowering round-trips through the
xla_client compiler with correct numerics, and the manifest schema stays
in sync with `configs.py`.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, train
from compile.configs import CONFIGS, CORE, ModelCfg, batch_spec

from jax._src.lib import xla_client as xc


def test_to_hlo_text_roundtrip_parse():
    """Lower a function to HLO text and re-parse it: the text form must
    round-trip through the HLO parser with the same entry signature.
    (Numeric execution of parsed text is validated on the *production*
    path by the Rust runtime tests — `rust/src/runtime/engine.rs` and
    `rust/tests/` compile and run every artifact via PJRT.)"""
    fn = lambda x, y: (x @ y + 2.0,)
    xs = jnp.arange(4.0).reshape(2, 2)
    ys = jnp.ones((2, 2))
    lowered = jax.jit(fn).lower(xs, ys)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[2,2]" in text

    mod = xc._xla.hlo_module_from_text(text)
    reparsed = xc.XlaComputation(
        mod.as_serialized_hlo_module_proto()
    ).as_hlo_text()
    assert "f32[2,2]" in reparsed
    # tuple root: one f32[2,2] output (reparsed text carries layouts)
    flat = reparsed.replace(" ", "")
    assert "->(f32[2,2]" in flat and "tuple(" in flat


def test_hlo_text_instruction_ids_parse_small():
    """The reason text (not proto) is the interchange format: parsing
    reassigns instruction ids so xla_extension 0.5.1's INT_MAX id check
    passes.  Verify the parser accepts our largest artifact file."""
    art = os.path.join(os.path.dirname(__file__), "../../artifacts")
    if not os.path.exists(os.path.join(art, "manifest.json")):
        pytest.skip("artifacts not built")
    biggest = max(
        (os.path.join(art, f) for f in os.listdir(art) if f.endswith(".hlo.txt")),
        key=os.path.getsize,
    )
    with open(biggest) as f:
        mod = xc._xla.hlo_module_from_text(f.read())
    assert mod is not None


def test_param_specs_are_stable_and_flat():
    cfg = CONFIGS["lm_fd_3l"]
    names, leaves, _ = aot.param_specs(cfg)
    assert len(names) == len(leaves)
    assert len(set(names)) == len(names), "duplicate parameter names"
    # deterministic ordering across calls (the rust side depends on it)
    names2, leaves2, _ = aot.param_specs(cfg)
    assert names == names2
    assert [l.shape for l in leaves] == [l.shape for l in leaves2]


def test_core_configs_exist():
    for name in CORE:
        assert name in CONFIGS


def test_batch_spec_covers_all_tasks():
    for cfg in CONFIGS.values():
        spec = batch_spec(cfg)
        assert all(len(s) == 3 for s in spec)
        for _name, shape, dt in spec:
            assert dt in ("i32", "f32")
            assert shape[0] == cfg.batch


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_matches_configs():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    for name, frag in manifest["configs"].items():
        assert name in CONFIGS, f"manifest has unknown config {name}"
        cfg = CONFIGS[name]
        assert frag["n"] == cfg.n
        assert frag["d"] == cfg.d
        assert frag["task"] == cfg.task
        assert frag["variant"] == cfg.variant
        names, leaves, _ = aot.param_specs(cfg)
        assert [p["name"] for p in frag["params"]] == names
        assert [tuple(p["shape"]) for p in frag["params"]] == [l.shape for l in leaves]


def test_step_lowering_shapes_tiny():
    """Lower a tiny step end-to-end (exercises the full aot path
    without writing files)."""
    cfg = ModelCfg(name="t", task="lm_causal", variant="fd", n=16, d=8, blocks=1,
                   batch=2, rpe_hidden=8, rpe_layers=2, vocab=40)
    names, leaves, treedef = aot.param_specs(cfg)
    unf = lambda flat: jax.tree_util.tree_unflatten(treedef, list(flat))
    nparams = len(leaves)

    def step_fn(*args):
        p = unf(args[:nparams])
        m = unf(args[nparams:2 * nparams])
        v = unf(args[2 * nparams:3 * nparams])
        t = args[3 * nparams]
        batch = args[3 * nparams + 1:]
        p, m, v, t, loss = train.train_step(p, m, v, t, batch, cfg)
        fl = jax.tree_util.tree_leaves
        return tuple(fl(p)) + tuple(fl(m)) + tuple(fl(v)) + (t, loss)

    bspec = [jax.ShapeDtypeStruct(s, jnp.int32) for (_n, s, _d) in batch_spec(cfg)]
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(step_fn).lower(*(leaves * 3), f32, *bspec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # output arity: 3 * params + t + loss
    assert text.count("f32[") > 0


def test_model_init_deterministic():
    cfg = CONFIGS["lm_fd_3l"]
    a = model.init(jax.random.PRNGKey(5), cfg)
    b = model.init(jax.random.PRNGKey(5), cfg)
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_to_hlo_text_preserves_large_constants():
    """Regression: the default HLO printer elides big array literals as
    ``constant({...})`` which the text parser silently reads as ZEROS —
    this nulled the Hilbert causal window (and with it the whole causal
    FD-TNO) on the Rust side while every jit-based test passed."""
    big = np.linspace(0.0, 1.0, 600, dtype=np.float32).reshape(600, 1)
    fn = lambda x: (x * jnp.asarray(big),)
    text = aot.to_hlo_text(jax.jit(fn).lower(jnp.zeros((600, 1), jnp.float32)))
    assert "constant({..." not in text.replace(" ", ""), "large constant elided"
    # a couple of interior values must appear verbatim
    assert "0.5008347" in text or "0.500835" in text
    # and no metadata attributes the 0.5.1 parser rejects
    assert "source_end_line" not in text
