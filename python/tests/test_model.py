"""Layer-2 model: init/loss/logits shapes, gradient flow, masking."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train
from compile.configs import CONFIGS, ModelCfg, batch_spec, MASK

KEY = jax.random.PRNGKey(1)


def tiny(task, variant, **kw):
    kw.setdefault("n", 32)
    kw.setdefault("d", 16)
    kw.setdefault("blocks", 1)
    kw.setdefault("batch", 2)
    kw.setdefault("rpe_hidden", 8)
    kw.setdefault("rpe_layers", 2)
    kw.setdefault("r", 8)
    kw.setdefault("m", 5)
    kw.setdefault("tbl", 9)
    kw.setdefault("vocab", 40)
    return ModelCfg(name="tiny", task=task, variant=variant, **kw)


def fake_batch(cfg, key=KEY):
    out = []
    ks = jax.random.split(key, 4)
    for i, (_name, shape, dt) in enumerate(batch_spec(cfg)):
        if dt == "i32":
            hi = cfg.vocab if len(shape) > 1 else cfg.num_classes
            out.append(jax.random.randint(ks[i], shape, 0, min(hi, 256)))
        else:
            out.append((jax.random.uniform(ks[i], shape) < 0.2).astype(jnp.float32))
    # masked-lm: guarantee ≥ 1 masked position
    if cfg.task == "lm_bidir":
        out[2] = out[2].at[:, 0].set(1.0)
    return tuple(out)


ALL = [
    ("lm_causal", "base"), ("lm_causal", "fd"),
    ("lm_bidir", "base"), ("lm_bidir", "ski"), ("lm_bidir", "fd"),
    ("cls", "base"), ("cls", "ski"), ("cls", "fd"),
]


@pytest.mark.parametrize("task,variant", ALL)
def test_loss_finite_and_grads_flow(task, variant):
    cfg = tiny(task, variant)
    params = model.init(KEY, cfg)
    batch = fake_batch(cfg)
    loss, metric = model.loss_fn(params, batch, cfg)
    assert jnp.isfinite(loss), f"{task}/{variant}: loss {loss}"
    assert jnp.isfinite(metric)
    grads = jax.grad(lambda p: model.loss_fn(p, batch, cfg)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0, "gradients are identically zero"
    # every TNO parameter gets gradient signal
    for bi, bp in enumerate(grads["blocks"]):
        tno_total = sum(
            float(jnp.sum(jnp.abs(g)))
            for g in jax.tree_util.tree_leaves(bp["gtu"]["tno"])
        )
        assert tno_total > 0, f"block {bi} TNO has zero grads"


@pytest.mark.parametrize("task,variant", ALL)
def test_logits_shapes(task, variant):
    cfg = tiny(task, variant)
    params = model.init(KEY, cfg)
    ids = jnp.zeros((cfg.batch, cfg.n), jnp.int32)
    lg = model.logits_fn(params, ids, cfg)
    if task == "cls":
        assert lg.shape == (cfg.batch, cfg.num_classes)
    else:
        assert lg.shape == (cfg.batch, cfg.n, cfg.vocab)
    entry = model.logits_entry(params, ids, cfg)
    want = cfg.num_classes if task == "cls" else cfg.vocab
    assert entry.shape == (cfg.batch, want)


def test_causal_model_logits_ignore_future():
    cfg = tiny("lm_causal", "fd")
    params = model.init(KEY, cfg)
    ids = jax.random.randint(KEY, (1, cfg.n), 0, cfg.vocab)
    lg0 = model.logits_fn(params, ids, cfg)
    ids2 = ids.at[:, 20:].set(1)
    lg1 = model.logits_fn(params, ids2, cfg)
    np.testing.assert_allclose(
        np.asarray(lg0[:, :20]), np.asarray(lg1[:, :20]), rtol=1e-4, atol=1e-4
    )


def test_bidir_model_uses_future_context():
    cfg = tiny("lm_bidir", "fd")
    params = model.init(KEY, cfg)
    ids = jax.random.randint(KEY, (1, cfg.n), 0, cfg.vocab)
    lg0 = model.logits_fn(params, ids, cfg)
    ids2 = ids.at[:, -1].set((ids[0, -1] + 1) % cfg.vocab)
    lg1 = model.logits_fn(params, ids2, cfg)
    assert float(jnp.max(jnp.abs(lg0[:, 0] - lg1[:, 0]))) > 1e-7, (
        "bidirectional model must see future tokens"
    )


def test_mask_batch_tokens_reference():
    ids = jax.random.randint(KEY, (4, 128), 0, 256)
    masked, tgt, mask = model.mask_batch_tokens(ids, jax.random.PRNGKey(2), rate=0.15)
    m = np.asarray(mask) > 0.5
    np.testing.assert_array_equal(np.asarray(masked)[m], MASK)
    np.testing.assert_array_equal(np.asarray(masked)[~m], np.asarray(ids)[~m])
    np.testing.assert_array_equal(np.asarray(tgt), np.asarray(ids))
    rate = float(mask.mean())
    assert 0.05 < rate < 0.30


def test_param_count_matches_manifest_configs():
    """The flat init tree of each registered config matches the shapes
    the AOT manifest will declare (aot.param_specs uses the same path)."""
    for name in ["lm_fd_3l", "lm_bidir_ski", "lra_text_base"]:
        cfg = CONFIGS[name]
        shapes = jax.eval_shape(lambda c=cfg: model.init(jax.random.PRNGKey(0), c))
        leaves = jax.tree_util.tree_leaves(shapes)
        total = sum(int(np.prod(l.shape)) for l in leaves)
        assert total > 10_000, f"{name}: implausibly small param count {total}"


def test_loss_decreases_under_gradient_step():
    cfg = tiny("lm_causal", "fd")
    params = model.init(KEY, cfg)
    batch = fake_batch(cfg)
    loss0, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch, cfg)[0])(params)
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params, grads)
    loss1, _ = model.loss_fn(params2, batch, cfg)
    assert loss1 < loss0, f"SGD step did not reduce loss: {loss0} -> {loss1}"


def test_train_step_counter_and_loss():
    cfg = tiny("lm_causal", "fd", warmup=2)
    params = model.init(KEY, cfg)
    m, v = train.adam_init(params)
    t = jnp.float32(0.0)
    batch = fake_batch(cfg)
    p2, m2, v2, t2, loss = train.train_step(params, m, v, t, batch, cfg)
    assert float(t2) == 1.0
    assert jnp.isfinite(loss)
    # params must actually move
    delta = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(p2), jax.tree_util.tree_leaves(params))
    )
    assert delta > 0


def test_train_step_reduces_loss_over_iterations():
    cfg = tiny("lm_causal", "fd", warmup=5, lr=3e-3)
    params = model.init(KEY, cfg)
    m, v = train.adam_init(params)
    t = jnp.float32(0.0)
    batch = fake_batch(cfg)  # overfit one batch
    step = jax.jit(lambda p, m, v, t: train.train_step(p, m, v, t, batch, cfg))
    losses = []
    for _ in range(25):
        params, m, v, t, loss = step(params, m, v, t)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, f"no learning: {losses[0]} -> {losses[-1]}"


def test_grad_clip_bounds_update():
    """With clip = tiny, one Adam step moves params by at most ~lr per
    coordinate (bias-corrected m/v ratio is bounded by 1)."""
    cfg = tiny("lm_causal", "fd", clip=1e-3, lr=1e-2)
    params = model.init(KEY, cfg)
    m, v = train.adam_init(params)
    batch = fake_batch(cfg)
    p2, *_ = train.train_step(params, m, v, jnp.float32(0.0), batch, cfg)
    max_move = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(p2), jax.tree_util.tree_leaves(params))
    )
    # lr at t=1 is lr/warmup; the Adam ratio |m̂|/(√v̂+ε) ≤ ~1
    assert max_move <= cfg.lr * 1.5
