"""Numeric checks of the paper's theory (§4, Appendices C–E).

* Proposition 1 — a scalar ReLU MLP with LayerNorm is piecewise linear.
* Theorem 1 — the SKI spectral-norm error bound dominates the actual
  error for smooth kernels, and the actual error shrinks with rank.
* Theorems 2–4 — smoothness of the frequency-response MLP orders the
  time-domain decay: GeLU ≲ SiLU ≪ ReLU tails.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import rpe as rpe_mod
from compile.kernels import ref
from compile.kernels.ski import interp_matrix

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# Proposition 1
# ---------------------------------------------------------------------------


def test_prop1_relu_mlp_piecewise_linear():
    # Threshold sits above f32 arithmetic noise (~1e-6 relative after
    # LayerNorm amplification) and below genuine ReLU slope changes; a
    # piecewise-linear function has curvature at isolated points only.
    params = rpe_mod.mlp_init(KEY, [1, 16, 16, 4])
    grid = jnp.linspace(-1.0, 1.0, 2001)[:, None]
    y = rpe_mod.mlp_apply(params, grid, act="relu")  # (2001, 4)
    dd = jnp.abs(y[2:] - 2.0 * y[1:-1] + y[:-2])
    scale = jnp.maximum(jnp.max(jnp.abs(y), axis=0), 1.0)
    kinks = jnp.sum(dd / scale[None] > 1e-4, axis=0)
    assert int(jnp.max(kinks)) < 150, f"not piecewise linear: {kinks} kinks"


def test_prop1_fails_for_gelu():
    """Sanity for the test itself: a GeLU MLP is *not* piecewise linear,
    so nearly every grid point carries curvature."""
    params = rpe_mod.mlp_init(KEY, [1, 16, 16, 4])
    grid = jnp.linspace(-1.0, 1.0, 2001)[:, None]
    y = rpe_mod.mlp_apply(params, grid, act="gelu")
    dd = jnp.abs(y[2:] - 2.0 * y[1:-1] + y[:-2])
    scale = jnp.maximum(jnp.max(jnp.abs(y), axis=0), 1.0)
    kinks = jnp.sum(dd / scale[None] > 1e-7, axis=0)
    assert int(jnp.min(kinks)) > 1000


# ---------------------------------------------------------------------------
# Theorem 1 (SKI error bound)
# ---------------------------------------------------------------------------


def spectral_norm(M):
    return float(jnp.linalg.norm(M, ord=2))


def test_theorem1_bound_dominates_actual_error():
    """Build T from a smooth kernel, form the SKI approximation with
    linear interpolation on r inducing points, and verify
    ‖WAWᵀ − T_r,opt‖₂ ≤ bound(r) with the paper's constants."""
    n, scale = 128, 24.0
    k = lambda t: np.exp(-0.5 * (t / scale) ** 2)  # gaussian, C^∞
    # L bounds |k''| for linear interpolation (N = 1): |k''| ≤ 1/scale²
    L = 1.0 / scale**2
    t_full = np.arange(n)
    T = jnp.asarray(k(t_full[:, None] - t_full[None, :]), jnp.float32)

    prev_err = None
    for r in [9, 17, 33, 65]:
        h = (n - 1) / (r - 1)
        p = np.arange(r) * h
        A = jnp.asarray(k(p[:, None] - p[None, :]), jnp.float32)
        W = interp_matrix(n, r)
        F = jnp.asarray(k(t_full[:, None] - p[None, :]), jnp.float32)
        B = jnp.asarray(k(p[:, None] - t_full[None, :]), jnp.float32)
        ski = W @ A @ W.T

        # optimal rank-r approximation via SVD
        U, S, Vt = jnp.linalg.svd(T)
        T_opt = (U[:, :r] * S[:r]) @ Vt[:r]
        E_ski = spectral_norm(ski - T_opt)
        # Nyström error term (A is symmetric PD here, invertible)
        E_nyst = spectral_norm(F @ jnp.linalg.solve(A, B) - T_opt)

        sig_r_A = float(jnp.linalg.svd(A, compute_uv=False)[-1])
        sig1 = min(
            float(jnp.linalg.svd(F, compute_uv=False)[0]),
            float(jnp.linalg.svd(B, compute_uv=False)[0]),
        )
        psi_max = h**2 / 8.0  # |ψ_N|/(N+1)! for linear interpolation
        bound = (
            np.sqrt(n * r) * psi_max * L * (2.0 * np.sqrt(n) + sig1 / sig_r_A) + E_nyst
        )
        assert E_ski <= bound * 1.01, f"r={r}: error {E_ski} exceeds bound {bound}"
        if prev_err is not None:
            assert E_ski <= prev_err * 1.5, "SKI error should not blow up with rank"
        prev_err = E_ski


def test_ski_error_shrinks_with_rank():
    n, scale = 128, 24.0
    k = lambda t: np.exp(-0.5 * (t / scale) ** 2)
    t_full = np.arange(n)
    T = jnp.asarray(k(t_full[:, None] - t_full[None, :]), jnp.float32)
    errs = []
    for r in [5, 9, 17, 33, 65]:
        h = (n - 1) / (r - 1)
        p = np.arange(r) * h
        A = jnp.asarray(k(p[:, None] - p[None, :]), jnp.float32)
        W = interp_matrix(n, r)
        errs.append(spectral_norm(W @ A @ W.T - T))
    assert errs[-1] < errs[0] * 0.05, f"no convergence: {errs}"
    assert all(b <= a * 1.05 for a, b in zip(errs, errs[1:])), errs


# ---------------------------------------------------------------------------
# Theorems 2–4 (smoothness ⇒ decay)
# ---------------------------------------------------------------------------


def impulse_tail_ratio(act: str, n: int = 512, d: int = 8, nseeds: int = 6) -> float:
    """tail-band envelope / head-band envelope of the FD RPE impulse
    response, averaged over seeds — smaller = faster decay."""
    head, tail = 0.0, 0.0
    for s in range(nseeds):
        params = rpe_mod.mlp_init(jax.random.PRNGKey(100 + s), [1, 32, 32, d], out_scale=0.3)
        khat = rpe_mod.fd_rpe_real(params, n, act=act)  # (n+1, d)
        kt = jnp.fft.irfft(khat.astype(jnp.complex64), n=2 * n, axis=0)[:n]
        a = np.abs(np.asarray(kt))
        head += float(a[1:8].max())
        tail += float(a[n // 2 :].max())
    return tail / head


def test_thm2_to_4_decay_ordering():
    gelu = impulse_tail_ratio("gelu")
    silu = impulse_tail_ratio("silu")
    relu = impulse_tail_ratio("relu")
    # ReLU (merely continuous) keeps visibly heavier tails than the
    # smooth activations; GeLU/SiLU are close at random init (paper
    # Figs 4-5 "visually similar").
    assert relu > 1.5 * max(gelu, silu), f"gelu {gelu} silu {silu} relu {relu}"
    assert gelu < 0.01 and silu < 0.01, f"smooth tails too heavy: {gelu}, {silu}"


def test_all_impulse_responses_decay_overall():
    for act in ["gelu", "silu", "relu"]:
        ratio = impulse_tail_ratio(act)
        assert ratio < 0.2, f"{act}: impulse response does not decay ({ratio})"
