"""Pytest wiring: make `compile.*` importable from the repo's python/ dir
and keep hypothesis deadlines off (Pallas interpret mode is slow and
deliberately so — correctness, not wall-clock, is under test here)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from hypothesis import settings

settings.register_profile("kernels", max_examples=12, deadline=None)
settings.load_profile("kernels")
