//! Offline stand-in for the `anyhow` crate.
//!
//! The real registry is not reachable from the build environment, so
//! this vendored shim provides the exact subset ski-tnn uses: the
//! [`Error`] type with context chaining, [`Result`], the [`anyhow!`] /
//! [`bail!`] macros, and the [`Context`] extension trait over both
//! `Result` and `Option`.  Semantics match upstream for that subset
//! (`{:#}` prints the full context chain, `?` converts any
//! `std::error::Error + Send + Sync + 'static`).

use std::error::Error as StdError;
use std::fmt;

/// Error type: a context chain, most recent context first.
pub struct Error {
    /// `chain[0]` is the outermost context / message.
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (what `.context()` does).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Outermost message only.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::Other, "disk on fire");
        Err(e).context("writing checkpoint")?;
        Ok(())
    }

    #[test]
    fn context_chains() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "writing checkpoint");
        let full = format!("{err:#}");
        assert!(full.contains("writing checkpoint") && full.contains("disk on fire"), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing key").unwrap_err();
        assert_eq!(err.to_string(), "missing key");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        fn bails() -> Result<()> {
            bail!("nope: {}", "reason");
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope: reason");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
