//! Offline shim of the `xla` crate (xla-rs / xla_extension bindings).
//!
//! The native PJRT runtime is a C++ dependency that cannot be fetched
//! in the offline build environment.  This shim keeps the whole crate
//! compiling and every *host-side* code path working:
//!
//! * [`Literal`] is a real, fully functional host tensor (dtype-tagged
//!   bytes + dims + tuples) — `vec1`/`reshape`/`to_vec`/
//!   `get_first_element`/`decompose_tuple` behave like upstream, so
//!   `runtime::HostTensor` round-trips and its tests run unchanged.
//! * [`PjRtClient::cpu`] succeeds (the client is a token), but
//!   [`PjRtClient::compile`] returns a descriptive [`Error`]: executing
//!   AOT artifacts needs the native backend.  Artifact-dependent tests
//!   and subcommands detect this (or the missing `artifacts/` dir) and
//!   skip or report instead of crashing.
//!
//! Swapping in the real crate is a one-line change in the root
//! `Cargo.toml`; no call site changes.

use std::borrow::Borrow;
use std::fmt;

/// Shim error type (mirrors upstream's string-y errors).
#[derive(Debug, Clone)]
pub struct Error {
    pub msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types (subset + a few extras so `match` wildcards stay
/// reachable, as with the real crate's larger enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    F32,
    F64,
}

/// Rust scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    const SIZE: usize;
    fn write_le(&self, out: &mut Vec<u8>);
    fn read_le(b: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $ty:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            const SIZE: usize = std::mem::size_of::<$t>();
            fn write_le(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn read_le(b: &[u8]) -> Self {
                let mut a = [0u8; std::mem::size_of::<$t>()];
                a.copy_from_slice(&b[..std::mem::size_of::<$t>()]);
                <$t>::from_le_bytes(a)
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(f64, ElementType::F64);
native!(i32, ElementType::S32);
native!(i64, ElementType::S64);
native!(u32, ElementType::U32);
native!(u64, ElementType::U64);

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host tensor literal: dtype-tagged little-endian bytes, or a tuple.
#[derive(Debug, Clone)]
pub enum Literal {
    Array { ty: ElementType, dims: Vec<i64>, bytes: Vec<u8> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * T::SIZE);
        for v in data {
            v.write_le(&mut bytes);
        }
        Literal::Array { ty: T::TY, dims: vec![data.len() as i64], bytes }
    }

    /// Tuple literal (what executables return with `return_tuple=True`).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal::Tuple(elems)
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { ty, dims: old, bytes } => {
                let want: i64 = dims.iter().product();
                let have: i64 = old.iter().product();
                if want != have {
                    return Err(Error::new(format!(
                        "reshape {old:?} -> {dims:?}: element count {have} != {want}"
                    )));
                }
                Ok(Literal::Array { ty: *ty, dims: dims.to_vec(), bytes: bytes.clone() })
            }
            Literal::Tuple(_) => Err(Error::new("cannot reshape a tuple literal")),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => {
                Ok(ArrayShape { ty: *ty, dims: dims.clone() })
            }
            Literal::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { dims, .. } => dims.iter().product::<i64>() as usize,
            Literal::Tuple(t) => t.len(),
        }
    }

    /// Decode the full buffer as `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, bytes, .. } => {
                if *ty != T::TY {
                    return Err(Error::new(format!(
                        "to_vec: literal is {ty:?}, requested {:?}",
                        T::TY
                    )));
                }
                Ok(bytes.chunks_exact(T::SIZE).map(T::read_le).collect())
            }
            Literal::Tuple(_) => Err(Error::new("to_vec on tuple literal")),
        }
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        match self {
            Literal::Array { ty, bytes, .. } => {
                if *ty != T::TY {
                    return Err(Error::new(format!(
                        "get_first_element: literal is {ty:?}, requested {:?}",
                        T::TY
                    )));
                }
                if bytes.len() < T::SIZE {
                    return Err(Error::new("get_first_element on empty literal"));
                }
                Ok(T::read_le(bytes))
            }
            Literal::Tuple(_) => Err(Error::new("get_first_element on tuple literal")),
        }
    }

    /// Take the elements out of a tuple literal.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(t) => Ok(std::mem::take(t)),
            Literal::Array { .. } => Err(Error::new("decompose_tuple on array literal")),
        }
    }
}

/// Parsed HLO module (shim: carries the source text only).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Reads the file so missing artifacts fail here with a clear
    /// message, matching upstream behaviour.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation handle (shim token).
pub struct XlaComputation {
    #[allow(dead_code)]
    proto_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto_len: proto.text.len() }
    }
}

/// Device buffer handle (shim: never constructed, compile always fails).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new("no native PJRT backend in this build"))
    }
}

/// Loaded executable (shim: never constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("no native PJRT backend in this build"))
    }
}

/// PJRT client token. `cpu()` succeeds so hosts can construct engines
/// and read manifests; `compile` is where the shim stops.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "host (xla shim; no native PJRT)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(
            "compiling HLO requires the native xla_extension backend, which is not \
             available in this offline build; swap rust/vendor/xla for the real crate",
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_roundtrip() {
        let l = Literal::vec1(&[1.0f32, -2.5, 3.25]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!(l.array_shape().unwrap().dims(), &[3]);
        assert_eq!(l.array_shape().unwrap().ty(), ElementType::F32);
    }

    #[test]
    fn reshape_checks_counts() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[42u32]).reshape(&[]).unwrap();
        assert_eq!(l.get_first_element::<u32>().unwrap(), 42);
        assert_eq!(l.element_count(), 1);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let l = Literal::vec1(&[1.0f32]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.get_first_element::<u32>().is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1i32]).decompose_tuple().is_err());
    }

    #[test]
    fn compile_is_stubbed() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("shim"));
        let comp = XlaComputation::from_proto(&HloModuleProto { text: "HloModule m".into() });
        assert!(c.compile(&comp).is_err());
    }
}
