//! Shard-runtime integration tests (tier-1: no artifacts needed).
//!
//! * Determinism suite: `apply_batch` sharded over 1, 2 and 8 workers
//!   is bitwise identical across all four backends.
//! * Pool lifecycle: a panicking task neither hangs nor kills the
//!   pool, and drop joins cleanly.
//! * Dispatcher quality: on a small randomized `(n, r, w, batch,
//!   threads)` grid, the parallelism-aware cost model never picks a
//!   backend measured slower than 2× the measured winner.

use std::panic::AssertUnwindSafe;
use std::time::Instant;

use ski_tnn::runtime::pool::Task;
use ski_tnn::runtime::ThreadPool;
use ski_tnn::toeplitz::{
    apply_batch_flat_sharded, apply_batch_sharded, build_op, gaussian_kernel, with_scratch,
    BackendKind, Dispatch, DispatchQuery, ToeplitzKernel, ToeplitzOp,
};
use ski_tnn::util::rng::Rng;

fn rows(rng: &mut Rng, count: usize, n: usize) -> Vec<Vec<f32>> {
    (0..count).map(|_| rng.normals(n)).collect()
}

#[test]
fn apply_batch_bitwise_identical_across_worker_counts() {
    let n = 128;
    let mut rng = Rng::new(42);
    let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 24.0));
    let causal = kernel.clone().causal();
    // 11 rows: not divisible by 2 or 8, so shards are uneven.
    let xs = rows(&mut rng, 11, n);
    for (kind, k) in [
        (BackendKind::Dense, &kernel),
        (BackendKind::Fft, &kernel),
        (BackendKind::Ski, &kernel),
        (BackendKind::Freq, &causal),
    ] {
        let op = build_op(k, kind, (n / 16).max(2), 9);
        let reference = op.apply_batch(&xs);
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let got = apply_batch_sharded(op.as_ref(), &xs, &pool);
            assert_eq!(
                got,
                reference,
                "{} backend must be bitwise identical at {threads} threads",
                op.name()
            );
        }
    }
}

#[test]
fn apply_batch_bitwise_identical_at_non_pow2_sizes() {
    // The length-agnostic satellite: sharded determinism must hold at
    // awkward sizes too — smooth composite (360) and prime (769),
    // where the spectral backends run mixed-radix/Bluestein plans on
    // per-worker scratch arenas.
    for n in [360usize, 769] {
        let mut rng = Rng::new(n as u64);
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 8.0));
        let causal = kernel.clone().causal();
        // 11 rows: not divisible by 2 or 8, so shards are uneven.
        let xs = rows(&mut rng, 11, n);
        for (kind, k) in [
            (BackendKind::Dense, &kernel),
            (BackendKind::Fft, &kernel),
            (BackendKind::Ski, &kernel),
            (BackendKind::Freq, &causal),
        ] {
            let op = build_op(k, kind, (n / 16).max(2), 9);
            let reference = op.apply_batch(&xs);
            for threads in [1usize, 2, 8] {
                let pool = ThreadPool::new(threads);
                let got = apply_batch_sharded(op.as_ref(), &xs, &pool);
                assert_eq!(
                    got,
                    reference,
                    "{} backend at n={n} must be bitwise identical at {threads} threads",
                    op.name()
                );
            }
        }
    }
}

#[test]
fn apply_batch_flat_bitwise_identical_across_worker_counts() {
    // The flat zero-allocation ABI must answer bit-for-bit what the
    // per-row scratch path answers, for every backend and worker
    // count — including awkward sizes (smooth composite 360, prime
    // 769) where the spectral backends run mixed-radix/Bluestein
    // plans.
    for n in [128usize, 360, 769] {
        let mut rng = Rng::new(n as u64 ^ 0xF1A7);
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 8.0));
        let causal = kernel.clone().causal();
        // 11 rows: not divisible by 2 or 8, so shards are uneven.
        let count = 11usize;
        let xs: Vec<f32> = (0..count).flat_map(|_| rng.normals(n)).collect();
        for (kind, k) in [
            (BackendKind::Dense, &kernel),
            (BackendKind::Fft, &kernel),
            (BackendKind::Ski, &kernel),
            (BackendKind::Freq, &causal),
        ] {
            let op = build_op(k, kind, (n / 16).max(2), 9);
            // Reference: each row through the per-row scratch entry.
            let reference: Vec<f32> =
                with_scratch(|s| xs.chunks(n).flat_map(|x| op.apply_with_scratch(x, s)).collect());
            let mut out = vec![0.0f32; count * n];
            for threads in [1usize, 2, 8] {
                let pool = ThreadPool::new(threads);
                out.fill(f32::NAN);
                apply_batch_flat_sharded(op.as_ref(), &xs, count, &mut out, &pool);
                assert_eq!(
                    out,
                    reference,
                    "{} backend at n={n} flat ABI must be bitwise per-row at {threads} threads",
                    op.name()
                );
            }
        }
    }
}

#[test]
fn pool_shutdown_is_clean_under_panic_in_task() {
    let pool = ThreadPool::new(4);
    // One shard panics; the scope must still drain the whole batch,
    // re-throw on the caller, and leave every worker alive.
    let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
        let tasks: Vec<Task> = (0..8)
            .map(|i| {
                let task: Task = Box::new(move || {
                    if i == 3 {
                        panic!("shard {i} panicked");
                    }
                });
                task
            })
            .collect();
        pool.scope(tasks);
    }));
    assert!(caught.is_err(), "task panic must propagate to the submitting thread");
    // The pool still computes correctly after the panic…
    let n = 64;
    let mut rng = Rng::new(5);
    let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 8.0));
    let op = build_op(&kernel, BackendKind::Fft, 0, 0);
    let xs = rows(&mut rng, 6, n);
    assert_eq!(apply_batch_sharded(op.as_ref(), &xs, &pool), op.apply_batch(&xs));
    // …and drop joins without hanging (a hang would time the suite out).
    drop(pool);
}

#[test]
#[ignore = "timing-based: run via `cargo test --release --test parallel -- --ignored` (CI bench-smoke tier), not the correctness gate"]
fn dispatcher_never_picks_far_from_measured_winner() {
    // Property: on a randomized grid of shapes, the backend the
    // parallelism-aware cost model selects is never measured slower
    // than 2× the measured winner.  Min-of-reps timing keeps scheduler
    // noise out; shapes stay at n ≥ 256 where the crossovers are
    // decisive rather than within-noise.  Ignored in the default test
    // run: wall-clock asserts on shared runners belong in the perf
    // tier, where a flake blocks nothing but the advisory gate.
    let mut rng = Rng::new(2024);
    let dispatch = Dispatch::default();
    for case in 0..5 {
        let n = 256usize << rng.below(3); // 256 | 512 | 1024
        let r = (n / 16) << rng.below(2); // n/16 | n/8
        let w = [5usize, 9][rng.below(2)];
        let batch = [1usize, 4, 8][rng.below(3)];
        let threads = [1usize, 2, 4][rng.below(3)];
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 8.0));
        let xs = rows(&mut rng, batch, n);
        let pool = ThreadPool::new(threads);
        let time = |op: &dyn ToeplitzOp| -> f64 {
            let _ = apply_batch_sharded(op, &xs, &pool); // warmup
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = Instant::now();
                std::hint::black_box(apply_batch_sharded(op, &xs, &pool));
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let candidates = [
            (BackendKind::Dense, time(build_op(&kernel, BackendKind::Dense, r, w).as_ref())),
            (BackendKind::Fft, time(build_op(&kernel, BackendKind::Fft, r, w).as_ref())),
            (BackendKind::Ski, time(build_op(&kernel, BackendKind::Ski, r, w).as_ref())),
        ];
        let winner = candidates.iter().cloned().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
        let picked = dispatch.select(&DispatchQuery { n, r, w, causal: false, batch, threads });
        let picked_time = candidates.iter().find(|(k, _)| *k == picked).map(|(_, t)| *t).unwrap();
        assert!(
            picked_time <= 2.0 * winner.1,
            "case {case} (n={n} r={r} w={w} batch={batch} threads={threads}): dispatcher picked \
             {} at {:.0} us but {} measured {:.0} us",
            picked.name(),
            1e6 * picked_time,
            winner.0.name(),
            1e6 * winner.1,
        );
    }
}

#[test]
fn serve_toeplitz_pooled_end_to_end_matches_dense_oracle() {
    use std::sync::Arc;
    use std::time::Duration;

    use ski_tnn::server::{serve_toeplitz_on, Batcher, ServerConfig};

    let n = 64usize;
    let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 16.0));
    let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
    let cfg = ServerConfig {
        max_batch: 8,
        n,
        max_wait: Duration::from_millis(2),
        queue_depth: 32,
        buckets: Vec::new(),
        ..ServerConfig::default()
    };
    let batcher = Batcher::new(cfg);
    let handle = batcher.handle();
    let kernel_check = kernel.clone();
    let client = std::thread::spawn(move || {
        for i in 0..10usize {
            let ids: Vec<i32> = (0..n as i32).map(|v| (v * 3 + i as i32) % 256).collect();
            let resp = handle.infer(ids.clone()).expect("infer");
            // Oracle: the same signal through the dense apply.
            let signal: Vec<f32> = ids.iter().map(|&t| t as f32 / 128.0 - 1.0).collect();
            let want = kernel_check.apply_dense(&signal);
            assert_eq!(resp.logits.len(), n);
            for (j, (a, b)) in resp.logits.iter().zip(want.iter()).enumerate() {
                assert!((a - b).abs() < 1e-4, "row {i} value {j}: {a} vs {b}");
            }
        }
    });
    let pool = Arc::new(ThreadPool::new(4));
    let stats = batcher.run(serve_toeplitz_on(op, pool)).unwrap();
    client.join().unwrap();
    assert_eq!(stats.requests, 10);
}
