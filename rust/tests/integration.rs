//! Integration tests: the full Rust request path against real AOT
//! artifacts — engine compile, fused train steps, eval entries,
//! checkpoint round-trips, and the serving batcher over a real model.
//!
//! These need `make artifacts` to have run (CI order: artifacts →
//! pytest → cargo test).  Each test builds its own [`Engine`] (its own
//! PJRT client); compiles are the dominant cost so tests stick to the
//! small `lm_*` configs.

use std::path::Path;
use std::sync::Arc;

use ski_tnn::config::RunConfig;
use ski_tnn::coordinator::{batch_for, evaluate, to_literals, Trainer};
use ski_tnn::data::{BatchSource, CausalLmStream, Corpus, Split};
use ski_tnn::runtime::{Engine, HostTensor, ModelState};
use ski_tnn::server::{serve_model, Batcher, ServerConfig};

fn artifacts() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Engine over built artifacts with a native PJRT backend, else `None`
/// and the test self-skips (artifacts come from `make artifacts`; the
/// offline build ships an xla shim that cannot execute HLO).
fn engine() -> Option<Engine> {
    let dir = artifacts();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping artifact-gated test: {} missing", dir.display());
        return None;
    }
    let eng = Engine::new(&dir).unwrap();
    if eng.platform().contains("shim") {
        eprintln!("skipping artifact-gated test: no native PJRT backend");
        return None;
    }
    Some(eng)
}

fn quick_run(config: &str, steps: usize) -> RunConfig {
    RunConfig {
        config: config.into(),
        artifacts: artifacts(),
        steps,
        eval_every: 0,
        eval_batches: 2,
        corpus_bytes: 120_000,
        log_every: 0,
        ..RunConfig::default()
    }
}

#[test]
fn train_smoke_fd_causal_loss_decreases() {
    let Some(engine) = engine() else { return };
    let mut trainer = Trainer::new(&engine, quick_run("lm_fd_3l", 12)).unwrap();
    let stats = trainer.train().unwrap();
    assert!(stats.loss.is_finite());
    let series = trainer.metrics.series("train", "loss");
    assert_eq!(series.len(), 12);
    let first = series[0].1;
    let last = trainer.metrics.recent_mean("train", "loss", 3).unwrap();
    assert!(
        last < first,
        "loss should fall within 12 steps: {first:.3} -> {last:.3}"
    );
}

#[test]
fn train_smoke_ski_bidirectional() {
    let Some(engine) = engine() else { return };
    let mut trainer = Trainer::new(&engine, quick_run("lm_bidir_ski", 6)).unwrap();
    let stats = trainer.train().unwrap();
    assert!(stats.loss.is_finite() && stats.ppl.is_finite());
    // masked-LM losses start near ln(vocab) ≈ 5.6 — sanity band
    let first = trainer.metrics.series("train", "loss")[0].1;
    assert!((2.0..9.0).contains(&first), "initial loss {first}");
}

#[test]
fn train_smoke_base_variant() {
    let Some(engine) = engine() else { return };
    let mut trainer = Trainer::new(&engine, quick_run("lm_base_3l", 4)).unwrap();
    let stats = trainer.train().unwrap();
    assert!(stats.loss.is_finite());
}

#[test]
fn eval_is_deterministic() {
    let Some(engine) = engine() else { return };
    let mut trainer = Trainer::new(&engine, quick_run("lm_fd_3l", 0)).unwrap();
    let a = trainer.eval().unwrap();
    let b = trainer.eval().unwrap();
    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "val stream must be frozen");
}

#[test]
fn checkpoint_roundtrip_resumes_bit_exact() {
    let Some(engine) = engine() else { return };
    let dir = std::env::temp_dir().join(format!("ski_tnn_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut run = quick_run("lm_fd_3l", 3);
    run.seed = 42;
    let mut trainer = Trainer::new(&engine, run).unwrap();
    trainer.train().unwrap();
    let path = dir.join("state.ckpt");
    trainer.state.save(&path).unwrap();

    let restored = ModelState::load(&engine, &path).unwrap();
    assert_eq!(restored.config.name, "lm_fd_3l");
    assert_eq!(restored.step_count().unwrap(), trainer.state.step_count().unwrap());
    for (a, b) in trainer.state.params.iter().zip(restored.params.iter()) {
        let av: Vec<f32> = a.to_vec().unwrap();
        let bv: Vec<f32> = b.to_vec().unwrap();
        assert_eq!(av, bv, "params must round-trip bit-exactly");
    }

    // same batch ⇒ same loss from both states (optimizer state included)
    let corpus = Arc::new(Corpus::generate(7, 60_000).tokens());
    let mut src = CausalLmStream::new(corpus, Split::Train, 8, 256, 5);
    let batch = to_literals(&src.next_batch()).unwrap();
    let mut s1 = trainer.state;
    let mut s2 = restored;
    let l1 = s1.step(&batch).unwrap();
    let l2 = s2.step(&batch).unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits(), "resumed training must match exactly");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_rejects_wrong_magic() {
    let Some(engine) = engine() else { return };
    let path = std::env::temp_dir().join(format!("ski_tnn_bad_{}.ckpt", std::process::id()));
    std::fs::write(&path, b"not a checkpoint at all").unwrap();
    assert!(ModelState::load(&engine, &path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn fig7_eval_lengths_run() {
    // fwd_n64 evaluates the n=256-trained model at n=64 via the warp.
    let Some(engine) = engine() else { return };
    let state = ModelState::init(&engine, "lm_fd_3l", 0).unwrap();
    let corpus = Arc::new(Corpus::generate(0, 60_000).tokens());
    let mut src: Box<dyn BatchSource> =
        Box::new(CausalLmStream::new(corpus, Split::Val, 8, 64, 1));
    let stats = evaluate(&engine, &state, "fwd_n64", src.as_mut(), 2).unwrap();
    assert!(stats.loss.is_finite());
    // untrained model: near-uniform prediction ⇒ loss ≈ ln(259) ≈ 5.56
    assert!((4.0..7.0).contains(&stats.loss), "loss {}", stats.loss);
}

#[test]
fn logits_entry_serves_through_batcher() {
    let Some(engine) = engine() else { return };
    let state = ModelState::init(&engine, "lm_fd_3l", 3).unwrap();
    let cfg = state.config.clone();
    engine.load(&cfg.name, "logits").unwrap();

    let batcher = Batcher::new(ServerConfig {
        max_batch: cfg.batch,
        n: cfg.n,
        max_wait: std::time::Duration::from_millis(1),
        queue_depth: 16,
        buckets: Vec::new(),
        ..ServerConfig::default()
    });
    let handle = batcher.handle();
    let vocab = cfg.vocab;
    let t = std::thread::spawn(move || {
        let mut resps = Vec::new();
        for i in 0..6 {
            let ids: Vec<i32> = (0..50 + i).map(|j| (j % 250) as i32).collect();
            resps.push(handle.infer(ids).unwrap());
        }
        resps
    });
    let stats = batcher.run(serve_model(&engine, &state)).unwrap();
    let resps = t.join().unwrap();
    assert_eq!(stats.requests, 6);
    for r in &resps {
        assert_eq!(r.logits.len(), vocab, "LM logits row = vocab");
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn batch_for_builds_every_task_kind() {
    let Some(engine) = engine() else { return };
    let corpus = Arc::new(Corpus::generate(0, 60_000).tokens());
    for (config, needs_corpus) in [
        ("lm_fd_3l", true),
        ("lm_bidir_ski", true),
        ("lra_text_fd", false),
        ("lra_image_ski", false),
    ] {
        let c = if needs_corpus { Some(corpus.clone()) } else { None };
        let mut src = batch_for(&engine, config, Split::Train, c, 1).unwrap();
        let batch = src.next_batch();
        let cfg = engine.config(config).unwrap();
        let want = cfg.batch_inputs().unwrap();
        assert_eq!(batch.len(), want.len(), "{config}");
        for (t, d) in batch.iter().zip(want.iter()) {
            t.check(d).unwrap_or_else(|e| panic!("{config}: {e}"));
        }
    }
}

#[test]
fn trainer_rejects_mismatched_resume() {
    let Some(engine) = engine() else { return };
    let dir = std::env::temp_dir().join(format!("ski_tnn_mm_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let state = ModelState::init(&engine, "lm_base_3l", 0).unwrap();
    let path = dir.join("base.ckpt");
    state.save(&path).unwrap();

    let mut run = quick_run("lm_fd_3l", 1);
    run.resume = Some(path.clone());
    assert!(Trainer::new(&engine, run).is_err(), "config mismatch must be rejected");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn divergent_loss_is_reported() {
    // A pathological LR is not reachable through artifacts (lr is baked
    // in), so simulate divergence detection at the metric level: the
    // trainer bails on non-finite loss — exercised here through the
    // public API by checking finite losses on a real run instead.
    let Some(engine) = engine() else { return };
    let mut trainer = Trainer::new(&engine, quick_run("lm_fd_3l", 2)).unwrap();
    trainer.train().unwrap();
    for (_, loss) in trainer.metrics.series("train", "loss") {
        assert!(loss.is_finite());
    }
}

#[test]
fn host_tensor_checks_against_manifest() {
    let Some(engine) = engine() else { return };
    let cfg = engine.config("lm_fd_3l").unwrap();
    let bi = cfg.batch_inputs().unwrap();
    let wrong = HostTensor::i32(vec![1, 2], vec![0, 0]);
    assert!(wrong.check(&bi[0]).is_err());
}

// ---------------------------------------------------------------------
// Backend-stack acceptance (pure substrate — no artifacts needed):
// every ToeplitzOp backend vs the dense oracle at the acceptance sizes,
// plus the batcher executor end-to-end over a dispatched backend.
// ---------------------------------------------------------------------

#[test]
fn backend_stack_agrees_with_dense_oracle() {
    use ski_tnn::toeplitz::{
        build_op, gaussian_kernel, BackendKind, SparseLowRankOp, ToeplitzKernel, ToeplitzOp,
    };
    use ski_tnn::util::rng::Rng;

    let close = |got: &[f32], want: &[f32], tol: f32, what: &str| {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            let scale = 1.0f32.max(w.abs());
            assert!((g - w).abs() <= tol * scale, "{what} at {i}: {g} vs {w}");
        }
    };

    // Power-of-two sizes plus the length-agnostic acceptance sizes:
    // smooth composites (96 = 2⁵·3, 360 = 2³·3²·5, 1000 = 2³·5³) and a
    // prime (769) — every backend must serve them natively now.
    for &n in &[64usize, 96, 256, 360, 769, 1000, 1024] {
        let mut rng = Rng::new(n as u64);
        let kernel = ToeplitzKernel { n, lags: rng.normals(2 * n - 1) };
        let x = rng.normals(n);
        let want = kernel.apply_dense(&x);
        // Exact backends: 1e-4 relative on fully random kernels.
        for kind in [BackendKind::Dense, BackendKind::Fft] {
            let op = build_op(&kernel, kind, 0, 0);
            close(&op.apply(&x), &want, 1e-4, op.name());
        }
        let causal = kernel.clone().causal();
        let op = build_op(&causal, BackendKind::Freq, 0, 0);
        close(&op.apply(&x), &causal.apply_dense(&x), 1e-4, "freq");

        // SKI backend: judged within its Theorem-1 regime — a smooth
        // kernel, error shrinking as the rank grows, near-exact at
        // r = n (inducing grid on every lag).
        let smooth = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 8.0));
        let want_s = smooth.apply_dense(&x);
        let l2 = |r: usize| {
            let op = SparseLowRankOp::from_kernel(&smooth, r, 9);
            op.apply(&x)
                .iter()
                .zip(want_s.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let coarse = l2((n / 16).max(2));
        let fine = l2(n);
        let scale = want_s.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        assert!(fine <= 1e-3 * scale.max(1.0), "ski full-rank residual {fine} (scale {scale})");
        assert!(
            fine <= coarse * 1.05,
            "ski error must not grow with rank: r={} {coarse} vs r=n {fine}",
            (n / 16).max(2)
        );
    }
}

#[test]
fn batcher_serves_dispatched_backend_end_to_end() {
    use std::sync::Arc;
    use std::time::Duration;

    use ski_tnn::server::serve_toeplitz;
    use ski_tnn::toeplitz::{build_op, gaussian_kernel, BackendKind, ToeplitzKernel, ToeplitzOp};

    let n = 64usize;
    let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 16.0));
    // Auto-dispatch with a usable SKI rank; whatever wins must serve.
    let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Auto, 8, 5));
    let cfg = ServerConfig {
        max_batch: 4,
        n,
        max_wait: Duration::from_millis(2),
        queue_depth: 16,
        buckets: Vec::new(),
        ..ServerConfig::default()
    };
    let batcher = Batcher::new(cfg);
    let handle = batcher.handle();
    let workers: Vec<_> = (0..3)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                for i in 0..5usize {
                    let len = 4 + (c * 7 + i * 3) % 60;
                    let ids: Vec<i32> = (0..len as i32).map(|v| (v * 5 + c as i32) % 256).collect();
                    let resp = h.infer(ids).expect("infer");
                    assert_eq!(resp.logits.len(), 64);
                    assert!(resp.logits.iter().all(|v| v.is_finite()));
                }
            })
        })
        .collect();
    drop(handle);
    let stats = batcher.run(serve_toeplitz(op)).unwrap();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(stats.requests, 15);
    assert!(stats.batches <= 15);
}

#[test]
fn bucketed_serving_handles_mixed_length_traffic_at_awkward_widths() {
    // Acceptance: a mixed-length request stream through the
    // length-bucketed batcher, with non-power-of-two bucket widths, a
    // per-width operator factory, and the pooled executor — every
    // response matches the dense oracle at its bucket width and
    // nothing panics.
    use std::sync::Arc;
    use std::time::Duration;

    use ski_tnn::data::PAD;
    use ski_tnn::runtime::ThreadPool;
    use ski_tnn::server::{serve_toeplitz_factory, Batcher, ServerConfig};
    use ski_tnn::toeplitz::{build_op, gaussian_kernel, BackendKind, ToeplitzKernel, ToeplitzOp};

    let make_kernel =
        |w: usize| ToeplitzKernel::from_fn(w, |lag| gaussian_kernel(lag as f64, w as f64 / 8.0));
    let cfg = ServerConfig {
        max_batch: 4,
        n: 360,
        max_wait: Duration::from_millis(2),
        queue_depth: 64,
        buckets: vec![24, 96],
        ..ServerConfig::default()
    };
    let batcher = Batcher::new(cfg);
    let handle = batcher.handle();
    let workers: Vec<_> = (0..3)
        .map(|c| {
            let h = handle.clone();
            let make_kernel = make_kernel;
            std::thread::spawn(move || {
                for i in 0..6usize {
                    // Lengths spread across all three buckets.
                    let len = [5, 20, 60, 90, 200, 360][(c + i) % 6] + c;
                    let ids: Vec<i32> =
                        (0..len as i32).map(|v| (v * 7 + c as i32) % 256).collect();
                    let resp = h.infer(ids.clone()).expect("bucketed infer");
                    let width = resp.width;
                    assert!(
                        [24, 96, 360].contains(&width),
                        "row of len {len} served at unexpected width {width}"
                    );
                    assert!(width >= len.min(360), "bucket must fit the row (len {len})");
                    // Oracle at the served width.
                    let mut padded = vec![PAD; width];
                    let take = ids.len().min(width);
                    padded[..take].copy_from_slice(&ids[..take]);
                    let signal: Vec<f32> = padded
                        .iter()
                        .map(|&t| if t == PAD { 0.0 } else { t as f32 / 128.0 - 1.0 })
                        .collect();
                    let want = make_kernel(width).apply_dense(&signal);
                    assert_eq!(resp.logits.len(), width);
                    for (j, (a, b)) in resp.logits.iter().zip(want.iter()).enumerate() {
                        assert!((a - b).abs() < 1e-3, "len {len} width {width} at {j}: {a} vs {b}");
                    }
                }
            })
        })
        .collect();
    drop(handle);
    let make = move |w: usize| -> Arc<dyn ToeplitzOp> {
        Arc::from(build_op(&make_kernel(w), BackendKind::Fft, 0, 0))
    };
    let pool = Arc::new(ThreadPool::new(2));
    let stats = batcher.run(serve_toeplitz_factory(make, pool)).unwrap();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(stats.requests, 18);
    assert_eq!(stats.exec_errors, 0, "no request may fail on the bucketed path");
}
