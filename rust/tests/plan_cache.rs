//! PlanCache under contention and across evictions.
//!
//! Two guarantees the unified execution-plan layer makes beyond its
//! unit tests:
//!
//! 1. **Exact accounting under a thread hammer** — lookups resolve
//!    under the cache lock, so `hits + misses` equals the number of
//!    lookups *exactly* (no lost counts, no double builds) even with
//!    8 threads racing over more distinct shapes than the cache holds.
//! 2. **Eviction is invisible to correctness** — a plan rebuilt after
//!    being evicted produces bitwise-identical tick output, because a
//!    plan is a pure function of its `ShapeKey` + kernel recipe.

use std::sync::Arc;

use ski_tnn::plan::{ExecutionPlan, PlanCache, ShapeKey};
use ski_tnn::runtime::ThreadPool;
use ski_tnn::toeplitz::{build_op, BackendKind, ToeplitzKernel, ToeplitzOp};

/// A deterministic spectral plan for width `n` — the same recipe every
/// time, so rebuilds after eviction must reproduce identical bits.
fn plan_for(n: usize) -> ExecutionPlan {
    let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
    let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
    ExecutionPlan::from_op(ShapeKey::for_width(n, 1), op)
}

/// 8 threads × 200 lookups over 12 distinct shapes against a cap-4
/// cache: every lookup is either a hit or a miss (never lost, never
/// both), occupancy stays bounded, and the insert/evict ledger
/// balances to the resident count.
#[test]
fn hammered_cache_accounts_for_every_lookup() {
    const THREADS: usize = 8;
    const LOOKUPS: usize = 200;
    let cache = Arc::new(PlanCache::new(4));
    let shapes: Vec<usize> = (0..12).map(|i| 8 + 8 * i).collect();
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let cache = Arc::clone(&cache);
            let shapes = shapes.clone();
            std::thread::spawn(move || {
                for i in 0..LOOKUPS {
                    // Each thread walks the shape list at a different
                    // stride so hits, misses, and evictions interleave.
                    let n = shapes[(i * (t + 1) + t) % shapes.len()];
                    let plan = cache.get_or_build(ShapeKey::for_width(n, 1), || plan_for(n));
                    assert_eq!(plan.key().n, n, "cache returned a plan for the wrong shape");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("hammer thread");
    }
    let s = cache.stats();
    assert_eq!(
        s.hits + s.misses,
        (THREADS * LOOKUPS) as u64,
        "every lookup must be counted exactly once: {s:?}"
    );
    assert!(s.len <= s.cap, "occupancy {} exceeds cap {}", s.len, s.cap);
    assert_eq!(
        s.misses,
        s.evicts + s.len as u64,
        "every miss inserts; inserts minus evictions must equal residency: {s:?}"
    );
    assert!(s.evicts > 0, "12 shapes through a cap-4 cache must have evicted");
}

/// Evict a plan by displacement, rebuild it through the same cache,
/// and assert the rebuilt plan's tick output is bitwise identical to
/// the original's.
#[test]
fn evicted_plan_rebuilds_bitwise_identical() {
    let n = 64usize;
    let rows = 2usize;
    let cache = PlanCache::new(2);
    let pool = ThreadPool::new(1);
    let key_a = ShapeKey::for_width(n, 1);
    let xs: Vec<f32> = (0..rows * n).map(|i| (i as f32) / 9.0 - 3.0).collect();
    let mut encode = |i: usize, sig: &mut [f32]| {
        sig.copy_from_slice(&xs[i * n..(i + 1) * n]);
    };

    let first: Vec<Vec<f32>> = {
        let plan = cache.get_or_build(key_a, || plan_for(n));
        let out = plan.execute_rows(rows, n, &mut encode, &pool).expect("first tick");
        out.iter().map(|r| (**r).to_vec()).collect()
    };

    // Two fresh shapes through a cap-2 cache displace plan A.
    for m in [96usize, 128] {
        let _ = cache.get_or_build(ShapeKey::for_width(m, 1), || plan_for(m));
    }
    assert!(cache.peek(&key_a).is_none(), "plan A must have been evicted");

    let plan = cache.get_or_build(key_a, || plan_for(n));
    let out = plan.execute_rows(rows, n, &mut encode, &pool).expect("rebuilt tick");
    for (i, (row, want)) in out.iter().zip(first.iter()).enumerate() {
        assert_eq!(
            &**row,
            want.as_slice(),
            "rebuilt plan diverged from the evicted original at row {i}"
        );
    }
    let s = cache.stats();
    assert!(s.evicts >= 1, "displacement must have evicted: {s:?}");
    assert_eq!(s.misses, 4, "A, B, C, and the rebuild of A are the only builds: {s:?}");
}
