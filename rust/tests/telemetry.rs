//! End-to-end telemetry: real serving and generation runs must leave
//! a snapshot behind that passes the CI completeness gate.
//!
//! Lives in its own test binary (own process) because these tests flip
//! the process-wide telemetry enable and assert on the **global**
//! registry/audit ring — isolation the library unit tests, which share
//! one process, deliberately avoid by using local instances.

use std::sync::Arc;

use ski_tnn::runtime::ThreadPool;
use ski_tnn::server::{audit_exec, serve_toeplitz_factory, Batcher, ServerConfig};
use ski_tnn::telemetry;
use ski_tnn::toeplitz::{
    build_op, gaussian_kernel, BackendKind, Dispatch, DispatchQuery, ToeplitzKernel, ToeplitzOp,
};
use ski_tnn::util::json;

/// A bucketed substrate serve run with the dispatch audit wrapped in
/// (the `ski-tnn serve --backend auto --stats-json` path) must emit a
/// snapshot carrying every core series: span percentiles, the pool
/// gauge, and predicted-vs-measured audit rows — both in memory and
/// through the atomic-rename file write.
#[test]
fn serve_substrate_emits_complete_snapshot() {
    telemetry::set_enabled(true);
    let n = 128usize;
    let threads = 2usize;
    let r = 16usize;
    let w = 9usize;
    let dispatch = Dispatch::default();
    let rank_for = move |width: usize| (width * r / n).max(2);
    let plan_for = move |width: usize| -> (BackendKind, bool) {
        dispatch.plan(&DispatchQuery {
            n: width,
            r: rank_for(width),
            w,
            causal: false,
            batch: 8,
            threads,
        })
    };
    let make_op = move |width: usize| -> Arc<dyn ToeplitzOp> {
        let (kind, _) = plan_for(width);
        let kernel =
            ToeplitzKernel::from_fn(width, |lag| gaussian_kernel(lag as f64, width as f64 / 8.0));
        let kernel = if kind == BackendKind::Freq { kernel.causal() } else { kernel };
        Arc::from(build_op(&kernel, kind, rank_for(width), w))
    };
    let pool = Arc::new(ThreadPool::new(threads));
    let batcher = Batcher::new(ServerConfig {
        max_batch: 8,
        n,
        max_wait: std::time::Duration::from_millis(1),
        queue_depth: 64,
        buckets: vec![32],
        ..ServerConfig::default()
    });
    let handle = batcher.handle();
    let client = std::thread::spawn(move || {
        for i in 0..48usize {
            let len = 8 + (i * 7) % (n - 8);
            let ids: Vec<i32> = (0..len).map(|j| (j % 256) as i32).collect();
            handle.infer(ids).expect("infer");
        }
    });
    let exec = audit_exec(
        serve_toeplitz_factory(make_op, pool),
        dispatch,
        plan_for,
        rank_for,
        w,
        threads,
        batcher.pressure(),
    );
    let stats = batcher.run(exec).expect("serve loop");
    client.join().unwrap();
    assert_eq!(stats.requests, 48);

    let doc = telemetry::snapshot();
    telemetry::check_snapshot(&doc).expect("live snapshot must pass the CI gate");
    let qw = doc
        .get("histograms")
        .and_then(|h| h.get("span.queue_wait"))
        .expect("queue-wait series present");
    let pct = |k: &str| qw.get(k).and_then(json::Json::as_f64).unwrap();
    assert!(pct("p50_ns") <= pct("p99_ns"), "percentiles must be ordered");
    let rows = telemetry::global_audit().rows();
    assert!(!rows.is_empty(), "audit ring captured executed batches");
    assert!(rows.iter().all(|row| row.measured_ns > 0.0), "measured wall times are positive");

    // The file path a `--stats-json` run takes: atomic-rename write,
    // then re-parse and re-gate what actually landed on disk.
    let path = std::env::temp_dir().join(format!("ski_tnn_e2e_{}.json", std::process::id()));
    telemetry::write_snapshot(&path).expect("write snapshot");
    let text = std::fs::read_to_string(&path).expect("snapshot file readable");
    let _ = std::fs::remove_file(&path);
    let ondisk = json::parse(&text).expect("snapshot parses");
    telemetry::check_snapshot(&ondisk).expect("on-disk snapshot must pass the CI gate");
}

/// One generation through the continuous-batching scheduler records
/// the decode-tick span and the token counter.
#[test]
fn generate_ticks_record_decode_span() {
    use ski_tnn::decode::{DecodeModel, DecodeModelConfig, DecodePolicy};
    use ski_tnn::server::{GenConfig, GenParams, GenScheduler};

    telemetry::set_enabled(true);
    let model = DecodeModel::new(DecodeModelConfig {
        d: 8,
        blocks: 1,
        n: 32,
        policy: DecodePolicy { rank: 8, max_rel_residual: 0.05 },
        seed: 3,
        ..DecodeModelConfig::default()
    });
    let before_ticks = telemetry::global().histogram("span.decode_tick").count();
    let before_tokens = telemetry::global().counter("decode.tokens").get();
    let sched = GenScheduler::new(GenConfig {
        max_sessions: 2,
        queue_depth: 8,
        max_new_cap: 16,
        threads: 1,
        ..GenConfig::default()
    });
    let handle = sched.handle();
    let client = std::thread::spawn(move || {
        handle.generate(vec![1, 2, 3], GenParams { max_new: 5, ..GenParams::default() })
    });
    let stats = sched.run(&model).expect("scheduler run");
    let resp = client.join().unwrap().expect("generate");
    assert_eq!(resp.tokens.len(), 5);
    assert!(stats.ticks >= 5, "at least one tick per generated token");
    let ticks = telemetry::global().histogram("span.decode_tick").count() - before_ticks;
    let tokens = telemetry::global().counter("decode.tokens").get() - before_tokens;
    assert!(ticks >= 5, "decode_tick span recorded {ticks} ticks, want >= 5");
    assert!(tokens >= 5, "decode.tokens counted {tokens}, want >= 5");
}
