//! Overload-control and fault-injection robustness: the serving
//! invariants the admission layer promises, proven end-to-end against
//! the public API under deterministic chaos.
//!
//! The contract under test:
//!
//! * every accepted request is answered **exactly once** — a typed
//!   `Overloaded` / `DeadlineExceeded` / `Exec` answer counts, a lost
//!   or doubled response never does;
//! * the admission ledger balances exactly at quiescence:
//!   `submitted == admitted + shed` and
//!   `admitted == completed + expired`;
//! * the queue stays bounded at 10× overcapacity (peak depth never
//!   exceeds `queue_depth`).

use std::sync::{Mutex, PoisonError};
use std::time::Duration;

use anyhow::Result;
use ski_tnn::data::PAD;
use ski_tnn::runtime::HostTensor;
use ski_tnn::server::{
    chaos, AdmissionPolicy, Batcher, Response, RetryPolicy, RowBatch, ServeError, ServerConfig,
    SubmitError,
};

/// Chaos state is process-global; tests that arm it take this lock so
/// they never observe each other's fault streams.  The guard disarms
/// on drop, panic included.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

struct ChaosSession<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl ChaosSession<'_> {
    fn arm(seed: u64) -> ChaosSession<'static> {
        let guard = CHAOS_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        // Settle env-var arming first so `install` owns the state.
        let _ = chaos::enabled();
        chaos::install(chaos::ChaosConfig::from_seed(seed));
        ChaosSession(guard)
    }
}

impl Drop for ChaosSession<'_> {
    fn drop(&mut self) {
        chaos::disarm();
    }
}

/// Echo executor: logits[row] = [sum of that row's non-PAD ids].
fn echo(batch: &HostTensor) -> Result<RowBatch> {
    let shape = batch.shape().to_vec();
    let ids = batch.as_i32()?;
    Ok(ids
        .chunks(shape[1])
        .map(|row| vec![row.iter().filter(|&&t| t != PAD).map(|&t| t as f32).sum::<f32>()])
        .collect::<Vec<_>>()
        .into())
}

fn cfg(queue_depth: usize, policy: AdmissionPolicy, deadline: Option<Duration>) -> ServerConfig {
    ServerConfig {
        max_batch: 4,
        n: 32,
        max_wait: Duration::from_millis(1),
        queue_depth,
        buckets: Vec::new(),
        policy,
        deadline,
    }
}

#[derive(Debug, Default)]
struct Drained {
    ok: u64,
    overloaded: u64,
    deadline_exceeded: u64,
    exec_failed: u64,
    lost: u64,
    double_answered: u64,
}

/// Drain every pending receiver, classifying the typed answers and
/// checking the exactly-once contract per channel.
fn drain(pending: Vec<std::sync::mpsc::Receiver<Response>>) -> Drained {
    let mut d = Drained::default();
    for rx in pending {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(resp) => {
                match resp.error {
                    None => d.ok += 1,
                    Some(ServeError::Overloaded) => d.overloaded += 1,
                    Some(ServeError::DeadlineExceeded) => d.deadline_exceeded += 1,
                    Some(ServeError::Exec(_)) => d.exec_failed += 1,
                }
                if rx.try_recv().is_ok() {
                    d.double_answered += 1;
                }
            }
            Err(_) => d.lost += 1,
        }
    }
    d
}

impl Drained {
    fn responses(&self) -> u64 {
        self.ok + self.overloaded + self.deadline_exceeded + self.exec_failed
    }

    fn merge(&mut self, o: &Drained) {
        self.ok += o.ok;
        self.overloaded += o.overloaded;
        self.deadline_exceeded += o.deadline_exceeded;
        self.exec_failed += o.exec_failed;
        self.lost += o.lost;
        self.double_answered += o.double_answered;
    }
}

/// The centerpiece: 80 requests against a depth-8 queue (10×
/// overcapacity) with executor failures and slow ticks injected, under
/// the shed-expired-first policy and a real deadline.  Every accepted
/// request must be answered exactly once, the ledger must balance to
/// the request, and the queue must stay bounded.
#[test]
fn chaos_soak_survives_ten_x_overcapacity() {
    let _chaos = ChaosSession::arm(7);
    let b = Batcher::new(cfg(
        8,
        AdmissionPolicy::ShedExpiredFirst,
        Some(Duration::from_millis(200)),
    ));
    let h = b.handle();
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut accepted = 0u64;
                let mut pending = Vec::new();
                for i in 0..20 {
                    // Blocking-admission submit: under a shed policy
                    // this never blocks — overflow comes back as a
                    // typed answer on the channel.
                    match h.submit(vec![c as i32 + 1; (i % 8) + 1]) {
                        Ok(rx) => {
                            accepted += 1;
                            pending.push(rx);
                        }
                        Err(SubmitError::Stopped) => panic!("server stopped mid-soak"),
                        Err(SubmitError::QueueFull) => unreachable!("submit never QueueFulls"),
                    }
                }
                (accepted, drain(pending))
            })
        })
        .collect();
    drop(h);
    let stats = b.run(chaos::chaos_exec(echo)).unwrap();

    let mut accepted = 0u64;
    let mut d = Drained::default();
    for c in clients {
        let (a, part) = c.join().unwrap();
        accepted += a;
        d.merge(&part);
    }
    assert_eq!(accepted, 80, "shed policies accept every submit");
    assert_eq!(d.responses(), accepted, "every accepted request answered: {d:?}");
    assert_eq!(d.lost, 0, "no lost responses: {d:?}");
    assert_eq!(d.double_answered, 0, "no double responses: {d:?}");

    let adm = stats.admission;
    assert!(adm.balanced(), "ledger must balance exactly: {adm:?}");
    assert_eq!(adm.submitted, 80, "{adm:?}");
    assert_eq!(adm.admitted + adm.shed, 80, "{adm:?}");
    assert_eq!(adm.completed + adm.expired, adm.admitted, "{adm:?}");
    assert!(adm.peak_depth <= 8, "queue must stay bounded: {adm:?}");
    // The client-side view and the server-side ledger agree.
    assert_eq!(d.overloaded, adm.shed, "{d:?} vs {adm:?}");
    assert_eq!(d.deadline_exceeded, adm.expired, "{d:?} vs {adm:?}");
}

/// With the server not yet draining, shed-newest answers exactly the
/// overflow with typed `Overloaded` and executes the rest — fully
/// deterministic because every submit lands before the serve loop
/// starts.
#[test]
fn shed_newest_answers_typed_overloaded() {
    let b = Batcher::new(cfg(2, AdmissionPolicy::ShedNewest, None));
    let h = b.handle();
    let pending: Vec<_> = (0..10).map(|i| h.submit(vec![i + 1]).unwrap()).collect();
    drop(h);
    let stats = b.run(echo).unwrap();
    let d = drain(pending);
    assert_eq!(d.ok, 2, "the two queued requests execute: {d:?}");
    assert_eq!(d.overloaded, 8, "all overflow typed Overloaded: {d:?}");
    assert_eq!(d.lost + d.double_answered, 0, "{d:?}");
    let adm = stats.admission;
    assert!(adm.balanced(), "{adm:?}");
    assert_eq!(adm.shed, 8, "{adm:?}");
    assert_eq!(adm.completed, 2, "{adm:?}");
}

/// Boundary: a zero deadline expires on arrival — rejected inside
/// submit, never queued, exactly one typed answer.
#[test]
fn zero_deadline_expires_on_arrival() {
    let b = Batcher::new(cfg(16, AdmissionPolicy::Block, Some(Duration::ZERO)));
    let h = b.handle();
    let t = std::thread::spawn(move || {
        (0..3)
            .map(|i| h.infer_response(vec![i + 1]).unwrap())
            .map(|resp| resp.error)
            .collect::<Vec<_>>()
    });
    let stats = b.run(echo).unwrap();
    let errors = t.join().unwrap();
    assert_eq!(errors, vec![Some(ServeError::DeadlineExceeded); 3]);
    let adm = stats.admission;
    assert!(adm.balanced(), "{adm:?}");
    assert_eq!(adm.expired, 3, "{adm:?}");
    assert_eq!(adm.completed, 0, "{adm:?}");
    assert_eq!(stats.requests, 0, "nothing executed");
}

/// Boundary: requests that outlive their deadline *while queued*
/// behind a slow batch get the typed answer from the pre-execute
/// sweep; the one that made it into the executor completes.
#[test]
fn deadline_expires_while_queued_behind_a_slow_batch() {
    let b = Batcher::new(ServerConfig {
        max_batch: 1,
        deadline: Some(Duration::from_millis(50)),
        ..cfg(16, AdmissionPolicy::Block, None)
    });
    let h = b.handle();
    let t = std::thread::spawn(move || {
        let pending: Vec<_> = (0..3).map(|i| h.submit(vec![i + 1]).unwrap()).collect();
        drain(pending)
    });
    let slow = |batch: &HostTensor| {
        std::thread::sleep(Duration::from_millis(150));
        echo(batch)
    };
    let stats = b.run(slow).unwrap();
    let d = t.join().unwrap();
    assert_eq!(d.ok, 1, "the executing request completes: {d:?}");
    assert_eq!(d.deadline_exceeded, 2, "the queued ones expire: {d:?}");
    assert_eq!(d.lost + d.double_answered, 0, "{d:?}");
    let adm = stats.admission;
    assert!(adm.balanced(), "{adm:?}");
    assert_eq!(adm.expired, 2, "{adm:?}");
}

/// Boundary: a deadline shorter than one gather window — the lone
/// request sits through the window, and the sweep answers it with
/// exactly one typed error instead of executing it late.
#[test]
fn deadline_shorter_than_gather_window_is_typed() {
    let b = Batcher::new(ServerConfig {
        max_wait: Duration::from_millis(50),
        deadline: Some(Duration::from_millis(10)),
        ..cfg(16, AdmissionPolicy::Block, None)
    });
    let h = b.handle();
    let t = std::thread::spawn(move || h.infer_response(vec![1, 2, 3]).unwrap());
    let stats = b.run(echo).unwrap();
    let resp = t.join().unwrap();
    assert_eq!(resp.error, Some(ServeError::DeadlineExceeded));
    let adm = stats.admission;
    assert!(adm.balanced(), "{adm:?}");
    assert_eq!(adm.expired, 1, "{adm:?}");
    assert_eq!(stats.requests, 0, "never executed");
}

/// Client-side retry: against a live, healthy server the first attempt
/// lands; against a full queue with no drain the attempts exhaust into
/// a typed `queue full` failure with the retries on the ledger.
#[test]
fn retry_exhausts_typed_on_queue_full_and_succeeds_live() {
    // Exhaustion: fill the depth-1 queue, never start the server.
    let b = Batcher::new(cfg(1, AdmissionPolicy::Block, None));
    let h = b.handle();
    let parked = h.try_submit(vec![9]).unwrap();
    let policy = RetryPolicy {
        attempts: 4,
        base: Duration::from_millis(1),
        max_backoff: Duration::from_millis(2),
        budget: Duration::from_secs(2),
        seed: 11,
    };
    let err = h.infer_with_retry(vec![1, 2], &policy).unwrap_err();
    assert!(format!("{err:#}").contains("queue full"), "typed root cause: {err:#}");
    assert_eq!(b.ledger().snapshot().retries, 3, "one per re-attempt");
    // Now drain: the parked request is still answered exactly once.
    drop(h);
    let stats = b.run(echo).unwrap();
    assert_eq!(parked.recv().unwrap().error, None);
    assert!(stats.admission.balanced(), "{:?}", stats.admission);
    assert_eq!(stats.admission.submitted, 1, "QueueFull is not a submission");

    // Live server: retry path degenerates to one clean attempt.
    let b = Batcher::new(cfg(16, AdmissionPolicy::Block, None));
    let h = b.handle();
    let t = std::thread::spawn(move || h.infer_with_retry(vec![2, 3, 4], &policy).unwrap());
    let stats = b.run(echo).unwrap();
    let resp = t.join().unwrap();
    assert_eq!(resp.logits, vec![9.0]);
    assert!(stats.admission.balanced(), "{:?}", stats.admission);
    assert_eq!(stats.admission.retries, 0);
}

/// Chaos-injected executor failures surface as typed `Exec` answers on
/// the affected batch only — the serve loop keeps going, and failed
/// requests still count as completed (answered) on the ledger.
#[test]
fn injected_executor_failures_answer_without_killing_the_loop() {
    let _chaos = ChaosSession::arm(3);
    let b = Batcher::new(cfg(16, AdmissionPolicy::Block, None));
    let h = b.handle();
    let t = std::thread::spawn(move || {
        let pending: Vec<_> = (0..24).map(|i| h.submit(vec![i + 1]).unwrap()).collect();
        drain(pending)
    });
    let stats = b.run(chaos::chaos_exec(echo)).unwrap();
    let d = t.join().unwrap();
    assert_eq!(d.responses(), 24, "every request answered: {d:?}");
    assert_eq!(d.lost + d.double_answered, 0, "{d:?}");
    assert_eq!(d.overloaded + d.deadline_exceeded, 0, "no shedding configured: {d:?}");
    let adm = stats.admission;
    assert!(adm.balanced(), "{adm:?}");
    assert_eq!(adm.completed, 24, "failed batches still answer: {adm:?}");
    // The chaos stream at seed 3 injects at least one failure across
    // 24 single-row batches at p=0.08 (deterministic: same seed, same
    // stream).
    if chaos::counts().exec_failures > 0 {
        assert!(d.exec_failed > 0, "injected failures must reach clients: {d:?}");
    }
}
