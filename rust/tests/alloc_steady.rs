//! Steady-state allocation gate for the flat spectral serve path.
//!
//! A counting global allocator wraps `System`; after a warmup that
//! grows the thread-local scratch arenas to steady-state capacity, the
//! serial flat core — `apply_batch_flat` through [`with_scratch`], the
//! exact code one shard of a serve tick runs — must perform **zero**
//! heap allocations per tick for every backend.  The sharded entry is
//! additionally checked to stay bounded: its only steady-state
//! allocations are the pool's per-shard task boxes and queue nodes, a
//! small constant per tick independent of how many ticks have run.
//!
//! One `#[test]` on purpose: the allocation counter is process-global,
//! so the measurement windows must not race other test threads.  The
//! verdict is written to `ALLOC_steady_state.json` (deliberately not a
//! `BENCH_*.json` — bench-check must not read it as a latency
//! baseline); CI's bench-smoke job uploads it with the bench
//! artifacts.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use ski_tnn::runtime::ThreadPool;
use ski_tnn::toeplitz::{
    apply_batch_flat_sharded, build_op, gaussian_kernel, with_scratch, BackendKind, ToeplitzKernel,
};
use ski_tnn::util::json::{self, Json};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_spectral_core_is_allocation_free() {
    let n = 1024usize;
    let rows = 4usize;
    let ticks = 10u64;
    let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 8.0));
    let causal = kernel.clone().causal();
    // Deterministic signal — no RNG state to allocate inside a window.
    let xs: Vec<f32> = (0..rows * n).map(|i| (i * 37 % 256) as f32 / 128.0 - 1.0).collect();
    let mut out = vec![0.0f32; rows * n];
    let mut report: Vec<Json> = Vec::new();

    // ---- serial flat core: strict zero after warmup ----
    for (kind, k) in [
        (BackendKind::Fft, &kernel),
        (BackendKind::Ski, &kernel),
        (BackendKind::Freq, &causal),
        (BackendKind::Dense, &kernel),
    ] {
        let op = build_op(k, kind, (n / 16).max(2), 9);
        // Warmup grows the arena's transform/gather buffers (and any
        // lazily registered telemetry handles) to their final size.
        for _ in 0..3 {
            with_scratch(|s| op.apply_batch_flat(&xs, rows, &mut out, s));
        }
        let before = allocs();
        for _ in 0..ticks {
            with_scratch(|s| op.apply_batch_flat(&xs, rows, &mut out, s));
        }
        let delta = allocs() - before;
        assert_eq!(
            delta,
            0,
            "{} backend allocated in steady state: {delta} allocs over {ticks} ticks",
            op.name()
        );
        report.push(Json::obj(vec![
            ("backend", Json::str(op.name())),
            ("abi", Json::str("serial_flat")),
            ("ticks", Json::num(ticks as f64)),
            ("allocs", Json::num(delta as f64)),
        ]));
    }

    // ---- sharded flat path: bounded, tick-count-independent ----
    // The pool's task boxes and queue nodes are the only steady-state
    // allocations; the per-row spectral work itself is covered by the
    // zero assertion above.
    let op = build_op(&kernel, BackendKind::Fft, (n / 16).max(2), 9);
    let pool = ThreadPool::new(2);
    for _ in 0..3 {
        apply_batch_flat_sharded(op.as_ref(), &xs, rows, &mut out, &pool);
    }
    let before = allocs();
    for _ in 0..ticks {
        apply_batch_flat_sharded(op.as_ref(), &xs, rows, &mut out, &pool);
    }
    let per_tick = (allocs() - before) as f64 / ticks as f64;
    assert!(per_tick <= 64.0, "sharded serve tick allocates too much: {per_tick} allocs/tick");
    report.push(Json::obj(vec![
        ("backend", Json::str("fft")),
        ("abi", Json::str("sharded_flat")),
        ("threads", Json::num(2.0)),
        ("ticks", Json::num(ticks as f64)),
        ("allocs_per_tick", Json::num(per_tick)),
    ]));

    let doc = Json::obj(vec![("alloc_gate", Json::arr(report))]);
    std::fs::write("ALLOC_steady_state.json", json::write(&doc)).expect("write alloc report");
}
