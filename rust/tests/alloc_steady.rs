//! Steady-state allocation gate for the serve path, in three tiers.
//!
//! A counting global allocator wraps `System`; after a warmup that
//! grows every arena and pool to steady-state capacity:
//!
//! 1. **Serial flat core** — `apply_batch_flat` through
//!    [`with_scratch`], the exact code one shard of a serve tick runs —
//!    must perform **zero** heap allocations per tick for every
//!    backend.
//! 2. **Batcher envelope (serial)** — the full substrate executor tick
//!    (`serve_toeplitz_on`: ids→signal packing, flat spectral apply,
//!    pooled response rows) must also be **zero** once the responses of
//!    the previous tick have been consumed: dropped `LogitsRow`s return
//!    their buffers to the executor's `RowPool`, so a warm tick draws
//!    everything from free lists.
//! 3. **Sharded flat path** — dispatches through the pool's recycled
//!    batch state (`ThreadPool::scope_fn`), so the old per-tick task
//!    boxes and queue nodes are gone; the only steady-state allocation
//!    left is the rare arena miss when a worker still holds the
//!    previous tick's batch handle, a small constant far below the
//!    64/tick bound the task-box design needed.
//!
//! One `#[test]` on purpose: the allocation counter is process-global,
//! so the measurement windows must not race other test threads.  The
//! per-tier verdicts are written to `ALLOC_steady_state.json`
//! (deliberately not a `BENCH_*.json` — bench-check must not read it as
//! a latency baseline); CI's bench-smoke job runs this gate as its own
//! named step and echoes the counts into the job summary.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use ski_tnn::runtime::{HostTensor, ThreadPool};
use ski_tnn::server::serve_toeplitz_on;
use ski_tnn::toeplitz::{
    apply_batch_flat_sharded, build_op, gaussian_kernel, with_scratch, BackendKind, ToeplitzKernel,
    ToeplitzOp,
};
use ski_tnn::util::json::{self, Json};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Sharded bound: strictly below the 64/tick the PR 7 task-box design
/// needed.  The recycled batch state leaves only the occasional arena
/// miss (a worker still holding the previous tick's `Arc`), so a small
/// single-digit budget holds with headroom.
const SHARDED_ALLOCS_PER_TICK: f64 = 8.0;

#[test]
fn steady_state_spectral_core_is_allocation_free() {
    let n = 1024usize;
    let rows = 4usize;
    let ticks = 10u64;
    let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 8.0));
    let causal = kernel.clone().causal();
    // Deterministic signal — no RNG state to allocate inside a window.
    let xs: Vec<f32> = (0..rows * n).map(|i| (i * 37 % 256) as f32 / 128.0 - 1.0).collect();
    let mut out = vec![0.0f32; rows * n];
    let mut report: Vec<Json> = Vec::new();

    // ---- tier 1 · serial flat core: strict zero after warmup ----
    for (kind, k) in [
        (BackendKind::Fft, &kernel),
        (BackendKind::Ski, &kernel),
        (BackendKind::Freq, &causal),
        (BackendKind::Dense, &kernel),
    ] {
        let op = build_op(k, kind, (n / 16).max(2), 9);
        // Warmup grows the arena's transform/gather buffers (and any
        // lazily registered telemetry handles) to their final size.
        for _ in 0..3 {
            with_scratch(|s| op.apply_batch_flat(&xs, rows, &mut out, s));
        }
        let before = allocs();
        for _ in 0..ticks {
            with_scratch(|s| op.apply_batch_flat(&xs, rows, &mut out, s));
        }
        let delta = allocs() - before;
        assert_eq!(
            delta,
            0,
            "{} backend allocated in steady state: {delta} allocs over {ticks} ticks",
            op.name()
        );
        report.push(Json::obj(vec![
            ("backend", Json::str(op.name())),
            ("abi", Json::str("serial_flat")),
            ("ticks", Json::num(ticks as f64)),
            ("allocs", Json::num(delta as f64)),
        ]));
    }

    // ---- tier 2 · full batcher envelope (serial): strict zero ----
    // The executor tick a single-width serve loop runs: pack ids into
    // the recycled flat signal buffer, flat spectral apply, pooled
    // response rows.  Dropping the previous tick's `RowBatch` stands in
    // for the clients consuming (and thereby returning) their
    // responses.
    {
        let op: Arc<dyn ToeplitzOp> =
            Arc::from(build_op(&kernel, BackendKind::Fft, (n / 16).max(2), 9));
        let mut exec = serve_toeplitz_on(op, Arc::new(ThreadPool::new(1)));
        let ids: Vec<i32> = (0..rows * n).map(|i| (i % 256) as i32).collect();
        let batch = HostTensor::i32(vec![rows, n], ids);
        for _ in 0..3 {
            let resp = exec(&batch).expect("warmup tick");
            drop(resp); // rows return to the executor's pool
        }
        let before = allocs();
        for _ in 0..ticks {
            let resp = exec(&batch).expect("steady tick");
            drop(resp);
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "batcher envelope allocated in steady state: {delta} allocs over {ticks} ticks"
        );
        report.push(Json::obj(vec![
            ("backend", Json::str("fft")),
            ("abi", Json::str("batcher_envelope")),
            ("ticks", Json::num(ticks as f64)),
            ("allocs", Json::num(delta as f64)),
        ]));
    }

    // ---- tier 3 · sharded flat path: bounded, tick-count-independent ----
    // scope_fn recycles the pool's batch state, so the per-tick task
    // boxes and queue nodes of the old design are gone; what remains is
    // the occasional arena miss, far below the old 64/tick budget.
    let op = build_op(&kernel, BackendKind::Fft, (n / 16).max(2), 9);
    let pool = ThreadPool::new(2);
    for _ in 0..3 {
        apply_batch_flat_sharded(op.as_ref(), &xs, rows, &mut out, &pool);
    }
    let before = allocs();
    for _ in 0..ticks {
        apply_batch_flat_sharded(op.as_ref(), &xs, rows, &mut out, &pool);
    }
    let per_tick = (allocs() - before) as f64 / ticks as f64;
    assert!(
        per_tick <= SHARDED_ALLOCS_PER_TICK,
        "sharded serve tick allocates too much: {per_tick} allocs/tick (budget {SHARDED_ALLOCS_PER_TICK})"
    );
    report.push(Json::obj(vec![
        ("backend", Json::str("fft")),
        ("abi", Json::str("sharded_flat")),
        ("threads", Json::num(2.0)),
        ("ticks", Json::num(ticks as f64)),
        ("allocs_per_tick", Json::num(per_tick)),
        ("budget_per_tick", Json::num(SHARDED_ALLOCS_PER_TICK)),
    ]));

    let doc = Json::obj(vec![("alloc_gate", Json::arr(report))]);
    std::fs::write("ALLOC_steady_state.json", json::write(&doc)).expect("write alloc report");
}
