//! Exact-arithmetic causal-Toeplitz → diagonal-SSM conversion.
//!
//! A causal Toeplitz operator `y_t = Σ_{τ≤t} k[τ] x_{t-τ}` whose taps
//! are (approximately) a mixture of geometric modes
//! `k[τ] ≈ Σ_i w_i λ_i^{τ-1}` (τ ≥ 1) is exactly the diagonal linear
//! recurrence
//!
//! ```text
//!   h_t = Λ h_{t-1} + 1·x_{t-1}       (Λ = diag(λ_1..λ_m))
//!   y_t = k[0]·x_t + wᵀ h_t
//! ```
//!
//! which decodes one token in O(m) — constant in sequence position —
//! instead of recomputing an O(n log n) FFT over the whole prefix
//! (Qin & Zhong 2023, "Accelerating Toeplitz Neural Network with
//! Constant-time Inference Complexity"; see PAPERS.md).
//!
//! The TNN's learned RPE kernels decay super-polynomially (paper
//! §4.2 / Theorems 2–4), so a small fixed dictionary of decay modes
//! fits them tightly.  Here the poles are a log-spaced grid of decay
//! rates (both signs, so sign-oscillating kernels fit too) and the
//! weights `w` solve the least-squares problem over the kernel's lags
//! via the [`crate::linalg`] SVD pseudo-inverse.  The achieved
//! ℓ₁ residual is recorded: it is a *sound per-token error bound*
//! (`|ŷ_t − y_t| ≤ ‖k − k̂‖₁ · max|x|`), which the decode property
//! tests assert token-for-token against the dense causal oracle.

use crate::linalg::{pinv, Mat};

/// A fitted rank-`m` diagonal state-space recurrence for one causal
/// Toeplitz kernel.
#[derive(Debug, Clone)]
pub struct DiagonalSsm {
    /// State size (number of poles).
    pub m: usize,
    /// Diagonal of Λ, each in (-1, 1).
    pub lambda: Vec<f32>,
    /// Combined output weights (`C·B` folded into one vector).
    pub w: Vec<f32>,
    /// Direct feedthrough — the lag-0 tap.
    pub k0: f32,
    /// ℓ₁ fit residual `Σ_τ |k[τ] − k̂[τ]|` over the fitted lags —
    /// a per-token output error bound per unit of `max|x|` for streams
    /// up to the fitted kernel length.  Past that horizon the
    /// recurrence keeps extrapolating the fitted geometric tail
    /// (graceful long-memory behaviour) where the dense operator would
    /// truncate; the two are then different-by-design, not "in error".
    pub l1_residual: f64,
    /// Number of lags the fit covered (kernel length − 1).
    pub lags: usize,
}

/// Log-spaced pole dictionary: `ceil(m/2)` positive decay modes
/// `exp(-γ)` with γ log-spaced between `1/horizon` (a mode that still
/// remembers the whole window) and `3` (a ~3-tap mode), plus
/// `floor(m/2)` mirrored negative poles for sign-oscillating kernels.
pub fn pole_grid(m: usize, horizon: usize) -> Vec<f64> {
    assert!(m >= 1, "SSM needs at least one pole");
    let pos = m - m / 2;
    let neg = m / 2;
    let gmin: f64 = (1.0 / horizon.max(2) as f64).min(0.5);
    let gmax: f64 = 3.0;
    let rate = |j: usize, count: usize| -> f64 {
        if count <= 1 {
            gmin
        } else {
            (gmin.ln() + (gmax.ln() - gmin.ln()) * j as f64 / (count - 1) as f64).exp()
        }
    };
    let mut poles: Vec<f64> = (0..pos).map(|j| (-rate(j, pos)).exp()).collect();
    poles.extend((0..neg).map(|j| -(-rate(j, neg)).exp()));
    poles
}

impl DiagonalSsm {
    /// Least-squares fit of a rank-`m` recurrence to causal taps
    /// (`taps[τ] = k[τ]`, `taps[0]` becomes the feedthrough).
    pub fn fit(taps: &[f32], m: usize) -> DiagonalSsm {
        assert!(!taps.is_empty(), "fit needs at least the lag-0 tap");
        assert!(m >= 1, "fit needs rank >= 1");
        let l = taps.len() - 1;
        if l == 0 {
            // Pure feedthrough: no recurrent part at all.
            return DiagonalSsm {
                m,
                lambda: vec![0.0; m],
                w: vec![0.0; m],
                k0: taps[0],
                l1_residual: 0.0,
                lags: 0,
            };
        }
        let poles = pole_grid(m, l);
        let k: Vec<f64> = taps[1..].iter().map(|&x| x as f64).collect();
        // Ridge-regularised least squares via the augmented system
        // [V; αI] w = [k; 0].  The pole dictionary is Vandermonde-like
        // and can be numerically rank-deficient; the ridge keeps ‖w‖
        // bounded so the f32 streaming recurrence stays well
        // conditioned (bias on the fit is O(α) ≪ the ℓ₁ residual we
        // report).
        let alpha = 1e-4 * k.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        let mut a = Mat::zeros(l + m, m);
        for i in 0..m {
            let mut p = 1.0f64;
            for t in 0..l {
                // Design row t is lag τ = t+1: V[t][i] = λ_i^t.
                a[(t, i)] = p;
                p *= poles[i];
            }
            a[(l + i, i)] = alpha;
        }
        let mut b = k.clone();
        b.extend(std::iter::repeat(0.0).take(m));
        let w = pinv(&a).matvec(&b);
        let mut v = Mat::zeros(l, m);
        for i in 0..m {
            let mut p = 1.0f64;
            for t in 0..l {
                v[(t, i)] = p;
                p *= poles[i];
            }
        }
        let khat = v.matvec(&w);
        let l1_residual: f64 = k.iter().zip(khat.iter()).map(|(a, b)| (a - b).abs()).sum();
        DiagonalSsm {
            m,
            lambda: poles.iter().map(|&p| p as f32).collect(),
            w: w.iter().map(|&x| x as f32).collect(),
            k0: taps[0],
            l1_residual,
            lags: l,
        }
    }

    /// Fresh (zero) recurrent state.
    pub fn init_state(&self) -> Vec<f32> {
        vec![0.0; self.m]
    }

    /// One decode step: emit `y_t` for input `x_t`, then absorb `x_t`
    /// into the state.  O(m), independent of sequence position.
    pub fn step(&self, h: &mut [f32], x: f32) -> f32 {
        debug_assert_eq!(h.len(), self.m);
        let mut y = self.k0 * x;
        for (hi, wi) in h.iter().zip(self.w.iter()) {
            y += wi * hi;
        }
        for (hi, li) in h.iter_mut().zip(self.lambda.iter()) {
            *hi = li * *hi + x;
        }
        y
    }

    /// The taps the fitted recurrence actually realises (for
    /// diagnostics / tests): `k̂[0] = k0`, `k̂[τ] = Σ_i w_i λ_i^{τ-1}`.
    pub fn realized_taps(&self, len: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(len);
        if len == 0 {
            return out;
        }
        out.push(self.k0);
        let mut pows: Vec<f64> = vec![1.0; self.m];
        for _ in 1..len {
            let mut acc = 0.0f64;
            for (p, &wi) in pows.iter_mut().zip(self.w.iter()) {
                acc += wi as f64 * *p;
            }
            out.push(acc as f32);
            for (p, &li) in pows.iter_mut().zip(self.lambda.iter()) {
                *p *= li as f64;
            }
        }
        // The loop above pushes k̂[τ] then advances the powers, so the
        // accumulated value at iteration τ uses λ^{τ-1} as required.
        out
    }

    /// Relative ℓ₁ residual (residual / ‖k[1..]‖₁), `0.0` when the
    /// kernel tail is all zero.
    pub fn rel_l1_residual(&self, taps: &[f32]) -> f64 {
        let norm: f64 = taps.iter().skip(1).map(|&x| (x as f64).abs()).sum();
        if norm <= 0.0 {
            0.0
        } else {
            self.l1_residual / norm
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, size, vecf};

    /// Dense causal-convolution oracle over taps.
    fn oracle(taps: &[f32], xs: &[f32]) -> Vec<f32> {
        (0..xs.len())
            .map(|t| {
                (0..=t)
                    .filter(|&j| t - j < taps.len())
                    .map(|j| taps[t - j] * xs[j])
                    .sum()
            })
            .collect()
    }

    #[test]
    fn pole_grid_shape() {
        for m in [1usize, 2, 3, 8, 17] {
            let g = pole_grid(m, 256);
            assert_eq!(g.len(), m);
            assert!(g.iter().all(|p| p.abs() < 1.0 && p.abs() > 0.0));
            let pos = g.iter().filter(|&&p| p > 0.0).count();
            assert_eq!(pos, m - m / 2);
        }
    }

    #[test]
    fn exact_on_in_dictionary_kernels() {
        // Taps built from the fit's own pole dictionary must be
        // recovered (least squares with the true basis included).
        check("ssm exact on dictionary mixtures", |rng| {
            let l = size(rng, 8, 256);
            let m = 2 * size(rng, 1, 4);
            let poles = pole_grid(m, l);
            let weights: Vec<f64> = (0..m).map(|_| rng.normal() as f64).collect();
            let mut taps = vec![rng.normal()];
            for t in 0..l {
                let v: f64 = poles
                    .iter()
                    .zip(weights.iter())
                    .map(|(&p, &w)| w * p.powi(t as i32))
                    .sum();
                taps.push(v as f32);
            }
            let ssm = DiagonalSsm::fit(&taps, m);
            assert!(
                ssm.l1_residual < 1e-3 * (l as f64).max(1.0),
                "residual {} too large for in-dictionary kernel (m={m}, l={l})",
                ssm.l1_residual
            );
        });
    }

    #[test]
    fn realized_taps_match_step_impulse() {
        // Feeding an impulse through step() must reproduce
        // realized_taps — the recurrence and the closed form agree.
        check("ssm impulse response == realized taps", |rng| {
            let l = size(rng, 2, 64);
            let taps = vecf(rng, l + 1);
            let ssm = DiagonalSsm::fit(&taps, 8.min(l));
            let mut h = ssm.init_state();
            let want = ssm.realized_taps(l + 1);
            let mut got = vec![ssm.step(&mut h, 1.0)];
            for _ in 1..=l {
                got.push(ssm.step(&mut h, 0.0));
            }
            let w_l1: f64 = ssm.w.iter().map(|&v| (v as f64).abs()).sum();
            let tol = (1e-4 + 1e-6 * w_l1) as f32;
            for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    (a - b).abs() < tol * (1.0 + b.abs()),
                    "tap {i}: step {a} vs closed form {b} (tol {tol})"
                );
            }
        });
    }

    #[test]
    fn decode_error_bounded_by_residual() {
        // The ℓ₁ residual is a sound per-token bound on arbitrary
        // (even adversarial) kernels — the recurrence computes exact
        // convolution with k̂, and |(k−k̂)∗x|_∞ ≤ ‖k−k̂‖₁·‖x‖_∞.
        check("ssm decode error ≤ l1 residual bound", |rng| {
            let l = size(rng, 4, 128);
            let taps = vecf(rng, l + 1);
            let m = size(rng, 2, 16);
            let ssm = DiagonalSsm::fit(&taps, m);
            let xs = vecf(rng, l + 1);
            let xmax = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs())) as f64;
            let want = oracle(&taps, &xs);
            let mut h = ssm.init_state();
            // Roundoff slack scales with ‖w‖₁: the f32 recurrence's
            // arithmetic error is O(‖w‖₁·max|h|·ε).
            let w_l1: f64 = ssm.w.iter().map(|&v| (v as f64).abs()).sum();
            let slack = (1e-3 + 1e-5 * w_l1) * (1.0 + xmax);
            for (t, (&x, &want_t)) in xs.iter().zip(want.iter()).enumerate() {
                let y = ssm.step(&mut h, x);
                let bound = ssm.l1_residual * xmax + slack;
                assert!(
                    ((y - want_t) as f64).abs() <= bound,
                    "t={t}: |{y} - {want_t}| > bound {bound} (m={m}, l={l})"
                );
            }
        });
    }

    #[test]
    fn residual_shrinks_with_rank() {
        // Smooth decaying kernel: higher rank ⇒ tighter fit (the
        // "tolerance tied to fitted rank m" contract).
        let l = 256;
        let taps: Vec<f32> = (0..=l)
            .map(|t| crate::toeplitz::gaussian_kernel(t as f64, 24.0))
            .collect();
        let errs: Vec<f64> = [2usize, 4, 8, 16, 32]
            .iter()
            .map(|&m| DiagonalSsm::fit(&taps, m).l1_residual)
            .collect();
        for w in errs.windows(2) {
            // Pole grids at different ranks are not nested, so allow a
            // small non-monotonic blip; the trend must still be down.
            assert!(w[1] <= w[0] * 1.25, "residual not shrinking: {errs:?}");
        }
        assert!(
            errs.last().unwrap() < &(errs[0] * 0.2 + 1e-9),
            "rank-32 fit should beat rank-2 clearly: {errs:?}"
        );
    }

    #[test]
    fn pure_feedthrough_kernel() {
        let ssm = DiagonalSsm::fit(&[2.5], 4);
        let mut h = ssm.init_state();
        assert_eq!(ssm.step(&mut h, 2.0), 5.0);
        assert_eq!(ssm.step(&mut h, -1.0), -2.5);
        assert_eq!(ssm.l1_residual, 0.0);
    }
}
