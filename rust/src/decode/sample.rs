//! Token sampling for autoregressive generation.
//!
//! Greedy argmax, temperature softmax, and top-k truncation, driven by
//! the deterministic [`crate::util::rng::Rng`] so generation is
//! reproducible per session seed (and stable across machines — no
//! platform RNG anywhere).

use crate::util::rng::Rng;

/// Sampling configuration + per-session RNG stream.
#[derive(Debug, Clone)]
pub struct Sampler {
    /// 0 (or negative) = greedy argmax.
    pub temperature: f32,
    /// 0 = no truncation; otherwise sample among the k highest logits.
    pub top_k: usize,
    rng: Rng,
}

impl Sampler {
    pub fn new(temperature: f32, top_k: usize, seed: u64) -> Sampler {
        Sampler { temperature, top_k, rng: Rng::new(seed) }
    }

    /// Deterministic argmax (first index on ties).
    pub fn greedy() -> Sampler {
        Sampler::new(0.0, 0, 0)
    }

    /// Pick a token id from a logits row.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        assert!(!logits.is_empty(), "sampling from empty logits");
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        // Top-k: indices of the k largest logits (all when top_k = 0).
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
            idx.truncate(self.top_k);
        }
        // Stable softmax over the kept set at this temperature.
        let inv_t = 1.0 / self.temperature;
        let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> =
            idx.iter().map(|&i| (((logits[i] - max) * inv_t) as f64).exp()).collect();
        idx[self.rng.weighted(&weights)]
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, size, vecf};

    #[test]
    fn greedy_picks_max() {
        check("greedy == argmax", |rng| {
            let n = size(rng, 2, 300);
            let logits = vecf(rng, n);
            let mut s = Sampler::greedy();
            let got = s.sample(&logits);
            for &v in &logits {
                assert!(logits[got] >= v);
            }
        });
    }

    #[test]
    fn top_k_one_is_greedy() {
        check("top_k=1 == greedy", |rng| {
            let n = size(rng, 2, 64);
            let logits = vecf(rng, n);
            let mut s = Sampler::new(0.8, 1, rng.next_u64());
            let mut g = Sampler::greedy();
            assert_eq!(s.sample(&logits), g.sample(&logits));
        });
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let logits: Vec<f32> = (0..50).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut a = Sampler::new(1.0, 10, 42);
        let mut b = Sampler::new(1.0, 10, 42);
        for _ in 0..100 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }

    #[test]
    fn temperature_prefers_heavy_logits() {
        let logits = vec![0.0f32, 4.0, 0.0, 0.0];
        let mut s = Sampler::new(1.0, 0, 9);
        let mut hits = 0;
        for _ in 0..500 {
            if s.sample(&logits) == 1 {
                hits += 1;
            }
        }
        // P(idx 1) = e⁴/(e⁴+3) ≈ 0.948.
        assert!(hits > 430, "heavy logit sampled only {hits}/500");
    }

    #[test]
    fn top_k_excludes_tail() {
        let logits = vec![5.0f32, 4.0, -100.0, -100.0];
        let mut s = Sampler::new(2.0, 2, 3);
        for _ in 0..200 {
            assert!(s.sample(&logits) < 2, "top-2 must exclude the tail");
        }
    }
}
