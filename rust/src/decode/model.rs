//! A pure-Rust streaming TNN language model.
//!
//! The byte-level analysis twin of the AOT-compiled model: GTU-style
//! blocks — per-channel *causal Toeplitz* token mixing, a sigmoid
//! channel gate, a dense channel mix, residual — over the shared
//! 256-byte + specials vocabulary (`data::VOCAB`).  Two execution
//! modes compute the same function:
//!
//! * [`DecodeModel::step`] — streaming: each kernel runs through its
//!   planned [`KernelDecoder`] (SSM or window), so one token costs
//!   O(blocks·d·m + blocks·d²) **independent of position** — no
//!   prefix recompute, no KV-cache analogue growing with context.
//! * [`DecodeModel::forward_full`] — the full-context oracle: the same
//!   blocks evaluated by dense causal convolution over the whole
//!   prefix, used by the equivalence tests and as the "recompute per
//!   token" baseline the decode bench compares against.
//!
//! Weights are seeded-random (this repo trains through the AOT path;
//! the decode subsystem is about *serving mechanics*), but the layout
//! mirrors the paper model so a converter from trained checkpoints
//! only has to fill the same arrays.

use std::sync::Arc;

use crate::data::VOCAB;
use crate::plan::{ExecutionPlan, PlanCache, ShapeKey};
use crate::runtime::pool::{global_pool, Task, ThreadPool};
use crate::toeplitz::{
    apply_causal_plan_into, apply_causal_taps, with_scratch, BackendKind, CostModel, OpScratch,
    SpectralPlan, ToeplitzKernel,
};
use crate::util::rng::Rng;

use super::{DecodeError, DecodePolicy, DecoderState, KernelDecoder};

/// Hyper-parameters of a streaming decode model.
#[derive(Debug, Clone, Copy)]
pub struct DecodeModelConfig {
    pub vocab: usize,
    /// Channel width.
    pub d: usize,
    /// Number of GTU blocks.
    pub blocks: usize,
    /// Kernel length = model context window.
    pub n: usize,
    /// Per-kernel streaming plan policy.
    pub policy: DecodePolicy,
    /// Backend for the full-context oracle's per-channel causal
    /// convolution (`Auto` = cost-model dispatch: dense below the
    /// crossover, spectral above).
    pub oracle_backend: BackendKind,
    /// Worker threads the oracle shards channels across: `0` = the
    /// process-global pool (`SKI_TNN_THREADS` / machine parallelism),
    /// `1` = serial, `N` = a model-owned pool of N.  Output is bitwise
    /// identical for every value.
    pub threads: usize,
    pub seed: u64,
}

impl Default for DecodeModelConfig {
    fn default() -> Self {
        DecodeModelConfig {
            vocab: VOCAB,
            d: 32,
            blocks: 2,
            n: 512,
            policy: DecodePolicy::default(),
            oracle_backend: BackendKind::Auto,
            threads: 0,
            seed: 0,
        }
    }
}

/// One GTU block: d causal kernels + gate/mix projections.
struct Block {
    /// Original causal taps per channel (oracle + re-planning).
    taps: Vec<Vec<f32>>,
    decoders: Vec<KernelDecoder>,
    /// (d, d) row-major gate projection.
    gate: Vec<f32>,
    /// (d, d) row-major channel mix.
    mix: Vec<f32>,
}

/// The model: embedding, blocks, output projection.
pub struct DecodeModel {
    pub cfg: DecodeModelConfig,
    /// (vocab, d) row-major.
    embed: Vec<f32>,
    blocks: Vec<Block>,
    /// (d, vocab) row-major.
    out_w: Vec<f32>,
    /// Per-channel spectral oracle plans, held in the unified
    /// execution-plan cache keyed by `(shape, kernel_id)` — every
    /// channel shares the context-length dispatch shape, so the
    /// `kernel_id` discriminator (block·d + channel + 1) is what keeps
    /// their distinct spectra apart.  Each resident plan's kernel
    /// spectrum is cached once at the native context length (the plan
    /// picks its own smooth transform size), so full-context forwards
    /// never re-FFT the (fixed) taps.  Spectra are lock-free
    /// [`SpectralPlan`]s — transform scratch lives in the shard
    /// runtime's per-worker arenas ([`with_scratch`]), not here.
    plans: PlanCache,
    /// Whether the configured oracle backend can ever take the cached
    /// spectral path: decided (and the plans pre-built) at
    /// construction — see [`spectral_oracle_possible`].
    spectral_planned: bool,
    /// Oracle shard pool when `cfg.threads >= 1`, spawned lazily on
    /// the first `forward_full` — streaming-only workloads (`generate`
    /// serving) never pay for idle workers.  Empty = the
    /// process-global pool.
    pool: std::sync::OnceLock<ThreadPool>,
}

/// Per-session recurrent state: one [`DecoderState`] per block/channel.
#[derive(Clone)]
pub struct StreamState {
    blocks: Vec<Vec<DecoderState>>,
}

impl StreamState {
    /// Total f32s held — the whole per-session memory footprint.
    pub fn size(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| b.iter())
            .map(|s| match s {
                DecoderState::Ssm(h) => h.len(),
                DecoderState::Window { buf, .. } => buf.len() + 1,
            })
            .sum()
    }

    /// Deliberately corrupt the state by flipping every decoder-state
    /// variant — the regression hook for the serve path's
    /// one-session-fails-not-the-process guarantee (a real corruption
    /// would come from a bug or bad deserialization; tests need a
    /// deterministic way to produce one).
    #[doc(hidden)]
    pub fn poison(&mut self) {
        for states in self.blocks.iter_mut() {
            for s in states.iter_mut() {
                *s = match s {
                    DecoderState::Ssm(h) => {
                        DecoderState::Window { buf: vec![0.0; h.len().max(1)], pos: 0 }
                    }
                    DecoderState::Window { buf, .. } => DecoderState::Ssm(vec![0.0; buf.len()]),
                };
            }
        }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Whether the full-context oracle can ever take the cached spectral
/// path under this config: forced spectral backends always, `Auto`
/// only when the FFT cost at the context length (priced at the plan's
/// own smooth transform length — no power-of-two padding any more)
/// beats the dense loop at its largest (t_len = n) — the gate for
/// building the per-channel plans at all.
fn spectral_oracle_possible(cfg: &DecodeModelConfig) -> bool {
    match cfg.oracle_backend {
        BackendKind::Dense | BackendKind::Ski => false,
        BackendKind::Fft | BackendKind::Freq => true,
        BackendKind::Auto => {
            let cost = CostModel::default();
            cost.fft_cost(cfg.n) < cost.dense_cost(cfg.n)
        }
    }
}

/// y = M x for row-major (d, d) M.
fn matvec(m: &[f32], x: &[f32], d: usize) -> Vec<f32> {
    (0..d).map(|i| (0..d).map(|j| m[i * d + j] * x[j]).sum()).collect()
}

/// Per-channel causal token-mix columns of the full-context oracle,
/// packed row-major into one flat `(d, t_len)` buffer:
/// `cols[c * t_len + t]` = channel `c`'s convolution output at
/// position `t`.  `plans` carries the per-channel spectra resolved
/// from the model's [`PlanCache`] (`None` = the dense loop).  Channels
/// are independent, so they shard across `pool` (the model's own when
/// `cfg.threads >= 1`, else the process-global one) as
/// **channel-aligned ranges** of the flat buffer — spectral applies
/// run through each worker's own scratch arena ([`with_scratch`]) and
/// write straight into their slice, so a warm spectral forward
/// allocates only this one buffer.  Short prefixes stay serial (the
/// per-shard dispatch overhead would dominate).  Either way every
/// channel runs exactly the same code, so the result is bitwise
/// identical for any worker count.
fn oracle_cols(
    block: &Block,
    plans: Option<&[Arc<SpectralPlan>]>,
    xs: &[Vec<f32>],
    pool: &ThreadPool,
) -> Vec<f32> {
    let d = block.taps.len();
    let t_len = xs.len();
    let mut cols = vec![0.0f32; d * t_len];
    if t_len == 0 {
        return cols;
    }
    // Gather channel `c`'s time series into the arena's row buffer and
    // convolve it straight into its column slice (`mem::take` lets the
    // spectral plan borrow the rest of the scratch).
    let col_into = |c: usize, out: &mut [f32], s: &mut OpScratch| {
        let mut series = std::mem::take(&mut s.row);
        series.clear();
        series.extend(xs.iter().map(|row| row[c]));
        if let Some(plans) = plans {
            apply_causal_plan_into(&plans[c], &series, out, s);
        } else {
            out.copy_from_slice(&apply_causal_taps(&block.taps[c], &series, BackendKind::Dense));
        }
        s.row = series;
    };
    let shards = pool.threads().min(d);
    if shards <= 1 || t_len < 32 {
        with_scratch(|s| {
            for (c, out) in cols.chunks_mut(t_len).enumerate() {
                col_into(c, out, s);
            }
        });
        return cols;
    }
    let chunk = d.div_ceil(shards);
    let tasks: Vec<Task> = cols
        .chunks_mut(chunk * t_len)
        .enumerate()
        .map(|(s_idx, shard)| {
            let start = s_idx * chunk;
            let col_into = &col_into;
            let task: Task = Box::new(move || {
                with_scratch(|s| {
                    for (j, out) in shard.chunks_mut(t_len).enumerate() {
                        col_into(start + j, out, s);
                    }
                })
            });
            task
        })
        .collect();
    pool.scope(tasks);
    cols
}

impl DecodeModel {
    /// Seeded-random init: decaying causal kernels (ℓ₁-normalised so
    /// every Toeplitz operator has gain ≤ 1), 1/√d projections.
    pub fn new(cfg: DecodeModelConfig) -> DecodeModel {
        assert!(cfg.d >= 1 && cfg.blocks >= 1 && cfg.n >= 2 && cfg.vocab >= 2);
        let mut rng = Rng::new(cfg.seed ^ 0xDEC0DE);
        let scale = 1.0 / (cfg.d as f32).sqrt();
        let embed: Vec<f32> = (0..cfg.vocab * cfg.d).map(|_| 0.5 * rng.normal()).collect();
        let out_w: Vec<f32> = (0..cfg.d * cfg.vocab).map(|_| scale * rng.normal()).collect();
        let blocks = (0..cfg.blocks)
            .map(|_| {
                let taps: Vec<Vec<f32>> = (0..cfg.d)
                    .map(|_| {
                        // Smoothed decaying taps — the regime the
                        // paper's decay bias enforces (§4.2), which is
                        // also where the SSM fit is tight.
                        let lam = 0.90 + 0.095 * rng.f32();
                        let mut prev = 0.0f32;
                        let mut t: Vec<f32> = (0..cfg.n)
                            .map(|i| {
                                // AR(1)-correlated noise under a λ^t envelope.
                                prev = 0.7 * prev + 0.3 * rng.normal();
                                prev * lam.powi(i as i32)
                            })
                            .collect();
                        let l1: f32 = t.iter().map(|v| v.abs()).sum();
                        if l1 > 0.0 {
                            for v in t.iter_mut() {
                                *v /= l1;
                            }
                        }
                        t
                    })
                    .collect();
                let decoders =
                    taps.iter().map(|t| KernelDecoder::plan_taps(t, cfg.policy)).collect();
                Block {
                    taps,
                    decoders,
                    gate: (0..cfg.d * cfg.d).map(|_| scale * rng.normal()).collect(),
                    mix: (0..cfg.d * cfg.d).map(|_| scale * rng.normal()).collect(),
                }
            })
            .collect();
        let model = DecodeModel {
            cfg,
            embed,
            blocks,
            out_w,
            plans: PlanCache::new((cfg.blocks * cfg.d).max(1)),
            spectral_planned: spectral_oracle_possible(&cfg),
            pool: std::sync::OnceLock::new(),
        };
        // Spectral oracle plans only when the configured backend can
        // ever reach them — a dense-forced or below-crossover model
        // skips blocks·d kernel FFTs and their spectrum buffers
        // entirely.  Plans are built at the native context length: the
        // plan itself picks the cheapest smooth transform size, so a
        // non-pow2 context no longer pads up to the next power of two.
        if model.spectral_planned {
            for b in 0..model.cfg.blocks {
                let _ = model.block_plans(b);
            }
        }
        model
    }

    /// The cache key for one channel's oracle plan: every channel
    /// shares the context-length dispatch shape, so the `kernel_id`
    /// discriminator is what keeps distinct spectra apart.
    fn plan_key(&self, block: usize, channel: usize) -> ShapeKey {
        ShapeKey {
            n: self.cfg.n,
            r: 0,
            w: 0,
            causal: true,
            threads: 1,
            batch_hint: 1,
            kernel_id: (block * self.cfg.d + channel) as u64 + 1,
        }
    }

    /// Resolve one block's per-channel spectra through the plan cache
    /// (building any evicted/missing ones from the stored taps).
    fn block_plans(&self, block: usize) -> Vec<Arc<SpectralPlan>> {
        (0..self.cfg.d)
            .map(|c| {
                let key = self.plan_key(block, c);
                let plan = self.plans.get_or_build(key, || {
                    let taps = &self.blocks[block].taps[c];
                    let spec = SpectralPlan::new(&ToeplitzKernel::from_causal_taps(taps));
                    ExecutionPlan::from_spectral(key, spec)
                });
                Arc::clone(plan.spectral().expect("from_spectral plans carry a spectrum"))
            })
            .collect()
    }

    /// The pool `forward_full` shards channels across (see
    /// `DecodeModelConfig::threads`).
    fn oracle_pool(&self) -> &ThreadPool {
        if self.cfg.threads >= 1 {
            self.pool.get_or_init(|| ThreadPool::new(self.cfg.threads))
        } else {
            global_pool()
        }
    }

    /// Fresh per-session state (all zeros — position 0).
    pub fn init_state(&self) -> StreamState {
        StreamState {
            blocks: self
                .blocks
                .iter()
                .map(|b| b.decoders.iter().map(KernelDecoder::init_state).collect())
                .collect(),
        }
    }

    /// One streaming step: consume `token`, return next-token logits.
    /// O(1) in sequence position.  A corrupted session state surfaces
    /// as a typed [`DecodeError`] instead of a panic, so the serving
    /// loop can fail one session without taking the process down.
    pub fn step(&self, state: &mut StreamState, token: i32) -> Result<Vec<f32>, DecodeError> {
        let d = self.cfg.d;
        let tok = (token.max(0) as usize).min(self.cfg.vocab - 1);
        let mut x: Vec<f32> = self.embed[tok * d..(tok + 1) * d].to_vec();
        for (block, states) in self.blocks.iter().zip(state.blocks.iter_mut()) {
            if states.len() != block.decoders.len() {
                return Err(DecodeError::StateMismatch { decoder: "planned", state: "missing" });
            }
            let mut u = Vec::with_capacity(d);
            for (c, (dec, st)) in block.decoders.iter().zip(states.iter_mut()).enumerate() {
                u.push(dec.step(st, x[c])?);
            }
            let g = matvec(&block.gate, &x, d);
            let v: Vec<f32> = u.iter().zip(g.iter()).map(|(&ui, &gi)| ui * sigmoid(gi)).collect();
            let h = matvec(&block.mix, &v, d);
            for c in 0..d {
                x[c] += h[c].tanh();
            }
        }
        let mut logits = vec![0.0f32; self.cfg.vocab];
        for c in 0..d {
            let xc = x[c];
            let row = &self.out_w[c * self.cfg.vocab..(c + 1) * self.cfg.vocab];
            for (l, &w) in logits.iter_mut().zip(row.iter()) {
                *l += xc * w;
            }
        }
        Ok(logits)
    }

    /// Full-context oracle: logits at every position, computed by
    /// dense causal convolution over the whole prefix (O(T·n) per
    /// channel — what a server WITHOUT this subsystem would pay every
    /// emitted token, modulo FFT log factors).
    pub fn forward_full(&self, tokens: &[i32]) -> Vec<Vec<f32>> {
        let d = self.cfg.d;
        let t_len = tokens.len();
        // xs[t] = residual stream at position t.
        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .map(|&tk| {
                let tok = (tk.max(0) as usize).min(self.cfg.vocab - 1);
                self.embed[tok * d..(tok + 1) * d].to_vec()
            })
            .collect();
        // Backend choice for the per-channel causal convolutions: the
        // direct loop at t_len vs the per-channel spectral plans whose
        // kernel spectra live in the model's plan cache
        // (`cfg.oracle_backend` forces one; Auto compares real costs).
        // Plans may be absent when construction gated them off.
        let use_spectral = t_len <= self.cfg.n
            && self.spectral_planned
            && match self.cfg.oracle_backend {
                BackendKind::Dense | BackendKind::Ski => false,
                BackendKind::Fft | BackendKind::Freq => true,
                BackendKind::Auto => {
                    let cost = CostModel::default();
                    cost.fft_cost(self.cfg.n) < cost.dense_cost(t_len)
                }
            };
        let pool = self.oracle_pool();
        for (bi, block) in self.blocks.iter().enumerate() {
            // cols[c * t_len + t]: channel c's token-mix output —
            // channels are independent, so they shard across the pool
            // (bitwise identical to the serial loop for any worker
            // count).  Spectral forwards resolve their per-channel
            // plans through the cache first (rebuilding any evicted
            // ones from the stored taps).
            let plans = if use_spectral { Some(self.block_plans(bi)) } else { None };
            let cols = oracle_cols(block, plans.as_deref(), &xs, pool);
            for t in 0..t_len {
                let g = matvec(&block.gate, &xs[t], d);
                let v: Vec<f32> = (0..d).map(|c| cols[c * t_len + t] * sigmoid(g[c])).collect();
                let h = matvec(&block.mix, &v, d);
                for c in 0..d {
                    xs[t][c] += h[c].tanh();
                }
            }
        }
        xs.iter()
            .map(|x| {
                let mut logits = vec![0.0f32; self.cfg.vocab];
                for c in 0..d {
                    let xc = x[c];
                    let row = &self.out_w[c * self.cfg.vocab..(c + 1) * self.cfg.vocab];
                    for (l, &w) in logits.iter_mut().zip(row.iter()) {
                        *l += xc * w;
                    }
                }
                logits
            })
            .collect()
    }

    /// How many kernels stream through the O(m) SSM path vs the
    /// window fallback: `(ssm, window)`.
    pub fn decoder_mix(&self) -> (usize, usize) {
        let ssm = self
            .blocks
            .iter()
            .flat_map(|b| b.decoders.iter())
            .filter(|d| d.is_ssm())
            .count();
        let total: usize = self.blocks.iter().map(|b| b.decoders.len()).sum();
        (ssm, total - ssm)
    }

    /// Worst-case per-token multiply-adds through the token-mixing
    /// decoders (the position-independent cost).
    pub fn decode_cost_per_token(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| b.decoders.iter())
            .map(KernelDecoder::cost_per_token)
            .sum()
    }

    /// The model's kernels as [`ToeplitzKernel`]s (benches/analyses).
    pub fn kernel(&self, block: usize, channel: usize) -> ToeplitzKernel {
        ToeplitzKernel::from_causal_taps(&self.blocks[block].taps[channel])
    }
}

/// Bytes → token ids (the shared byte vocabulary).
pub fn tokenize(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

/// Token ids → printable text (non-byte specials render as '·').
pub fn detokenize(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| {
            if (0..256).contains(&t) {
                let b = t as u8;
                if b.is_ascii_graphic() || b == b' ' || b == b'\n' {
                    b as char
                } else {
                    '·'
                }
            } else {
                '·'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn tiny_cfg(seed: u64) -> DecodeModelConfig {
        DecodeModelConfig {
            d: 8,
            blocks: 2,
            n: 48,
            policy: DecodePolicy { rank: 8, max_rel_residual: 0.05 },
            seed,
            ..DecodeModelConfig::default()
        }
    }

    #[test]
    fn prop_streaming_matches_full_context_forward() {
        // The tentpole equivalence at model level: token-for-token,
        // streaming decode == full-context recompute.  Exact-window
        // policy removes SSM fit error so tolerance is pure f32 noise.
        check("stream == full forward (exact windows)", |rng| {
            let mut cfg = tiny_cfg(rng.next_u64());
            cfg.policy = DecodePolicy { rank: 8, max_rel_residual: 0.0 };
            cfg.n = 24;
            let model = DecodeModel::new(cfg);
            let toks: Vec<i32> = (0..20).map(|_| rng.below(256) as i32).collect();
            let want = model.forward_full(&toks);
            let mut st = model.init_state();
            for (t, &tk) in toks.iter().enumerate() {
                let got = model.step(&mut st, tk).expect("stream step");
                for (v, (a, b)) in got.iter().zip(want[t].iter()).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                        "t={t} vocab={v}: {a} vs {b}"
                    );
                }
            }
        });
    }

    #[test]
    fn streaming_with_ssm_tracks_full_forward() {
        // Default policy (SSM where the fit is tight): logits drift is
        // bounded — enough that greedy decode stays sensible.
        let model = DecodeModel::new(tiny_cfg(3));
        let toks: Vec<i32> = (0..40).map(|i| (i * 17 % 256) as i32).collect();
        let want = model.forward_full(&toks);
        let mut st = model.init_state();
        let mut worst = 0.0f32;
        for (t, &tk) in toks.iter().enumerate() {
            let got = model.step(&mut st, tk).expect("stream step");
            for (a, b) in got.iter().zip(want[t].iter()) {
                worst = worst.max((a - b).abs());
            }
        }
        // Kernels are ℓ₁-normalised and the policy caps the fit's
        // relative residual at 5%, so drift stays well under the
        // logits' O(1) scale.
        assert!(worst < 1.0, "ssm logits drift {worst} too large");
    }

    #[test]
    fn oracle_backends_agree_token_for_token() {
        // The refactored oracle must be backend-invariant: forcing the
        // dense loop and the cached spectral path produces the same
        // logits at every position within f32 roundoff.
        let mut dense_cfg = tiny_cfg(13);
        dense_cfg.oracle_backend = BackendKind::Dense;
        let mut fft_cfg = tiny_cfg(13);
        fft_cfg.oracle_backend = BackendKind::Fft;
        let a = DecodeModel::new(dense_cfg);
        let b = DecodeModel::new(fft_cfg);
        let toks: Vec<i32> = (0..30).map(|i| (i * 31 % 256) as i32).collect();
        let ya = a.forward_full(&toks);
        let yb = b.forward_full(&toks);
        for (t, (ra, rb)) in ya.iter().zip(yb.iter()).enumerate() {
            for (v, (x, y)) in ra.iter().zip(rb.iter()).enumerate() {
                assert!(
                    (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                    "t={t} vocab={v}: dense {x} vs fft {y}"
                );
            }
        }
    }

    #[test]
    fn oracle_threads_are_bitwise_equivalent() {
        // cfg.threads only changes scheduling: the sharded channel
        // loop must reproduce the serial oracle bit-for-bit.
        let mut serial_cfg = tiny_cfg(17);
        serial_cfg.threads = 1;
        let mut par_cfg = tiny_cfg(17);
        par_cfg.threads = 4;
        // t_len >= 32 so the parallel path actually engages.
        let toks: Vec<i32> = (0..40).map(|i| (i * 13 % 256) as i32).collect();
        let a = DecodeModel::new(serial_cfg).forward_full(&toks);
        let b = DecodeModel::new(par_cfg).forward_full(&toks);
        assert_eq!(a, b, "oracle must be bitwise identical across worker counts");
    }

    #[test]
    fn oracle_backends_agree_at_non_pow2_context() {
        // A context length that is not a power of two: the spectral
        // oracle plans run at their own smooth transform size and must
        // still match the dense loop at every position.
        let mut dense_cfg = tiny_cfg(19);
        dense_cfg.n = 40;
        dense_cfg.oracle_backend = BackendKind::Dense;
        let mut fft_cfg = dense_cfg;
        fft_cfg.oracle_backend = BackendKind::Fft;
        let a = DecodeModel::new(dense_cfg);
        let b = DecodeModel::new(fft_cfg);
        let toks: Vec<i32> = (0..40).map(|i| (i * 29 % 256) as i32).collect();
        let ya = a.forward_full(&toks);
        let yb = b.forward_full(&toks);
        for (t, (ra, rb)) in ya.iter().zip(yb.iter()).enumerate() {
            for (v, (x, y)) in ra.iter().zip(rb.iter()).enumerate() {
                assert!(
                    (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                    "t={t} vocab={v}: dense {x} vs fft {y}"
                );
            }
        }
    }

    #[test]
    fn poisoned_state_errors_instead_of_panicking() {
        let model = DecodeModel::new(tiny_cfg(23));
        let mut st = model.init_state();
        let _ = model.step(&mut st, 1).unwrap();
        st.poison();
        let err = model.step(&mut st, 2).unwrap_err();
        assert!(err.to_string().contains("variant mismatch"), "{err}");
    }

    #[test]
    fn state_is_per_session() {
        // Two sessions with different prefixes must not interfere.
        let model = DecodeModel::new(tiny_cfg(5));
        let mut a = model.init_state();
        let mut b = model.init_state();
        let la1 = model.step(&mut a, 10).unwrap();
        let _ = model.step(&mut b, 200).unwrap();
        let mut a2 = model.init_state();
        let la2 = model.step(&mut a2, 10).unwrap();
        assert_eq!(la1, la2, "fresh sessions with same input must agree");
        let lb = model.step(&mut b, 10).unwrap();
        assert_ne!(la1, lb, "different histories must give different logits");
    }

    #[test]
    fn logits_are_finite_and_vocab_sized() {
        let model = DecodeModel::new(tiny_cfg(7));
        let mut st = model.init_state();
        for t in 0..64 {
            let logits = model.step(&mut st, (t % 259) as i32).unwrap();
            assert_eq!(logits.len(), model.cfg.vocab);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn decoder_mix_reports_ssm_usage() {
        // With the default policy on decaying kernels most channels
        // should stream through the SSM path.
        let cfg = DecodeModelConfig {
            d: 8,
            blocks: 1,
            n: 256,
            policy: DecodePolicy { rank: 16, max_rel_residual: 0.10 },
            seed: 11,
            ..DecodeModelConfig::default()
        };
        let model = DecodeModel::new(cfg);
        let (ssm, win) = model.decoder_mix();
        assert_eq!(ssm + win, 8);
        assert!(
            model.decode_cost_per_token() <= 8 * 256,
            "decode cost must not exceed the all-window worst case"
        );
    }

    #[test]
    fn tokenize_roundtrip() {
        let s = "SKI to go faster!";
        assert_eq!(detokenize(&tokenize(s)), s);
    }
}
