//! Streaming autoregressive decode — constant-time-per-token inference
//! for causal Toeplitz operators.
//!
//! The training story of this repo (paper §3.2/§3.3) keeps a full
//! O(n log n) FFT per forward; this subsystem is the inference-time
//! complement: following Qin & Zhong (2023, PAPERS.md), every causal
//! Toeplitz kernel converts to a recurrence with per-token cost
//! independent of sequence position, so generation does **not** pay a
//! full-context recompute per emitted token.
//!
//! | module | role |
//! |---|---|
//! | [`ssm`] | causal-Toeplitz → rank-m diagonal SSM fit (`h = Λh + x`) |
//! | [`sample`] | greedy / temperature / top-k sampling, seeded |
//! | [`model`] | pure-Rust streaming TNN LM + full-context oracle |
//! | [`session`] | per-session recurrent state, prefill + step |
//!
//! [`KernelDecoder`] is the per-kernel decision: long, decaying
//! kernels stream through the fitted SSM in O(m); short kernels (or
//! kernels the dictionary fits poorly) use an exact sliding-window
//! recurrence in O(window).  Either way the scheduler in
//! `server::generate` sees one `step(state, x) -> y` interface.

pub mod model;
pub mod sample;
pub mod session;
pub mod ssm;

pub use model::{DecodeModel, DecodeModelConfig};
pub use sample::Sampler;
pub use session::Session;
pub use ssm::{pole_grid, DiagonalSsm};

use crate::toeplitz::ToeplitzKernel;

/// Typed decode failure — the request path's alternative to panicking.
///
/// A corrupted per-session state (decoder/state variant mismatch) used
/// to `panic!` inside [`KernelDecoder::step`], which is reachable from
/// the generation server's tick loop: one bad session would abort the
/// whole serve process.  It now surfaces as an error that
/// `server::generate` routes back to the owning request only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeError {
    /// The per-session [`DecoderState`] does not match the model's
    /// planned [`KernelDecoder`] — a variant mismatch, or a state
    /// vector whose length diverges from the decoder count.
    StateMismatch {
        /// Planned decoder kind (`"ssm"`/`"window"`; `"planned"` for a
        /// whole-vector length mismatch).
        decoder: &'static str,
        /// State kind actually carried by the session (`"ssm"`/
        /// `"window"`; `"missing"` for a whole-vector length mismatch).
        state: &'static str,
    },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::StateMismatch { decoder, state } => write!(
                f,
                "decoder/state variant mismatch: {decoder} decoder stepped with {state} state \
                 (corrupted session)"
            ),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Policy knobs for planning a kernel's streaming decoder.
#[derive(Debug, Clone, Copy)]
pub struct DecodePolicy {
    /// SSM state size to fit for long kernels.
    pub rank: usize,
    /// Fall back to the exact sliding window when the fit's relative
    /// ℓ₁ residual exceeds this (exactness beats speed on kernels the
    /// decay dictionary cannot represent).
    pub max_rel_residual: f64,
}

impl Default for DecodePolicy {
    fn default() -> Self {
        DecodePolicy { rank: 16, max_rel_residual: 0.05 }
    }
}

/// Exact sliding-window recurrence: keeps the last `taps.len()` inputs
/// in a ring buffer and convolves directly.  O(window) per token —
/// constant in sequence *position*, exact for any kernel.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    pub taps: Vec<f32>,
}

impl SlidingWindow {
    pub fn step(&self, buf: &mut [f32], pos: &mut usize, x: f32) -> f32 {
        let cap = self.taps.len();
        debug_assert_eq!(buf.len(), cap);
        buf[*pos] = x;
        let mut y = 0.0f32;
        for (tau, &k) in self.taps.iter().enumerate() {
            // Input at position t-τ lives τ slots behind the cursor.
            let idx = (*pos + cap - tau) % cap;
            y += k * buf[idx];
        }
        *pos = (*pos + 1) % cap;
        y
    }
}

/// Per-kernel streaming decoder: fitted SSM or exact window.
#[derive(Debug, Clone)]
pub enum KernelDecoder {
    Ssm(DiagonalSsm),
    Window(SlidingWindow),
}

/// Mutable per-session state for one [`KernelDecoder`].
#[derive(Debug, Clone)]
pub enum DecoderState {
    Ssm(Vec<f32>),
    Window { buf: Vec<f32>, pos: usize },
}

impl DecoderState {
    /// Short variant name (`"ssm"`/`"window"`) for error reports.
    pub fn kind_name(&self) -> &'static str {
        match self {
            DecoderState::Ssm(_) => "ssm",
            DecoderState::Window { .. } => "window",
        }
    }
}

impl KernelDecoder {
    /// Plan a decoder for a causal kernel under `policy`.
    ///
    /// Kernels no longer than the SSM rank stream exactly through the
    /// window (same cost, zero error); longer kernels get the rank-m
    /// SSM fit unless the fit is poor, in which case the full-length
    /// window preserves exactness.
    pub fn plan(kernel: &ToeplitzKernel, policy: DecodePolicy) -> KernelDecoder {
        assert!(
            kernel.is_causal(),
            "streaming decode needs a causal kernel (call .causal() first)"
        );
        let taps = kernel.causal_taps();
        Self::plan_taps(&taps, policy)
    }

    /// Plan from raw causal taps (`taps[τ] = k[τ]`).
    pub fn plan_taps(taps: &[f32], policy: DecodePolicy) -> KernelDecoder {
        assert!(!taps.is_empty());
        assert!(policy.rank >= 1);
        if taps.len() - 1 <= policy.rank {
            return KernelDecoder::Window(SlidingWindow { taps: taps.to_vec() });
        }
        let ssm = DiagonalSsm::fit(taps, policy.rank);
        if ssm.rel_l1_residual(taps) > policy.max_rel_residual {
            return KernelDecoder::Window(SlidingWindow { taps: taps.to_vec() });
        }
        KernelDecoder::Ssm(ssm)
    }

    /// Force the exact sliding-window decoder (oracle / fallback).
    pub fn window(taps: &[f32]) -> KernelDecoder {
        KernelDecoder::Window(SlidingWindow { taps: taps.to_vec() })
    }

    pub fn init_state(&self) -> DecoderState {
        match self {
            KernelDecoder::Ssm(s) => DecoderState::Ssm(s.init_state()),
            KernelDecoder::Window(w) => {
                DecoderState::Window { buf: vec![0.0; w.taps.len()], pos: 0 }
            }
        }
    }

    /// One decode step: consume `x_t`, emit `y_t`.  A decoder/state
    /// variant mismatch (a corrupted session) is a typed error, not a
    /// panic — it is reachable from the generation server, where one
    /// bad session must fail its own request, not the process.
    pub fn step(&self, state: &mut DecoderState, x: f32) -> Result<f32, DecodeError> {
        match (self, &mut *state) {
            (KernelDecoder::Ssm(s), DecoderState::Ssm(h)) => return Ok(s.step(h, x)),
            (KernelDecoder::Window(w), DecoderState::Window { buf, pos }) => {
                return Ok(w.step(buf, pos, x));
            }
            _ => {}
        }
        Err(DecodeError::StateMismatch { decoder: self.kind_name(), state: state.kind_name() })
    }

    /// Short variant name (`"ssm"`/`"window"`) for error reports.
    pub fn kind_name(&self) -> &'static str {
        match self {
            KernelDecoder::Ssm(_) => "ssm",
            KernelDecoder::Window(_) => "window",
        }
    }

    /// Sound per-token output error bound per unit of `max|x|`
    /// (0 for the exact window).
    pub fn l1_error(&self) -> f64 {
        match self {
            KernelDecoder::Ssm(s) => s.l1_residual,
            KernelDecoder::Window(_) => 0.0,
        }
    }

    /// Multiply-adds per decoded token (the O(1) story in numbers).
    pub fn cost_per_token(&self) -> usize {
        match self {
            KernelDecoder::Ssm(s) => 2 * s.m + 1,
            KernelDecoder::Window(w) => w.taps.len(),
        }
    }

    pub fn is_ssm(&self) -> bool {
        matches!(self, KernelDecoder::Ssm(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check, size, vecf};

    /// Random causal kernel via the public masking path.
    fn random_causal(rng: &mut crate::util::rng::Rng, n: usize) -> ToeplitzKernel {
        ToeplitzKernel { n, lags: vecf(rng, 2 * n - 1) }.causal()
    }

    #[test]
    fn prop_window_decode_matches_causal_dense_oracle() {
        // Satellite contract: recurrent decode == causal dense apply,
        // token for token.  The window path must be (f32-)exact.
        check("window decode == causal dense", |rng| {
            let n = size(rng, 2, 128);
            let k = random_causal(rng, n);
            let x = vecf(rng, n);
            let want = k.apply_dense(&x);
            let dec = KernelDecoder::window(&k.causal_taps());
            let mut st = dec.init_state();
            let got: Vec<f32> =
                x.iter().map(|&xi| dec.step(&mut st, xi).expect("window step")).collect();
            assert_close(&got, &want, 1e-4, "window decode");
        });
    }

    #[test]
    fn prop_ssm_decode_matches_oracle_within_fit_residual() {
        // Satellite contract, SSM path: tolerance tied to the fitted
        // rank m through the recorded ℓ₁ residual (plus f32 roundoff
        // scaled by the fit's weight norm).
        check("ssm decode ≤ residual from causal dense", |rng| {
            let n = size(rng, 8, 128);
            let m = size(rng, 2, 16);
            let k = random_causal(rng, n);
            let x = vecf(rng, n);
            let want = k.apply_dense(&x);
            let ssm = DiagonalSsm::fit(&k.causal_taps(), m);
            let xmax = x.iter().fold(0.0f32, |a, &b| a.max(b.abs())) as f64;
            let w_l1: f64 = ssm.w.iter().map(|&v| (v as f64).abs()).sum();
            let bound = ssm.l1_residual * xmax + (1e-3 + 1e-5 * w_l1) * (1.0 + xmax);
            let mut h = ssm.init_state();
            for (t, (&xi, &wi)) in x.iter().zip(want.iter()).enumerate() {
                let y = ssm.step(&mut h, xi);
                assert!(
                    ((y - wi) as f64).abs() <= bound,
                    "t={t}: |{y} - {wi}| > {bound} (m={m}, n={n})"
                );
            }
        });
    }

    #[test]
    fn prop_planned_decoder_tracks_oracle() {
        // Whatever the policy picks (SSM or fallback window), the
        // end-to-end guarantee holds: error ≤ planned l1_error bound.
        check("planned decoder ≤ declared error", |rng| {
            let n = size(rng, 2, 192);
            let k = random_causal(rng, n);
            let x = vecf(rng, n);
            let want = k.apply_dense(&x);
            let dec = KernelDecoder::plan(&k, DecodePolicy::default());
            let xmax = x.iter().fold(0.0f32, |a, &b| a.max(b.abs())) as f64;
            let w_l1 = match &dec {
                KernelDecoder::Ssm(s) => s.w.iter().map(|&v| (v as f64).abs()).sum(),
                KernelDecoder::Window(_) => 0.0,
            };
            let bound = dec.l1_error() * xmax + (2e-3 + 1e-5 * w_l1) * (1.0 + xmax);
            let mut st = dec.init_state();
            for (t, (&xi, &wi)) in x.iter().zip(want.iter()).enumerate() {
                let y = dec.step(&mut st, xi).expect("planned step");
                assert!(
                    ((y - wi) as f64).abs() <= bound,
                    "t={t}: |{y} - {wi}| > {bound} (n={n}, ssm={})",
                    dec.is_ssm()
                );
            }
        });
    }

    #[test]
    fn plan_prefers_window_for_short_kernels() {
        let mut rng = crate::util::rng::Rng::new(1);
        let short = random_causal(&mut rng, 8);
        let dec = KernelDecoder::plan(&short, DecodePolicy { rank: 16, max_rel_residual: 0.05 });
        assert!(!dec.is_ssm(), "short kernel must use the exact window");
        assert_eq!(dec.cost_per_token(), 8);
    }

    #[test]
    fn plan_uses_ssm_for_long_decaying_kernels() {
        // Smooth exponentially-decaying kernel (the TNN regime after
        // the decay bias): the SSM fit is tight and the plan must take
        // the O(m) path.
        let n = 1024;
        let k = ToeplitzKernel::from_fn(n, |lag| {
            if lag < 0 {
                0.0
            } else {
                0.97f32.powi(lag as i32) + 0.5 * 0.80f32.powi(lag as i32)
            }
        });
        let policy = DecodePolicy { rank: 32, max_rel_residual: 0.05 };
        let dec = KernelDecoder::plan(&k, policy);
        assert!(dec.is_ssm(), "decaying kernel must stream through the SSM");
        assert!(
            dec.cost_per_token() < n / 4,
            "O(m) cost {} should beat the O(n) window",
            dec.cost_per_token()
        );
    }

    #[test]
    fn plan_falls_back_on_bad_fits() {
        // White-noise taps are maximally far from the decay
        // dictionary: the policy must refuse the lossy SSM.
        let mut rng = crate::util::rng::Rng::new(7);
        let k = random_causal(&mut rng, 256);
        let dec = KernelDecoder::plan(&k, DecodePolicy { rank: 8, max_rel_residual: 0.05 });
        assert!(!dec.is_ssm(), "noise kernel must fall back to the exact window");
    }

    #[test]
    fn step_reports_state_mismatch_as_typed_error() {
        // The satellite regression: a corrupted session (state variant
        // not matching the planned decoder) must be an Err, not a
        // panic — it is reachable from the generation server.
        let dec = KernelDecoder::window(&[1.0, 0.5]);
        let mut wrong = DecoderState::Ssm(vec![0.0; 4]);
        let err = dec.step(&mut wrong, 1.0).unwrap_err();
        assert_eq!(err, DecodeError::StateMismatch { decoder: "window", state: "ssm" });
        assert!(err.to_string().contains("variant mismatch"), "{err}");
        // And the matched pairing still works afterwards.
        let mut ok = dec.init_state();
        assert!(dec.step(&mut ok, 1.0).is_ok());
    }

    #[test]
    #[should_panic]
    fn plan_rejects_noncausal_kernels() {
        let mut rng = crate::util::rng::Rng::new(3);
        let k = ToeplitzKernel { n: 16, lags: vecf(&mut rng, 31) };
        let _ = KernelDecoder::plan(&k, DecodePolicy::default());
    }
}
