//! Per-session autoregressive state.
//!
//! A [`Session`] owns everything one live generation needs: the token
//! history, the model's recurrent [`StreamState`] (a few KB of f32s —
//! the whole per-user memory footprint, constant in context length),
//! and the seeded [`Sampler`].  Construction runs the prompt *prefill*
//! (one streaming step per prompt token); each subsequent
//! [`Session::step`] samples and absorbs exactly one token in O(1).

use super::model::{DecodeModel, StreamState};
use super::{DecodeError, Sampler};
use crate::data::PAD;

/// Tokens generated across all sessions (telemetry; the per-run
/// `GenStats.tokens` stays the report of record).
static DECODE_TOKENS: crate::telemetry::LazyCounter =
    crate::telemetry::LazyCounter::new("decode.tokens");

/// One live generation.
pub struct Session {
    pub id: u64,
    /// Prompt + generated tokens, in order.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub sampler: Sampler,
    state: StreamState,
    /// Logits predicting the next (not yet sampled) token.
    next_logits: Vec<f32>,
}

impl Session {
    /// Open a session: allocate state and prefill the prompt.  An
    /// empty prompt is seeded with a single PAD so there is always a
    /// distribution to sample from.  A decode failure during prefill
    /// (corrupted state) surfaces as a typed error for the scheduler
    /// to route back to the owning request.
    pub fn new(
        model: &DecodeModel,
        id: u64,
        prompt: &[i32],
        sampler: Sampler,
        max_new: usize,
    ) -> Result<Session, DecodeError> {
        let mut state = model.init_state();
        let tokens: Vec<i32> = if prompt.is_empty() { vec![PAD] } else { prompt.to_vec() };
        let mut next_logits = Vec::new();
        for &t in &tokens {
            next_logits = model.step(&mut state, t)?;
        }
        Ok(Session {
            id,
            prompt_len: tokens.len(),
            tokens,
            max_new,
            sampler,
            state,
            next_logits,
        })
    }

    /// Number of tokens generated so far.
    pub fn generated_len(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// The generated suffix.
    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }

    pub fn done(&self) -> bool {
        self.generated_len() >= self.max_new
    }

    /// Sample one token, absorb it into the recurrent state, return
    /// it.  O(1) in context length.  Panics if called past `done()`
    /// (a scheduler bug, not a data condition); a corrupted state is
    /// a typed error the scheduler fails this session's request with.
    pub fn step(&mut self, model: &DecodeModel) -> Result<i32, DecodeError> {
        assert!(!self.done(), "session {} already finished", self.id);
        let tok = self.sampler.sample(&self.next_logits) as i32;
        self.tokens.push(tok);
        DECODE_TOKENS.incr();
        if !self.done() {
            // The finished session's state never feeds a sample again;
            // skipping the last model step saves one decode per
            // session without changing outputs.
            self.next_logits = model.step(&mut self.state, tok)?;
        }
        Ok(tok)
    }

    /// Per-session recurrent memory, in f32 elements.
    pub fn state_size(&self) -> usize {
        self.state.size()
    }

    /// Corrupt this session's recurrent state (see
    /// [`StreamState::poison`]) — regression-test hook only.
    #[doc(hidden)]
    pub fn poison_for_test(&mut self) {
        self.state.poison();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::model::DecodeModelConfig;
    use crate::decode::DecodePolicy;

    fn model() -> DecodeModel {
        DecodeModel::new(DecodeModelConfig {
            d: 8,
            blocks: 1,
            n: 32,
            policy: DecodePolicy { rank: 8, max_rel_residual: 0.05 },
            seed: 1,
            ..DecodeModelConfig::default()
        })
    }

    #[test]
    fn generates_exactly_max_new() {
        let m = model();
        let mut s = Session::new(&m, 0, &[1, 2, 3], Sampler::greedy(), 7).unwrap();
        while !s.done() {
            s.step(&m).unwrap();
        }
        assert_eq!(s.generated_len(), 7);
        assert_eq!(s.tokens.len(), 10);
        assert!(s.generated().iter().all(|&t| (0..259).contains(&t)));
    }

    #[test]
    fn greedy_sessions_are_deterministic() {
        let m = model();
        let run = || {
            let mut s = Session::new(&m, 0, &[65, 66], Sampler::greedy(), 12).unwrap();
            while !s.done() {
                s.step(&m).unwrap();
            }
            s.generated().to_vec()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn seeds_decorrelate_sampled_sessions() {
        let m = model();
        let run = |seed: u64| {
            let mut s = Session::new(&m, seed, &[65], Sampler::new(1.2, 20, seed), 24).unwrap();
            while !s.done() {
                s.step(&m).unwrap();
            }
            s.generated().to_vec()
        };
        assert_eq!(run(9), run(9), "same seed reproduces");
        assert_ne!(run(1), run(2), "different seeds should diverge");
    }

    #[test]
    fn empty_prompt_is_padded() {
        let m = model();
        let mut s = Session::new(&m, 0, &[], Sampler::greedy(), 3).unwrap();
        assert_eq!(s.prompt_len, 1);
        while !s.done() {
            s.step(&m).unwrap();
        }
        assert_eq!(s.generated_len(), 3);
    }

    #[test]
    fn session_continuation_matches_uninterrupted_decode() {
        // Interleaving other work between steps must not change a
        // session's output — the state is fully self-contained.
        let m = model();
        let mut a = Session::new(&m, 0, &[10, 20], Sampler::greedy(), 8).unwrap();
        let mut b = Session::new(&m, 1, &[10, 20], Sampler::greedy(), 8).unwrap();
        let mut other = Session::new(&m, 2, &[99], Sampler::greedy(), 8).unwrap();
        let mut out_a = Vec::new();
        let mut out_b = Vec::new();
        while !a.done() {
            out_a.push(a.step(&m).unwrap());
            if !other.done() {
                other.step(&m).unwrap(); // interleaved "traffic"
            }
        }
        while !b.done() {
            out_b.push(b.step(&m).unwrap());
        }
        assert_eq!(out_a, out_b);
    }
}
