//! Dense linear-algebra substrate: matrices, one-sided Jacobi SVD,
//! pseudo-inverse, spectral norms.
//!
//! Built from scratch (no BLAS/LAPACK offline) to support the paper's
//! Theorem 1 verification: computing the *optimal* rank-r approximation
//! `T_{r,opt}`, the Nyström error `‖F A⁻¹ B − T_{r,opt}‖₂` and the SKI
//! error `‖W A Wᵀ − T_{r,opt}‖₂` requires full SVDs of the (small)
//! Gram matrices involved.  One-sided Jacobi is slow but numerically
//! robust and exact enough at the n ≤ 256 sizes the tests use.

/// Dense row-major f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn t(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    pub fn matmul(&self, o: &Mat) -> Mat {
        assert_eq!(self.cols, o.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, o.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..o.cols {
                    out[(i, j)] += a * o[(k, j)];
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| (0..self.cols).map(|j| self[(i, j)] * x[j]).sum())
            .collect()
    }

    pub fn sub(&self, o: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(o.data.iter()).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|v| v * s).collect(),
        }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Thin SVD `A = U diag(s) Vᵀ`, singular values descending.
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub vt: Mat,
}

/// One-sided Jacobi SVD.  Orthogonalises the columns of A by plane
/// rotations on the right; converges quadratically.  For rows < cols we
/// decompose the transpose and swap factors.
pub fn svd(a: &Mat) -> Svd {
    if a.rows < a.cols {
        let s = svd(&a.t());
        return Svd { u: s.vt.t(), s: s.s, vt: s.u.t() };
    }
    let m = a.rows;
    let n = a.cols;
    let mut u = a.clone(); // working copy; columns become U*s
    let mut v = Mat::eye(n);
    let eps = 1e-13;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let mut alpha = 0.0;
                let mut beta = 0.0;
                let mut gamma = 0.0;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    alpha += up * up;
                    beta += uq * uq;
                    gamma += up * uq;
                }
                off = off.max(gamma.abs() / (alpha * beta).sqrt().max(1e-300));
                if gamma.abs() <= eps * (alpha * beta).sqrt() {
                    continue;
                }
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // Column norms are the singular values.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> =
        (0..n).map(|j| u.col(j).iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());
    let mut uu = Mat::zeros(m, n);
    let mut vt = Mat::zeros(n, n);
    let mut s = vec![0.0; n];
    for (dst, &src) in order.iter().enumerate() {
        s[dst] = norms[src];
        let inv = if norms[src] > 1e-300 { 1.0 / norms[src] } else { 0.0 };
        for i in 0..m {
            uu[(i, dst)] = u[(i, src)] * inv;
        }
        for i in 0..n {
            vt[(dst, i)] = v[(i, src)];
        }
    }
    Svd { u: uu, s, vt }
}

/// Moore–Penrose pseudo-inverse via SVD with relative tolerance.
pub fn pinv(a: &Mat) -> Mat {
    let d = svd(a);
    let tol = 1e-12 * d.s.first().copied().unwrap_or(0.0).max(1e-300);
    let k = d.s.len();
    let mut si = Mat::zeros(k, k);
    for i in 0..k {
        if d.s[i] > tol {
            si[(i, i)] = 1.0 / d.s[i];
        }
    }
    d.vt.t().matmul(&si).matmul(&d.u.t())
}

/// Best rank-r approximation (Eckart–Young).
pub fn rank_r_approx(a: &Mat, r: usize) -> Mat {
    let d = svd(a);
    let k = r.min(d.s.len());
    let mut out = Mat::zeros(a.rows, a.cols);
    for t in 0..k {
        for i in 0..a.rows {
            for j in 0..a.cols {
                out[(i, j)] += d.s[t] * d.u[(i, t)] * d.vt[(t, j)];
            }
        }
    }
    out
}

/// Spectral norm (largest singular value) via power iteration on AᵀA.
pub fn spectral_norm(a: &Mat) -> f64 {
    let n = a.cols;
    if n == 0 || a.rows == 0 {
        return 0.0;
    }
    let mut x: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin()).collect();
    let norm = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>().sqrt();
    let mut sigma = 0.0;
    for _ in 0..200 {
        let ax = a.matvec(&x);
        let atax = a.t().matvec(&ax);
        let nn = norm(&atax);
        if nn < 1e-300 {
            return 0.0;
        }
        let next_sigma = norm(&ax);
        x = atax.iter().map(|v| v / nn).collect();
        if (next_sigma - sigma).abs() <= 1e-10 * next_sigma.max(1e-300) {
            return next_sigma;
        }
        sigma = next_sigma;
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, size};
    use crate::util::rng::Rng;

    fn randmat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal() as f64)
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = randmat(&mut rng, 4, 6);
        assert_eq!(Mat::eye(4).matmul(&a).data, a.data);
    }

    #[test]
    fn prop_svd_reconstructs() {
        check("svd reconstruction", |rng| {
            let m = size(rng, 2, 24);
            let n = size(rng, 2, 24);
            let a = randmat(rng, m, n);
            let d = svd(&a);
            let k = d.s.len();
            let mut smat = Mat::zeros(k, k);
            for i in 0..k {
                smat[(i, i)] = d.s[i];
            }
            let rec = d.u.matmul(&smat).matmul(&d.vt);
            assert!(rec.sub(&a).frobenius() < 1e-8 * a.frobenius().max(1.0));
        });
    }

    #[test]
    fn prop_svd_orthogonal() {
        check("svd orthogonality", |rng| {
            let m = size(rng, 3, 20);
            let n = size(rng, 2, m);
            let a = randmat(rng, m, n);
            let d = svd(&a);
            let utu = d.u.t().matmul(&d.u);
            let vvt = d.vt.matmul(&d.vt.t());
            assert!(utu.sub(&Mat::eye(n)).frobenius() < 1e-8);
            assert!(vvt.sub(&Mat::eye(n)).frobenius() < 1e-8);
        });
    }

    #[test]
    fn prop_pinv_property() {
        check("A A+ A = A", |rng| {
            let m = size(rng, 2, 16);
            let n = size(rng, 2, 16);
            let a = randmat(rng, m, n);
            let ap = pinv(&a);
            let aaa = a.matmul(&ap).matmul(&a);
            assert!(aaa.sub(&a).frobenius() < 1e-7 * a.frobenius().max(1.0));
        });
    }

    #[test]
    fn spectral_matches_svd() {
        let mut rng = Rng::new(5);
        for _ in 0..5 {
            let a = randmat(&mut rng, 12, 9);
            let s1 = spectral_norm(&a);
            let s2 = svd(&a).s[0];
            assert!((s1 - s2).abs() < 1e-6 * s2, "{s1} vs {s2}");
        }
    }

    #[test]
    fn rank_r_is_eckart_young() {
        let mut rng = Rng::new(6);
        let a = randmat(&mut rng, 10, 10);
        let d = svd(&a);
        for r in [1usize, 3, 7] {
            let approx = rank_r_approx(&a, r);
            let err = spectral_norm(&a.sub(&approx));
            // Spectral error of best rank-r approx is σ_{r+1}.
            assert!((err - d.s[r]).abs() < 1e-6 * d.s[0], "r={r}: {err} vs {}", d.s[r]);
        }
    }

    #[test]
    fn pinv_of_singular() {
        // Rank-1 matrix: pinv well-defined, A A+ A = A.
        let a = Mat::from_fn(4, 4, |i, j| ((i + 1) * (j + 1)) as f64);
        let ap = pinv(&a);
        assert!(a.matmul(&ap).matmul(&a).sub(&a).frobenius() < 1e-8);
    }
}
