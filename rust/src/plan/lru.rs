//! The one bounded-map primitive behind every cache in the crate.
//!
//! [`LruCore`] is deliberately tiny: a `HashMap` plus a monotonic
//! use-stamp, evicting the least-recently-used entry whenever an
//! insert pushes the map past its capacity.  It does **no locking and
//! no telemetry** — each consumer wraps it in whatever concurrency
//! shell it needs ([`PlanCache`](super::PlanCache) puts it behind a
//! `Mutex` with hit/miss/evict accounting, the FFT plan maps in
//! `dsp::fft` behind their process `Mutex`es, and
//! `runtime::Engine`'s executable cache behind a `RefCell`, since
//! `Rc<Executable>` is single-threaded anyway).
//!
//! Eviction scans for the minimum stamp, O(len) per displaced entry.
//! Every cache in this crate is small (tens of entries) and inserts
//! are rare (one per *distinct shape*, not per request), so the scan
//! is cheaper than maintaining an intrusive list — and the warm-path
//! `get` stays a single hash lookup plus one integer store, which is
//! what the zero-allocation serving gate cares about.

use std::collections::HashMap;
use std::hash::Hash;

/// A bounded map with least-recently-used eviction.  `cap` is the
/// maximum number of resident entries; `cap == 0` is clamped to 1.
#[derive(Debug)]
pub struct LruCore<K: Eq + Hash + Clone, V> {
    cap: usize,
    tick: u64,
    map: HashMap<K, (V, u64)>,
}

impl<K: Eq + Hash + Clone, V> LruCore<K, V> {
    pub fn new(cap: usize) -> LruCore<K, V> {
        LruCore { cap: cap.max(1), tick: 0, map: HashMap::new() }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up `k`, marking it most-recently-used on a hit.  The warm
    /// path: one hash probe and one stamp store, no allocation.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(k).map(|(v, stamp)| {
            *stamp = tick;
            &*v
        })
    }

    /// Look up `k` without touching recency (diagnostics only).
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.map.get(k).map(|(v, _)| v)
    }

    /// Insert `k → v` as most-recently-used and evict down to
    /// capacity, returning the displaced `(key, value)` pairs so the
    /// caller can account for released memory.  Replacing an existing
    /// key never evicts.
    pub fn insert(&mut self, k: K, v: V) -> Vec<(K, V)> {
        self.tick += 1;
        self.map.insert(k, (v, self.tick));
        let mut evicted = Vec::new();
        while self.map.len() > self.cap {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
                .expect("non-empty map has a minimum stamp");
            if let Some((v, _)) = self.map.remove(&oldest) {
                evicted.push((oldest, v));
            }
        }
        evicted
    }

    /// Visit every resident value (memory accounting sweeps).
    pub fn values(&self) -> impl Iterator<Item = &V> {
        self.map.values().map(|(v, _)| v)
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used_past_cap() {
        let mut c: LruCore<usize, usize> = LruCore::new(2);
        assert!(c.insert(1, 10).is_empty());
        assert!(c.insert(2, 20).is_empty());
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(&10));
        let evicted = c.insert(3, 30);
        assert_eq!(evicted, vec![(2, 20)]);
        assert_eq!(c.len(), 2);
        assert!(c.peek(&1).is_some() && c.peek(&3).is_some());
    }

    #[test]
    fn replacing_a_key_does_not_evict() {
        let mut c: LruCore<&str, u32> = LruCore::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        assert!(c.insert("a", 3).is_empty());
        assert_eq!(c.len(), 2);
        assert_eq!(c.peek(&"a"), Some(&3));
    }

    #[test]
    fn zero_cap_clamps_to_one() {
        let mut c: LruCore<u8, u8> = LruCore::new(0);
        c.insert(1, 1);
        let evicted = c.insert(2, 2);
        assert_eq!(c.len(), 1);
        assert_eq!(evicted, vec![(1, 1)]);
    }
}
