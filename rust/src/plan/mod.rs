//! The unified execution-plan layer: one bounded, shape-keyed cache
//! from backend choice down to serve-tick buffers.
//!
//! The paper's contribution is *planning* — choosing the right
//! operator per shape (sparse + low-rank SKI for bidirectional sites,
//! the Hilbert-completed frequency response for causal ones) so every
//! apply runs at O(n) / O(n log n) (§3.2, §3.3).  Before this module
//! that decision was scattered: `Dispatch` picked backends,
//! `server::batcher` cached per-width operators and tick buffers,
//! `decode::model` held per-channel spectra, and `dsp::fft` grew a
//! process-wide plan map without bound.  Here the pieces meet in one
//! lifecycle:
//!
//! ```text
//!   ShapeKey ──▶ PlanCache::get_or_build ──▶ ExecutionPlan (build)
//!                      │ bounded, LRU                │ warm()
//!                      │ hit/miss/evict/bytes        ▼
//!                      └──────────▶ execute_rows (warm tick:
//!                                   zero allocations, shared plan)
//! ```
//!
//! * [`ShapeKey`] — the full dispatch shape `(n, r, w, causal,
//!   threads, batch-hint)` plus a `kernel_id` for sites (the decode
//!   oracle) that hold *different* kernels at the same shape.
//! * [`ExecutionPlan`] — everything a warm tick needs, built once:
//!   the backend choice and predicted cost from
//!   [`Dispatch`], the operator (with its cached
//!   [`SpectralPlan`] spectrum where spectral), and the tick state —
//!   flat signal/result buffers plus the response [`RowPool`] — whose
//!   reuse across ticks is what keeps the serve path allocation-free.
//! * [`PlanCache`] — a concurrently shared, **bounded** map of plans
//!   with LRU eviction ([`LruCore`]), exact hit/miss/evict accounting
//!   (lookups are resolved under the lock, so `hits + misses` equals
//!   lookups even under a thread hammer), and per-plan + aggregate
//!   resident-byte accounting surfaced as the
//!   `plan.cache.{hit,miss,evict,bytes,size}` telemetry series.
//!
//! The FFT plan maps in [`dsp::fft`](crate::dsp) are this cache's
//! inner tier: an [`ExecutionPlan`] holds its spectrum, the spectrum
//! holds its shared transform plan, and both tiers are bounded with
//! the same [`LruCore`] primitive.

mod lru;

pub use lru::LruCore;

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::{ensure, Result};

use crate::runtime::ThreadPool;
use crate::server::{RowBatch, RowPool};
use crate::telemetry::{LazyCounter, LazyGauge};
use crate::toeplitz::{
    apply_batch_flat_sharded, BackendKind, Dispatch, DispatchQuery, FftOp, SpectralPlan,
    ToeplitzOp,
};

static PLAN_CACHE_HIT: LazyCounter = LazyCounter::new("plan.cache.hit");
static PLAN_CACHE_MISS: LazyCounter = LazyCounter::new("plan.cache.miss");
static PLAN_CACHE_EVICT: LazyCounter = LazyCounter::new("plan.cache.evict");
static PLAN_CACHE_BYTES: LazyGauge = LazyGauge::new("plan.cache.bytes");
static PLAN_CACHE_SIZE: LazyGauge = LazyGauge::new("plan.cache.size");

/// Aggregate resident bytes / plan count across every live
/// [`PlanCache`] in the process — the gauges report totals, not one
/// cache's view, so a serve cache and a decode cache sum coherently.
static TOTAL_BYTES: AtomicI64 = AtomicI64::new(0);
static TOTAL_SIZE: AtomicI64 = AtomicI64::new(0);

/// The full shape one execution plan is keyed on — everything
/// [`Dispatch`] looks at, plus a `kernel_id` discriminator for callers
/// (the decode oracle) that cache *different kernels* at the same
/// dispatch shape.  `kernel_id == 0` means "the kernel is determined
/// by the shape" (the serving substrate's width-derived kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    /// Sequence length (row width).
    pub n: usize,
    /// SKI rank available (0 ⇒ SKI ineligible).
    pub r: usize,
    /// Band width for the sparse component.
    pub w: usize,
    /// Causal site (excludes SKI, prefers the Hilbert spectrum).
    pub causal: bool,
    /// Worker threads the executing pool offers.
    pub threads: usize,
    /// Expected rows per tick (sizes the warmed buffers; 0 = unknown).
    pub batch_hint: usize,
    /// Distinguishes kernels sharing a dispatch shape (0 = none).
    pub kernel_id: u64,
}

impl ShapeKey {
    /// The serving substrate's key: one plan per bucket width.
    pub fn for_width(n: usize, threads: usize) -> ShapeKey {
        ShapeKey { n, r: 0, w: 0, causal: false, threads, batch_hint: 0, kernel_id: 0 }
    }

    /// This key as a [`Dispatch`] query.
    pub fn query(&self) -> DispatchQuery {
        DispatchQuery {
            n: self.n,
            r: self.r,
            w: self.w,
            causal: self.causal,
            batch: self.batch_hint.max(1),
            threads: self.threads.max(1),
        }
    }
}

/// Per-plan tick state: the flat signal/result buffers and the
/// response-row pool.  Living inside the plan (rather than the serve
/// closure) is what lets every consumer of a cached plan inherit the
/// zero-allocation warm tick.
struct TickState {
    xs: Vec<f32>,
    out: Vec<f32>,
    rows: RowPool,
}

/// Everything a warm tick needs for one shape, built once and shared:
/// backend choice + predicted cost, the operator (holding its cached
/// spectrum), and the recycled tick buffers.  Lifecycle: **build**
/// (constructors) → **warm** ([`warm`](Self::warm), optional — sizes
/// buffers and runs one throwaway apply so scratch arenas and FFT
/// twiddles exist before traffic) → **execute**
/// ([`execute_rows`](Self::execute_rows), allocation-free once warm).
pub struct ExecutionPlan {
    key: ShapeKey,
    backend: BackendKind,
    parallel: bool,
    predicted_ns: Option<f64>,
    op: Arc<dyn ToeplitzOp>,
    spectral: Option<Arc<SpectralPlan>>,
    tick: Mutex<TickState>,
    warmed: AtomicBool,
}

impl ExecutionPlan {
    /// Build from an explicit dispatch decision (the `plan --explain`
    /// path and [`plan_shape`]).
    pub fn new(
        key: ShapeKey,
        backend: BackendKind,
        parallel: bool,
        predicted_ns: Option<f64>,
        op: Arc<dyn ToeplitzOp>,
    ) -> ExecutionPlan {
        ExecutionPlan {
            key,
            backend,
            parallel,
            predicted_ns,
            op,
            spectral: None,
            tick: Mutex::new(TickState { xs: Vec::new(), out: Vec::new(), rows: RowPool::new() }),
            warmed: AtomicBool::new(false),
        }
    }

    /// Wrap an already-built operator (the serve executors: their
    /// factories decided the backend when they built the op).
    pub fn from_op(key: ShapeKey, op: Arc<dyn ToeplitzOp>) -> ExecutionPlan {
        let backend = BackendKind::parse(op.name()).unwrap_or(BackendKind::Auto);
        ExecutionPlan::new(key, backend, key.threads > 1, None, op)
    }

    /// Wrap a causal spectrum (the decode oracle's per-channel plans):
    /// the plan object and the operator share one `Arc`'d spectrum —
    /// no duplicate tables.
    pub fn from_spectral(key: ShapeKey, plan: SpectralPlan) -> ExecutionPlan {
        let plan = Arc::new(plan);
        let op: Arc<dyn ToeplitzOp> = Arc::new(FftOp::from_shared(Arc::clone(&plan)));
        ExecutionPlan {
            key,
            backend: if key.causal { BackendKind::Freq } else { BackendKind::Fft },
            parallel: key.threads > 1,
            predicted_ns: None,
            op,
            spectral: Some(plan),
            tick: Mutex::new(TickState { xs: Vec::new(), out: Vec::new(), rows: RowPool::new() }),
            warmed: AtomicBool::new(false),
        }
    }

    pub fn key(&self) -> &ShapeKey {
        &self.key
    }

    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Whether the dispatch decision was to shard batches across the
    /// pool (informational; the executing pool is the ground truth).
    pub fn parallel(&self) -> bool {
        self.parallel
    }

    /// The winning backend's predicted batch cost, when the plan was
    /// built through [`Dispatch`].
    pub fn predicted_ns(&self) -> Option<f64> {
        self.predicted_ns
    }

    pub fn op(&self) -> &Arc<dyn ToeplitzOp> {
        &self.op
    }

    /// The cached causal spectrum, for consumers (the decode oracle)
    /// that apply it directly rather than through the operator.
    pub fn spectral(&self) -> Option<&Arc<SpectralPlan>> {
        self.spectral.as_ref()
    }

    /// Whether at least one tick (or an explicit [`warm`](Self::warm))
    /// has run through this plan.
    pub fn warmed(&self) -> bool {
        self.warmed.load(Ordering::Acquire)
    }

    /// Pre-size the tick buffers for `key.batch_hint` rows and run one
    /// throwaway apply, so the first real tick finds warm scratch
    /// arenas and built FFT tables.
    pub fn warm(&self) {
        let rows = self.key.batch_hint.max(1);
        let n = self.op.n();
        let mut guard = self.tick.lock().unwrap_or_else(PoisonError::into_inner);
        let t = &mut *guard;
        t.xs.clear();
        t.xs.resize(rows * n, 0.0);
        t.out.clear();
        t.out.resize(rows * n, 0.0);
        crate::toeplitz::with_scratch(|s| self.op.apply_batch_flat(&t.xs, rows, &mut t.out, s));
        self.warmed.store(true, Ordering::Release);
    }

    /// Execute one tick of `rows` width-`width` rows: `encode` writes
    /// each row's f32 signal into the recycled flat buffer, the
    /// operator runs through the allocation-free sharded flat ABI, and
    /// the responses come from (and return to) this plan's [`RowPool`]
    /// — a warm tick allocates nothing.
    pub fn execute_rows(
        &self,
        rows: usize,
        width: usize,
        encode: &mut dyn FnMut(usize, &mut [f32]),
        pool: &ThreadPool,
    ) -> Result<RowBatch> {
        let n = self.op.n();
        ensure!(width == n, "row width {width} does not match operator n {n}");
        let mut guard = self.tick.lock().unwrap_or_else(PoisonError::into_inner);
        let t = &mut *guard;
        t.xs.clear();
        t.xs.resize(rows * n, 0.0);
        for (i, sig) in t.xs.chunks_mut(n).enumerate() {
            encode(i, sig);
        }
        t.out.clear();
        t.out.resize(rows * n, 0.0);
        apply_batch_flat_sharded(self.op.as_ref(), &t.xs, rows, &mut t.out, pool);
        let mut resp = t.rows.batch();
        resp.extend(t.out.chunks(n).map(|c| t.rows.row(c)));
        self.warmed.store(true, Ordering::Release);
        Ok(resp)
    }

    /// Estimated resident bytes: the operator's tables (spectrum,
    /// band, kernel lags) plus this plan's tick buffers and pooled
    /// response rows.
    pub fn resident_bytes(&self) -> usize {
        let t = self.tick.lock().unwrap_or_else(PoisonError::into_inner);
        self.op.resident_bytes()
            + (t.xs.capacity() + t.out.capacity()) * std::mem::size_of::<f32>()
            + t.rows.resident_bytes()
    }

    /// The shape report `ski-tnn plan --explain` prints.
    pub fn report(&self) -> PlanReport {
        PlanReport {
            key: self.key,
            backend: self.backend.name(),
            parallel: self.parallel,
            predicted_ns: self.predicted_ns,
            transform_len: self.op.transform_len(),
            transform_strategy: self.op.transform_strategy(),
            flops_estimate: self.op.flops_estimate(),
            resident_bytes: self.resident_bytes(),
        }
    }
}

/// One shape's plan, flattened for display (`ski-tnn plan --explain`).
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub key: ShapeKey,
    pub backend: &'static str,
    pub parallel: bool,
    pub predicted_ns: Option<f64>,
    pub transform_len: Option<usize>,
    pub transform_strategy: Option<&'static str>,
    pub flops_estimate: f64,
    pub resident_bytes: usize,
}

/// Build a full [`ExecutionPlan`] for a shape through the cost-model
/// dispatcher: decide the backend (honouring a forced `kind`), whether
/// sharding pays, and the predicted batch cost; then build the
/// operator via `make(kind)`.
pub fn plan_shape(
    key: ShapeKey,
    dispatch: &Dispatch,
    kind: BackendKind,
    make: impl FnOnce(BackendKind) -> Arc<dyn ToeplitzOp>,
) -> ExecutionPlan {
    let q = key.query();
    let (chosen, parallel, predicted) = match kind {
        BackendKind::Auto => dispatch.plan_costed(&q),
        k => {
            let q = DispatchQuery { causal: k == BackendKind::Freq, ..q };
            let parallel = dispatch.should_shard(k, &q);
            (k, parallel, dispatch.predicted_ns(k, &q).unwrap_or(0.0))
        }
    };
    ExecutionPlan::new(key, chosen, parallel, Some(predicted), make(chosen))
}

/// Exact counters for one [`PlanCache`] — mirrored into the global
/// `plan.cache.*` telemetry series, kept separately so tests can
/// assert exact counts without enabling telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evicts: u64,
    pub len: usize,
    pub cap: usize,
}

struct CacheInner {
    lru: LruCore<ShapeKey, Arc<ExecutionPlan>>,
    published_bytes: i64,
    published_size: i64,
}

/// A concurrently shared, bounded map of [`ExecutionPlan`]s with LRU
/// eviction and exact accounting.
///
/// Lookups resolve **under the lock** — including the build on a miss
/// — so `hits + misses` equals lookups exactly even when 8 threads
/// hammer mixed shapes, and two threads can never build the same plan
/// twice.  Plan builds are rare (one per distinct shape, not per
/// request) and never re-enter the cache, so holding the lock through
/// a build cannot deadlock; the warm path is one mutex, one hash
/// probe, one `Arc` clone — no allocation.
pub struct PlanCache {
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evicts: AtomicU64,
}

impl PlanCache {
    /// A cache holding at most `cap` plans (`0` is clamped to 1).
    pub fn new(cap: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(CacheInner {
                lru: LruCore::new(cap),
                published_bytes: 0,
                published_size: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evicts: AtomicU64::new(0),
        }
    }

    /// The resident plan for `key`, building (and caching, evicting
    /// the LRU plan past capacity) on a miss.
    pub fn get_or_build(
        &self,
        key: ShapeKey,
        build: impl FnOnce() -> ExecutionPlan,
    ) -> Arc<ExecutionPlan> {
        let mut inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(p) = inner.lru.get(&key) {
            let p = Arc::clone(p);
            self.hits.fetch_add(1, Ordering::Relaxed);
            PLAN_CACHE_HIT.incr();
            return p;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        PLAN_CACHE_MISS.incr();
        let plan = Arc::new(build());
        let evicted = inner.lru.insert(key, Arc::clone(&plan));
        if !evicted.is_empty() {
            self.evicts.fetch_add(evicted.len() as u64, Ordering::Relaxed);
            PLAN_CACHE_EVICT.add(evicted.len() as u64);
        }
        Self::republish(&mut inner);
        plan
    }

    /// The resident plan for `key` without building (diagnostics).
    pub fn peek(&self, key: &ShapeKey) -> Option<Arc<ExecutionPlan>> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).lru.peek(key).cloned()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).lru.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn cap(&self) -> usize {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).lru.cap()
    }

    /// Exact lifetime counters plus current occupancy.
    pub fn stats(&self) -> PlanCacheStats {
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evicts: self.evicts.load(Ordering::Relaxed),
            len: inner.lru.len(),
            cap: inner.lru.cap(),
        }
    }

    /// Recompute and return this cache's resident bytes (tick buffers
    /// grow with traffic after insert, so accounting published at
    /// mutation time can lag; callers wanting fresh totals — the stats
    /// snapshot path, `plan --explain` — refresh here).
    pub fn refresh_bytes(&self) -> usize {
        let mut inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        Self::republish(&mut inner);
        inner.published_bytes.max(0) as usize
    }

    /// Republishes this cache's resident-byte / size contribution into
    /// the process-wide totals behind the `plan.cache.{bytes,size}`
    /// gauges.  Called with the cache lock held.
    fn republish(inner: &mut CacheInner) {
        let bytes: usize = inner.lru.values().map(|p| p.resident_bytes()).sum();
        let size = inner.lru.len();
        let db = bytes as i64 - inner.published_bytes;
        let ds = size as i64 - inner.published_size;
        inner.published_bytes = bytes as i64;
        inner.published_size = size as i64;
        let tb = TOTAL_BYTES.fetch_add(db, Ordering::Relaxed) + db;
        let ts = TOTAL_SIZE.fetch_add(ds, Ordering::Relaxed) + ds;
        PLAN_CACHE_BYTES.set(tb.max(0) as f64);
        PLAN_CACHE_SIZE.set(ts.max(0) as f64);
    }
}

impl Drop for PlanCache {
    fn drop(&mut self) {
        let inner = self.inner.get_mut().unwrap_or_else(PoisonError::into_inner);
        TOTAL_BYTES.fetch_sub(inner.published_bytes, Ordering::Relaxed);
        TOTAL_SIZE.fetch_sub(inner.published_size, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::toeplitz::{build_op, ToeplitzKernel};

    fn plan_for(n: usize) -> ExecutionPlan {
        let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
        let op: Arc<dyn ToeplitzOp> =
            Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
        ExecutionPlan::from_op(ShapeKey::for_width(n, 1), op)
    }

    #[test]
    fn cache_counts_hits_misses_and_evictions_exactly() {
        let cache = PlanCache::new(2);
        for &n in &[8usize, 16, 8, 16, 24, 8] {
            let _ = cache.get_or_build(ShapeKey::for_width(n, 1), || plan_for(n));
        }
        let s = cache.stats();
        // 8 → miss, 16 → miss, 8 → hit, 16 → hit, 24 → miss (evicts 8),
        // 8 → miss (evicts 16).
        assert_eq!((s.hits, s.misses, s.evicts), (2, 4, 2), "{s:?}");
        assert_eq!(s.len, 2);
        assert!(s.len <= s.cap);
    }

    #[test]
    fn execute_rows_matches_direct_apply_and_recycles_buffers() {
        let n = 16;
        let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
        let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
        let plan = ExecutionPlan::from_op(ShapeKey::for_width(n, 1), Arc::clone(&op));
        let pool = ThreadPool::new(1);
        let xs: Vec<f32> = (0..2 * n).map(|i| (i as f32) / 7.0 - 2.0).collect();
        let mut encode = |i: usize, sig: &mut [f32]| {
            sig.copy_from_slice(&xs[i * n..(i + 1) * n]);
        };
        assert!(!plan.warmed());
        let first = plan.execute_rows(2, n, &mut encode, &pool).unwrap();
        assert!(plan.warmed());
        for (row, x) in first.iter().zip(xs.chunks(n)) {
            assert_eq!(**row, *op.apply(x), "plan tick must equal direct apply");
        }
        let mut ptrs: Vec<*const f32> = first.iter().map(|r| r.as_ptr()).collect();
        drop(first);
        let second = plan.execute_rows(2, n, &mut encode, &pool).unwrap();
        let mut again: Vec<*const f32> = second.iter().map(|r| r.as_ptr()).collect();
        ptrs.sort();
        again.sort();
        assert_eq!(ptrs, again, "response rows must recycle through the plan's pool");
    }

    #[test]
    fn execute_rows_rejects_width_mismatch() {
        let plan = plan_for(4);
        let pool = ThreadPool::new(1);
        let err = plan
            .execute_rows(1, 8, &mut |_i, sig| sig.fill(0.0), &pool)
            .expect_err("width mismatch must error");
        assert!(err.to_string().contains("does not match operator n"), "{err}");
    }

    #[test]
    fn plan_shape_prices_forced_and_auto_backends() {
        let dispatch = Dispatch::default();
        let key = ShapeKey {
            n: 256,
            r: 16,
            w: 9,
            causal: false,
            threads: 2,
            batch_hint: 8,
            kernel_id: 0,
        };
        let kernel = ToeplitzKernel::from_fn(256, |lag| 1.0 / (1.0 + lag.abs() as f32));
        let auto = plan_shape(key, &dispatch, BackendKind::Auto, |kind| {
            Arc::from(build_op(&kernel, kind, key.r, key.w))
        });
        assert_ne!(auto.backend(), BackendKind::Auto, "auto must resolve");
        assert!(auto.predicted_ns().unwrap() > 0.0);
        let forced = plan_shape(key, &dispatch, BackendKind::Dense, |kind| {
            Arc::from(build_op(&kernel, kind, key.r, key.w))
        });
        assert_eq!(forced.backend(), BackendKind::Dense);
        let report = forced.report();
        assert_eq!(report.backend, "dense");
        assert!(report.resident_bytes > 0);
    }
}
