//! Pooled response rows — the recycling half of the serve tick's
//! zero-allocation envelope.
//!
//! The batcher's executor produces one logits row per request, and each
//! row is *moved* to its requester: ownership genuinely leaves the
//! serve loop, so a plain `Vec<f32>` would be a fresh allocation every
//! tick, forever.  [`RowPool`] closes the loop.  Executor rows are
//! [`LogitsRow`]s that remember their home pool and hand their buffer
//! back when dropped (i.e. once the client has consumed the response),
//! and the executor's per-tick container is a [`RowBatch`] that does
//! the same for the outer `Vec`.  After one warm round through the
//! clients, a serve tick draws every response buffer from the free list
//! and allocates nothing — the invariant `tests/alloc_steady.rs`
//! enforces in CI.
//!
//! Rows built from plain vectors (the XLA model path, test oracles) or
//! by cloning are *untethered*: they behave exactly like a `Vec<f32>`
//! and simply drop.  The free lists are bounded ([`ROWS_CAP`] /
//! [`BATCH_CAP`]), so a burst of in-flight responses returning at once
//! can never turn the pool into a leak.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex};

/// Most row buffers a pool will hold; returns beyond this just drop.
const ROWS_CAP: usize = 1024;
/// Most batch containers a pool will hold.
const BATCH_CAP: usize = 8;

#[derive(Default)]
struct PoolInner {
    rows: Vec<Vec<f32>>,
    batches: Vec<Vec<LogitsRow>>,
}

/// Shared free list of response-row buffers for one bucket width.
/// Cheap to clone (one `Arc`); every [`LogitsRow`] it hands out keeps a
/// handle so the buffer finds its way home from any thread.
#[derive(Clone, Default)]
pub struct RowPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl RowPool {
    pub fn new() -> RowPool {
        RowPool::default()
    }

    /// A row holding a copy of `data`, backed by a recycled buffer when
    /// one is free — same-width reuse never reallocates.
    pub fn row(&self, data: &[f32]) -> LogitsRow {
        let mut buf = self.inner.lock().unwrap().rows.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(data);
        LogitsRow { data: buf, home: Some(self.clone()) }
    }

    /// An empty per-tick container, recycled when one is free.
    pub fn batch(&self) -> RowBatch {
        let rows = self.inner.lock().unwrap().batches.pop().unwrap_or_default();
        RowBatch { rows, home: Some(self.clone()) }
    }

    fn give_row(&self, row: Vec<f32>) {
        let mut inner = self.inner.lock().unwrap();
        if inner.rows.len() < ROWS_CAP {
            inner.rows.push(row);
        }
    }

    fn give_batch(&self, batch: Vec<LogitsRow>) {
        debug_assert!(batch.is_empty(), "containers must be drained before return");
        let mut inner = self.inner.lock().unwrap();
        if inner.batches.len() < BATCH_CAP {
            inner.batches.push(batch);
        }
    }

    /// How many row buffers are currently parked in the free list.
    pub fn free_rows(&self) -> usize {
        self.inner.lock().unwrap().rows.len()
    }

    /// Bytes currently parked in the free lists (rows in flight with
    /// clients are owed to their requesters, not the pool).
    pub fn resident_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        let rows: usize =
            inner.rows.iter().map(|r| r.capacity() * std::mem::size_of::<f32>()).sum();
        let batches: usize =
            inner.batches.iter().map(|b| b.capacity() * std::mem::size_of::<LogitsRow>()).sum();
        rows + batches
    }
}

/// One response row of logits.  Dereferences to `[f32]`; pooled rows
/// return their buffer to the [`RowPool`] they came from when dropped,
/// untethered rows (from [`From<Vec<f32>>`] or [`Clone`]) just drop.
#[derive(Default)]
pub struct LogitsRow {
    data: Vec<f32>,
    home: Option<RowPool>,
}

impl From<Vec<f32>> for LogitsRow {
    fn from(data: Vec<f32>) -> LogitsRow {
        LogitsRow { data, home: None }
    }
}

impl Deref for LogitsRow {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl Drop for LogitsRow {
    fn drop(&mut self) {
        if let Some(home) = self.home.take() {
            home.give_row(std::mem::take(&mut self.data));
        }
    }
}

impl Clone for LogitsRow {
    /// Clones are untethered — only the original returns to its pool.
    fn clone(&self) -> LogitsRow {
        LogitsRow { data: self.data.clone(), home: None }
    }
}

impl fmt::Debug for LogitsRow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.data.fmt(f)
    }
}

impl PartialEq for LogitsRow {
    fn eq(&self, other: &LogitsRow) -> bool {
        self.data == other.data
    }
}

impl PartialEq<Vec<f32>> for LogitsRow {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.data == *other
    }
}

/// The executor's per-tick result: one [`LogitsRow`] per batch row.
/// Dereferences to the inner `Vec` (the batcher drains it row by row);
/// a pooled container returns any rows still aboard and then its own
/// allocation to the pool on drop.
#[derive(Default)]
pub struct RowBatch {
    rows: Vec<LogitsRow>,
    home: Option<RowPool>,
}

impl RowBatch {
    pub fn new() -> RowBatch {
        RowBatch::default()
    }
}

impl From<Vec<Vec<f32>>> for RowBatch {
    fn from(rows: Vec<Vec<f32>>) -> RowBatch {
        RowBatch { rows: rows.into_iter().map(LogitsRow::from).collect(), home: None }
    }
}

impl Deref for RowBatch {
    type Target = Vec<LogitsRow>;
    fn deref(&self) -> &Vec<LogitsRow> {
        &self.rows
    }
}

impl DerefMut for RowBatch {
    fn deref_mut(&mut self) -> &mut Vec<LogitsRow> {
        &mut self.rows
    }
}

impl Drop for RowBatch {
    fn drop(&mut self) {
        if let Some(home) = self.home.take() {
            let mut rows = std::mem::take(&mut self.rows);
            rows.clear(); // undrained rows go home first
            home.give_batch(rows);
        }
    }
}

impl fmt::Debug for RowBatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.rows.fmt(f)
    }
}

impl PartialEq for RowBatch {
    fn eq(&self, other: &RowBatch) -> bool {
        self.rows == other.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dropped_row_returns_its_buffer_to_the_pool() {
        let pool = RowPool::new();
        let row = pool.row(&[1.0, 2.0, 3.0]);
        assert_eq!(row, vec![1.0, 2.0, 3.0]);
        assert_eq!(pool.free_rows(), 0);
        let ptr = row.as_ptr();
        drop(row);
        assert_eq!(pool.free_rows(), 1);
        // Single-threaded, the next row pops the very same buffer.
        let again = pool.row(&[4.0, 5.0]);
        assert_eq!(again.as_ptr(), ptr);
        assert_eq!(again, vec![4.0, 5.0]);
    }

    #[test]
    fn clones_and_plain_rows_are_untethered() {
        let pool = RowPool::new();
        let row = pool.row(&[1.0]);
        let copy = row.clone();
        drop(copy);
        assert_eq!(pool.free_rows(), 0, "clone must not return to the pool");
        drop(row);
        assert_eq!(pool.free_rows(), 1);
        drop(LogitsRow::from(vec![9.0]));
        assert_eq!(pool.free_rows(), 1);
    }

    #[test]
    fn batch_drop_returns_undrained_rows_and_container() {
        let pool = RowPool::new();
        let mut batch = pool.batch();
        for i in 0..4 {
            let row = pool.row(&[i as f32]);
            batch.push(row);
        }
        // Drain half (simulating responses handed to requesters), then
        // hand those rows back the way clients do: by dropping.
        let taken: Vec<LogitsRow> = batch.drain(..2).collect();
        drop(taken);
        assert_eq!(pool.free_rows(), 2);
        drop(batch);
        assert_eq!(pool.free_rows(), 4, "undrained rows must return on container drop");
        // The container itself is recycled too.
        let next = pool.batch();
        assert!(next.is_empty() && next.capacity() >= 4);
    }

    #[test]
    fn from_vec_of_vecs_adapts_plain_executors() {
        let batch = RowBatch::from(vec![vec![1.0, 2.0], vec![3.0]]);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0], vec![1.0, 2.0]);
        assert_eq!(batch[1], vec![3.0]);
    }
}
