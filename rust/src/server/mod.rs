//! Serving — dynamic batcher + request router over the `logits` entry,
//! and the session-aware generation scheduler.
//!
//! The inference-side counterpart of the coordinator (vLLM-router
//! shaped, scaled to this paper's needs): client threads submit token
//! sequences through a bounded queue; the single runtime thread drains
//! the queue with a batch-size/timeout policy, pads to the artifact's
//! fixed batch, executes once, and routes each row of logits back to
//! its caller with queueing/latency metadata.
//!
//! The model executor is abstracted as a closure so the batching policy
//! is unit-testable without XLA; [`serve_model`] adapts a
//! [`ModelState`](crate::runtime::ModelState) + engine into that
//! closure for the real thing.
//!
//! [`GenScheduler`] is the autoregressive sibling: a continuous-
//! batching loop over live [`crate::decode::Session`]s that interleaves
//! one O(1) decode step per session per tick (see `server::generate`).

mod batcher;
mod generate;
mod rows;

pub use batcher::{
    audit_exec, serve_model, serve_toeplitz, serve_toeplitz_factory, serve_toeplitz_on, Batcher,
    BatcherStats, Request, Response, ServerConfig, SERVE_PLAN_CAP,
};
pub use rows::{LogitsRow, RowBatch, RowPool};
pub use generate::{
    GenClient, GenConfig, GenParams, GenRequest, GenResponse, GenScheduler, GenStats,
};
