//! Serving — dynamic batcher + request router over the `logits` entry,
//! and the session-aware generation scheduler.
//!
//! The inference-side counterpart of the coordinator (vLLM-router
//! shaped, scaled to this paper's needs): client threads submit token
//! sequences through a bounded queue; the single runtime thread drains
//! the queue with a batch-size/timeout policy, pads to the artifact's
//! fixed batch, executes once, and routes each row of logits back to
//! its caller with queueing/latency metadata.
//!
//! The model executor is abstracted as a closure so the batching policy
//! is unit-testable without XLA; [`serve_model`] adapts a
//! [`ModelState`](crate::runtime::ModelState) + engine into that
//! closure for the real thing.
//!
//! [`GenScheduler`] is the autoregressive sibling: a continuous-
//! batching loop over live [`crate::decode::Session`]s that interleaves
//! one O(1) decode step per session per tick (see `server::generate`).
//!
//! **Overload control** lives in [`admission`]: both loops sit behind a
//! bounded admission queue with a shed policy and per-request
//! deadlines, publish a [`PressureGauge`] the dispatcher consumes to
//! downshift backends one cost rung, and account every request in an
//! [`AdmissionLedger`] that must balance exactly at quiescence.
//! [`chaos`] is the matching deterministic fault-injection harness
//! (seeded, zero cost when off) that the soak CI job drives.

mod admission;
mod batcher;
pub mod chaos;
mod generate;
mod rows;

pub use admission::{
    admission_queue, Admissible, AdmissionLedger, AdmissionPolicy, AdmissionReceiver,
    AdmissionSender, AdmissionSnapshot, PressureGauge, RecvTimeout, RetryPolicy, ServeError,
    SubmitError, TryRecv, SERVER_PRESSURE,
};
pub use batcher::{
    audit_exec, pressure_scaled_wait, serve_model, serve_toeplitz, serve_toeplitz_factory,
    serve_toeplitz_on, serve_toeplitz_pressured, Batcher, BatcherStats, Request, Response,
    ServerConfig, GATHER_SHRINK, SERVE_PLAN_CAP,
};
pub use generate::{
    GenClient, GenConfig, GenParams, GenRequest, GenResponse, GenScheduler, GenStats,
};
pub use rows::{LogitsRow, RowBatch, RowPool};
