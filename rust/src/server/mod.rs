//! Serving — dynamic batcher + request router over the `logits` entry.
//!
//! The inference-side counterpart of the coordinator (vLLM-router
//! shaped, scaled to this paper's needs): client threads submit token
//! sequences through a bounded queue; the single runtime thread drains
//! the queue with a batch-size/timeout policy, pads to the artifact's
//! fixed batch, executes once, and routes each row of logits back to
//! its caller with queueing/latency metadata.
//!
//! The model executor is abstracted as a closure so the batching policy
//! is unit-testable without XLA; [`serve_model`] adapts a
//! [`ModelState`](crate::runtime::ModelState) + engine into that
//! closure for the real thing.

mod batcher;

pub use batcher::{serve_model, Batcher, BatcherStats, Request, Response, ServerConfig};
