//! Dynamic batching policy and request plumbing.
//!
//! Policy: block for the first request, then keep admitting until
//! either the model batch is full or `max_wait` has elapsed since the
//! first admit — the standard latency/throughput knob.  Short rows are
//! padded with PAD to the model context; surplus capacity is padded
//! with zero rows and the corresponding logits discarded.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::data::PAD;
use crate::runtime::{global_pool, Engine, HostTensor, ModelState, ThreadPool};
use crate::toeplitz::{apply_batch_sharded, ToeplitzOp};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Rows per model execution (must equal the artifact batch dim).
    pub max_batch: usize,
    /// Model context length (rows are padded/truncated to this).
    pub n: usize,
    /// How long to hold an open batch hoping for more requests.
    pub max_wait: Duration,
    /// Bounded queue depth — overflow is backpressure, not OOM.
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            n: 256,
            max_wait: Duration::from_millis(2),
            queue_depth: 64,
        }
    }
}

/// One inference request: token ids in, logits out.
pub struct Request {
    pub ids: Vec<i32>,
    pub resp: SyncSender<Response>,
    pub submitted: Instant,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Logits row for this request (num_classes or vocab wide).
    pub logits: Vec<f32>,
    /// Time spent queued before execution started.
    pub queued: Duration,
    /// Size of the batch this request rode in (diagnostics).
    pub batch_rows: usize,
}

/// Aggregate server-side counters.
#[derive(Debug, Default, Clone)]
pub struct BatcherStats {
    pub requests: usize,
    pub batches: usize,
    pub padded_rows: usize,
    pub exec_seconds: f64,
    /// Per-request time spent queued before its batch executed —
    /// recorded server-side so latency reports don't rely on ad-hoc
    /// client-side timing.  Bounded: holds the most recent
    /// [`QUEUE_SAMPLE_CAP`] samples so a long-lived server stays O(1)
    /// in request count.
    pub queue_seconds: Vec<f64>,
}

/// Latency-sample window size shared by the batcher and the
/// generation scheduler (8 B × 65536 = 512 KiB worst case).
pub const QUEUE_SAMPLE_CAP: usize = 65536;

impl BatcherStats {
    pub fn mean_batch_fill(&self, max_batch: usize) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.requests as f64 / (self.batches * max_batch) as f64
    }

    /// Queue-latency percentile (`p` in [0, 1]); 0.0 before traffic.
    pub fn queue_pct(&self, p: f64) -> f64 {
        crate::util::bench::percentiles_of(&self.queue_seconds, &[p])[0]
    }

    /// (p50, p95, p99) queue latency, seconds.
    pub fn queue_percentiles(&self) -> (f64, f64, f64) {
        let ps = crate::util::bench::percentiles_of(&self.queue_seconds, &[0.50, 0.95, 0.99]);
        (ps[0], ps[1], ps[2])
    }
}

/// Client handle: submit sequences, receive logits.
#[derive(Clone)]
pub struct ClientHandle {
    tx: SyncSender<Request>,
}

impl ClientHandle {
    /// Blocking round-trip: submit and wait for the response.
    pub fn infer(&self, ids: Vec<i32>) -> Result<Response> {
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Request { ids, resp: rtx, submitted: Instant::now() })
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))
    }

    /// Non-blocking submit; `Err` means the queue is full (backpressure).
    pub fn try_submit(&self, ids: Vec<i32>) -> Result<Receiver<Response>> {
        let (rtx, rrx) = sync_channel(1);
        match self.tx.try_send(Request { ids, resp: rtx, submitted: Instant::now() }) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => Err(anyhow!("queue full")),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("server stopped")),
        }
    }
}

/// The dynamic batcher. Owns the request queue; `run` drives an
/// executor closure until all client handles are dropped.
pub struct Batcher {
    pub cfg: ServerConfig,
    rx: Receiver<Request>,
    tx: Option<SyncSender<Request>>,
}

impl Batcher {
    pub fn new(cfg: ServerConfig) -> Batcher {
        let (tx, rx) = sync_channel(cfg.queue_depth);
        Batcher { cfg, rx, tx: Some(tx) }
    }

    /// A cloneable client handle (hand to worker threads).
    pub fn handle(&self) -> ClientHandle {
        ClientHandle { tx: self.tx.clone().expect("server already running") }
    }

    /// Drain one batch according to the policy. `None` = all senders
    /// gone and queue empty (shutdown).
    fn gather(&self) -> Option<Vec<Request>> {
        let first = match self.rx.recv() {
            Ok(r) => r,
            Err(_) => return None,
        };
        let mut reqs = vec![first];
        let deadline = Instant::now() + self.cfg.max_wait;
        while reqs.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(r) => reqs.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(reqs)
    }

    /// Run the serve loop with an arbitrary executor.
    ///
    /// `exec` maps a padded `(max_batch, n)` i32 tensor to per-row
    /// logits.  Drop the `Batcher`'s own sender first so the loop ends
    /// when every [`ClientHandle`] is gone.
    pub fn run<F>(mut self, mut exec: F) -> Result<BatcherStats>
    where
        F: FnMut(&HostTensor) -> Result<Vec<Vec<f32>>>,
    {
        drop(self.tx.take()); // only client handles keep the queue alive
        let (bcap, n) = (self.cfg.max_batch, self.cfg.n);
        let mut stats = BatcherStats::default();
        while let Some(reqs) = self.gather() {
            let started = Instant::now();
            let mut ids = vec![PAD; bcap * n];
            for (row, req) in reqs.iter().enumerate() {
                let take = req.ids.len().min(n);
                ids[row * n..row * n + take].copy_from_slice(&req.ids[..take]);
            }
            let batch = HostTensor::i32(vec![bcap, n], ids);
            let t0 = Instant::now();
            let rows = exec(&batch)?;
            stats.exec_seconds += t0.elapsed().as_secs_f64();
            if rows.len() < reqs.len() {
                return Err(anyhow!("executor returned {} rows for {} requests",
                    rows.len(), reqs.len()));
            }
            let nreq = reqs.len();
            stats.requests += nreq;
            stats.batches += 1;
            stats.padded_rows += bcap - nreq;
            for (i, (req, logits)) in reqs.into_iter().zip(rows).enumerate() {
                let queued = started.duration_since(req.submitted);
                crate::util::bench::push_sample(
                    &mut stats.queue_seconds,
                    QUEUE_SAMPLE_CAP,
                    stats.requests - nreq + i,
                    queued.as_secs_f64(),
                );
                let _ = req.resp.send(Response { logits, queued, batch_rows: bcap });
            }
        }
        Ok(stats)
    }
}

/// Adapt a real model into a [`Batcher::run`] executor.
pub fn serve_model<'a>(
    engine: &'a Engine,
    state: &'a ModelState,
) -> impl FnMut(&HostTensor) -> Result<Vec<Vec<f32>>> + 'a {
    move |batch: &HostTensor| {
        let ids = batch.to_literal()?;
        let out = state.logits(engine, &ids)?;
        let shape = out.shape().to_vec();
        let data = out.as_f32()?;
        let width = shape[1];
        Ok(data.chunks(width).map(|c| c.to_vec()).collect())
    }
}

/// Map one batcher row of token ids to an f32 signal on [-1, 1)
/// (PAD → 0, so padded tail positions are silent).
fn ids_to_signal(row: &[i32]) -> Vec<f32> {
    row.iter().map(|&t| if t == PAD { 0.0 } else { t as f32 / 128.0 - 1.0 }).collect()
}

/// Adapt a [`ToeplitzOp`] backend into a [`Batcher::run`] executor:
/// each row's ids become an f32 signal and the response row is the
/// operator applied to it, with the batch **sharded across the global
/// thread pool** (`SKI_TNN_THREADS`-sized) instead of looped serially.
/// This is how the backend dispatcher rides the same
/// queueing/batching policy as the XLA model path — and the
/// artifact-free load-test target of `ski-tnn serve --backend …`.
pub fn serve_toeplitz(
    op: Arc<dyn ToeplitzOp>,
) -> impl FnMut(&HostTensor) -> Result<Vec<Vec<f32>>> {
    move |batch: &HostTensor| exec_toeplitz(op.as_ref(), global_pool(), batch)
}

/// [`serve_toeplitz`] on an explicit pool (per-run `--threads`).
pub fn serve_toeplitz_on(
    op: Arc<dyn ToeplitzOp>,
    pool: Arc<ThreadPool>,
) -> impl FnMut(&HostTensor) -> Result<Vec<Vec<f32>>> {
    move |batch: &HostTensor| exec_toeplitz(op.as_ref(), &pool, batch)
}

fn exec_toeplitz(
    op: &dyn ToeplitzOp,
    pool: &ThreadPool,
    batch: &HostTensor,
) -> Result<Vec<Vec<f32>>> {
    let shape = batch.shape().to_vec();
    ensure!(shape.len() == 2, "expected a (batch, n) ids tensor, got {shape:?}");
    ensure!(shape[1] == op.n(), "row width {} does not match operator n {}", shape[1], op.n());
    let ids = batch.as_i32()?;
    let rows: Vec<Vec<f32>> = ids.chunks(shape[1]).map(ids_to_signal).collect();
    Ok(apply_batch_sharded(op, &rows, pool))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo executor: logits[row] = [sum of that row's non-PAD ids].
    fn echo(batch: &HostTensor) -> Result<Vec<Vec<f32>>> {
        let shape = batch.shape().to_vec();
        let ids = batch.as_i32()?;
        Ok(ids
            .chunks(shape[1])
            .map(|row| {
                vec![row.iter().filter(|&&t| t != PAD).map(|&t| t as f32).sum::<f32>()]
            })
            .collect())
    }

    fn small_cfg() -> ServerConfig {
        ServerConfig { max_batch: 4, n: 8, max_wait: Duration::from_millis(5), queue_depth: 16 }
    }

    #[test]
    fn roundtrip_many_clients() {
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        let clients: Vec<_> = (0..3)
            .map(|c| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..20 {
                        let ids = vec![c as i32 + 1; (i % 8) + 1];
                        let want: f32 = ids.iter().map(|&t| t as f32).sum();
                        let resp = h.infer(ids).unwrap();
                        assert_eq!(resp.logits, vec![want]);
                        assert_eq!(resp.batch_rows, 4);
                    }
                })
            })
            .collect();
        drop(h);
        let stats = b.run(echo).unwrap();
        for c in clients {
            c.join().unwrap();
        }
        assert_eq!(stats.requests, 60);
        assert!(stats.batches <= 60);
        assert!(stats.batches >= 15, "batching should coalesce: {}", stats.batches);
    }

    #[test]
    fn batches_coalesce_under_burst() {
        let b = Batcher::new(ServerConfig {
            max_wait: Duration::from_millis(50),
            ..small_cfg()
        });
        let h = b.handle();
        let t = std::thread::spawn(move || {
            let pending: Vec<_> =
                (0..8).map(|i| h.try_submit(vec![i as i32 + 1]).unwrap()).collect();
            let resps: Vec<Response> =
                pending.into_iter().map(|rx| rx.recv().unwrap()).collect();
            resps
        });
        let stats = b.run(echo).unwrap();
        let resps = t.join().unwrap();
        assert_eq!(resps.len(), 8);
        // 8 requests at max_batch 4 must ride exactly 2 full batches
        assert_eq!(stats.batches, 2, "burst should fill batches");
        assert_eq!(stats.padded_rows, 0);
    }

    #[test]
    fn truncates_overlong_rows() {
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        let t = std::thread::spawn(move || h.infer(vec![1; 100]).unwrap());
        let stats = b.run(echo).unwrap();
        let resp = t.join().unwrap();
        assert_eq!(resp.logits, vec![8.0], "row must be truncated to n=8");
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.padded_rows, 3);
    }

    #[test]
    fn queue_percentiles_recorded_server_side() {
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        let t = std::thread::spawn(move || {
            for i in 0..12 {
                let _ = h.infer(vec![i as i32 + 1]).unwrap();
            }
        });
        let stats = b.run(echo).unwrap();
        t.join().unwrap();
        assert_eq!(stats.queue_seconds.len(), stats.requests);
        let (p50, p95, p99) = stats.queue_percentiles();
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(p99 >= 0.0 && p99 < 5.0, "queue p99 {p99}s out of range");
        assert_eq!(stats.queue_pct(0.99), p99);
    }

    #[test]
    fn toeplitz_executor_serves_backend_applies() {
        use crate::toeplitz::{build_op, BackendKind, ToeplitzKernel};
        let n = 8;
        let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
        let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        let ids: Vec<i32> = (0..n as i32).collect();
        let t = {
            let ids = ids.clone();
            std::thread::spawn(move || h.infer(ids).unwrap())
        };
        let stats = b.run(serve_toeplitz(op)).unwrap();
        let resp = t.join().unwrap();
        // Oracle: the same signal through the dense apply.
        let want = kernel.apply_dense(&ids_to_signal(&ids));
        assert_eq!(resp.logits.len(), n);
        for (i, (a, b)) in resp.logits.iter().zip(want.iter()).enumerate() {
            assert!((a - b).abs() < 1e-4, "row value {i}: {a} vs {b}");
        }
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn toeplitz_executor_pooled_matches_serial() {
        // The sharded executor must answer bit-for-bit what a
        // single-thread pool answers, whatever the worker count.
        use crate::toeplitz::{build_op, BackendKind, ToeplitzKernel};
        let n = 16;
        let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
        let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
        let ids: Vec<i32> = (0..4 * n as i32).collect();
        let batch = HostTensor::i32(vec![4, n], ids);
        let mut serial = serve_toeplitz_on(op.clone(), Arc::new(ThreadPool::new(1)));
        let mut pooled = serve_toeplitz_on(op, Arc::new(ThreadPool::new(4)));
        assert_eq!(serial(&batch).unwrap(), pooled(&batch).unwrap());
    }

    #[test]
    fn toeplitz_executor_rejects_width_mismatch() {
        use crate::toeplitz::{build_op, BackendKind, ToeplitzKernel};
        let kernel = ToeplitzKernel::from_fn(4, |_| 1.0);
        let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Dense, 0, 0));
        let mut exec = serve_toeplitz(op);
        let batch = HostTensor::i32(vec![1, 8], vec![0; 8]);
        assert!(exec(&batch).is_err(), "width mismatch must surface as an executor error");
    }

    #[test]
    fn shutdown_when_handles_dropped() {
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        drop(h);
        let stats = b.run(echo).unwrap(); // must return immediately
        assert_eq!(stats.requests, 0);
    }
}
