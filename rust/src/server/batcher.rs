//! Dynamic batching policy and request plumbing.
//!
//! Policy: block for the first request, then keep admitting until
//! either the model batch is full or the gather window has elapsed
//! since the first admit — the standard latency/throughput knob.
//! Short rows are padded with PAD; surplus capacity is padded with
//! zero rows and the corresponding logits discarded.
//!
//! **Length buckets**: with `ServerConfig::buckets` set, a gathered
//! batch is partitioned by row length into per-bucket sub-batches —
//! each request pads only to the smallest bucket ≥ its length instead
//! of the full model context, so mixed-length traffic stops paying
//! max-length compute for every short row.  Empty `buckets` keeps the
//! single fixed-width behaviour (the AOT model path, whose artifact
//! batch shape is baked in).
//!
//! **Hardening**: an executor failure answers the affected requests
//! with error responses and the serve loop keeps going — a malformed
//! batch can no longer abort the batcher (`BatcherStats::exec_errors`
//! counts the casualties).
//!
//! **Overload control** (see [`super::admission`]): the request queue
//! is a bounded [`admission_queue`] with a configurable shed policy
//! and per-request deadlines.  Requests whose deadline passes while
//! queued are answered with a typed [`ServeError::DeadlineExceeded`]
//! after every gather, the loop publishes a [`PressureGauge`] the
//! dispatch closures read to downshift backends, and the gather window
//! itself shrinks under pressure ([`pressure_scaled_wait`]) — under
//! load the batcher trades batching efficiency for latency headroom
//! instead of collapsing.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::data::PAD;
use crate::plan::{ExecutionPlan, PlanCache, ShapeKey};
use crate::runtime::{global_pool, Engine, HostTensor, ModelState, ThreadPool};
use crate::telemetry;
use crate::toeplitz::{BackendKind, Dispatch, DispatchQuery, ToeplitzOp, PRESSURE_DOWNSHIFT};
use crate::util::rng::Rng;

use super::admission::{
    admission_queue, Admissible, AdmissionLedger, AdmissionPolicy, AdmissionReceiver,
    AdmissionSender, AdmissionSnapshot, PressureGauge, RecvTimeout, RetryPolicy, ServeError,
    SubmitError, SERVER_PRESSURE,
};
use super::rows::{LogitsRow, RowBatch};

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Rows per model execution (must equal the artifact batch dim).
    pub max_batch: usize,
    /// Model context length (rows are padded/truncated to this).
    pub n: usize,
    /// How long to hold an open batch hoping for more requests (the
    /// zero-pressure gather window; it shrinks as pressure rises).
    pub max_wait: Duration,
    /// Bounded queue depth — overflow is backpressure or shedding
    /// (per `policy`), never OOM.
    pub queue_depth: usize,
    /// Length buckets (row widths) for mixed-length serving; empty =
    /// one fixed width `n`.  Normalised at startup: sorted, deduped,
    /// clamped to `n`, with `n` always the top bucket.
    pub buckets: Vec<usize>,
    /// What a full queue does to a blocking submit.
    pub policy: AdmissionPolicy,
    /// Default per-request deadline (from submit); `None` = no
    /// deadline.  Clients may override per handle.
    pub deadline: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            n: 256,
            max_wait: Duration::from_millis(2),
            queue_depth: 64,
            buckets: Vec::new(),
            policy: AdmissionPolicy::Block,
            deadline: None,
        }
    }
}

impl ServerConfig {
    /// The effective bucket widths, ascending, ending at `n` (a single
    /// `[n]` when bucketing is off).
    pub fn bucket_widths(&self) -> Vec<usize> {
        let mut ws: Vec<usize> =
            self.buckets.iter().copied().filter(|&w| w >= 1 && w < self.n).collect();
        ws.sort_unstable();
        ws.dedup();
        ws.push(self.n);
        ws
    }

    /// The width a row of `len` ids executes at: the smallest bucket
    /// that fits it, else the top bucket (the row is truncated there,
    /// exactly like the fixed-width path truncates to `n`).
    pub fn bucket_for(&self, len: usize) -> usize {
        let ws = self.bucket_widths();
        ws[bucket_index(&ws, len)]
    }
}

/// Index of the smallest bucket fitting `len` in precomputed
/// (ascending, non-empty) widths, else the last — the one bucket rule,
/// shared by [`ServerConfig::bucket_for`] and the run-loop partition.
fn bucket_index(widths: &[usize], len: usize) -> usize {
    widths.iter().position(|&w| len <= w).unwrap_or(widths.len() - 1)
}

/// One inference request: token ids in, logits out.
pub struct Request {
    pub ids: Vec<i32>,
    pub resp: SyncSender<Response>,
    pub submitted: Instant,
    /// Absolute deadline; past it the request is answered with
    /// [`ServeError::DeadlineExceeded`] instead of executing.
    pub deadline: Option<Instant>,
}

impl Admissible for Request {
    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    fn reject(self, err: ServeError) {
        let queued = self.submitted.elapsed();
        let _ = self.resp.send(Response {
            logits: LogitsRow::default(),
            queued,
            batch_rows: 0,
            width: 0,
            error: Some(err),
        });
    }
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Logits row for this request (num_classes or vocab wide).
    /// Dereferences to `[f32]`; substrate rows are pooled — dropping
    /// the response returns the buffer to the serving plan's
    /// [`RowPool`](super::rows::RowPool), which is what keeps a warm
    /// serve tick allocation-free end to end.
    pub logits: LogitsRow,
    /// Time spent queued before execution started.
    pub queued: Duration,
    /// Size of the batch this request rode in (diagnostics).
    pub batch_rows: usize,
    /// Row width this request executed at (its length bucket; `cfg.n`
    /// when bucketing is off).
    pub width: usize,
    /// Set when this request did not execute successfully: a typed
    /// overload/deadline answer from admission control, or
    /// [`ServeError::Exec`] when its batch's executor failed (the
    /// batcher loop carried on).  [`ClientHandle::infer`] surfaces it
    /// as an `Err`.
    pub error: Option<ServeError>,
}

/// Aggregate server-side counters.
#[derive(Debug, Default, Clone)]
pub struct BatcherStats {
    pub requests: usize,
    pub batches: usize,
    pub padded_rows: usize,
    /// Total tensor rows across executions — the honest denominator
    /// for batch fill: bucketed sub-batches size their tensors to
    /// their own rows, so `batches * max_batch` would over-count their
    /// capacity.
    pub exec_rows: usize,
    /// Requests answered with an error because their batch's executor
    /// failed (the loop itself survives — see the module docs).
    pub exec_errors: usize,
    pub exec_seconds: f64,
    /// Per-request time spent queued before its batch executed —
    /// recorded server-side so latency reports don't rely on ad-hoc
    /// client-side timing.  Bounded: holds the most recent
    /// [`QUEUE_SAMPLE_CAP`] raw samples — kept as a compatibility view
    /// of recent traffic; the percentile accessors read `queue_hist`.
    pub queue_seconds: Vec<f64>,
    /// Whole-run queue-latency histogram (log₂-bucketed, O(1) memory —
    /// see `telemetry::Histogram`).  [`queue_pct`](Self::queue_pct) /
    /// [`queue_percentiles`](Self::queue_percentiles) read this, so a
    /// long-lived server reports percentiles over **every** request
    /// instead of the bounded recent-sample window above.
    pub queue_hist: Arc<telemetry::Histogram>,
    /// End-of-run admission ledger snapshot — at this point
    /// [`AdmissionSnapshot::balanced`] must hold (the chaos soak
    /// gates on it).
    pub admission: AdmissionSnapshot,
}

/// Latency-sample window size shared by the batcher and the
/// generation scheduler (8 B × 65536 = 512 KiB worst case).
pub const QUEUE_SAMPLE_CAP: usize = 65536;

impl BatcherStats {
    pub fn mean_batch_fill(&self, max_batch: usize) -> f64 {
        // Executed row capacity when recorded (always, for stats from
        // a `run` loop); the legacy `batches * max_batch` denominator
        // is kept for stats assembled without per-execution tracking.
        let cap = if self.exec_rows > 0 { self.exec_rows } else { self.batches * max_batch };
        if cap == 0 {
            return 0.0;
        }
        self.requests as f64 / cap as f64
    }

    /// Queue-latency percentile (`p` in [0, 1]); 0.0 before traffic.
    /// Reads the whole-run histogram: covers every request ever
    /// served, bucketed — the estimate is within 2× of the exact
    /// order statistic (`queue_seconds` still holds exact recent raw
    /// samples for anyone who needs them).
    pub fn queue_pct(&self, p: f64) -> f64 {
        self.queue_hist.quantile(p) * 1e-9
    }

    /// (p50, p95, p99) queue latency, seconds, over the whole run.
    pub fn queue_percentiles(&self) -> (f64, f64, f64) {
        (self.queue_pct(0.50), self.queue_pct(0.95), self.queue_pct(0.99))
    }

    /// Record one request's queue wait everywhere it is reported: the
    /// whole-run histogram, the bounded recent-sample window, and
    /// (when telemetry is enabled) the global `span.queue_wait`
    /// series.
    fn record_queue_wait(&mut self, index: usize, queued: Duration) {
        let secs = queued.as_secs_f64();
        self.queue_hist.record_secs(secs);
        crate::util::bench::push_sample(&mut self.queue_seconds, QUEUE_SAMPLE_CAP, index, secs);
        telemetry::SPAN_QUEUE_WAIT.record_ns(queued.as_nanos() as u64);
    }
}

/// Client handle: submit sequences, receive logits.
#[derive(Clone)]
pub struct ClientHandle {
    tx: AdmissionSender<Request>,
    deadline: Option<Duration>,
}

impl ClientHandle {
    /// This handle with a different per-request deadline (`None`
    /// disables; the config default is what [`Batcher::handle`]
    /// installs).
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> ClientHandle {
        self.deadline = deadline;
        self
    }

    fn request(&self, ids: Vec<i32>) -> (Request, Receiver<Response>) {
        let (rtx, rrx) = sync_channel(1);
        let now = Instant::now();
        let deadline = self.deadline.map(|d| now + d);
        (Request { ids, resp: rtx, submitted: now, deadline }, rrx)
    }

    /// Blocking round-trip: submit and wait for the response.  A
    /// failed execution comes back as `Err` (the response's `error`
    /// field), not a dead server.
    pub fn infer(&self, ids: Vec<i32>) -> Result<Response> {
        let resp = self.infer_response(ids)?;
        match &resp.error {
            None => Ok(resp),
            Some(e) => Err(anyhow!("inference failed: {e}")),
        }
    }

    /// [`infer`](Self::infer) without the error-field mapping: the
    /// typed overload/deadline/executor answer comes back as the
    /// response itself — the raw form retry loops match on.
    pub fn infer_response(&self, ids: Vec<i32>) -> Result<Response> {
        let (req, rrx) = self.request(ids);
        self.tx.submit(req).map_err(|e| anyhow!("{e}"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))
    }

    /// Blocking-admission submit that hands back the response channel
    /// without waiting for the answer.  Under a `block` policy this
    /// waits for queue room; under a shed policy it returns
    /// immediately and the queue may shed — the typed `Overloaded` /
    /// `DeadlineExceeded` answer arrives on the channel like any
    /// other.  `Ok` therefore guarantees exactly one response;
    /// `Err(Stopped)` means the serve loop is gone.
    pub fn submit(&self, ids: Vec<i32>) -> Result<Receiver<Response>, SubmitError> {
        let (req, rrx) = self.request(ids);
        self.tx.submit(req)?;
        Ok(rrx)
    }

    /// Non-blocking submit; a full queue is an immediate typed
    /// [`SubmitError::QueueFull`] (client-side backpressure — nothing
    /// was queued and no response will arrive).
    pub fn try_submit(&self, ids: Vec<i32>) -> Result<Receiver<Response>, SubmitError> {
        let (req, rrx) = self.request(ids);
        self.tx.try_submit(req)?;
        Ok(rrx)
    }

    /// Submit with client-side retry: jittered exponential backoff on
    /// `QueueFull` and on typed overload answers, bounded by the
    /// policy's attempt count and total-time budget.  Non-retryable
    /// failures (executor errors, server stopped) return immediately.
    pub fn infer_with_retry(&self, ids: Vec<i32>, policy: &RetryPolicy) -> Result<Response> {
        let ledger = self.tx.ledger();
        let started = Instant::now();
        let mut rng = Rng::new(policy.seed);
        let mut last_err = anyhow!("no attempt made");
        for attempt in 0..policy.attempts.max(1) {
            if attempt > 0 {
                let pause = policy.backoff(attempt as u32 - 1, &mut rng);
                if started.elapsed() + pause >= policy.budget {
                    break;
                }
                std::thread::sleep(pause);
                ledger.note_retry();
            }
            match self.try_submit(ids.clone()) {
                Err(SubmitError::Stopped) => return Err(anyhow!("server stopped")),
                Err(SubmitError::QueueFull) => {
                    last_err = anyhow!("queue full");
                }
                Ok(rrx) => {
                    let resp = rrx.recv().map_err(|_| anyhow!("server dropped request"))?;
                    match &resp.error {
                        None => return Ok(resp),
                        Some(e) if e.retryable() => {
                            last_err = anyhow!("inference failed: {e}");
                        }
                        Some(e) => return Err(anyhow!("inference failed: {e}")),
                    }
                }
            }
        }
        Err(last_err.context(format!(
            "retries exhausted ({} attempts, {:?} elapsed)",
            policy.attempts,
            started.elapsed()
        )))
    }
}

/// Fraction of the gather window surrendered at full pressure: the
/// batcher stops waiting for stragglers when the queue is the
/// bottleneck, trading batch fill for deadline headroom.
pub const GATHER_SHRINK: f64 = 0.75;

/// The gather window at a given pressure: `max_wait` at 0, shrinking
/// linearly to `(1 - GATHER_SHRINK) * max_wait` at 1.
pub fn pressure_scaled_wait(max_wait: Duration, pressure: f64) -> Duration {
    max_wait.mul_f64(1.0 - GATHER_SHRINK * pressure.clamp(0.0, 1.0))
}

/// The dynamic batcher. Owns the request queue; `run` drives an
/// executor closure until all client handles are dropped.
pub struct Batcher {
    pub cfg: ServerConfig,
    rx: AdmissionReceiver<Request>,
    tx: Option<AdmissionSender<Request>>,
    pressure: PressureGauge,
}

impl Batcher {
    pub fn new(cfg: ServerConfig) -> Batcher {
        let (tx, rx) = admission_queue(cfg.queue_depth, cfg.policy, cfg.deadline);
        Batcher { cfg, rx, tx: Some(tx), pressure: PressureGauge::new() }
    }

    /// A cloneable client handle (hand to worker threads), carrying
    /// the config's default deadline.
    pub fn handle(&self) -> ClientHandle {
        ClientHandle {
            tx: self.tx.clone().expect("server already running"),
            deadline: self.cfg.deadline,
        }
    }

    /// The overload gauge this batcher publishes each gather — hand a
    /// clone to the dispatch closures for pressure-aware planning
    /// ([`Dispatch::plan_pressured`](crate::toeplitz::Dispatch::plan_pressured)).
    pub fn pressure(&self) -> PressureGauge {
        self.pressure.clone()
    }

    /// Live admission accounting (the end-of-run snapshot rides
    /// [`BatcherStats::admission`]).
    pub fn ledger(&self) -> Arc<AdmissionLedger> {
        self.rx.ledger()
    }

    /// Drain one batch according to the policy. `None` = all senders
    /// gone and queue empty (shutdown).
    fn gather(&self) -> Option<Vec<Request>> {
        let first = self.rx.recv()?;
        // Publish pressure once per gather, from the post-pop queue
        // state: the gauge feeds the dispatch closures and telemetry,
        // and scales this gather's own window.
        let pressure = self.rx.pressure();
        self.pressure.set(pressure);
        SERVER_PRESSURE.set(pressure);
        let mut reqs = vec![first];
        let deadline = Instant::now() + pressure_scaled_wait(self.cfg.max_wait, pressure);
        while reqs.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                RecvTimeout::Item(r) => reqs.push(r),
                RecvTimeout::TimedOut | RecvTimeout::Disconnected => break,
            }
        }
        Some(reqs)
    }

    /// Run the serve loop with an arbitrary executor.
    ///
    /// `exec` maps a padded `(max_batch, width)` i32 tensor to per-row
    /// logits — `width` is `cfg.n` without buckets, a bucket width
    /// with them (one executor call per bucket present in the
    /// gathered batch).  An executor failure answers its requests with
    /// error responses and the loop continues.  Drop the `Batcher`'s
    /// own sender first so the loop ends when every [`ClientHandle`]
    /// is gone.
    pub fn run<F>(mut self, mut exec: F) -> Result<BatcherStats>
    where
        F: FnMut(&HostTensor) -> Result<RowBatch>,
    {
        drop(self.tx.take()); // only client handles keep the queue alive
        let ledger = self.rx.ledger();
        let widths = self.cfg.bucket_widths();
        let mut stats = BatcherStats::default();
        let mut expired_total = 0usize;
        while let Some(reqs) = self.gather() {
            let started = Instant::now();
            // Deadline sweep: anything that expired while queued (or
            // while the gather window held the batch open) gets its
            // typed answer now, before any compute is spent on it.
            let mut live = Vec::with_capacity(reqs.len());
            for req in reqs {
                if req.expired(started) {
                    let queued = started.duration_since(req.submitted);
                    stats.record_queue_wait(stats.requests + expired_total, queued);
                    expired_total += 1;
                    ledger.note_expired();
                    req.reject(ServeError::DeadlineExceeded);
                } else {
                    live.push(req);
                }
            }
            if live.is_empty() {
                continue;
            }
            // Partition into per-bucket sub-batches (arrival order is
            // kept within a bucket; one bucket ⇒ one execution, so
            // the non-bucketed path is exactly the old single batch).
            let groups = {
                let _span = telemetry::span(&telemetry::SPAN_BUCKET_GATHER);
                let mut groups: Vec<(usize, Vec<Request>)> =
                    widths.iter().map(|&w| (w, Vec::new())).collect();
                for req in live {
                    let slot = bucket_index(&widths, req.ids.len());
                    groups[slot].1.push(req);
                }
                groups
            };
            for (width, group) in groups {
                if !group.is_empty() {
                    self.execute(width, group, started, &mut exec, &mut stats, &ledger);
                }
            }
        }
        stats.admission = ledger.snapshot();
        Ok(stats)
    }

    /// Execute one same-width sub-batch and answer its requests
    /// (logits on success, error responses on executor failure).
    fn execute<F>(
        &self,
        width: usize,
        reqs: Vec<Request>,
        started: Instant,
        exec: &mut F,
        stats: &mut BatcherStats,
        ledger: &AdmissionLedger,
    ) where
        F: FnMut(&HostTensor) -> Result<RowBatch>,
    {
        // Tensor row count: the fixed-width path pads to the model
        // batch (the AOT artifact's shape is baked in); bucketed
        // sub-batches carry exactly their own rows — the substrate
        // executors take any row count, and padding every bucket to
        // max_batch would multiply the dead-row compute by the number
        // of buckets present.
        let nreq = reqs.len();
        let rows_cap = if self.cfg.buckets.is_empty() { self.cfg.max_batch } else { nreq };
        let mut ids = vec![PAD; rows_cap * width];
        for (row, req) in reqs.iter().enumerate() {
            let take = req.ids.len().min(width);
            ids[row * width..row * width + take].copy_from_slice(&req.ids[..take]);
        }
        let batch = HostTensor::i32(vec![rows_cap, width], ids);
        let t0 = Instant::now();
        let result = {
            let _span = telemetry::span(&telemetry::SPAN_SHARD_EXEC);
            exec(&batch)
        };
        stats.exec_seconds += t0.elapsed().as_secs_f64();
        stats.requests += nreq;
        stats.batches += 1;
        stats.exec_rows += rows_cap;
        stats.padded_rows += rows_cap - nreq;
        let mut rows = match result {
            Ok(rows) if rows.len() >= nreq => rows,
            Ok(rows) => {
                // Contract violation — fail this batch's requests, not
                // the server.
                self.fail_batch(
                    reqs,
                    &format!("executor returned {} rows for {nreq} requests", rows.len()),
                    started,
                    width,
                    rows_cap,
                    stats,
                    ledger,
                );
                return;
            }
            Err(e) => {
                self.fail_batch(reqs, &format!("{e:#}"), started, width, rows_cap, stats, ledger);
                return;
            }
        };
        // Drain rather than consume: padded surplus rows and the batch
        // container itself return to the executor's pool when `rows`
        // drops at the end of this scope.
        for (i, (req, logits)) in reqs.into_iter().zip(rows.drain(..)).enumerate() {
            let queued = started.duration_since(req.submitted);
            stats.record_queue_wait(stats.requests - nreq + i, queued);
            let _ = req.resp.send(Response {
                logits,
                queued,
                batch_rows: rows_cap,
                width,
                error: None,
            });
        }
        ledger.note_completed(nreq as u64);
    }

    /// Answer every request of a failed batch with an error response.
    #[allow(clippy::too_many_arguments)]
    fn fail_batch(
        &self,
        reqs: Vec<Request>,
        msg: &str,
        started: Instant,
        width: usize,
        rows_cap: usize,
        stats: &mut BatcherStats,
        ledger: &AdmissionLedger,
    ) {
        let nreq = reqs.len();
        stats.exec_errors += nreq;
        for (i, req) in reqs.into_iter().enumerate() {
            let queued = started.duration_since(req.submitted);
            // Errored requests stay in the latency percentiles — they
            // are often the longest-queued ones when the executor is
            // struggling, and dropping them would flatter the report.
            stats.record_queue_wait(stats.requests - nreq + i, queued);
            let _ = req.resp.send(Response {
                logits: LogitsRow::default(),
                queued,
                batch_rows: rows_cap,
                width,
                error: Some(ServeError::Exec(msg.to_string())),
            });
        }
        // Executor failures are completions for the admission ledger:
        // the request was admitted and answered (just not happily) —
        // only deadline answers count as `expired`.
        ledger.note_completed(nreq as u64);
    }
}

/// Adapt a real model into a [`Batcher::run`] executor.
pub fn serve_model<'a>(
    engine: &'a Engine,
    state: &'a ModelState,
) -> impl FnMut(&HostTensor) -> Result<RowBatch> + 'a {
    move |batch: &HostTensor| {
        let ids = batch.to_literal()?;
        let out = state.logits(engine, &ids)?;
        let shape = out.shape().to_vec();
        let data = out.as_f32()?;
        let width = shape[1];
        Ok(data.chunks(width).map(|c| c.to_vec()).collect::<Vec<_>>().into())
    }
}

/// Map one batcher row of token ids to an f32 signal on [-1, 1)
/// (PAD → 0, so padded tail positions are silent), written into a
/// caller-provided row of the flat batch buffer.
fn ids_to_signal_into(row: &[i32], out: &mut [f32]) {
    for (o, &t) in out.iter_mut().zip(row) {
        *o = if t == PAD { 0.0 } else { t as f32 / 128.0 - 1.0 };
    }
}

/// [`ids_to_signal_into`] into a fresh row — the test oracles' form.
#[cfg(test)]
fn ids_to_signal(row: &[i32]) -> Vec<f32> {
    let mut out = vec![0.0f32; row.len()];
    ids_to_signal_into(row, &mut out);
    out
}

/// Most per-width [`ExecutionPlan`]s one bucketed serve loop keeps
/// resident — comfortably above any realistic bucket count, small
/// enough that adversarial width traffic stays bounded.
pub const SERVE_PLAN_CAP: usize = 8;

/// Adapt a [`ToeplitzOp`] backend into a [`Batcher::run`] executor:
/// each row's ids become an f32 signal and the response row is the
/// operator applied to it, with the batch packed into one flat buffer
/// and **sharded row-aligned across the global thread pool**
/// (`SKI_TNN_THREADS`-sized) instead of looped serially.
/// The operator rides a single-entry [`PlanCache`] whose
/// [`ExecutionPlan`] owns the tick buffers and response-row pool, so a
/// warm serve tick allocates nothing.  This is how the backend
/// dispatcher rides the same queueing/batching policy as the XLA model
/// path — and the artifact-free load-test target of
/// `ski-tnn serve --backend …`.
pub fn serve_toeplitz(
    op: Arc<dyn ToeplitzOp>,
) -> impl FnMut(&HostTensor) -> Result<RowBatch> {
    let plans = PlanCache::new(1);
    move |batch: &HostTensor| {
        let pool = global_pool();
        let key = ShapeKey::for_width(op.n(), pool.threads());
        let plan = plans.get_or_build(key, || ExecutionPlan::from_op(key, Arc::clone(&op)));
        exec_plan(&plan, pool, batch)
    }
}

/// [`serve_toeplitz`] on an explicit pool (per-run `--threads`).
pub fn serve_toeplitz_on(
    op: Arc<dyn ToeplitzOp>,
    pool: Arc<ThreadPool>,
) -> impl FnMut(&HostTensor) -> Result<RowBatch> {
    let plans = PlanCache::new(1);
    move |batch: &HostTensor| {
        let key = ShapeKey::for_width(op.n(), pool.threads());
        let plan = plans.get_or_build(key, || ExecutionPlan::from_op(key, Arc::clone(&op)));
        exec_plan(&plan, &pool, batch)
    }
}

/// Length-bucketed substrate serving: `make(width)` builds (once, then
/// cached) the operator for each bucket width the batcher executes at,
/// so one serve loop answers mixed-length traffic with a right-sized
/// plan per bucket instead of padding everything to a single `n`.
/// Plans live in a bounded [`PlanCache`] keyed by
/// [`ShapeKey::for_width`]; each resident plan owns its own tick
/// buffers and row pool, so every bucket's serve tick is
/// allocation-free once warm, and an eviction (more than
/// [`SERVE_PLAN_CAP`] widths) simply rebuilds on the next request.
pub fn serve_toeplitz_factory(
    make: impl Fn(usize) -> Arc<dyn ToeplitzOp>,
    pool: Arc<ThreadPool>,
) -> impl FnMut(&HostTensor) -> Result<RowBatch> {
    let plans = PlanCache::new(SERVE_PLAN_CAP);
    move |batch: &HostTensor| {
        let shape = batch.shape();
        ensure!(shape.len() == 2, "expected a (batch, width) ids tensor, got {shape:?}");
        let width = shape[1];
        let key = ShapeKey::for_width(width, pool.threads());
        let plan = plans.get_or_build(key, || ExecutionPlan::from_op(key, make(width)));
        exec_plan(&plan, &pool, batch)
    }
}

/// Pressure-adaptive bucketed serving: like
/// [`serve_toeplitz_factory`], but the backend each batch executes on
/// is re-chosen **per tick** through `plan_for` — which typically
/// reads the batcher's [`PressureGauge`] via
/// [`Dispatch::plan_pressured`](crate::toeplitz::Dispatch::plan_pressured)
/// and downshifts fft → SKI one cost rung under overload.  Each
/// `(width, backend)` pair caches its own [`ExecutionPlan`]
/// (`kernel_id` encodes the backend rung), so shifting down under a
/// burst and back up afterwards is two warm cache hits, not a plan
/// rebuild — and the un-pressured plan is never evicted by its
/// degraded twin.
pub fn serve_toeplitz_pressured(
    make: impl Fn(usize, BackendKind) -> Arc<dyn ToeplitzOp>,
    plan_for: impl Fn(usize) -> (BackendKind, bool),
    pool: Arc<ThreadPool>,
) -> impl FnMut(&HostTensor) -> Result<RowBatch> {
    let plans = PlanCache::new(SERVE_PLAN_CAP);
    move |batch: &HostTensor| {
        let shape = batch.shape();
        ensure!(shape.len() == 2, "expected a (batch, width) ids tensor, got {shape:?}");
        let width = shape[1];
        let (kind, _parallel) = plan_for(width);
        let mut key = ShapeKey::for_width(width, pool.threads());
        // Backend rung in the key (1-based; 0 stays the rung-less
        // factory/fixed-op entries' id).
        key.kernel_id = 1 + kind as u64;
        let plan = plans.get_or_build(key, || ExecutionPlan::from_op(key, make(width, kind)));
        exec_plan(&plan, &pool, batch)
    }
}

/// Wrap a substrate executor with the telemetry **dispatch audit**:
/// when telemetry is enabled, every executed batch re-derives its
/// dispatch query from the tensor shape (through the same `plan_for` /
/// `rank_for` the serving path used to build its operators), prices
/// the chosen backend with the cost model, measures the actual batch
/// wall time, and records the pair via `telemetry::record_dispatch` —
/// the data behind the cost-model calibration table in stats
/// snapshots.  The row also carries the pressure reading and whether
/// the executed backend was a pressure downshift of the unpressured
/// plan, so degradation is auditable after the fact.  With telemetry
/// disabled this is a transparent pass-through.
pub fn audit_exec<F, P, R>(
    mut exec: F,
    dispatch: Dispatch,
    plan_for: P,
    rank_for: R,
    w: usize,
    threads: usize,
    pressure: PressureGauge,
) -> impl FnMut(&HostTensor) -> Result<RowBatch>
where
    F: FnMut(&HostTensor) -> Result<RowBatch>,
    P: Fn(usize) -> (BackendKind, bool),
    R: Fn(usize) -> usize,
{
    move |batch: &HostTensor| {
        if !telemetry::enabled() {
            return exec(batch);
        }
        let shape = batch.shape().to_vec();
        let rows = shape.first().copied().unwrap_or(0);
        let width = shape.get(1).copied().unwrap_or(0);
        let p = pressure.get();
        let (kind, parallel) = plan_for(width);
        let query = DispatchQuery {
            n: width,
            r: rank_for(width),
            w,
            causal: kind == BackendKind::Freq,
            batch: rows,
            threads: if parallel { threads } else { 1 },
        };
        let unpressured = dispatch.plan(&query).0;
        let downshifted = p >= PRESSURE_DOWNSHIFT
            && kind != unpressured
            && dispatch.downshift(unpressured, &query) == Some(kind);
        let predicted = dispatch.predicted_ns(kind, &query).unwrap_or(0.0);
        let t0 = Instant::now();
        let out = exec(batch);
        let measured = 1e9 * t0.elapsed().as_secs_f64();
        telemetry::record_dispatch(telemetry::AuditRow {
            n: query.n,
            r: query.r,
            w: query.w,
            causal: query.causal,
            threads: query.threads,
            rows,
            backend: kind.name(),
            predicted_ns: predicted,
            measured_ns: measured,
            pressure: p,
            downshifted,
        });
        out
    }
}

/// Execute one batcher tick through a cached [`ExecutionPlan`]: decode
/// the ids tensor into the plan's recycled flat signal buffer, run the
/// allocation-free sharded flat ABI, and answer from the plan's row
/// pool.  The plan owns every buffer, so a warm tick allocates nothing
/// — the tier `tests/alloc_steady.rs` pins in CI.
fn exec_plan(plan: &ExecutionPlan, pool: &ThreadPool, batch: &HostTensor) -> Result<RowBatch> {
    let shape = batch.shape();
    ensure!(shape.len() == 2, "expected a (batch, n) ids tensor, got {shape:?}");
    let ids = batch.as_i32()?;
    let (rows, width) = (shape[0], shape[1]);
    let mut encode =
        |i: usize, sig: &mut [f32]| ids_to_signal_into(&ids[i * width..(i + 1) * width], sig);
    plan.execute_rows(rows, width, &mut encode, pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo executor: logits[row] = [sum of that row's non-PAD ids].
    fn echo(batch: &HostTensor) -> Result<RowBatch> {
        let shape = batch.shape().to_vec();
        let ids = batch.as_i32()?;
        Ok(ids
            .chunks(shape[1])
            .map(|row| {
                vec![row.iter().filter(|&&t| t != PAD).map(|&t| t as f32).sum::<f32>()]
            })
            .collect::<Vec<_>>()
            .into())
    }

    fn small_cfg() -> ServerConfig {
        ServerConfig {
            max_batch: 4,
            n: 8,
            max_wait: Duration::from_millis(5),
            queue_depth: 16,
            buckets: Vec::new(),
            policy: AdmissionPolicy::Block,
            deadline: None,
        }
    }

    #[test]
    fn roundtrip_many_clients() {
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        let clients: Vec<_> = (0..3)
            .map(|c| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..20 {
                        let ids = vec![c as i32 + 1; (i % 8) + 1];
                        let want: f32 = ids.iter().map(|&t| t as f32).sum();
                        let resp = h.infer(ids).unwrap();
                        assert_eq!(resp.logits, vec![want]);
                        assert_eq!(resp.batch_rows, 4);
                    }
                })
            })
            .collect();
        drop(h);
        let stats = b.run(echo).unwrap();
        for c in clients {
            c.join().unwrap();
        }
        assert_eq!(stats.requests, 60);
        assert!(stats.batches <= 60);
        assert!(stats.batches >= 15, "batching should coalesce: {}", stats.batches);
        // The admission ledger balances exactly at quiescence.
        assert!(stats.admission.balanced(), "{:?}", stats.admission);
        assert_eq!(stats.admission.submitted, 60);
        assert_eq!(stats.admission.completed, 60);
        assert_eq!(stats.admission.shed + stats.admission.expired, 0);
        assert!(stats.admission.peak_depth <= 16);
    }

    #[test]
    fn batches_coalesce_under_burst() {
        let b = Batcher::new(ServerConfig {
            max_wait: Duration::from_millis(50),
            ..small_cfg()
        });
        let h = b.handle();
        let t = std::thread::spawn(move || {
            let pending: Vec<_> =
                (0..8).map(|i| h.try_submit(vec![i as i32 + 1]).unwrap()).collect();
            let resps: Vec<Response> =
                pending.into_iter().map(|rx| rx.recv().unwrap()).collect();
            resps
        });
        let stats = b.run(echo).unwrap();
        let resps = t.join().unwrap();
        assert_eq!(resps.len(), 8);
        // 8 requests at max_batch 4 must ride exactly 2 full batches
        assert_eq!(stats.batches, 2, "burst should fill batches");
        assert_eq!(stats.padded_rows, 0);
    }

    #[test]
    fn try_submit_failure_paths_are_typed() {
        // Queue full: the batcher is not draining, so the bounded
        // queue fills and the next try_submit must say so immediately.
        let b = Batcher::new(ServerConfig { queue_depth: 2, ..small_cfg() });
        let h = b.handle();
        let _p1 = h.try_submit(vec![1]).unwrap();
        let _p2 = h.try_submit(vec![2]).unwrap();
        assert_eq!(h.try_submit(vec![3]).unwrap_err(), SubmitError::QueueFull);
        // Submit after shutdown: dropping the batcher drops the
        // receiver; every submit path reports Stopped, typed.
        drop(b);
        assert_eq!(h.try_submit(vec![4]).unwrap_err(), SubmitError::Stopped);
        let err = h.infer(vec![5]).unwrap_err();
        assert_eq!(err.to_string(), "server stopped");
    }

    #[test]
    fn pressure_scales_the_gather_window() {
        let w = Duration::from_millis(8);
        assert_eq!(pressure_scaled_wait(w, 0.0), w, "no pressure keeps the full window");
        let full = pressure_scaled_wait(w, 1.0);
        assert!(
            (1_900_000..=2_100_000).contains(&full.as_nanos()),
            "full pressure leaves (1 - GATHER_SHRINK) = 25%: {full:?}"
        );
        let mid = pressure_scaled_wait(w, 0.5);
        assert!(mid < w && mid > full, "monotone in pressure: {mid:?}");
        assert_eq!(pressure_scaled_wait(w, 7.0), full, "pressure clamps to 1");
    }

    #[test]
    fn truncates_overlong_rows() {
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        let t = std::thread::spawn(move || h.infer(vec![1; 100]).unwrap());
        let stats = b.run(echo).unwrap();
        let resp = t.join().unwrap();
        assert_eq!(resp.logits, vec![8.0], "row must be truncated to n=8");
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.padded_rows, 3);
    }

    #[test]
    fn queue_percentiles_recorded_server_side() {
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        let t = std::thread::spawn(move || {
            for i in 0..12 {
                let _ = h.infer(vec![i as i32 + 1]).unwrap();
            }
        });
        let stats = b.run(echo).unwrap();
        t.join().unwrap();
        assert_eq!(stats.queue_seconds.len(), stats.requests);
        let (p50, p95, p99) = stats.queue_percentiles();
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(p99 >= 0.0 && p99 < 5.0, "queue p99 {p99}s out of range");
        assert_eq!(stats.queue_pct(0.99), p99);
    }

    #[test]
    fn queue_stats_cover_whole_run_via_histogram() {
        // More traffic than the bounded raw-sample window holds: the
        // early slow outliers fall out of `queue_seconds` but must
        // stay visible in the whole-run percentiles.
        let mut stats = BatcherStats::default();
        for i in 0..(QUEUE_SAMPLE_CAP + 64) {
            let secs = if i < 10 { 1.0 } else { 1e-6 };
            stats.record_queue_wait(i, Duration::from_secs_f64(secs));
            stats.requests += 1;
        }
        assert_eq!(stats.queue_seconds.len(), QUEUE_SAMPLE_CAP, "window stays bounded");
        assert!(
            stats.queue_seconds.iter().all(|&s| s < 1e-3),
            "outliers aged out of the raw window"
        );
        assert_eq!(stats.queue_hist.count() as usize, QUEUE_SAMPLE_CAP + 64);
        // The 1s outliers survive in the histogram max (within the 2x
        // bucketing tolerance).
        assert!(stats.queue_pct(1.0) > 0.4, "whole-run max lost: {}", stats.queue_pct(1.0));
        assert!(stats.queue_pct(0.5) < 1e-3, "median should be the fast traffic");
    }

    #[test]
    fn audit_exec_records_predicted_vs_measured() {
        let _g = telemetry::test_guard();
        let was = telemetry::enabled();
        telemetry::set_enabled(true);
        let before = telemetry::global_audit().rows().len();
        let mut exec = audit_exec(
            echo,
            Dispatch::default(),
            |_width| (BackendKind::Fft, false),
            |_width| 4,
            9,
            2,
            PressureGauge::new(),
        );
        let batch = HostTensor::i32(vec![2, 8], vec![1; 16]);
        exec(&batch).unwrap();
        let rows = telemetry::global_audit().rows();
        telemetry::set_enabled(was);
        assert!(rows.len() > before, "audit row must be recorded");
        let row = rows.last().unwrap();
        assert_eq!(row.backend, "fft");
        assert_eq!(row.n, 8);
        assert_eq!(row.rows, 2);
        assert_eq!(row.threads, 1, "serial plan audits as one thread");
        assert!(row.predicted_ns > 0.0, "cost model must price the fft row");
        assert!(row.measured_ns > 0.0);
        assert_eq!(row.pressure, 0.0, "idle gauge audits as zero pressure");
        assert!(!row.downshifted, "fft at zero pressure is not a downshift");
    }

    #[test]
    fn audit_exec_flags_pressure_downshifts() {
        let _g = telemetry::test_guard();
        let was = telemetry::enabled();
        telemetry::set_enabled(true);
        let gauge = PressureGauge::new();
        gauge.set(0.95);
        // The serving path chose SKI at a shape whose unpressured plan
        // is fft (the wide band makes SKI the pricier rung): the audit
        // row must carry the downshift flag.
        let mut exec = audit_exec(
            echo,
            Dispatch::default(),
            |_width| (BackendKind::Ski, false),
            |_width| 8,
            400,
            1,
            gauge,
        );
        let n = 4096;
        let batch = HostTensor::i32(vec![1, n], vec![1; n]);
        exec(&batch).unwrap();
        let rows = telemetry::global_audit().rows();
        telemetry::set_enabled(was);
        let row = rows.last().unwrap();
        assert_eq!(row.backend, "ski");
        assert!((row.pressure - 0.95).abs() < 1e-12);
        assert!(
            row.downshifted,
            "ski under pressure at an fft-planned shape must audit as a downshift"
        );
    }

    #[test]
    fn pressured_executor_switches_rungs_per_tick() {
        use crate::toeplitz::{build_op, ToeplitzKernel};
        use std::sync::Mutex;
        let n = 16;
        let gauge = PressureGauge::new();
        let g = gauge.clone();
        let built = Arc::new(Mutex::new(Vec::new()));
        let b2 = built.clone();
        let make = move |w: usize, kind: BackendKind| -> Arc<dyn ToeplitzOp> {
            b2.lock().unwrap().push(kind);
            let kernel = ToeplitzKernel::from_fn(w, |lag| 1.0 / (1.0 + lag.abs() as f32));
            Arc::from(build_op(&kernel, kind, 4, 3))
        };
        let plan_for = move |_width: usize| {
            if g.get() >= PRESSURE_DOWNSHIFT {
                (BackendKind::Ski, false)
            } else {
                (BackendKind::Fft, false)
            }
        };
        let mut exec = serve_toeplitz_pressured(make, plan_for, Arc::new(ThreadPool::new(1)));
        let batch = HostTensor::i32(vec![2, n], (0..2 * n as i32).collect());
        gauge.set(0.0);
        let calm = exec(&batch).unwrap();
        assert_eq!(calm.len(), 2);
        gauge.set(0.9);
        let pressed = exec(&batch).unwrap();
        assert!(pressed.iter().all(|r| r.iter().all(|v| v.is_finite())));
        gauge.set(0.0);
        exec(&batch).unwrap();
        let kinds = built.lock().unwrap().clone();
        // Each rung built exactly once; the return to fft was a cache
        // hit on the still-resident unpressured plan.
        assert_eq!(kinds, vec![BackendKind::Fft, BackendKind::Ski]);
    }

    #[test]
    fn toeplitz_executor_serves_backend_applies() {
        use crate::toeplitz::{build_op, BackendKind, ToeplitzKernel};
        let n = 8;
        let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
        let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        let ids: Vec<i32> = (0..n as i32).collect();
        let t = {
            let ids = ids.clone();
            std::thread::spawn(move || h.infer(ids).unwrap())
        };
        let stats = b.run(serve_toeplitz(op)).unwrap();
        let resp = t.join().unwrap();
        // Oracle: the same signal through the dense apply.
        let want = kernel.apply_dense(&ids_to_signal(&ids));
        assert_eq!(resp.logits.len(), n);
        for (i, (a, b)) in resp.logits.iter().zip(want.iter()).enumerate() {
            assert!((a - b).abs() < 1e-4, "row value {i}: {a} vs {b}");
        }
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn toeplitz_executor_pooled_matches_serial() {
        // The sharded executor must answer bit-for-bit what a
        // single-thread pool answers, whatever the worker count.
        use crate::toeplitz::{build_op, BackendKind, ToeplitzKernel};
        let n = 16;
        let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
        let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
        let ids: Vec<i32> = (0..4 * n as i32).collect();
        let batch = HostTensor::i32(vec![4, n], ids);
        let mut serial = serve_toeplitz_on(op.clone(), Arc::new(ThreadPool::new(1)));
        let mut pooled = serve_toeplitz_on(op, Arc::new(ThreadPool::new(4)));
        assert_eq!(serial(&batch).unwrap(), pooled(&batch).unwrap());
    }

    #[test]
    fn toeplitz_executor_recycles_response_rows_across_ticks() {
        // Once a tick's responses are consumed (dropped), the next tick
        // must answer from the very same buffers — the envelope the
        // allocation gate pins in CI.
        use crate::toeplitz::{build_op, BackendKind, ToeplitzKernel};
        let n = 16;
        let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
        let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Fft, 0, 0));
        let mut exec = serve_toeplitz_on(op, Arc::new(ThreadPool::new(1)));
        let batch = HostTensor::i32(vec![2, n], (0..2 * n as i32).collect());
        let first = exec(&batch).unwrap();
        let mut ptrs: Vec<*const f32> = first.iter().map(|r| r.as_ptr()).collect();
        let want: Vec<Vec<f32>> = first.iter().map(|r| r.to_vec()).collect();
        drop(first); // responses consumed → rows return to the pool
        let second = exec(&batch).unwrap();
        let mut again: Vec<*const f32> = second.iter().map(|r| r.as_ptr()).collect();
        ptrs.sort();
        again.sort();
        assert_eq!(ptrs, again, "row buffers must be recycled, not reallocated");
        for (r, w) in second.iter().zip(want.iter()) {
            assert_eq!(*r, *w, "recycled rows must still carry fresh results");
        }
    }

    #[test]
    fn toeplitz_executor_rejects_width_mismatch() {
        use crate::toeplitz::{build_op, BackendKind, ToeplitzKernel};
        let kernel = ToeplitzKernel::from_fn(4, |_| 1.0);
        let op: Arc<dyn ToeplitzOp> = Arc::from(build_op(&kernel, BackendKind::Dense, 0, 0));
        let mut exec = serve_toeplitz(op);
        let batch = HostTensor::i32(vec![1, 8], vec![0; 8]);
        assert!(exec(&batch).is_err(), "width mismatch must surface as an executor error");
    }

    #[test]
    fn bucket_widths_normalised() {
        let cfg = ServerConfig { n: 64, buckets: vec![32, 8, 8, 0, 200, 32], ..small_cfg() };
        assert_eq!(cfg.bucket_widths(), vec![8, 32, 64]);
        assert_eq!(cfg.bucket_for(1), 8);
        assert_eq!(cfg.bucket_for(8), 8);
        assert_eq!(cfg.bucket_for(9), 32);
        assert_eq!(cfg.bucket_for(64), 64);
        assert_eq!(cfg.bucket_for(500), 64, "overlong rows truncate at the top bucket");
        // No buckets: single fixed width.
        assert_eq!(small_cfg().bucket_widths(), vec![8]);
    }

    #[test]
    fn bucketed_batches_execute_at_bucket_widths() {
        // Mixed-length traffic must run as per-bucket sub-batches:
        // short rows at the small width, long rows at the top width,
        // every response still correct.
        use std::sync::Mutex;
        let b = Batcher::new(ServerConfig {
            max_batch: 8,
            n: 32,
            max_wait: Duration::from_millis(20),
            queue_depth: 32,
            buckets: vec![8],
            ..ServerConfig::default()
        });
        let h = b.handle();
        let t = std::thread::spawn(move || {
            // Interleave short (≤ 8) and long rows, all submitted up
            // front so they coalesce into one gather.
            let pending: Vec<_> = (0..8)
                .map(|i| {
                    let len = if i % 2 == 0 { 3 + i / 2 } else { 20 + i };
                    h.try_submit(vec![1; len]).unwrap()
                })
                .collect();
            pending.into_iter().map(|rx| rx.recv().unwrap()).collect::<Vec<Response>>()
        });
        let shapes = std::sync::Arc::new(Mutex::new(Vec::new()));
        let s2 = shapes.clone();
        let stats = b
            .run(move |batch| {
                s2.lock().unwrap().push((batch.shape()[0], batch.shape()[1]));
                echo(batch)
            })
            .unwrap();
        let resps = t.join().unwrap();
        assert_eq!(stats.requests, 8);
        let seen = shapes.lock().unwrap().clone();
        let widths: Vec<usize> = seen.iter().map(|&(_, w)| w).collect();
        assert!(
            widths.contains(&8) && widths.contains(&32),
            "both buckets must execute: {seen:?}"
        );
        assert!(widths.iter().all(|w| *w == 8 || *w == 32), "{seen:?}");
        // Bucketed sub-batches carry exactly their own rows — no
        // max_batch padding multiplied per bucket.
        assert_eq!(seen.iter().map(|&(rows, _)| rows).sum::<usize>(), 8, "{seen:?}");
        assert_eq!(stats.padded_rows, 0, "bucketed batches must not pad rows");
        for (i, r) in resps.iter().enumerate() {
            let len = if i % 2 == 0 { 3 + i / 2 } else { 20 + i };
            assert_eq!(r.logits, vec![len as f32], "row {i} sum");
            assert_eq!(r.width, if len <= 8 { 8 } else { 32 });
            assert!(r.error.is_none());
        }
    }

    #[test]
    fn executor_failure_errors_requests_not_the_loop() {
        // Satellite hardening: one failing execution answers its own
        // requests with errors; the loop keeps serving.
        let b = Batcher::new(ServerConfig { max_batch: 1, ..small_cfg() });
        let h = b.handle();
        let t = std::thread::spawn(move || {
            let bad = h.infer(vec![99]); // magic id → executor fails
            let good = h.infer(vec![1, 2]);
            (bad, good)
        });
        let stats = b
            .run(|batch| {
                let ids = batch.as_i32()?;
                if ids.contains(&99) {
                    return Err(anyhow!("synthetic executor failure"));
                }
                echo(batch)
            })
            .unwrap();
        let (bad, good) = t.join().unwrap();
        let err = bad.expect_err("failed batch must surface as request error");
        assert!(err.to_string().contains("synthetic executor failure"), "{err}");
        assert_eq!(good.unwrap().logits, vec![3.0], "server must keep serving after a failure");
        assert_eq!(stats.exec_errors, 1);
        assert_eq!(stats.requests, 2);
        // Executor failures count as completed (answered) admissions.
        assert!(stats.admission.balanced(), "{:?}", stats.admission);
        assert_eq!(stats.admission.completed, 2);
    }

    #[test]
    fn bucketed_toeplitz_factory_serves_per_width_ops() {
        use crate::toeplitz::{gaussian_kernel, ToeplitzKernel};
        let widths = [8usize, 24];
        let b = Batcher::new(ServerConfig {
            max_batch: 4,
            n: 24,
            max_wait: Duration::from_millis(10),
            queue_depth: 16,
            buckets: vec![8],
            ..ServerConfig::default()
        });
        let h = b.handle();
        let t = std::thread::spawn(move || {
            let short: Vec<i32> = (0..6).collect();
            let long: Vec<i32> = (0..20).collect();
            let rs = h.infer(short.clone()).unwrap();
            let rl = h.infer(long.clone()).unwrap();
            (short, rs, long, rl)
        });
        let make = |w: usize| -> Arc<dyn ToeplitzOp> {
            let kernel =
                ToeplitzKernel::from_fn(w, |lag| gaussian_kernel(lag as f64, w as f64 / 4.0));
            Arc::from(crate::toeplitz::build_op(&kernel, crate::toeplitz::BackendKind::Fft, 0, 0))
        };
        let pool = Arc::new(ThreadPool::new(1));
        let stats = b.run(serve_toeplitz_factory(make, pool)).unwrap();
        let (short, rs, long, rl) = t.join().unwrap();
        // Oracles at each bucket width (pad the ids to the width the
        // batcher executed at, then dense-apply the same kernel).
        for (ids, resp, w) in [(&short, &rs, widths[0]), (&long, &rl, widths[1])] {
            assert_eq!(resp.width, w);
            assert_eq!(resp.logits.len(), w);
            let mut padded = vec![PAD; w];
            padded[..ids.len()].copy_from_slice(ids);
            let kernel =
                ToeplitzKernel::from_fn(w, |lag| gaussian_kernel(lag as f64, w as f64 / 4.0));
            let want = kernel.apply_dense(&ids_to_signal(&padded));
            for (i, (a, b)) in resp.logits.iter().zip(want.iter()).enumerate() {
                assert!((a - b).abs() < 1e-4, "width {w} value {i}: {a} vs {b}");
            }
        }
        assert_eq!(stats.requests, 2);
    }

    #[test]
    fn shutdown_when_handles_dropped() {
        let b = Batcher::new(small_cfg());
        let h = b.handle();
        drop(h);
        let stats = b.run(echo).unwrap(); // must return immediately
        assert_eq!(stats.requests, 0);
    }
}
