//! Bounded admission control for the serving stack.
//!
//! Both serve loops ([`super::Batcher`] and [`super::GenScheduler`])
//! used to accept work on std mpsc channels: bounded, but with no
//! deadline awareness and only one overflow behaviour (block).  Under
//! sustained overload the queue-wait histogram just recorded the
//! collapse.  This module replaces the channel with an explicit
//! admission queue that owns the overload policy:
//!
//! * **Capacity** is a hard bound — `peak_depth` never exceeds it, so
//!   overload cannot become unbounded memory.
//! * **Policy** picks what happens when the bound is hit:
//!   [`AdmissionPolicy::Block`] reproduces the old backpressure,
//!   [`AdmissionPolicy::ShedNewest`] answers the incoming request with
//!   a typed [`ServeError::Overloaded`], and
//!   [`AdmissionPolicy::ShedExpiredFirst`] first evicts queued
//!   requests whose deadline already passed (answering each with
//!   [`ServeError::DeadlineExceeded`]) before shedding the newcomer.
//! * **Deadlines** ride each request ([`Admissible::deadline`]).  An
//!   expired request is *answered*, never silently dropped — the
//!   exactly-one-response contract `tests/overload.rs` enforces.
//! * **Accounting** is exact: the always-on [`AdmissionLedger`]
//!   satisfies `submitted == admitted + shed` and
//!   `admitted == completed + expired` at quiescence, which is what
//!   the chaos soak gate balances in CI.  The same counts mirror into
//!   the telemetry registry (`server.admission.*`) when it is enabled.
//!
//! The [`PressureGauge`] folds queue occupancy and deadline headroom
//! into one [0, 1] scalar the dispatcher uses to walk the backend cost
//! ladder *down* (fft → SKI) and the batcher uses to shrink its gather
//! window — graceful degradation instead of collapse.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::telemetry::{LazyCounter, LazyGauge};
use crate::util::rng::Rng;

/// `server.admission.admitted` — requests that entered the queue
/// (including ones later answered as expired).
static ADMITTED: LazyCounter = LazyCounter::new("server.admission.admitted");
/// `server.admission.shed` — requests answered `Overloaded` at the
/// gate without ever being queued.
static SHED: LazyCounter = LazyCounter::new("server.admission.shed");
/// `server.admission.expired` — admitted requests answered
/// `DeadlineExceeded` before execution.
static EXPIRED: LazyCounter = LazyCounter::new("server.admission.expired");
/// `server.admission.retries` — client-side re-submissions after an
/// overload answer (see [`RetryPolicy`]).
static RETRIES: LazyCounter = LazyCounter::new("server.admission.retries");
/// `server.pressure` — the most recent [`PressureGauge`] publication.
pub static SERVER_PRESSURE: LazyGauge = LazyGauge::new("server.pressure");

/// Typed serve-path error carried in `Response::error` /
/// `GenResponse::error` — the load-control outcomes are first-class
/// values clients can match on, not string prefixes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Shed at the admission gate: the queue was full and the policy
    /// chose this request.  Retryable by definition.
    Overloaded,
    /// The request's deadline passed before its batch executed.
    DeadlineExceeded,
    /// The executor (or decode session) failed; the message is the
    /// underlying error chain.
    Exec(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "overloaded: shed by admission control"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::Exec(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for ServeError {}

impl ServeError {
    /// Overload outcomes are worth re-submitting; executor failures
    /// are not — the same batch would fail again.
    pub fn retryable(&self) -> bool {
        matches!(self, ServeError::Overloaded | ServeError::DeadlineExceeded)
    }
}

/// Typed submit failure from the non-blocking client paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is at capacity right now (backpressure; retryable).
    QueueFull,
    /// The serve loop is gone — no retry will ever succeed.
    Stopped,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::Stopped => write!(f, "server stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What a full queue does to a blocking submit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Wait for a slot (the pre-admission-control behaviour).
    #[default]
    Block,
    /// Answer the incoming request with [`ServeError::Overloaded`].
    ShedNewest,
    /// Evict already-expired queued requests first (each answered with
    /// [`ServeError::DeadlineExceeded`]); shed the newcomer only if
    /// nothing in the queue had expired.
    ShedExpiredFirst,
}

impl AdmissionPolicy {
    /// Parse the CLI/config spelling (`block | shed-newest |
    /// shed-expired-first`).
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s {
            "block" => Some(AdmissionPolicy::Block),
            "shed-newest" => Some(AdmissionPolicy::ShedNewest),
            "shed-expired-first" => Some(AdmissionPolicy::ShedExpiredFirst),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::ShedNewest => "shed-newest",
            AdmissionPolicy::ShedExpiredFirst => "shed-expired-first",
        }
    }
}

/// A queueable request: carries an optional absolute deadline and
/// knows how to answer itself with a typed error — rejection consumes
/// the request, so every path out of the queue produces exactly one
/// response.
pub trait Admissible: Send {
    fn deadline(&self) -> Option<Instant>;

    /// Answer the request's client with `err` (exactly once).
    fn reject(self, err: ServeError);

    fn expired(&self, now: Instant) -> bool {
        self.deadline().is_some_and(|d| now >= d)
    }
}

/// Exact admission accounting, always on (plain relaxed atomics — the
/// telemetry mirror is the only part gated on the registry flag).
#[derive(Debug, Default)]
pub struct AdmissionLedger {
    submitted: AtomicU64,
    admitted: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    completed: AtomicU64,
    retries: AtomicU64,
    peak_depth: AtomicU64,
}

impl AdmissionLedger {
    fn note_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    fn note_admitted(&self, depth: usize) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.peak_depth.fetch_max(depth as u64, Ordering::Relaxed);
        ADMITTED.incr();
    }

    fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        SHED.incr();
    }

    /// An admitted request answered `DeadlineExceeded` — callable from
    /// the serve loops too (post-gather expiry happens outside the
    /// queue).
    pub fn note_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
        EXPIRED.incr();
    }

    /// `k` admitted requests answered by the serve loop (success or
    /// executor error — every non-expired answer counts).
    pub fn note_completed(&self, k: u64) {
        self.completed.fetch_add(k, Ordering::Relaxed);
    }

    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
        RETRIES.incr();
    }

    pub fn snapshot(&self) -> AdmissionSnapshot {
        AdmissionSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            peak_depth: self.peak_depth.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time ledger view; rides `BatcherStats` / `GenStats`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionSnapshot {
    pub submitted: u64,
    pub admitted: u64,
    pub shed: u64,
    pub expired: u64,
    pub completed: u64,
    pub retries: u64,
    pub peak_depth: u64,
}

impl AdmissionSnapshot {
    /// The exactly-once contract at quiescence: every submit was
    /// either admitted or shed, and every admit was either completed
    /// or expired — so `expired == admitted - completed` exactly.
    pub fn balanced(&self) -> bool {
        self.submitted == self.admitted + self.shed
            && self.admitted == self.completed + self.expired
    }

    /// Total responses the queue side guarantees were sent.
    pub fn answered(&self) -> u64 {
        self.completed + self.shed + self.expired
    }
}

/// Overload pressure in [0, 1], shared between the serve loop (writer)
/// and the dispatch closures (readers).  Stored as `f64` bits in one
/// atomic — reading it costs a relaxed load.
#[derive(Debug, Clone, Default)]
pub struct PressureGauge(Arc<AtomicU64>);

impl PressureGauge {
    pub fn new() -> PressureGauge {
        PressureGauge::default()
    }

    pub fn set(&self, p: f64) {
        self.0.store(p.clamp(0.0, 1.0).to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct Inner<T> {
    q: VecDeque<T>,
    senders: usize,
    receiver_alive: bool,
}

struct Shared<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    policy: AdmissionPolicy,
    /// The config's default deadline budget — normalises deadline
    /// headroom into the pressure signal's urgency term.
    budget: Option<Duration>,
    ledger: Arc<AdmissionLedger>,
}

impl<T> Shared<T> {
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner<T>> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Producer half of the admission queue (cloneable, like a channel
/// sender; the receiver observes disconnect when the last clone
/// drops).
pub struct AdmissionSender<T: Admissible>(Arc<Shared<T>>);

/// Consumer half; owned by the serve loop.  Dropping it makes every
/// subsequent submit fail with [`SubmitError::Stopped`].
pub struct AdmissionReceiver<T: Admissible>(Arc<Shared<T>>);

/// Non-blocking receive outcome.
pub enum TryRecv<T> {
    Item(T),
    Empty,
    Disconnected,
}

/// Bounded-wait receive outcome.
pub enum RecvTimeout<T> {
    Item(T),
    TimedOut,
    Disconnected,
}

/// Build a bounded admission queue.  `budget` is the default deadline
/// the pressure signal normalises headroom against (the server
/// config's `deadline`).
pub fn admission_queue<T: Admissible>(
    cap: usize,
    policy: AdmissionPolicy,
    budget: Option<Duration>,
) -> (AdmissionSender<T>, AdmissionReceiver<T>) {
    let cap = cap.max(1);
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner {
            q: VecDeque::with_capacity(cap),
            senders: 1,
            receiver_alive: true,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        cap,
        policy,
        budget,
        ledger: Arc::new(AdmissionLedger::default()),
    });
    (AdmissionSender(Arc::clone(&shared)), AdmissionReceiver(shared))
}

impl<T: Admissible> Clone for AdmissionSender<T> {
    fn clone(&self) -> Self {
        self.0.lock().senders += 1;
        AdmissionSender(Arc::clone(&self.0))
    }
}

impl<T: Admissible> Drop for AdmissionSender<T> {
    fn drop(&mut self) {
        let mut g = self.0.lock();
        g.senders -= 1;
        if g.senders == 0 {
            drop(g);
            // Wake a receiver blocked on an empty queue so it can
            // observe the disconnect.
            self.0.not_empty.notify_all();
        }
    }
}

impl<T: Admissible> Drop for AdmissionReceiver<T> {
    fn drop(&mut self) {
        self.0.lock().receiver_alive = false;
        // Wake blocked submitters so they fail with `Stopped`.
        self.0.not_full.notify_all();
    }
}

impl<T: Admissible> AdmissionSender<T> {
    pub fn ledger(&self) -> Arc<AdmissionLedger> {
        Arc::clone(&self.0.ledger)
    }

    /// Blocking submit under the queue's policy.  `Ok(())` guarantees
    /// the request's client will receive exactly one response —
    /// possibly a typed `Overloaded`/`DeadlineExceeded` sent right
    /// here.  `Err(Stopped)` means the request was returned unanswered
    /// because the serve loop is gone.
    pub fn submit(&self, item: T) -> Result<(), SubmitError> {
        let shared = &self.0;
        let ledger = &shared.ledger;
        let now = Instant::now();
        let mut g = shared.lock();
        if !g.receiver_alive {
            return Err(SubmitError::Stopped);
        }
        // Expired on arrival: admitted for accounting, answered
        // immediately, never queued.
        if item.expired(now) {
            ledger.note_submitted();
            ledger.note_admitted(g.q.len());
            ledger.note_expired();
            drop(g);
            item.reject(ServeError::DeadlineExceeded);
            return Ok(());
        }
        while g.q.len() >= shared.cap {
            match shared.policy {
                AdmissionPolicy::Block => {
                    // Bounded wait: a deadlined request must not block
                    // past its own deadline.
                    let wait = item
                        .deadline()
                        .map(|d| d.saturating_duration_since(Instant::now()))
                        .unwrap_or(Duration::from_millis(50));
                    if wait.is_zero() {
                        ledger.note_submitted();
                        ledger.note_admitted(g.q.len());
                        ledger.note_expired();
                        drop(g);
                        item.reject(ServeError::DeadlineExceeded);
                        return Ok(());
                    }
                    let (guard, _timeout) = shared
                        .not_full
                        .wait_timeout(g, wait)
                        .unwrap_or_else(PoisonError::into_inner);
                    g = guard;
                    if !g.receiver_alive {
                        return Err(SubmitError::Stopped);
                    }
                }
                AdmissionPolicy::ShedNewest => {
                    ledger.note_submitted();
                    ledger.note_shed();
                    drop(g);
                    item.reject(ServeError::Overloaded);
                    return Ok(());
                }
                AdmissionPolicy::ShedExpiredFirst => {
                    let now = Instant::now();
                    let mut evicted = Vec::new();
                    let mut kept = VecDeque::with_capacity(g.q.len());
                    while let Some(queued) = g.q.pop_front() {
                        if queued.expired(now) {
                            evicted.push(queued);
                        } else {
                            kept.push_back(queued);
                        }
                    }
                    g.q = kept;
                    if evicted.is_empty() {
                        // Nothing reclaimable: shed the newcomer.
                        ledger.note_submitted();
                        ledger.note_shed();
                        drop(g);
                        item.reject(ServeError::Overloaded);
                        return Ok(());
                    }
                    for stale in evicted {
                        ledger.note_expired();
                        stale.reject(ServeError::DeadlineExceeded);
                    }
                    // Loop re-checks: the queue now has room.
                }
            }
        }
        ledger.note_submitted();
        g.q.push_back(item);
        let depth = g.q.len();
        ledger.note_admitted(depth);
        drop(g);
        shared.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking submit: a full queue is an immediate typed
    /// [`SubmitError::QueueFull`] — no response channel was consumed,
    /// so the caller retries (or sheds) client-side.
    pub fn try_submit(&self, item: T) -> Result<(), SubmitError> {
        let shared = &self.0;
        let ledger = &shared.ledger;
        let now = Instant::now();
        let mut g = shared.lock();
        if !g.receiver_alive {
            return Err(SubmitError::Stopped);
        }
        if item.expired(now) {
            ledger.note_submitted();
            ledger.note_admitted(g.q.len());
            ledger.note_expired();
            drop(g);
            item.reject(ServeError::DeadlineExceeded);
            return Ok(());
        }
        if g.q.len() >= shared.cap {
            return Err(SubmitError::QueueFull);
        }
        ledger.note_submitted();
        g.q.push_back(item);
        let depth = g.q.len();
        ledger.note_admitted(depth);
        drop(g);
        shared.not_empty.notify_one();
        Ok(())
    }
}

impl<T: Admissible> AdmissionReceiver<T> {
    pub fn ledger(&self) -> Arc<AdmissionLedger> {
        Arc::clone(&self.0.ledger)
    }

    /// Blocking receive; `None` when every sender is gone and the
    /// queue has drained (shutdown) — mpsc `recv` semantics.
    pub fn recv(&self) -> Option<T> {
        let mut g = self.0.lock();
        loop {
            if let Some(item) = g.q.pop_front() {
                drop(g);
                self.0.not_full.notify_one();
                return Some(item);
            }
            if g.senders == 0 {
                return None;
            }
            g = self.0.not_empty.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Bounded-wait receive — mpsc `recv_timeout` semantics.
    pub fn recv_timeout(&self, dur: Duration) -> RecvTimeout<T> {
        let deadline = Instant::now() + dur;
        let mut g = self.0.lock();
        loop {
            if let Some(item) = g.q.pop_front() {
                drop(g);
                self.0.not_full.notify_one();
                return RecvTimeout::Item(item);
            }
            if g.senders == 0 {
                return RecvTimeout::Disconnected;
            }
            let now = Instant::now();
            if now >= deadline {
                return RecvTimeout::TimedOut;
            }
            let (guard, _timeout) = self
                .0
                .not_empty
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = guard;
        }
    }

    /// Non-blocking receive — mpsc `try_recv` semantics.
    pub fn try_recv(&self) -> TryRecv<T> {
        let mut g = self.0.lock();
        if let Some(item) = g.q.pop_front() {
            drop(g);
            self.0.not_full.notify_one();
            return TryRecv::Item(item);
        }
        if g.senders == 0 {
            return TryRecv::Disconnected;
        }
        TryRecv::Empty
    }

    /// Queue depth right now (diagnostics; racy by nature).
    pub fn depth(&self) -> usize {
        self.0.lock().q.len()
    }

    /// Overload pressure in [0, 1]: occupancy × (½ + ½ × urgency),
    /// where urgency is how much of the *oldest* queued request's
    /// deadline budget has already been spent waiting.  A full queue
    /// of fresh requests reads 0.5; a full queue whose head is about
    /// to expire reads 1.0; without deadlines the signal is occupancy
    /// alone, halved — still enough to cross the downshift threshold
    /// only when genuinely saturated.
    pub fn pressure(&self) -> f64 {
        let g = self.0.lock();
        let occupancy = g.q.len() as f64 / self.0.cap as f64;
        let urgency = match (g.q.front().and_then(|i| i.deadline()), self.0.budget) {
            (Some(deadline), Some(budget)) if !budget.is_zero() => {
                let left = deadline.saturating_duration_since(Instant::now());
                (1.0 - left.as_secs_f64() / budget.as_secs_f64()).clamp(0.0, 1.0)
            }
            _ => 0.0,
        };
        (occupancy * (0.5 + 0.5 * urgency)).clamp(0.0, 1.0)
    }
}

/// Client-side retry policy: jittered exponential backoff with a
/// total-attempt deadline.  Used by `ClientHandle::infer_with_retry`
/// and `GenClient::generate_with_retry`; retries count into
/// `server.admission.retries`.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Attempts including the first (≥ 1).
    pub attempts: usize,
    /// First backoff; doubles per retry.
    pub base: Duration,
    /// Per-retry backoff ceiling.
    pub max_backoff: Duration,
    /// Total budget across attempts — no retry starts past this.
    pub budget: Duration,
    /// Jitter seed (deterministic backoff stream per client).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 4,
            base: Duration::from_millis(1),
            max_backoff: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `retry` (0-based): exponential,
    /// capped, with half-interval jitter so synchronized clients
    /// desynchronize instead of re-stampeding the gate.
    pub fn backoff(&self, retry: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .base
            .saturating_mul(1u32 << retry.min(16))
            .min(self.max_backoff)
            .max(Duration::from_micros(1));
        exp.mul_f64(0.5 + 0.5 * rng.f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{sync_channel, SyncSender};

    struct Item {
        id: usize,
        deadline: Option<Instant>,
        resp: SyncSender<Result<usize, ServeError>>,
    }

    impl Admissible for Item {
        fn deadline(&self) -> Option<Instant> {
            self.deadline
        }

        fn reject(self, err: ServeError) {
            let _ = self.resp.send(Err(err));
        }
    }

    fn item(
        id: usize,
        deadline: Option<Instant>,
    ) -> (Item, std::sync::mpsc::Receiver<Result<usize, ServeError>>) {
        let (tx, rx) = sync_channel(1);
        (Item { id, deadline, resp: tx }, rx)
    }

    #[test]
    fn fifo_roundtrip_and_disconnect() {
        let (tx, rx) = admission_queue::<Item>(4, AdmissionPolicy::Block, None);
        for i in 0..3 {
            let (it, _rx) = item(i, None);
            tx.submit(it).unwrap();
        }
        assert_eq!(rx.depth(), 3);
        for i in 0..3 {
            match rx.try_recv() {
                TryRecv::Item(it) => assert_eq!(it.id, i),
                _ => panic!("expected item {i}"),
            }
        }
        drop(tx);
        assert!(rx.recv().is_none(), "all senders gone => disconnect");
        let snap = rx.ledger().snapshot();
        assert_eq!(snap.submitted, 3);
        assert_eq!(snap.admitted, 3);
        assert_eq!(snap.peak_depth, 3);
    }

    #[test]
    fn try_submit_full_is_typed_queue_full() {
        let (tx, rx) = admission_queue::<Item>(2, AdmissionPolicy::ShedNewest, None);
        let (it, _r1) = item(0, None);
        tx.try_submit(it).unwrap();
        let (it, _r2) = item(1, None);
        tx.try_submit(it).unwrap();
        let (it, _r3) = item(2, None);
        assert_eq!(tx.try_submit(it).unwrap_err(), SubmitError::QueueFull);
        let snap = rx.ledger().snapshot();
        assert_eq!(snap.submitted, 2, "a QueueFull submit is not counted as submitted");
        assert_eq!(snap.shed, 0, "try_submit rejects client-side, not at the gate");
    }

    #[test]
    fn submit_after_receiver_drop_is_stopped() {
        let (tx, rx) = admission_queue::<Item>(2, AdmissionPolicy::Block, None);
        drop(rx);
        let (it, _r) = item(0, None);
        assert_eq!(tx.submit(it).unwrap_err(), SubmitError::Stopped);
        let (it, _r) = item(1, None);
        assert_eq!(tx.try_submit(it).unwrap_err(), SubmitError::Stopped);
    }

    #[test]
    fn shed_newest_answers_overloaded() {
        let (tx, rx) = admission_queue::<Item>(1, AdmissionPolicy::ShedNewest, None);
        let (it, _r1) = item(0, None);
        tx.submit(it).unwrap();
        let (it, r2) = item(1, None);
        tx.submit(it).unwrap();
        assert_eq!(r2.recv().unwrap(), Err(ServeError::Overloaded));
        let snap = rx.ledger().snapshot();
        assert_eq!(snap.submitted, 2);
        assert_eq!(snap.admitted, 1);
        assert_eq!(snap.shed, 1);
        assert!(!snap.balanced(), "one request still queued");
    }

    #[test]
    fn shed_expired_first_evicts_stale_queue_entries() {
        let (tx, rx) = admission_queue::<Item>(2, AdmissionPolicy::ShedExpiredFirst, None);
        let soon = Instant::now() + Duration::from_millis(1);
        let (stale, stale_rx) = item(0, Some(soon));
        tx.submit(stale).unwrap();
        let (fresh, _fresh_rx) = item(1, Some(Instant::now() + Duration::from_secs(60)));
        tx.submit(fresh).unwrap();
        std::thread::sleep(Duration::from_millis(5)); // head expires
        let (newcomer, _new_rx) = item(2, Some(Instant::now() + Duration::from_secs(60)));
        tx.submit(newcomer).unwrap();
        assert_eq!(
            stale_rx.recv().unwrap(),
            Err(ServeError::DeadlineExceeded),
            "stale head evicted with a typed answer"
        );
        assert_eq!(rx.depth(), 2, "fresh + newcomer remain");
        let snap = rx.ledger().snapshot();
        assert_eq!(snap.submitted, 3);
        assert_eq!(snap.admitted, 3);
        assert_eq!(snap.expired, 1);
        assert_eq!(snap.shed, 0);
    }

    #[test]
    fn expired_on_arrival_is_answered_not_queued() {
        let (tx, rx) = admission_queue::<Item>(4, AdmissionPolicy::Block, None);
        let (it, r) = item(0, Some(Instant::now() - Duration::from_millis(1)));
        tx.submit(it).unwrap();
        assert_eq!(r.recv().unwrap(), Err(ServeError::DeadlineExceeded));
        assert_eq!(rx.depth(), 0);
        let snap = rx.ledger().snapshot();
        assert_eq!((snap.admitted, snap.expired), (1, 1));
        assert!(snap.balanced());
    }

    #[test]
    fn pressure_combines_occupancy_and_headroom() {
        let budget = Duration::from_millis(100);
        let (tx, rx) = admission_queue::<Item>(4, AdmissionPolicy::Block, Some(budget));
        assert_eq!(rx.pressure(), 0.0, "empty queue has no pressure");
        for i in 0..4 {
            let (it, _r) = item(i, Some(Instant::now() + budget));
            tx.submit(it).unwrap();
        }
        let p = rx.pressure();
        assert!((0.45..=0.65).contains(&p), "full queue of fresh deadlines: {p}");
        std::thread::sleep(Duration::from_millis(80));
        let p = rx.pressure();
        assert!(p > 0.8, "full queue with the head nearly expired: {p}");
        let gauge = PressureGauge::new();
        gauge.set(p);
        assert!((gauge.get() - p).abs() < 1e-12);
        gauge.set(7.0);
        assert_eq!(gauge.get(), 1.0, "gauge clamps to [0, 1]");
    }

    #[test]
    fn backoff_is_jittered_exponential_and_capped() {
        let policy = RetryPolicy::default();
        let mut rng = Rng::new(42);
        let b0 = policy.backoff(0, &mut rng);
        assert!(b0 >= policy.base / 2 && b0 <= policy.base, "{b0:?}");
        let b4 = policy.backoff(4, &mut rng);
        assert!(b4 >= policy.base * 8, "exponential growth: {b4:?}");
        let b30 = policy.backoff(30, &mut rng);
        assert!(b30 <= policy.max_backoff, "cap honoured: {b30:?}");
        // Same seed => same jitter stream (deterministic clients).
        let s1: Vec<_> = {
            let mut r = Rng::new(9);
            (0..5).map(|i| policy.backoff(i, &mut r)).collect()
        };
        let s2: Vec<_> = {
            let mut r = Rng::new(9);
            (0..5).map(|i| policy.backoff(i, &mut r)).collect()
        };
        assert_eq!(s1, s2);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in
            [AdmissionPolicy::Block, AdmissionPolicy::ShedNewest, AdmissionPolicy::ShedExpiredFirst]
        {
            assert_eq!(AdmissionPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(AdmissionPolicy::parse("nope"), None);
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::Block);
    }
}
