//! Session-aware generation scheduler — continuous batching for
//! streaming decode.
//!
//! The autoregressive counterpart of [`super::Batcher`]: clients
//! submit prompts through the same bounded-queue/backpressure
//! discipline, but instead of one fixed-shape execution per request
//! the scheduler keeps a pool of live [`Session`]s and interleaves
//! **one decode step across every live session per tick** (continuous
//! batching, vLLM-style).  A finishing session frees its slot
//! mid-stream and a queued prompt is admitted immediately — no
//! head-of-line blocking on long generations, per-token cost O(1) in
//! context thanks to the Toeplitz→SSM conversion.
//!
//! Queue latency is recorded server-side per session (the same
//! p50/p95/p99 surface as [`super::BatcherStats`]) so `ski-tnn
//! generate` reports come from the scheduler, not client-side timing.
//!
//! Overload control mirrors the batcher's (see [`super::admission`]):
//! the prompt queue is a bounded admission queue with a shed policy
//! and per-request deadlines, prompts that expire while queued are
//! answered with a typed [`ServeError::DeadlineExceeded`] before any
//! prefill compute is spent, and every request is accounted in the
//! scheduler's [`AdmissionLedger`].

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::admission::{
    admission_queue, Admissible, AdmissionLedger, AdmissionPolicy, AdmissionReceiver,
    AdmissionSender, AdmissionSnapshot, RetryPolicy, ServeError, SubmitError, TryRecv,
    SERVER_PRESSURE,
};
use super::batcher::QUEUE_SAMPLE_CAP;
use super::chaos;
use crate::decode::{DecodeError, DecodeModel, Sampler, Session};
use crate::runtime::pool::{resolve_threads, ThreadPool};
use crate::util::bench::{percentiles_of, push_sample};
use crate::util::rng::Rng;

/// Scheduler tuning knobs.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Concurrent decode slots (live sessions per tick).
    pub max_sessions: usize,
    /// Bounded prompt queue — overflow is backpressure or shedding
    /// (per `policy`), not OOM.
    pub queue_depth: usize,
    /// Server-side cap on tokens per request.
    pub max_new_cap: usize,
    /// Worker threads the tick loop shards live sessions across
    /// (0 = auto: `SKI_TNN_THREADS` / available parallelism; 1 =
    /// serial reference).  Sessions are independent, so generated
    /// tokens are bitwise identical for any value.
    pub threads: usize,
    /// What a full queue does to a blocking submit.
    pub policy: AdmissionPolicy,
    /// Default per-request deadline; `None` = no deadline.
    pub deadline: Option<Duration>,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            max_sessions: 8,
            queue_depth: 64,
            max_new_cap: 512,
            threads: 0,
            policy: AdmissionPolicy::Block,
            deadline: None,
        }
    }
}

/// Per-request sampling/length parameters.
#[derive(Debug, Clone, Copy)]
pub struct GenParams {
    pub max_new: usize,
    /// 0 = greedy.
    pub temperature: f32,
    /// 0 = no truncation.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams { max_new: 32, temperature: 0.0, top_k: 0, seed: 0 }
    }
}

/// One generation request.
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub params: GenParams,
    resp: SyncSender<GenResponse>,
    submitted: Instant,
    /// Absolute deadline; past it the prompt is answered with
    /// [`ServeError::DeadlineExceeded`] instead of decoding.
    deadline: Option<Instant>,
}

impl Admissible for GenRequest {
    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    fn reject(self, err: ServeError) {
        let queued = self.submitted.elapsed();
        let _ = self.resp.send(GenResponse { tokens: Vec::new(), queued, error: Some(err) });
    }
}

/// One finished generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// Generated tokens (prompt excluded; one decode step each).
    pub tokens: Vec<i32>,
    /// Time between submit and admission to a decode slot.
    pub queued: Duration,
    /// Set when this request did not generate: a typed
    /// overload/deadline answer from admission control, or
    /// [`ServeError::Exec`] when its session failed (corrupted decode
    /// state — the serve process and every other live session carried
    /// on).  [`GenClient::generate`] surfaces it as an `Err`.
    pub error: Option<ServeError>,
}

/// Aggregate scheduler counters.
#[derive(Debug, Default, Clone)]
pub struct GenStats {
    pub sessions: usize,
    pub tokens: usize,
    /// Scheduler ticks (one tick = one step across all live sessions).
    pub ticks: usize,
    /// Σ live sessions over ticks — `mean_concurrency` numerator.
    pub active_session_ticks: usize,
    /// Wall time inside model decode steps.
    pub decode_seconds: f64,
    /// Prefill wall time (prompt absorption at admission).
    pub prefill_seconds: f64,
    /// Per-session queue wait, recorded at admission.  Bounded to the
    /// most recent `QUEUE_SAMPLE_CAP` samples, like the batcher's.
    pub queue_seconds: Vec<f64>,
    /// End-of-run admission ledger snapshot — must satisfy
    /// [`AdmissionSnapshot::balanced`] at quiescence.
    pub admission: AdmissionSnapshot,
}

impl GenStats {
    /// Mean live sessions per tick — >1 means decode steps from many
    /// users genuinely shared the loop.
    pub fn mean_concurrency(&self) -> f64 {
        if self.ticks == 0 {
            return 0.0;
        }
        self.active_session_ticks as f64 / self.ticks as f64
    }

    /// (p50, p95, p99) queue wait, seconds.
    pub fn queue_percentiles(&self) -> (f64, f64, f64) {
        let ps = percentiles_of(&self.queue_seconds, &[0.50, 0.95, 0.99]);
        (ps[0], ps[1], ps[2])
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let t = self.decode_seconds + self.prefill_seconds;
        if t > 0.0 {
            self.tokens as f64 / t
        } else {
            0.0
        }
    }
}

/// Client handle: submit prompts, receive generations.
#[derive(Clone)]
pub struct GenClient {
    tx: AdmissionSender<GenRequest>,
    deadline: Option<Duration>,
}

impl GenClient {
    /// This handle with a different per-request deadline (`None`
    /// disables; the config default is what [`GenScheduler::handle`]
    /// installs).
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> GenClient {
        self.deadline = deadline;
        self
    }

    fn request(&self, prompt: Vec<i32>, params: GenParams) -> (GenRequest, Receiver<GenResponse>) {
        let (rtx, rrx) = sync_channel(1);
        let now = Instant::now();
        let deadline = self.deadline.map(|d| now + d);
        (GenRequest { prompt, params, resp: rtx, submitted: now, deadline }, rrx)
    }

    /// Blocking round-trip.  A per-session decode failure (or a typed
    /// overload/deadline answer) comes back as `Err`, not a dead
    /// server.
    pub fn generate(&self, prompt: Vec<i32>, params: GenParams) -> Result<GenResponse> {
        let resp = self.generate_response(prompt, params)?;
        match &resp.error {
            None => Ok(resp),
            Some(e) => Err(anyhow!("generation failed: {e}")),
        }
    }

    /// [`generate`](Self::generate) without the error-field mapping:
    /// typed overload/deadline/session answers come back as the
    /// response itself — the raw form retry loops match on.
    pub fn generate_response(&self, prompt: Vec<i32>, params: GenParams) -> Result<GenResponse> {
        let (req, rrx) = self.request(prompt, params);
        self.tx.submit(req).map_err(|_| anyhow!("generation server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("generation server dropped session"))
    }

    /// Non-blocking submit; a full queue is an immediate typed
    /// [`SubmitError::QueueFull`] (backpressure — nothing was queued
    /// and no response will arrive).
    pub fn try_submit(
        &self,
        prompt: Vec<i32>,
        params: GenParams,
    ) -> Result<Receiver<GenResponse>, SubmitError> {
        let (req, rrx) = self.request(prompt, params);
        self.tx.try_submit(req)?;
        Ok(rrx)
    }

    /// Submit with client-side retry: jittered exponential backoff on
    /// `QueueFull` and on typed overload answers, bounded by the
    /// policy's attempt count and total-time budget.
    pub fn generate_with_retry(
        &self,
        prompt: Vec<i32>,
        params: GenParams,
        policy: &RetryPolicy,
    ) -> Result<GenResponse> {
        let ledger = self.tx.ledger();
        let started = Instant::now();
        let mut rng = Rng::new(policy.seed);
        let mut last_err = anyhow!("no attempt made");
        for attempt in 0..policy.attempts.max(1) {
            if attempt > 0 {
                let pause = policy.backoff(attempt as u32 - 1, &mut rng);
                if started.elapsed() + pause >= policy.budget {
                    break;
                }
                std::thread::sleep(pause);
                ledger.note_retry();
            }
            match self.try_submit(prompt.clone(), params) {
                Err(SubmitError::Stopped) => return Err(anyhow!("generation server stopped")),
                Err(SubmitError::QueueFull) => {
                    last_err = anyhow!("generation queue full");
                }
                Ok(rrx) => {
                    let resp =
                        rrx.recv().map_err(|_| anyhow!("generation server dropped session"))?;
                    match &resp.error {
                        None => return Ok(resp),
                        Some(e) if e.retryable() => {
                            last_err = anyhow!("generation failed: {e}");
                        }
                        Some(e) => return Err(anyhow!("generation failed: {e}")),
                    }
                }
            }
        }
        Err(last_err.context(format!(
            "retries exhausted ({} attempts, {:?} elapsed)",
            policy.attempts,
            started.elapsed()
        )))
    }
}

/// A session occupying a decode slot.
struct Live {
    session: Session,
    resp: SyncSender<GenResponse>,
    queued: Duration,
    /// Set when a decode step failed: the session is retired on the
    /// next sweep with an error response instead of tokens.
    error: Option<String>,
}

/// One admitted-but-not-yet-prefilled request (the unit the grouped
/// prefill shards across the pool).
struct Admission {
    id: u64,
    prompt: Vec<i32>,
    params: GenParams,
    max_new: usize,
    resp: SyncSender<GenResponse>,
    queued: Duration,
    built: Option<Result<Session, DecodeError>>,
}

/// Length bucket of a prompt: the next power of two ≥ len (capped so
/// tiny prompts share one bucket).  Used only to ORDER admissions so
/// the sharded prefill hands each worker prompts of similar length —
/// per-session results are independent of the grouping.
fn prompt_bucket(len: usize) -> usize {
    len.max(8).next_power_of_two()
}

/// The continuous-batching scheduler.  Owns the prompt queue; `run`
/// drives the model until all client handles are gone and every live
/// session has drained.
pub struct GenScheduler {
    pub cfg: GenConfig,
    rx: AdmissionReceiver<GenRequest>,
    tx: Option<AdmissionSender<GenRequest>>,
    next_id: u64,
}

impl GenScheduler {
    pub fn new(cfg: GenConfig) -> GenScheduler {
        let (tx, rx) = admission_queue(cfg.queue_depth, cfg.policy, cfg.deadline);
        GenScheduler { cfg, rx, tx: Some(tx), next_id: 0 }
    }

    /// A cloneable client handle (hand to worker threads), carrying
    /// the config's default deadline.
    pub fn handle(&self) -> GenClient {
        GenClient {
            tx: self.tx.clone().expect("scheduler already running"),
            deadline: self.cfg.deadline,
        }
    }

    /// Admit a group of requests: record queue waits, assign ids in
    /// arrival order, then prefill every prompt **sharded across the
    /// pool**, grouped by prompt-length bucket so each worker's shard
    /// holds similar-length prompts (balanced shards under
    /// mixed-length traffic).  Sessions are independent — the request
    /// seed is used verbatim — so identical (prompt, seed) requests
    /// reproduce identical tokens regardless of grouping or worker
    /// count.  A request whose prefill fails (corrupted state) is
    /// answered with an error response here; it never occupies a slot.
    fn admit_group(
        &mut self,
        reqs: Vec<GenRequest>,
        model: &DecodeModel,
        pool: &ThreadPool,
        stats: &mut GenStats,
        active: &mut Vec<Live>,
        ledger: &AdmissionLedger,
    ) {
        let mut adms: Vec<Admission> = reqs
            .into_iter()
            .map(|req| {
                let queued = req.submitted.elapsed();
                push_sample(
                    &mut stats.queue_seconds,
                    QUEUE_SAMPLE_CAP,
                    stats.sessions,
                    queued.as_secs_f64(),
                );
                crate::telemetry::SPAN_QUEUE_WAIT.record_ns(queued.as_nanos() as u64);
                stats.sessions += 1;
                let id = self.next_id;
                self.next_id += 1;
                let max_new = req.params.max_new.min(self.cfg.max_new_cap);
                Admission {
                    id,
                    prompt: req.prompt,
                    params: req.params,
                    max_new,
                    resp: req.resp,
                    queued,
                    built: None,
                }
            })
            .collect();
        // Stable sort: arrival order within a bucket is preserved.
        adms.sort_by_key(|a| prompt_bucket(a.prompt.len()));
        let t0 = Instant::now();
        pool.shard_mut(&mut adms, |_, shard| {
            for a in shard.iter_mut() {
                let p = a.params;
                let sampler = Sampler::new(p.temperature, p.top_k, p.seed);
                a.built = Some(Session::new(model, a.id, &a.prompt, sampler, a.max_new));
            }
        });
        stats.prefill_seconds += t0.elapsed().as_secs_f64();
        for a in adms {
            match a.built.expect("prefill ran for every admission") {
                Ok(mut session) => {
                    // Chaos hook: a freshly admitted session may be
                    // corrupted here, which must fail only its own
                    // request (the fault the tick loop is hardened
                    // against).
                    if chaos::poison_next_session() {
                        session.poison_for_test();
                    }
                    active.push(Live { session, resp: a.resp, queued: a.queued, error: None })
                }
                Err(e) => {
                    // Answered ⇒ completed, for the admission ledger.
                    ledger.note_completed(1);
                    let _ = a.resp.send(GenResponse {
                        tokens: Vec::new(),
                        queued: a.queued,
                        error: Some(ServeError::Exec(e.to_string())),
                    });
                }
            }
        }
    }

    /// Run the scheduler loop.  Returns when every [`GenClient`] is
    /// dropped and all admitted sessions have finished.
    pub fn run(mut self, model: &DecodeModel) -> Result<GenStats> {
        drop(self.tx.take()); // only client handles keep the queue alive
        let pool = ThreadPool::new(resolve_threads(self.cfg.threads));
        let ledger = self.rx.ledger();
        let mut stats = GenStats::default();
        let mut active: Vec<Live> = Vec::new();
        let mut disconnected = false;
        loop {
            // Admission: block when idle, otherwise top up free slots;
            // everything gathered this round prefills as one group.
            let mut incoming: Vec<GenRequest> = Vec::new();
            if active.is_empty() {
                if disconnected {
                    break;
                }
                match self.rx.recv() {
                    Some(r) => incoming.push(r),
                    None => break,
                }
            }
            while !disconnected && active.len() + incoming.len() < self.cfg.max_sessions {
                match self.rx.try_recv() {
                    TryRecv::Item(r) => incoming.push(r),
                    TryRecv::Empty => break,
                    TryRecv::Disconnected => {
                        disconnected = true;
                        break;
                    }
                }
            }
            // Publish pressure once per scheduling round (the same
            // gauge the batcher feeds; whichever loop is serving owns
            // the reading).
            SERVER_PRESSURE.set(self.rx.pressure());
            // Deadline sweep: prompts that expired while queued are
            // answered before any prefill compute is spent on them.
            let now = Instant::now();
            let (live_in, expired): (Vec<_>, Vec<_>) =
                incoming.into_iter().partition(|r| !r.expired(now));
            for req in expired {
                ledger.note_expired();
                req.reject(ServeError::DeadlineExceeded);
            }
            if !live_in.is_empty() {
                self.admit_group(live_in, model, &pool, &mut stats, &mut active, &ledger);
            }
            if active.is_empty() {
                // Every admission this round failed prefill or
                // expired (or none arrived): nothing to tick.
                continue;
            }
            // Chaos hook: an injected slow tick inflates queue waits,
            // exercising deadlines and shedding downstream.
            chaos::inject_stall();
            // One tick: a decode step for every live session, sharded
            // across the pool (sessions are independent — each owns
            // its state and sampler — so this is bitwise identical to
            // the serial loop for any worker count).
            let t0 = Instant::now();
            let stepped = {
                let _span = crate::telemetry::span(&crate::telemetry::SPAN_DECODE_TICK);
                step_sessions(&pool, model, &mut active)
            };
            stats.decode_seconds += t0.elapsed().as_secs_f64();
            stats.ticks += 1;
            stats.active_session_ticks += active.len();
            stats.tokens += stepped;
            retire_finished(&mut active, &ledger);
        }
        stats.admission = ledger.snapshot();
        Ok(stats)
    }
}

/// One decode step for every unfinished live session, sharded across
/// `pool` in fixed contiguous chunks.  Returns how many sessions
/// actually stepped (a commutative sum, so the count is deterministic
/// too).  A step failure (corrupted session) marks that session only;
/// [`retire_finished`] answers its request with the error.
fn step_sessions(pool: &ThreadPool, model: &DecodeModel, active: &mut [Live]) -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let stepped = AtomicUsize::new(0);
    pool.shard_mut(active, |_, shard| {
        let mut local = 0usize;
        for live in shard.iter_mut() {
            if live.error.is_none() && !live.session.done() {
                match live.session.step(model) {
                    Ok(_) => local += 1,
                    Err(e) => live.error = Some(e.to_string()),
                }
            }
        }
        stepped.fetch_add(local, Ordering::Relaxed);
    });
    stepped.into_inner()
}

/// Retire finished and failed sessions — their slots free mid-stream.
/// A failed session answers its own request with the error; every
/// other live session (and the serve loop itself) is untouched.
/// Either way the answer is a completion for the admission ledger.
fn retire_finished(active: &mut Vec<Live>, ledger: &AdmissionLedger) {
    active.retain_mut(|live| {
        if let Some(e) = live.error.take() {
            ledger.note_completed(1);
            let _ = live.resp.send(GenResponse {
                tokens: Vec::new(),
                queued: live.queued,
                error: Some(ServeError::Exec(e)),
            });
            return false;
        }
        if !live.session.done() {
            return true;
        }
        let tokens = live.session.generated().to_vec();
        ledger.note_completed(1);
        let _ = live.resp.send(GenResponse { tokens, queued: live.queued, error: None });
        false
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::model::DecodeModelConfig;
    use crate::decode::{DecodeModel, DecodePolicy};

    fn tiny_model() -> DecodeModel {
        DecodeModel::new(DecodeModelConfig {
            d: 8,
            blocks: 1,
            n: 32,
            policy: DecodePolicy { rank: 8, max_rel_residual: 0.05 },
            seed: 2,
            ..DecodeModelConfig::default()
        })
    }

    #[test]
    fn roundtrip_many_clients() {
        let model = tiny_model();
        let sched = GenScheduler::new(GenConfig {
            max_sessions: 4,
            queue_depth: 16,
            max_new_cap: 64,
            threads: 4,
            ..GenConfig::default()
        });
        let h = sched.handle();
        let clients: Vec<_> = (0..3)
            .map(|c| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..4 {
                        let prompt = vec![(c * 40 + i) as i32; 3];
                        let params = GenParams { max_new: 6, ..GenParams::default() };
                        let resp = h.generate(prompt, params).unwrap();
                        assert_eq!(resp.tokens.len(), 6);
                        assert!(resp.tokens.iter().all(|&t| (0..259).contains(&t)));
                    }
                })
            })
            .collect();
        drop(h);
        let stats = sched.run(&model).unwrap();
        for c in clients {
            c.join().unwrap();
        }
        assert_eq!(stats.sessions, 12);
        assert_eq!(stats.tokens, 12 * 6);
        assert_eq!(stats.queue_seconds.len(), 12);
        let (p50, p95, p99) = stats.queue_percentiles();
        assert!(p50 <= p95 && p95 <= p99);
        // The admission ledger balances exactly at quiescence.
        assert!(stats.admission.balanced(), "{:?}", stats.admission);
        assert_eq!(stats.admission.completed, 12);
    }

    #[test]
    fn continuous_batching_interleaves_sessions() {
        let model = tiny_model();
        let sched = GenScheduler::new(GenConfig {
            max_sessions: 6,
            queue_depth: 16,
            max_new_cap: 64,
            threads: 2,
            ..GenConfig::default()
        });
        let h = sched.handle();
        let t = std::thread::spawn(move || {
            let pending: Vec<_> = (0..6)
                .map(|i| {
                    h.try_submit(
                        vec![i as i32 + 1],
                        GenParams { max_new: 8, ..GenParams::default() },
                    )
                    .unwrap()
                })
                .collect();
            pending.into_iter().map(|rx| rx.recv().unwrap()).collect::<Vec<_>>()
        });
        let stats = sched.run(&model).unwrap();
        let resps = t.join().unwrap();
        assert_eq!(resps.len(), 6);
        assert_eq!(stats.tokens, 48);
        // 48 tokens in far fewer ticks than 48 ⇒ sessions genuinely
        // shared the decode loop.
        assert!(stats.ticks < 30, "no interleaving: {} ticks", stats.ticks);
        assert!(
            stats.mean_concurrency() > 1.5,
            "mean concurrency {:.2} too low",
            stats.mean_concurrency()
        );
    }

    #[test]
    fn scheduler_matches_direct_session_decode() {
        // Riding through the scheduler must not perturb a session:
        // same prompt/params ⇒ identical tokens to a direct decode.
        let model = tiny_model();
        let params = GenParams { max_new: 10, temperature: 0.0, top_k: 0, seed: 5 };
        let mut direct = Session::new(&model, 0, &[7, 8, 9], Sampler::greedy(), 10).unwrap();
        while !direct.done() {
            direct.step(&model).unwrap();
        }
        let sched = GenScheduler::new(GenConfig::default());
        let h = sched.handle();
        let t = std::thread::spawn(move || h.generate(vec![7, 8, 9], params).unwrap());
        let _ = sched.run(&model).unwrap();
        let resp = t.join().unwrap();
        assert_eq!(resp.tokens, direct.generated().to_vec());
    }

    #[test]
    fn parallel_ticks_match_serial_token_for_token() {
        // The sharded tick loop is a pure scheduling change: the same
        // (prompt, seed) set must yield byte-identical generations at
        // any worker count.
        let model = tiny_model();
        let run = |threads: usize| -> Vec<Vec<i32>> {
            let sched = GenScheduler::new(GenConfig {
                max_sessions: 8,
                queue_depth: 16,
                max_new_cap: 64,
                threads,
                ..GenConfig::default()
            });
            let h = sched.handle();
            let t = std::thread::spawn(move || {
                let pending: Vec<_> = (0..8)
                    .map(|i| {
                        let params = GenParams {
                            max_new: 10,
                            temperature: 1.1,
                            top_k: 12,
                            seed: 1000 + i as u64,
                        };
                        h.try_submit(vec![i as i32 + 1, 2 * i as i32], params).unwrap()
                    })
                    .collect();
                pending.into_iter().map(|rx| rx.recv().unwrap().tokens).collect::<Vec<_>>()
            });
            sched.run(&model).unwrap();
            t.join().unwrap()
        };
        let serial = run(1);
        assert_eq!(serial, run(2), "2 workers diverged from serial");
        assert_eq!(serial, run(8), "8 workers diverged from serial");
    }

    #[test]
    fn corrupted_session_fails_its_own_request_only() {
        // The satellite regression: a decoder/state variant mismatch
        // used to panic inside the tick loop and kill the whole serve
        // process.  Now the poisoned session's request gets an error
        // response while the healthy session generates to completion.
        let model = tiny_model();
        let pool = ThreadPool::new(2);
        let ledger = AdmissionLedger::default();
        let (tx_bad, rx_bad) = sync_channel(1);
        let (tx_ok, rx_ok) = sync_channel(1);
        let mut bad = Session::new(&model, 0, &[1, 2], Sampler::greedy(), 4).unwrap();
        bad.poison_for_test();
        let good = Session::new(&model, 1, &[3, 4], Sampler::greedy(), 4).unwrap();
        let mut active = vec![
            Live { session: bad, resp: tx_bad, queued: Duration::ZERO, error: None },
            Live { session: good, resp: tx_ok, queued: Duration::ZERO, error: None },
        ];
        let mut guard = 0;
        while !active.is_empty() {
            step_sessions(&pool, &model, &mut active);
            retire_finished(&mut active, &ledger);
            guard += 1;
            assert!(guard < 32, "sessions must drain");
        }
        let bad_resp = rx_bad.recv().unwrap();
        assert!(bad_resp.error.is_some(), "poisoned session must error");
        assert!(bad_resp.tokens.is_empty());
        let ok_resp = rx_ok.recv().unwrap();
        assert!(ok_resp.error.is_none(), "healthy session must be unaffected");
        assert_eq!(ok_resp.tokens.len(), 4);
        assert_eq!(ledger.snapshot().completed, 2, "every answer is a ledger completion");
    }

    #[test]
    fn scheduler_survives_corrupted_session_via_client_api() {
        // End-to-end through GenClient: the corrupted request's client
        // sees Err, the scheduler's run loop returns Ok (process
        // alive), and a subsequent healthy request still serves.
        let model = tiny_model();
        let pool = ThreadPool::new(1);
        let ledger = AdmissionLedger::default();
        let (tx_bad, rx_bad) = sync_channel::<GenResponse>(1);
        let mut bad = Session::new(&model, 7, &[9], Sampler::greedy(), 8).unwrap();
        bad.poison_for_test();
        let mut active =
            vec![Live { session: bad, resp: tx_bad, queued: Duration::ZERO, error: None }];
        step_sessions(&pool, &model, &mut active);
        retire_finished(&mut active, &ledger);
        assert!(active.is_empty(), "failed session must free its slot");
        assert!(rx_bad.recv().unwrap().error.is_some());
        // The scheduler keeps serving healthy traffic afterwards.
        let sched = GenScheduler::new(GenConfig::default());
        let h = sched.handle();
        let t = std::thread::spawn(move || {
            h.generate(vec![5, 6], GenParams { max_new: 3, ..GenParams::default() }).unwrap()
        });
        let stats = sched.run(&model).unwrap();
        assert_eq!(t.join().unwrap().tokens.len(), 3);
        assert_eq!(stats.sessions, 1);
    }

    #[test]
    fn bucketed_prefill_preserves_per_session_determinism() {
        // Mixed-length prompts admitted as one group: the bucketed,
        // pool-sharded prefill must not perturb any session's tokens
        // relative to a serial one-at-a-time scheduler.
        let model = tiny_model();
        let run = |threads: usize, queue_ahead: bool| -> Vec<Vec<i32>> {
            let sched = GenScheduler::new(GenConfig {
                max_sessions: 8,
                queue_depth: 16,
                max_new_cap: 64,
                threads,
                ..GenConfig::default()
            });
            let h = sched.handle();
            let t = std::thread::spawn(move || {
                let prompts: Vec<Vec<i32>> = (0..6)
                    .map(|i| (0..(3 + i * 7)).map(|j| ((i * 31 + j) % 256) as i32).collect())
                    .collect();
                let pending: Vec<_> = prompts
                    .into_iter()
                    .enumerate()
                    .map(|(i, p)| {
                        let params = GenParams {
                            max_new: 5,
                            temperature: 0.9,
                            top_k: 8,
                            seed: 100 + i as u64,
                        };
                        if !queue_ahead {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        h.try_submit(p, params).unwrap()
                    })
                    .collect();
                pending.into_iter().map(|rx| rx.recv().unwrap().tokens).collect::<Vec<_>>()
            });
            sched.run(&model).unwrap();
            t.join().unwrap()
        };
        let serial = run(1, false);
        assert_eq!(serial, run(4, true), "grouped parallel prefill diverged");
    }

    #[test]
    fn zero_token_requests_complete() {
        let model = tiny_model();
        let sched = GenScheduler::new(GenConfig::default());
        let h = sched.handle();
        let t = std::thread::spawn(move || {
            h.generate(vec![1], GenParams { max_new: 0, ..GenParams::default() }).unwrap()
        });
        let stats = sched.run(&model).unwrap();
        let resp = t.join().unwrap();
        assert!(resp.tokens.is_empty());
        assert_eq!(stats.sessions, 1);
    }

    #[test]
    fn backpressure_on_full_queue() {
        let model = tiny_model();
        let sched = GenScheduler::new(GenConfig {
            max_sessions: 2,
            queue_depth: 1,
            max_new_cap: 8,
            ..GenConfig::default()
        });
        let h = sched.handle();
        // Scheduler not running: the bounded queue must reject the
        // second submit instead of buffering unboundedly, with the
        // typed error.
        let _first = h.try_submit(vec![1], GenParams::default()).unwrap();
        assert_eq!(
            h.try_submit(vec![2], GenParams::default()).unwrap_err(),
            SubmitError::QueueFull
        );
        drop(h);
        let stats = sched.run(&model).unwrap();
        assert_eq!(stats.sessions, 1);
    }

    #[test]
    fn expired_prompt_answers_typed_deadline_error() {
        // A prompt whose deadline passes while queued must get exactly
        // one DeadlineExceeded answer and never occupy a decode slot.
        let model = tiny_model();
        let sched = GenScheduler::new(GenConfig {
            deadline: Some(Duration::ZERO),
            ..GenConfig::default()
        });
        let h = sched.handle();
        let t = std::thread::spawn(move || {
            h.generate(vec![1, 2], GenParams { max_new: 4, ..GenParams::default() })
        });
        let stats = sched.run(&model).unwrap();
        let err = t.join().unwrap().expect_err("zero deadline must expire");
        assert!(err.to_string().contains("deadline exceeded"), "{err}");
        assert_eq!(stats.sessions, 0, "expired prompts never reach prefill");
        assert!(stats.admission.balanced(), "{:?}", stats.admission);
        assert_eq!(stats.admission.expired, 1);
    }
}
