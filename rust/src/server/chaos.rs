//! Deterministic fault injection for the serving stack.
//!
//! The same zero-cost discipline as the telemetry handles: every hook
//! is one relaxed atomic load when chaos is off (the default), and the
//! decision stream is drawn from one seeded [`Rng`] when it is on —
//! the same seed replays the same injection sequence, which is what
//! lets `tests/overload.rs` and the CI `robustness-soak` job assert
//! exact invariants under induced failure instead of flaky ones.
//!
//! Arming: set the `SKI_TNN_CHAOS` environment variable to a seed
//! (`0`/`off`/empty leaves it disarmed) or call [`install`] with an
//! explicit [`ChaosConfig`].  [`disarm`] returns to the no-op state.
//!
//! Faults injected (each an independent Bernoulli draw per site):
//! * **Executor failures** — [`chaos_exec`] wraps a batcher executor
//!   and makes it fail whole batches, exercising the fail-the-batch-
//!   not-the-loop hardening.
//! * **Slow ticks** — [`inject_stall`] sleeps inside the serve/decode
//!   loop, inflating queue waits until deadlines and shedding engage.
//! * **Poisoned sessions** — [`poison_next_session`] tells the
//!   generation scheduler to corrupt a freshly admitted session, which
//!   must fail only its own request.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, PoisonError};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

use super::rows::RowBatch;

/// Injection rates and knobs; [`ChaosConfig::from_seed`] gives the
/// soak defaults, struct-update syntax tunes individual rates.
#[derive(Debug, Clone, Copy)]
pub struct ChaosConfig {
    /// PRNG seed — the whole decision stream derives from it.
    pub seed: u64,
    /// P(an executed batch fails wholesale).
    pub exec_failure: f64,
    /// P(a serve/decode tick stalls for `stall` first).
    pub slow_tick: f64,
    /// Stall duration for an injected slow tick.
    pub stall: Duration,
    /// P(a freshly admitted decode session is poisoned).
    pub poison_session: f64,
}

impl ChaosConfig {
    /// Soak-calibrated defaults: frequent enough that a few hundred
    /// requests exercise every failure path, rare enough that most
    /// traffic still completes.
    pub fn from_seed(seed: u64) -> ChaosConfig {
        ChaosConfig {
            seed,
            exec_failure: 0.08,
            slow_tick: 0.05,
            stall: Duration::from_millis(3),
            poison_session: 0.05,
        }
    }
}

/// What chaos actually did — lets a soak report injected fault counts
/// next to the admission ledger.
#[derive(Debug, Default, Clone, Copy)]
pub struct ChaosCounts {
    pub exec_failures: u64,
    pub stalls: u64,
    pub poisoned: u64,
}

struct State {
    cfg: ChaosConfig,
    rng: Rng,
    counts: ChaosCounts,
}

/// Fast-path gate: hooks bail on one relaxed load when disarmed.
static ARMED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();
static STATE: Mutex<Option<State>> = Mutex::new(None);

fn lock_state() -> std::sync::MutexGuard<'static, Option<State>> {
    STATE.lock().unwrap_or_else(PoisonError::into_inner)
}

fn env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(v) = std::env::var("SKI_TNN_CHAOS") {
            let v = v.trim();
            if !(v.is_empty() || v == "0" || v.eq_ignore_ascii_case("off")) {
                // Any non-numeric value still arms with a fixed seed so
                // `SKI_TNN_CHAOS=on` does something sensible.
                let seed = v.parse::<u64>().unwrap_or(1);
                install(ChaosConfig::from_seed(seed));
            }
        }
    });
}

/// Is fault injection armed?  The only cost every hook pays when off.
pub fn enabled() -> bool {
    env_init();
    ARMED.load(Ordering::Relaxed)
}

/// Arm fault injection with an explicit config (tests, `ski-tnn
/// soak`).  Resets the decision stream and counts.
pub fn install(cfg: ChaosConfig) {
    let mut g = lock_state();
    *g = Some(State { rng: Rng::new(cfg.seed), cfg, counts: ChaosCounts::default() });
    drop(g);
    ARMED.store(true, Ordering::Relaxed);
}

/// Disarm: every hook returns to the no-op fast path.
pub fn disarm() {
    ARMED.store(false, Ordering::Relaxed);
    *lock_state() = None;
}

/// Fault counts injected since [`install`].
pub fn counts() -> ChaosCounts {
    lock_state().as_ref().map(|s| s.counts).unwrap_or_default()
}

/// Draw one decision; `None` when disarmed (between `enabled()` and
/// the lock, `disarm` may have raced — treated as disarmed).
fn draw(p: impl Fn(&ChaosConfig) -> f64, count: impl Fn(&mut ChaosCounts)) -> bool {
    let mut g = lock_state();
    let Some(state) = g.as_mut() else { return false };
    let hit = state.rng.bool(p(&state.cfg));
    if hit {
        count(&mut state.counts);
    }
    hit
}

/// Should the current batch execution fail?  Returns the injected
/// error message so callers produce a recognisable failure.
pub fn inject_exec_failure() -> Option<&'static str> {
    if !enabled() {
        return None;
    }
    draw(|c| c.exec_failure, |k| k.exec_failures += 1)
        .then_some("chaos: injected executor failure")
}

/// Maybe stall the calling serve/decode tick.
pub fn inject_stall() {
    if !enabled() {
        return;
    }
    let stall = {
        let mut g = lock_state();
        let Some(state) = g.as_mut() else { return };
        if !state.rng.bool(state.cfg.slow_tick) {
            return;
        }
        state.counts.stalls += 1;
        state.cfg.stall
    };
    // Sleep outside the lock: a stall must slow one tick, not every
    // concurrent hook.
    std::thread::sleep(stall);
}

/// Should the session being admitted right now be poisoned?
pub fn poison_next_session() -> bool {
    if !enabled() {
        return false;
    }
    draw(|c| c.poison_session, |k| k.poisoned += 1)
}

/// Wrap a [`super::Batcher::run`] executor with executor-failure and
/// slow-tick injection.  Disarmed, the wrapper is a pass-through
/// costing one atomic load per batch.
pub fn chaos_exec<F>(mut exec: F) -> impl FnMut(&HostTensor) -> Result<RowBatch>
where
    F: FnMut(&HostTensor) -> Result<RowBatch>,
{
    move |batch: &HostTensor| {
        if let Some(msg) = inject_exec_failure() {
            return Err(anyhow!(msg));
        }
        inject_stall();
        exec(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests that arm/disarm the global chaos state (the
    /// same discipline as `telemetry::test_guard`).
    pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
        static GUARD: Mutex<()> = Mutex::new(());
        GUARD.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn decision_stream(seed: u64, n: usize) -> Vec<(bool, bool)> {
        install(ChaosConfig { stall: Duration::ZERO, ..ChaosConfig::from_seed(seed) });
        let out = (0..n)
            .map(|_| (inject_exec_failure().is_some(), poison_next_session()))
            .collect();
        disarm();
        out
    }

    #[test]
    fn disarmed_hooks_are_no_ops() {
        let _g = test_guard();
        let _ = enabled(); // settle any env-var arming first
        disarm();
        assert!(!enabled());
        assert!(inject_exec_failure().is_none());
        assert!(!poison_next_session());
        inject_stall(); // must not sleep or panic
        let mut exec = chaos_exec(|_b: &HostTensor| Ok(RowBatch::from(vec![vec![1.0f32]])));
        let batch = HostTensor::i32(vec![1, 1], vec![0]);
        assert!(exec(&batch).is_ok(), "disarmed wrapper is a pass-through");
    }

    #[test]
    fn same_seed_replays_same_decision_stream() {
        let _g = test_guard();
        let a = decision_stream(1234, 256);
        let b = decision_stream(1234, 256);
        assert_eq!(a, b, "seeded chaos must be deterministic");
        let c = decision_stream(99, 256);
        assert_ne!(a, c, "different seeds must diverge");
        assert!(a.iter().any(|&(f, _)| f), "rates must actually fire over 256 draws");
    }

    #[test]
    fn counts_track_injections() {
        let _g = test_guard();
        install(ChaosConfig {
            exec_failure: 1.0,
            poison_session: 1.0,
            slow_tick: 0.0,
            ..ChaosConfig::from_seed(7)
        });
        assert_eq!(inject_exec_failure(), Some("chaos: injected executor failure"));
        assert!(poison_next_session());
        let k = counts();
        assert_eq!((k.exec_failures, k.poisoned, k.stalls), (1, 1, 0));
        disarm();
        assert_eq!(counts().exec_failures, 0, "disarm clears state");
    }

    #[test]
    fn chaos_exec_injects_failures_at_rate_one() {
        let _g = test_guard();
        install(ChaosConfig { exec_failure: 1.0, ..ChaosConfig::from_seed(3) });
        let mut exec = chaos_exec(|_b: &HostTensor| Ok(RowBatch::from(vec![vec![1.0f32]])));
        let batch = HostTensor::i32(vec![1, 1], vec![0]);
        let err = exec(&batch).unwrap_err();
        assert!(err.to_string().contains("chaos: injected executor failure"), "{err}");
        disarm();
    }
}
