//! `ski-tnn` — the launcher CLI.
//!
//! Subcommands:
//!
//! * `list`  — show every artifact config in the manifest.
//! * `train` — run the training orchestrator on one config.
//! * `eval`  — evaluate a checkpoint (or fresh init) on the val split.
//! * `serve` — start the dynamic batcher on a config and drive it with
//!   synthetic client load, reporting server-side latency percentiles.
//! * `generate` — streaming autoregressive generation through the
//!   decode subsystem (causal-Toeplitz→SSM, O(1) per token): one-shot
//!   text generation or a continuous-batching load test.
//! * `plan` — explain the execution plan for a shape without serving
//!   traffic: chosen backend, sharding decision, transform length,
//!   estimated resident bytes, plan-cache counters
//!   (`ski-tnn plan --explain --n 1024 --threads 4`).
//! * `bench-check` — offline perf gate: compare the `BENCH_*.json`
//!   artifacts emitted by the benches against `bench/baseline.json`
//!   and fail on median regressions (CI's `bench-smoke` job; see
//!   README "Threading & benchmarking in CI").  `--stats-snapshot
//!   STATS.json` additionally gates a telemetry snapshot for
//!   completeness.
//! * `stats` — pretty-print a telemetry stats snapshot written by
//!   `--stats-json` (latency percentiles, counters/gauges, dispatch
//!   audit); `--check` applies the CI completeness gate first.
//!
//! Shared flags come from [`ski_tnn::config::RunConfig`]
//! (`--config-file run.json` plus per-flag overrides).  Examples:
//!
//! ```text
//! ski-tnn list
//! ski-tnn train --config lm_fd_3l --steps 300 --out-dir runs/fd
//! ski-tnn eval  --config lm_fd_3l --resume runs/fd/lm_fd_3l_step300.ckpt
//! ski-tnn serve --config lra_text_fd --requests 200 --clients 4
//! ski-tnn serve --backend auto --n 4096 --requests 500   # artifact-free substrate serving
//! ski-tnn generate --prompt "ski to go " --tokens 120 --temperature 0.8
//! ski-tnn generate --sessions 8 --requests 64 --tokens 96 --slots 8
//! ```
//!
//! `--backend auto|dense|fft|ski|freq` selects the Toeplitz operator
//! backend (`toeplitz::ToeplitzOp`): `serve` runs it behind the
//! dynamic batcher with no artifacts needed, `generate` forces the
//! full-context oracle's path; `auto` defers to the cost-model
//! dispatcher (`toeplitz::Dispatch`).
//!
//! `--threads N` sizes the shard runtime (`runtime::pool`): batched
//! applies and scheduler ticks run across N threads, bitwise identical
//! to `--threads 1`.  Default 0 = auto (`SKI_TNN_THREADS`, else the
//! machine's parallelism).
//!
//! `--telemetry` (or `SKI_TNN_TELEMETRY=1`) enables the lock-free
//! metrics registry ([`ski_tnn::telemetry`]): request-path span
//! histograms, FFT plan-cache counters, the dispatch audit ring.
//! `--stats-json STATS.json` implies it and writes periodic
//! atomic-rename snapshots readable by `ski-tnn stats`.

use anyhow::{bail, Result};

use ski_tnn::config::RunConfig;
use ski_tnn::coordinator::Trainer;
use ski_tnn::runtime::{Engine, HostTensor, ModelState};
use ski_tnn::server::{serve_model, Batcher, RowBatch, ServerConfig};
use ski_tnn::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(true);
    match args.subcommand.as_deref() {
        Some("list") => cmd_list(&args),
        Some("corpus") => cmd_corpus(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("plan") => cmd_plan(&args),
        Some("bench-check") => cmd_bench_check(&args),
        Some("stats") => cmd_stats(&args),
        Some(other) => {
            bail!(
                "unknown subcommand {other:?} \
                 (try list|train|eval|serve|generate|plan|bench-check|stats)"
            )
        }
        None => {
            eprintln!(
                "usage: ski-tnn <list|train|eval|serve|generate|plan|bench-check|stats> [flags]"
            );
            eprintln!("see `cargo doc` or README.md for the full flag set");
            Ok(())
        }
    }
}

/// Honour `--telemetry` / `--stats-json` (and `SKI_TNN_TELEMETRY`,
/// read lazily by the registry): flip the global enable and, when a
/// snapshot path is configured, start the background stats writer.
/// The returned guard must stay alive for the whole command — its Drop
/// writes the final snapshot.
fn telemetry_setup(rc: &RunConfig) -> Option<ski_tnn::telemetry::StatsWriter> {
    if rc.telemetry || rc.stats_json.is_some() {
        ski_tnn::telemetry::set_enabled(true);
    }
    rc.stats_json.as_ref().map(|p| {
        ski_tnn::telemetry::StatsWriter::start(p.clone(), std::time::Duration::from_secs(2))
    })
}

/// Dump the synthetic corpus to a file (debugging / cross-language
/// experiments: the python side can train on the exact same bytes).
fn cmd_corpus(args: &Args) -> Result<()> {
    let bytes = args.usize_or("bytes", 1 << 20);
    let seed = args.u64_or("seed", 0);
    let out = args.str_or("out", "corpus.bin");
    let c = ski_tnn::data::Corpus::generate(seed, bytes);
    std::fs::write(&out, &c.bytes)?;
    println!("wrote {bytes} bytes (seed {seed}) to {out}");
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let engine = Engine::new(&rc.artifacts)?;
    println!("{:<22} {:>9} {:>7} {:>5} {:>6} {:>7}  entries", "config", "task", "variant", "n", "d", "params");
    for (name, cfg) in &engine.manifest().configs {
        println!(
            "{:<22} {:>9} {:>7} {:>5} {:>6} {:>6}k  {}",
            name,
            cfg.task.as_str(),
            cfg.variant.as_str(),
            cfg.n,
            cfg.d,
            cfg.param_count / 1000,
            cfg.entries.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let engine = Engine::new(&rc.artifacts)?;
    println!("platform: {}", engine.platform());
    let mut trainer = Trainer::new(&engine, rc)?;
    let stats = trainer.train()?;
    println!(
        "final: loss {:.4} ppl {:.2} acc {:.3}",
        stats.loss, stats.ppl, stats.acc
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let engine = Engine::new(&rc.artifacts)?;
    let mut trainer = Trainer::new(&engine, rc)?;
    let stats = trainer.eval()?;
    println!(
        "val: loss {:.4} ppl {:.2} acc {:.3}",
        stats.loss, stats.ppl, stats.acc
    );
    Ok(())
}

/// Drive a batcher with synthetic client load (random byte rows of
/// random length below `n`) and print the shared serving report —
/// the one load/report path both serve modes go through.
fn run_synthetic_load<F>(
    batcher: Batcher,
    exec: F,
    clients: usize,
    per_client: usize,
    n: usize,
    seed: u64,
    max_batch: usize,
) -> Result<()>
where
    F: FnMut(&HostTensor) -> Result<RowBatch>,
{
    let handle = batcher.handle();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut rng = ski_tnn::util::rng::Rng::new(seed + c as u64);
                for _ in 0..per_client {
                    let len = 8 + rng.below(n - 8);
                    let ids: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                    let _ = h.infer(ids).expect("infer");
                }
            })
        })
        .collect();
    drop(handle);
    let t0 = std::time::Instant::now();
    let stats = batcher.run(exec)?;
    let total = t0.elapsed().as_secs_f64();
    for w in workers {
        w.join().unwrap();
    }
    println!(
        "served {} requests in {} batches ({:.1}% fill), {:.1} req/s",
        stats.requests,
        stats.batches,
        100.0 * stats.mean_batch_fill(max_batch),
        stats.requests as f64 / total
    );
    // Queue latency straight from the batcher — no client-side timing.
    let (p50, p95, p99) = stats.queue_percentiles();
    println!(
        "queue wait p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms  (exec {:.1}% of wall)",
        1e3 * p50,
        1e3 * p95,
        1e3 * p99,
        100.0 * stats.exec_seconds / total
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(backend) = args.get("backend") {
        // Explicit `--backend auto|dense|fft|ski|freq`: serve the
        // pure-Rust Toeplitz substrate through the same batcher — no
        // artifacts or PJRT needed, the backend dispatcher under real
        // load.  (CLI flag only, so a run-config JSON meant for the
        // oracle never silently abandons the XLA model path.)
        let backend = backend.to_string();
        return cmd_serve_substrate(args, &backend);
    }
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let requests = args.usize_or("requests", 200);
    let clients = args.usize_or("clients", 4);
    let engine = Engine::new(&rc.artifacts)?;
    let cfg = engine.config(&rc.config)?.clone();
    let state = match &rc.resume {
        Some(p) => ModelState::load(&engine, p)?,
        None => ModelState::init(&engine, &rc.config, rc.seed as u32)?,
    };
    // warm the logits compile before load arrives
    engine.load(&rc.config, "logits")?;

    let server_cfg = ServerConfig {
        max_batch: cfg.batch,
        n: cfg.n,
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        queue_depth: args.usize_or("queue-depth", 64),
        // The AOT artifact's batch shape is baked in — no buckets.
        buckets: Vec::new(),
    };
    println!(
        "serving {} (batch {}, n {}) with {clients} clients × {} requests",
        rc.config,
        cfg.batch,
        cfg.n,
        requests / clients
    );
    let batcher = Batcher::new(server_cfg);
    run_synthetic_load(
        batcher,
        serve_model(&engine, &state),
        clients,
        requests / clients,
        cfg.n,
        rc.seed,
        cfg.batch,
    )
}

/// Artifact-free serving: client rows are interpreted as f32 signals
/// and answered by [`ToeplitzOp`](ski_tnn::toeplitz::ToeplitzOp)
/// backends — requested explicitly or chosen by the cost-model
/// dispatcher — with the same queueing/latency report as model
/// serving.  Any `--n` works (the spectral plans pick their own smooth
/// transform lengths), and `--buckets 64,256` (or run-config JSON)
/// turns on length-bucketed batching: mixed-length request streams
/// batch within buckets, each with a right-sized per-width operator.
fn cmd_serve_substrate(args: &Args, backend: &str) -> Result<()> {
    use ski_tnn::runtime::{resolve_threads, ThreadPool};
    use ski_tnn::server::{audit_exec, serve_toeplitz_factory, serve_toeplitz_on};
    use ski_tnn::toeplitz::{
        build_op, gaussian_kernel, BackendKind, Dispatch, DispatchQuery, ToeplitzKernel,
        ToeplitzOp,
    };

    let n = args.usize_or("n", 256);
    anyhow::ensure!(n >= 16, "--n must be at least 16, got {n}");
    let requests = args.usize_or("requests", 200);
    let clients = args.usize_or("clients", 4).max(1);
    let r = args.usize_or("rank", (n / 16).max(2));
    let w = args.usize_or("band", 9);
    // Thread count and buckets via RunConfig so `"threads"`/`"buckets"`
    // in a --config-file are honoured here exactly as in `generate`
    // (CLI flags still win).
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let threads = resolve_threads(rc.threads);
    let requested = BackendKind::parse(backend)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend:?} (auto|dense|fft|ski|freq)"))?;
    let server_cfg = ServerConfig {
        max_batch: args.usize_or("max-batch", 8),
        n,
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        queue_depth: args.usize_or("queue-depth", 64),
        buckets: rc.buckets.clone(),
    };
    let dispatch = Dispatch::default();
    let max_batch = server_cfg.max_batch;
    // Per-width backend choice: `plan` decides backend AND whether
    // sharding pays at that shape; for a forced backend the same model
    // still gates the sharding (tiny shapes run serially instead of
    // paying shard overhead).
    // SKI rank scales with the bucket width (same r/n ratio at every
    // width) — one definition shared by the dispatch query and the
    // operator build so the two can never diverge.
    let rank_for = move |width: usize| (width * r / n.max(1)).max(2);
    let plan_for = move |width: usize| -> (BackendKind, bool) {
        let query = DispatchQuery {
            n: width,
            r: rank_for(width),
            w,
            causal: false,
            batch: max_batch,
            threads,
        };
        match requested {
            BackendKind::Auto => dispatch.plan(&query),
            k => {
                let q = DispatchQuery { causal: k == BackendKind::Freq, ..query };
                (k, dispatch.should_shard(k, &q))
            }
        }
    };
    let make_op = move |width: usize| -> std::sync::Arc<dyn ToeplitzOp> {
        let (kind, _) = plan_for(width);
        let kernel =
            ToeplitzKernel::from_fn(width, |lag| gaussian_kernel(lag as f64, width as f64 / 8.0));
        let kernel = if kind == BackendKind::Freq { kernel.causal() } else { kernel };
        std::sync::Arc::from(build_op(&kernel, kind, rank_for(width), w))
    };
    let widths = server_cfg.bucket_widths();
    let (kind, parallelize) = plan_for(n);
    let pool_threads = if parallelize { threads } else { 1 };
    let pool = std::sync::Arc::new(ThreadPool::new(pool_threads));
    let batcher = Batcher::new(server_cfg);
    let seed = args.u64_or("seed", 0);
    let per_client = (requests / clients).max(1);
    if widths.len() > 1 {
        println!(
            "serving substrate backend {} (requested {requested:?}), n={n}, length buckets \
             {widths:?}, batch {max_batch} sharded over {pool_threads} threads",
            kind.name()
        );
        run_synthetic_load(
            batcher,
            audit_exec(
                serve_toeplitz_factory(make_op, pool),
                dispatch,
                plan_for,
                rank_for,
                w,
                threads,
            ),
            clients,
            per_client,
            n,
            seed,
            max_batch,
        )
    } else {
        let op = make_op(n);
        println!(
            "serving substrate backend {} (requested {requested:?} → dispatched), n={n}, \
             ~{:.0} flops/apply, batch {max_batch} sharded over {pool_threads} threads",
            op.name(),
            op.flops_estimate()
        );
        run_synthetic_load(
            batcher,
            audit_exec(serve_toeplitz_on(op, pool), dispatch, plan_for, rank_for, w, threads),
            clients,
            per_client,
            n,
            seed,
            max_batch,
        )
    }
}

/// Explain the execution plan for a shape without serving traffic:
/// build it through the same [`PlanCache`](ski_tnn::plan::PlanCache) /
/// [`plan_shape`](ski_tnn::plan::plan_shape) path the serve executors
/// use, warm it, and print the chosen backend, sharding decision,
/// transform length, estimated resident bytes, and the plan-cache
/// counters the lookup touched.
///
/// ```text
/// ski-tnn plan --explain --n 1024 --rank 64 --band 9 --batch 8 \
///   --threads 4 --backend auto [--causal]
/// ```
fn cmd_plan(args: &Args) -> Result<()> {
    use ski_tnn::plan::{plan_shape, PlanCache, ShapeKey};
    use ski_tnn::runtime::resolve_threads;
    use ski_tnn::toeplitz::{build_op, gaussian_kernel, BackendKind, Dispatch, ToeplitzKernel};

    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let n = args.usize_or("n", 256);
    anyhow::ensure!(n >= 16, "--n must be at least 16, got {n}");
    let r = args.usize_or("rank", (n / 16).max(2));
    let w = args.usize_or("band", 9);
    let batch = args.usize_or("batch", 8);
    let threads = resolve_threads(rc.threads);
    let causal = args.flag("causal");
    let backend_flag = rc.backend.clone().unwrap_or_else(|| "auto".to_string());
    let requested = BackendKind::parse(&backend_flag).ok_or_else(|| {
        anyhow::anyhow!("unknown backend {backend_flag:?} (auto|dense|fft|ski|freq)")
    })?;
    let key = ShapeKey { n, r, w, causal, threads, batch_hint: batch, kernel_id: 0 };
    let dispatch = Dispatch::default();
    let cache = PlanCache::new(1);
    let plan = cache.get_or_build(key, || {
        plan_shape(key, &dispatch, requested, |kind| {
            let kernel =
                ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 8.0));
            let kernel = if kind == BackendKind::Freq { kernel.causal() } else { kernel };
            std::sync::Arc::from(build_op(&kernel, kind, r, w))
        })
    });
    plan.warm();
    let report = plan.report();
    println!(
        "execution plan for n={n} r={r} w={w} causal={causal} batch={batch} threads={threads}"
    );
    println!("  backend        : {} (requested {})", report.backend, requested.name());
    let sharding = if report.parallel {
        format!("parallel across {threads} threads")
    } else {
        "serial (shard overhead beats the win at this shape)".to_string()
    };
    println!("  sharding       : {sharding}");
    if let Some(ns) = report.predicted_ns {
        println!("  predicted cost : {ns:.0} ns/batch");
    }
    match (report.transform_len, report.transform_strategy) {
        (Some(len), Some(strategy)) => println!("  transform      : {len} points ({strategy})"),
        (Some(len), None) => println!("  transform      : {len} points"),
        _ => println!("  transform      : none (time-domain backend)"),
    }
    println!("  flops estimate : {:.0} per apply", report.flops_estimate);
    println!(
        "  resident bytes : {} (this plan) / {} (cache total, warmed)",
        report.resident_bytes,
        cache.refresh_bytes()
    );
    let s = cache.stats();
    println!(
        "  plan cache     : {} hit / {} miss / {} evict, {}/{} resident",
        s.hits, s.misses, s.evicts, s.len, s.cap
    );
    let (fft_entries, fft_bytes) = ski_tnn::dsp::plan_cache_stats();
    println!("  fft plan cache : {fft_entries} transform plans, {fft_bytes} table bytes");
    Ok(())
}

/// Offline perf gate: compare emitted `BENCH_*.json` medians against
/// `bench/baseline.json` (calibration-scaled), failing the process on
/// regressions beyond the baseline threshold.  `--update` rewrites the
/// baseline from the current artifacts; `--arm-from <candidate.json>`
/// promotes a comparison run's measured candidate into the baseline
/// (dropping `"bootstrap": true`) without re-running benches.
fn cmd_bench_check(args: &Args) -> Result<()> {
    let baseline = args.str_or("baseline", "bench/baseline.json");
    if let Some(candidate) = args.get("arm-from") {
        // Promote a measured candidate (written by a prior comparison
        // run) into the committed baseline, dropping its bootstrap
        // marker — no benches are re-run.
        return ski_tnn::util::benchcheck::arm_from(candidate, &baseline);
    }
    let dir = args.str_or("dir", ".");
    let update = args.flag("update");
    let allow_missing = args.flag("allow-missing");
    let threshold = args.get("threshold").and_then(|v| v.parse::<f64>().ok());
    if let Some(snap) = args.get("stats-snapshot") {
        ski_tnn::util::benchcheck::check_stats_snapshot(snap)?;
        println!("bench-check: telemetry snapshot {snap} OK");
    }
    let ok = ski_tnn::util::benchcheck::run(&baseline, &dir, update, threshold, allow_missing)?;
    anyhow::ensure!(ok, "bench-check: median regression beyond threshold (see report above)");
    Ok(())
}

/// Inspect a telemetry stats snapshot written by `--stats-json`:
/// latency-series percentiles, counters/gauges, FFT plan-cache hit
/// rate and the dispatch-audit calibration table.  `--check` applies
/// the same completeness gate CI uses before printing.
fn cmd_stats(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("file"))
        .unwrap_or("STATS.json");
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading stats snapshot {path}: {e}"))?;
    let doc = ski_tnn::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    if args.flag("check") {
        ski_tnn::telemetry::check_snapshot(&doc)
            .map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?;
        println!("stats: snapshot {path} passes the completeness gate");
    }
    ski_tnn::telemetry::print_snapshot(&doc);
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    use ski_tnn::decode::model::{detokenize, tokenize};
    use ski_tnn::decode::{DecodeModel, DecodeModelConfig, DecodePolicy};
    use ski_tnn::server::{GenConfig, GenParams, GenScheduler};
    use ski_tnn::toeplitz::{BackendKind, Dispatch, DispatchQuery};

    let seed = args.u64_or("seed", 0);
    // Backend for the full-context oracle and thread count for the
    // scheduler: run-config JSON or CLI (`RunConfig::apply_args` gives
    // the CLI flag precedence).
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let backend_flag = rc.backend.unwrap_or_else(|| "auto".to_string());
    let oracle_backend = BackendKind::parse(&backend_flag)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend_flag:?} (auto|dense|fft|ski|freq)"))?;
    let cfg = DecodeModelConfig {
        d: args.usize_or("d", 32),
        blocks: args.usize_or("blocks", 2),
        n: args.usize_or("n", 1024),
        policy: DecodePolicy {
            rank: args.usize_or("rank", 16),
            max_rel_residual: args.f64_or("max-rel-residual", 0.05),
        },
        oracle_backend,
        threads: rc.threads,
        seed,
        ..DecodeModelConfig::default()
    };
    let dispatched = Dispatch::default().select(&DispatchQuery {
        n: cfg.n,
        r: 0,
        w: 0,
        causal: true,
        batch: 1,
        threads: 1,
    });
    println!(
        "full-context oracle backend: {} (dispatcher would pick {} at n={})",
        oracle_backend.name(),
        dispatched.name(),
        cfg.n
    );
    let t0 = std::time::Instant::now();
    let model = DecodeModel::new(cfg);
    let (ssm, win) = model.decoder_mix();
    println!(
        "decode model d={} blocks={} n={} rank={}: {} SSM / {} window decoders, \
         ~{} token-mix madds/token (planned in {:.2}s)",
        cfg.d,
        cfg.blocks,
        cfg.n,
        cfg.policy.rank,
        ssm,
        win,
        model.decode_cost_per_token(),
        t0.elapsed().as_secs_f64()
    );

    let params = GenParams {
        max_new: args.usize_or("tokens", 64),
        temperature: args.f64_or("temperature", 0.0) as f32,
        top_k: args.usize_or("top-k", 0),
        seed,
    };
    let sched = GenScheduler::new(GenConfig {
        max_sessions: args.usize_or("slots", 8),
        queue_depth: args.usize_or("queue-depth", 64),
        max_new_cap: args.usize_or("max-new-cap", 512),
        threads: rc.threads,
    });
    let handle = sched.handle();
    let sessions = args.usize_or("sessions", 1);

    if sessions <= 1 {
        // One-shot generation: print the continuation.
        let prompt_text = args.str_or("prompt", "the toeplitz operator ");
        let prompt = tokenize(&prompt_text);
        let t = std::thread::spawn(move || handle.generate(prompt, params));
        let stats = sched.run(&model)?;
        let resp = t.join().expect("client thread")?;
        println!("prompt : {prompt_text:?}");
        println!("output : {:?}", detokenize(&resp.tokens));
        println!(
            "{} tokens, {:.2} ms prefill, {:.3} ms/token decode ({:.0} tok/s)",
            resp.tokens.len(),
            1e3 * stats.prefill_seconds,
            1e3 * stats.decode_seconds / resp.tokens.len().max(1) as f64,
            stats.tokens_per_sec()
        );
        return Ok(());
    }

    // Load test: many client threads against the continuous-batching
    // scheduler, stats reported from the server side.
    let requests = args.usize_or("requests", sessions * 4);
    let per_client = (requests / sessions).max(1);
    let workers: Vec<_> = (0..sessions)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut rng = ski_tnn::util::rng::Rng::new(seed ^ (c as u64 + 1));
                for _ in 0..per_client {
                    let len = 4 + rng.below(28);
                    let prompt: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                    let p = GenParams { seed: rng.next_u64(), ..params };
                    let _ = h.generate(prompt, p).expect("generate");
                }
            })
        })
        .collect();
    drop(handle);
    let t0 = std::time::Instant::now();
    let stats = sched.run(&model)?;
    let wall = t0.elapsed().as_secs_f64();
    for w in workers {
        w.join().unwrap();
    }
    let (p50, p95, p99) = stats.queue_percentiles();
    println!(
        "{} sessions, {} tokens in {} ticks (mean concurrency {:.2})",
        stats.sessions,
        stats.tokens,
        stats.ticks,
        stats.mean_concurrency()
    );
    println!(
        "throughput {:.0} tok/s aggregate ({:.0} tok/s wall), queue wait p50 {:.1} ms  \
         p95 {:.1} ms  p99 {:.1} ms",
        stats.tokens_per_sec(),
        stats.tokens as f64 / wall.max(1e-9),
        1e3 * p50,
        1e3 * p95,
        1e3 * p99
    );
    Ok(())
}
