//! `ski-tnn` — the launcher CLI.
//!
//! Subcommands:
//!
//! * `list`  — show every artifact config in the manifest.
//! * `train` — run the training orchestrator on one config.
//! * `eval`  — evaluate a checkpoint (or fresh init) on the val split.
//! * `serve` — start the dynamic batcher on a config and drive it with
//!   synthetic client load, reporting server-side latency percentiles.
//! * `generate` — streaming autoregressive generation through the
//!   decode subsystem (causal-Toeplitz→SSM, O(1) per token): one-shot
//!   text generation or a continuous-batching load test.
//! * `plan` — explain the execution plan for a shape without serving
//!   traffic: chosen backend, sharding decision, transform length,
//!   estimated resident bytes, plan-cache counters
//!   (`ski-tnn plan --explain --n 1024 --threads 4`).
//! * `bench-check` — offline perf gate: compare the `BENCH_*.json`
//!   artifacts emitted by the benches against `bench/baseline.json`
//!   and fail on median regressions (CI's `bench-smoke` job; see
//!   README "Threading & benchmarking in CI").  `--stats-snapshot
//!   STATS.json` additionally gates a telemetry snapshot for
//!   completeness.
//! * `stats` — pretty-print a telemetry stats snapshot written by
//!   `--stats-json` (latency percentiles, counters/gauges, dispatch
//!   audit); `--check` applies the CI completeness gate first.
//! * `soak` — deterministic chaos soak: overload the substrate batcher
//!   with a burst far beyond capacity while seeded fault injection
//!   (`SKI_TNN_CHAOS` / `--chaos-seed`) fails executors and stalls
//!   ticks, then hard-verify the exactly-one-response contract and the
//!   admission-ledger balance, writing a machine-readable verdict
//!   (CI's `robustness-soak` job gates on it).
//!
//! Shared flags come from [`ski_tnn::config::RunConfig`]
//! (`--config-file run.json` plus per-flag overrides).  Examples:
//!
//! ```text
//! ski-tnn list
//! ski-tnn train --config lm_fd_3l --steps 300 --out-dir runs/fd
//! ski-tnn eval  --config lm_fd_3l --resume runs/fd/lm_fd_3l_step300.ckpt
//! ski-tnn serve --config lra_text_fd --requests 200 --clients 4
//! ski-tnn serve --backend auto --n 4096 --requests 500   # artifact-free substrate serving
//! ski-tnn generate --prompt "ski to go " --tokens 120 --temperature 0.8
//! ski-tnn generate --sessions 8 --requests 64 --tokens 96 --slots 8
//! ski-tnn soak --requests 400 --clients 8 --queue-depth 32 --chaos-seed 1337
//! ```
//!
//! Overload control (`serve`, `generate`, `soak`): `--admission
//! block|shed-newest|shed-expired-first` picks the admission policy of
//! the bounded request queue and `--deadline-ms N` answers requests
//! still queued past the budget with a typed `DeadlineExceeded` error
//! instead of executing them late (see README "Overload &
//! robustness").
//!
//! `--backend auto|dense|fft|ski|freq` selects the Toeplitz operator
//! backend (`toeplitz::ToeplitzOp`): `serve` runs it behind the
//! dynamic batcher with no artifacts needed, `generate` forces the
//! full-context oracle's path; `auto` defers to the cost-model
//! dispatcher (`toeplitz::Dispatch`).
//!
//! `--threads N` sizes the shard runtime (`runtime::pool`): batched
//! applies and scheduler ticks run across N threads, bitwise identical
//! to `--threads 1`.  Default 0 = auto (`SKI_TNN_THREADS`, else the
//! machine's parallelism).
//!
//! `--telemetry` (or `SKI_TNN_TELEMETRY=1`) enables the lock-free
//! metrics registry ([`ski_tnn::telemetry`]): request-path span
//! histograms, FFT plan-cache counters, the dispatch audit ring.
//! `--stats-json STATS.json` implies it and writes periodic
//! atomic-rename snapshots readable by `ski-tnn stats`.

use anyhow::{bail, Result};

use ski_tnn::config::RunConfig;
use ski_tnn::coordinator::Trainer;
use ski_tnn::runtime::{Engine, HostTensor, ModelState};
use ski_tnn::server::{serve_model, Batcher, RowBatch, ServerConfig};
use ski_tnn::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(true);
    match args.subcommand.as_deref() {
        Some("list") => cmd_list(&args),
        Some("corpus") => cmd_corpus(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("plan") => cmd_plan(&args),
        Some("bench-check") => cmd_bench_check(&args),
        Some("stats") => cmd_stats(&args),
        Some("soak") => cmd_soak(&args),
        Some(other) => {
            bail!(
                "unknown subcommand {other:?} \
                 (try list|train|eval|serve|generate|plan|bench-check|stats|soak)"
            )
        }
        None => {
            eprintln!(
                "usage: ski-tnn <list|train|eval|serve|generate|plan|bench-check|stats|soak> \
                 [flags]"
            );
            eprintln!("see `cargo doc` or README.md for the full flag set");
            Ok(())
        }
    }
}

/// Honour `--telemetry` / `--stats-json` (and `SKI_TNN_TELEMETRY`,
/// read lazily by the registry): flip the global enable and, when a
/// snapshot path is configured, start the background stats writer.
/// The returned guard must stay alive for the whole command — its Drop
/// writes the final snapshot.
fn telemetry_setup(rc: &RunConfig) -> Option<ski_tnn::telemetry::StatsWriter> {
    if rc.telemetry || rc.stats_json.is_some() {
        ski_tnn::telemetry::set_enabled(true);
    }
    rc.stats_json.as_ref().map(|p| {
        ski_tnn::telemetry::StatsWriter::start(p.clone(), std::time::Duration::from_secs(2))
    })
}

/// Dump the synthetic corpus to a file (debugging / cross-language
/// experiments: the python side can train on the exact same bytes).
fn cmd_corpus(args: &Args) -> Result<()> {
    let bytes = args.usize_or("bytes", 1 << 20);
    let seed = args.u64_or("seed", 0);
    let out = args.str_or("out", "corpus.bin");
    let c = ski_tnn::data::Corpus::generate(seed, bytes);
    std::fs::write(&out, &c.bytes)?;
    println!("wrote {bytes} bytes (seed {seed}) to {out}");
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let engine = Engine::new(&rc.artifacts)?;
    println!("{:<22} {:>9} {:>7} {:>5} {:>6} {:>7}  entries", "config", "task", "variant", "n", "d", "params");
    for (name, cfg) in &engine.manifest().configs {
        println!(
            "{:<22} {:>9} {:>7} {:>5} {:>6} {:>6}k  {}",
            name,
            cfg.task.as_str(),
            cfg.variant.as_str(),
            cfg.n,
            cfg.d,
            cfg.param_count / 1000,
            cfg.entries.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let engine = Engine::new(&rc.artifacts)?;
    println!("platform: {}", engine.platform());
    let mut trainer = Trainer::new(&engine, rc)?;
    let stats = trainer.train()?;
    println!(
        "final: loss {:.4} ppl {:.2} acc {:.3}",
        stats.loss, stats.ppl, stats.acc
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let engine = Engine::new(&rc.artifacts)?;
    let mut trainer = Trainer::new(&engine, rc)?;
    let stats = trainer.eval()?;
    println!(
        "val: loss {:.4} ppl {:.2} acc {:.3}",
        stats.loss, stats.ppl, stats.acc
    );
    Ok(())
}

/// Drive a batcher with synthetic client load (random byte rows of
/// random length below `n`) and print the shared serving report —
/// the one load/report path both serve modes go through.
fn run_synthetic_load<F>(
    batcher: Batcher,
    exec: F,
    clients: usize,
    per_client: usize,
    n: usize,
    seed: u64,
    max_batch: usize,
) -> Result<()>
where
    F: FnMut(&HostTensor) -> Result<RowBatch>,
{
    let handle = batcher.handle();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut rng = ski_tnn::util::rng::Rng::new(seed + c as u64);
                for _ in 0..per_client {
                    let len = 8 + rng.below(n - 8);
                    let ids: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                    // Typed overload/deadline answers are expected
                    // under burst load; the admission line below
                    // accounts for every one of them.
                    let _ = h.infer(ids);
                }
            })
        })
        .collect();
    drop(handle);
    let t0 = std::time::Instant::now();
    let stats = batcher.run(exec)?;
    let total = t0.elapsed().as_secs_f64();
    for w in workers {
        w.join().unwrap();
    }
    println!(
        "served {} requests in {} batches ({:.1}% fill), {:.1} req/s",
        stats.requests,
        stats.batches,
        100.0 * stats.mean_batch_fill(max_batch),
        stats.requests as f64 / total
    );
    // Queue latency straight from the batcher — no client-side timing.
    let (p50, p95, p99) = stats.queue_percentiles();
    println!(
        "queue wait p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms  (exec {:.1}% of wall)",
        1e3 * p50,
        1e3 * p95,
        1e3 * p99,
        100.0 * stats.exec_seconds / total
    );
    let adm = stats.admission;
    if adm.shed + adm.expired + adm.retries > 0 {
        println!(
            "admission: {} submitted, {} shed, {} expired, {} retries (peak queue depth {})",
            adm.submitted, adm.shed, adm.expired, adm.retries, adm.peak_depth
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(backend) = args.get("backend") {
        // Explicit `--backend auto|dense|fft|ski|freq`: serve the
        // pure-Rust Toeplitz substrate through the same batcher — no
        // artifacts or PJRT needed, the backend dispatcher under real
        // load.  (CLI flag only, so a run-config JSON meant for the
        // oracle never silently abandons the XLA model path.)
        let backend = backend.to_string();
        return cmd_serve_substrate(args, &backend);
    }
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let requests = args.usize_or("requests", 200);
    let clients = args.usize_or("clients", 4);
    let engine = Engine::new(&rc.artifacts)?;
    let cfg = engine.config(&rc.config)?.clone();
    let state = match &rc.resume {
        Some(p) => ModelState::load(&engine, p)?,
        None => ModelState::init(&engine, &rc.config, rc.seed as u32)?,
    };
    // warm the logits compile before load arrives
    engine.load(&rc.config, "logits")?;

    let server_cfg = ServerConfig {
        max_batch: cfg.batch,
        n: cfg.n,
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        queue_depth: args.usize_or("queue-depth", 64),
        // The AOT artifact's batch shape is baked in — no buckets.
        buckets: Vec::new(),
        policy: rc.admission_policy()?,
        deadline: rc.deadline(),
    };
    println!(
        "serving {} (batch {}, n {}) with {clients} clients × {} requests",
        rc.config,
        cfg.batch,
        cfg.n,
        requests / clients
    );
    let batcher = Batcher::new(server_cfg);
    run_synthetic_load(
        batcher,
        serve_model(&engine, &state),
        clients,
        requests / clients,
        cfg.n,
        rc.seed,
        cfg.batch,
    )
}

/// Artifact-free serving: client rows are interpreted as f32 signals
/// and answered by [`ToeplitzOp`](ski_tnn::toeplitz::ToeplitzOp)
/// backends — requested explicitly or chosen by the cost-model
/// dispatcher — with the same queueing/latency report as model
/// serving.  Any `--n` works (the spectral plans pick their own smooth
/// transform lengths), and `--buckets 64,256` (or run-config JSON)
/// turns on length-bucketed batching: mixed-length request streams
/// batch within buckets, each with a right-sized per-width operator.
fn cmd_serve_substrate(args: &Args, backend: &str) -> Result<()> {
    use ski_tnn::runtime::resolve_threads;
    use ski_tnn::toeplitz::BackendKind;

    let n = args.usize_or("n", 256);
    anyhow::ensure!(n >= 16, "--n must be at least 16, got {n}");
    let requests = args.usize_or("requests", 200);
    let clients = args.usize_or("clients", 4).max(1);
    let r = args.usize_or("rank", (n / 16).max(2));
    let w = args.usize_or("band", 9);
    // Thread count and buckets via RunConfig so `"threads"`/`"buckets"`
    // in a --config-file are honoured here exactly as in `generate`
    // (CLI flags still win).
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let threads = resolve_threads(rc.threads);
    let requested = BackendKind::parse(backend)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend:?} (auto|dense|fft|ski|freq)"))?;
    let server_cfg = ServerConfig {
        max_batch: args.usize_or("max-batch", 8),
        n,
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        queue_depth: args.usize_or("queue-depth", 64),
        buckets: rc.buckets.clone(),
        policy: rc.admission_policy()?,
        deadline: rc.deadline(),
    };
    let max_batch = server_cfg.max_batch;
    let widths = server_cfg.bucket_widths();
    let batcher = Batcher::new(server_cfg);
    let (kind, pool_threads, exec) = substrate_exec(&batcher, requested, r, w, threads, false);
    let seed = args.u64_or("seed", 0);
    let per_client = (requests / clients).max(1);
    if widths.len() > 1 {
        println!(
            "serving substrate backend {} (requested {requested:?}), n={n}, length buckets \
             {widths:?}, batch {max_batch} sharded over {pool_threads} threads",
            kind.name()
        );
    } else {
        println!(
            "serving substrate backend {} (requested {requested:?} → dispatched), n={n}, \
             batch {max_batch} sharded over {pool_threads} threads",
            kind.name()
        );
    }
    run_synthetic_load(batcher, exec, clients, per_client, n, seed, max_batch)
}

/// The substrate executor both `serve --backend …` and `soak` run:
/// pressure-adaptive per-tick backend replanning through the batcher's
/// [`PressureGauge`](ski_tnn::server::PressureGauge), per-`(width,
/// rung)` plan caching, optional chaos fault injection, and the
/// telemetry dispatch audit.  Returns the unpressured plan for `n`
/// (backend kind + pool threads) alongside the executor, for the
/// startup banner.
fn substrate_exec(
    batcher: &Batcher,
    requested: ski_tnn::toeplitz::BackendKind,
    r: usize,
    w: usize,
    threads: usize,
    chaos: bool,
) -> (ski_tnn::toeplitz::BackendKind, usize, impl FnMut(&HostTensor) -> Result<RowBatch>) {
    use ski_tnn::runtime::ThreadPool;
    use ski_tnn::server::{audit_exec, serve_toeplitz_pressured, PressureGauge};
    use ski_tnn::toeplitz::{
        build_op, gaussian_kernel, BackendKind, Dispatch, DispatchQuery, ToeplitzKernel,
        ToeplitzOp,
    };

    let n = batcher.cfg.n;
    let max_batch = batcher.cfg.max_batch;
    let dispatch = Dispatch::default();
    // SKI rank scales with the bucket width (same r/n ratio at every
    // width) — one definition shared by the dispatch query and the
    // operator build so the two can never diverge.
    let rank_for = move |width: usize| (width * r / n.max(1)).max(2);
    // Per-width backend choice at a given pressure reading: `plan`
    // decides backend AND whether sharding pays at that shape; past
    // `PRESSURE_DOWNSHIFT` the auto path degrades fft → SKI one cost
    // rung.  A forced backend never downshifts, but the cost model
    // still gates its sharding (tiny shapes run serially instead of
    // paying shard overhead).
    let plan_at = move |width: usize, pressure: f64| -> (BackendKind, bool) {
        let query = DispatchQuery {
            n: width,
            r: rank_for(width),
            w,
            causal: false,
            batch: max_batch,
            threads,
        };
        match requested {
            BackendKind::Auto => dispatch.plan_pressured(&query, pressure),
            k => {
                let q = DispatchQuery { causal: k == BackendKind::Freq, ..query };
                (k, dispatch.should_shard(k, &q))
            }
        }
    };
    let make = move |width: usize, kind: BackendKind| -> std::sync::Arc<dyn ToeplitzOp> {
        let kernel =
            ToeplitzKernel::from_fn(width, |lag| gaussian_kernel(lag as f64, width as f64 / 8.0));
        let kernel = if kind == BackendKind::Freq { kernel.causal() } else { kernel };
        std::sync::Arc::from(build_op(&kernel, kind, rank_for(width), w))
    };
    let (kind, parallelize) = plan_at(n, 0.0);
    let pool_threads = if parallelize { threads } else { 1 };
    let pool = std::sync::Arc::new(ThreadPool::new(pool_threads));
    // Live replanning: the batcher publishes queue pressure on every
    // gather; each tick re-reads it through the gauge.
    let gauge = batcher.pressure();
    let pressured = move |g: PressureGauge| move |width: usize| plan_at(width, g.get());
    let base = serve_toeplitz_pressured(make, pressured(gauge.clone()), pool);
    let base: Box<dyn FnMut(&HostTensor) -> Result<RowBatch>> = if chaos {
        Box::new(ski_tnn::server::chaos::chaos_exec(base))
    } else {
        Box::new(base)
    };
    let exec = audit_exec(base, dispatch, pressured(gauge.clone()), rank_for, w, threads, gauge);
    (kind, pool_threads, exec)
}

/// Explain the execution plan for a shape without serving traffic:
/// build it through the same [`PlanCache`](ski_tnn::plan::PlanCache) /
/// [`plan_shape`](ski_tnn::plan::plan_shape) path the serve executors
/// use, warm it, and print the chosen backend, sharding decision,
/// transform length, estimated resident bytes, and the plan-cache
/// counters the lookup touched.
///
/// ```text
/// ski-tnn plan --explain --n 1024 --rank 64 --band 9 --batch 8 \
///   --threads 4 --backend auto [--causal]
/// ```
fn cmd_plan(args: &Args) -> Result<()> {
    use ski_tnn::plan::{plan_shape, PlanCache, ShapeKey};
    use ski_tnn::runtime::resolve_threads;
    use ski_tnn::toeplitz::{build_op, gaussian_kernel, BackendKind, Dispatch, ToeplitzKernel};

    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let n = args.usize_or("n", 256);
    anyhow::ensure!(n >= 16, "--n must be at least 16, got {n}");
    let r = args.usize_or("rank", (n / 16).max(2));
    let w = args.usize_or("band", 9);
    let batch = args.usize_or("batch", 8);
    let threads = resolve_threads(rc.threads);
    let causal = args.flag("causal");
    let backend_flag = rc.backend.clone().unwrap_or_else(|| "auto".to_string());
    let requested = BackendKind::parse(&backend_flag).ok_or_else(|| {
        anyhow::anyhow!("unknown backend {backend_flag:?} (auto|dense|fft|ski|freq)")
    })?;
    let key = ShapeKey { n, r, w, causal, threads, batch_hint: batch, kernel_id: 0 };
    let dispatch = Dispatch::default();
    let cache = PlanCache::new(1);
    let plan = cache.get_or_build(key, || {
        plan_shape(key, &dispatch, requested, |kind| {
            let kernel =
                ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 8.0));
            let kernel = if kind == BackendKind::Freq { kernel.causal() } else { kernel };
            std::sync::Arc::from(build_op(&kernel, kind, r, w))
        })
    });
    plan.warm();
    let report = plan.report();
    println!(
        "execution plan for n={n} r={r} w={w} causal={causal} batch={batch} threads={threads}"
    );
    println!("  backend        : {} (requested {})", report.backend, requested.name());
    let sharding = if report.parallel {
        format!("parallel across {threads} threads")
    } else {
        "serial (shard overhead beats the win at this shape)".to_string()
    };
    println!("  sharding       : {sharding}");
    if let Some(ns) = report.predicted_ns {
        println!("  predicted cost : {ns:.0} ns/batch");
    }
    match (report.transform_len, report.transform_strategy) {
        (Some(len), Some(strategy)) => println!("  transform      : {len} points ({strategy})"),
        (Some(len), None) => println!("  transform      : {len} points"),
        _ => println!("  transform      : none (time-domain backend)"),
    }
    println!("  flops estimate : {:.0} per apply", report.flops_estimate);
    println!(
        "  resident bytes : {} (this plan) / {} (cache total, warmed)",
        report.resident_bytes,
        cache.refresh_bytes()
    );
    let s = cache.stats();
    println!(
        "  plan cache     : {} hit / {} miss / {} evict, {}/{} resident",
        s.hits, s.misses, s.evicts, s.len, s.cap
    );
    let (fft_entries, fft_bytes) = ski_tnn::dsp::plan_cache_stats();
    println!("  fft plan cache : {fft_entries} transform plans, {fft_bytes} table bytes");
    Ok(())
}

/// Offline perf gate: compare emitted `BENCH_*.json` medians against
/// `bench/baseline.json` (calibration-scaled), failing the process on
/// regressions beyond the baseline threshold.  `--update` rewrites the
/// baseline from the current artifacts; `--arm-from <candidate.json>`
/// promotes a comparison run's measured candidate into the baseline
/// (dropping `"bootstrap": true`) without re-running benches.
fn cmd_bench_check(args: &Args) -> Result<()> {
    let baseline = args.str_or("baseline", "bench/baseline.json");
    if let Some(candidate) = args.get("arm-from") {
        // Promote a measured candidate (written by a prior comparison
        // run) into the committed baseline, dropping its bootstrap
        // marker — no benches are re-run.
        return ski_tnn::util::benchcheck::arm_from(candidate, &baseline);
    }
    let dir = args.str_or("dir", ".");
    let update = args.flag("update");
    let allow_missing = args.flag("allow-missing");
    let threshold = args.get("threshold").and_then(|v| v.parse::<f64>().ok());
    if let Some(snap) = args.get("stats-snapshot") {
        ski_tnn::util::benchcheck::check_stats_snapshot(snap)?;
        println!("bench-check: telemetry snapshot {snap} OK");
    }
    let ok = ski_tnn::util::benchcheck::run(&baseline, &dir, update, threshold, allow_missing)?;
    anyhow::ensure!(ok, "bench-check: median regression beyond threshold (see report above)");
    Ok(())
}

/// Inspect a telemetry stats snapshot written by `--stats-json`:
/// latency-series percentiles, counters/gauges, FFT plan-cache hit
/// rate and the dispatch-audit calibration table.  `--check` applies
/// the same completeness gate CI uses before printing.
fn cmd_stats(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("file"))
        .unwrap_or("STATS.json");
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading stats snapshot {path}: {e}"))?;
    let doc = ski_tnn::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    if args.flag("check") {
        ski_tnn::telemetry::check_snapshot(&doc)
            .map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?;
        println!("stats: snapshot {path} passes the completeness gate");
    }
    ski_tnn::telemetry::print_snapshot(&doc);
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    use ski_tnn::decode::model::{detokenize, tokenize};
    use ski_tnn::decode::{DecodeModel, DecodeModelConfig, DecodePolicy};
    use ski_tnn::server::{GenConfig, GenParams, GenScheduler};
    use ski_tnn::toeplitz::{BackendKind, Dispatch, DispatchQuery};

    let seed = args.u64_or("seed", 0);
    // Backend for the full-context oracle and thread count for the
    // scheduler: run-config JSON or CLI (`RunConfig::apply_args` gives
    // the CLI flag precedence).
    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let policy = rc.admission_policy()?;
    let deadline = rc.deadline();
    let backend_flag = rc.backend.unwrap_or_else(|| "auto".to_string());
    let oracle_backend = BackendKind::parse(&backend_flag)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend_flag:?} (auto|dense|fft|ski|freq)"))?;
    let cfg = DecodeModelConfig {
        d: args.usize_or("d", 32),
        blocks: args.usize_or("blocks", 2),
        n: args.usize_or("n", 1024),
        policy: DecodePolicy {
            rank: args.usize_or("rank", 16),
            max_rel_residual: args.f64_or("max-rel-residual", 0.05),
        },
        oracle_backend,
        threads: rc.threads,
        seed,
        ..DecodeModelConfig::default()
    };
    let dispatched = Dispatch::default().select(&DispatchQuery {
        n: cfg.n,
        r: 0,
        w: 0,
        causal: true,
        batch: 1,
        threads: 1,
    });
    println!(
        "full-context oracle backend: {} (dispatcher would pick {} at n={})",
        oracle_backend.name(),
        dispatched.name(),
        cfg.n
    );
    let t0 = std::time::Instant::now();
    let model = DecodeModel::new(cfg);
    let (ssm, win) = model.decoder_mix();
    println!(
        "decode model d={} blocks={} n={} rank={}: {} SSM / {} window decoders, \
         ~{} token-mix madds/token (planned in {:.2}s)",
        cfg.d,
        cfg.blocks,
        cfg.n,
        cfg.policy.rank,
        ssm,
        win,
        model.decode_cost_per_token(),
        t0.elapsed().as_secs_f64()
    );

    let params = GenParams {
        max_new: args.usize_or("tokens", 64),
        temperature: args.f64_or("temperature", 0.0) as f32,
        top_k: args.usize_or("top-k", 0),
        seed,
    };
    let sched = GenScheduler::new(GenConfig {
        max_sessions: args.usize_or("slots", 8),
        queue_depth: args.usize_or("queue-depth", 64),
        max_new_cap: args.usize_or("max-new-cap", 512),
        threads: rc.threads,
        policy,
        deadline,
    });
    let handle = sched.handle();
    let sessions = args.usize_or("sessions", 1);

    if sessions <= 1 {
        // One-shot generation: print the continuation.
        let prompt_text = args.str_or("prompt", "the toeplitz operator ");
        let prompt = tokenize(&prompt_text);
        let t = std::thread::spawn(move || handle.generate(prompt, params));
        let stats = sched.run(&model)?;
        let resp = t.join().expect("client thread")?;
        println!("prompt : {prompt_text:?}");
        println!("output : {:?}", detokenize(&resp.tokens));
        println!(
            "{} tokens, {:.2} ms prefill, {:.3} ms/token decode ({:.0} tok/s)",
            resp.tokens.len(),
            1e3 * stats.prefill_seconds,
            1e3 * stats.decode_seconds / resp.tokens.len().max(1) as f64,
            stats.tokens_per_sec()
        );
        return Ok(());
    }

    // Load test: many client threads against the continuous-batching
    // scheduler, stats reported from the server side.
    let requests = args.usize_or("requests", sessions * 4);
    let per_client = (requests / sessions).max(1);
    let workers: Vec<_> = (0..sessions)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut rng = ski_tnn::util::rng::Rng::new(seed ^ (c as u64 + 1));
                for _ in 0..per_client {
                    let len = 4 + rng.below(28);
                    let prompt: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                    let p = GenParams { seed: rng.next_u64(), ..params };
                    // Typed overload/deadline answers are expected
                    // when shedding is configured.
                    let _ = h.generate(prompt, p);
                }
            })
        })
        .collect();
    drop(handle);
    let t0 = std::time::Instant::now();
    let stats = sched.run(&model)?;
    let wall = t0.elapsed().as_secs_f64();
    for w in workers {
        w.join().unwrap();
    }
    let (p50, p95, p99) = stats.queue_percentiles();
    println!(
        "{} sessions, {} tokens in {} ticks (mean concurrency {:.2})",
        stats.sessions,
        stats.tokens,
        stats.ticks,
        stats.mean_concurrency()
    );
    println!(
        "throughput {:.0} tok/s aggregate ({:.0} tok/s wall), queue wait p50 {:.1} ms  \
         p95 {:.1} ms  p99 {:.1} ms",
        stats.tokens_per_sec(),
        stats.tokens as f64 / wall.max(1e-9),
        1e3 * p50,
        1e3 * p95,
        1e3 * p99
    );
    let adm = stats.admission;
    if adm.shed + adm.expired + adm.retries > 0 {
        println!(
            "admission: {} submitted, {} shed, {} expired, {} retries (peak queue depth {})",
            adm.submitted, adm.shed, adm.expired, adm.retries, adm.peak_depth
        );
    }
    Ok(())
}

/// Deterministic chaos soak (CI's `robustness-soak` hard gate): burst
/// the substrate batcher far past capacity with seeded fault injection
/// armed, then verify the two serving invariants the overload layer
/// promises — every accepted request is answered exactly once (no
/// losses, no doubles), and the admission ledger balances exactly
/// (`submitted == admitted + shed`, `admitted == completed +
/// expired`).  Half the clients fire non-blocking bursts
/// (`try_submit`), half go through the jittered retry path, so both
/// client disciplines are exercised in one run.  The verdict is
/// written as JSON (`--out`, default `CHAOS_soak.json`) and the
/// process exits non-zero on any violation.
fn cmd_soak(args: &Args) -> Result<()> {
    use std::time::Duration;

    use ski_tnn::runtime::resolve_threads;
    use ski_tnn::server::chaos::{self, ChaosConfig};
    use ski_tnn::server::{AdmissionPolicy, RetryPolicy, ServeError, SubmitError};
    use ski_tnn::toeplitz::BackendKind;
    use ski_tnn::util::json::{self, Json};

    #[derive(Debug, Default)]
    struct Tally {
        accepted: u64,
        rejected_fast: u64,
        responses: u64,
        ok: u64,
        overloaded: u64,
        deadline_exceeded: u64,
        exec_failed: u64,
        lost: u64,
        double_answered: u64,
        retry_ok: u64,
        retry_gave_up: u64,
    }

    impl Tally {
        fn merge(&mut self, o: &Tally) {
            self.accepted += o.accepted;
            self.rejected_fast += o.rejected_fast;
            self.responses += o.responses;
            self.ok += o.ok;
            self.overloaded += o.overloaded;
            self.deadline_exceeded += o.deadline_exceeded;
            self.exec_failed += o.exec_failed;
            self.lost += o.lost;
            self.double_answered += o.double_answered;
            self.retry_ok += o.retry_ok;
            self.retry_gave_up += o.retry_gave_up;
        }
    }

    let rc = RunConfig::from_args(args)?;
    let _stats_writer = telemetry_setup(&rc);
    let n = args.usize_or("n", 256);
    anyhow::ensure!(n >= 16, "--n must be at least 16, got {n}");
    let requests = args.usize_or("requests", 400);
    let clients = args.usize_or("clients", 8).max(2);
    let queue_depth = args.usize_or("queue-depth", 32);
    let seed = args.u64_or("seed", 0);
    let out = args.str_or("out", "CHAOS_soak.json");
    let r = args.usize_or("rank", (n / 16).max(2));
    let w = args.usize_or("band", 9);
    // Shed under pressure by default — a purely blocking soak would
    // never reach the overload paths this command exists to verify.
    // Explicit `--admission` / `--deadline-ms` still win.
    let policy = if rc.admission.is_some() {
        rc.admission_policy()?
    } else {
        AdmissionPolicy::ShedExpiredFirst
    };
    let deadline = rc.deadline().or(Some(Duration::from_millis(250)));
    // Arm fault injection: `--chaos-seed` wins, else the
    // `SKI_TNN_CHAOS` env already parsed by the chaos module.
    if let Some(s) = args.get("chaos-seed") {
        chaos::install(ChaosConfig::from_seed(s.parse().unwrap_or(1)));
    }
    let armed = chaos::enabled();
    let threads = resolve_threads(rc.threads);
    let backend_flag = rc.backend.clone().unwrap_or_else(|| "auto".to_string());
    let requested = BackendKind::parse(&backend_flag).ok_or_else(|| {
        anyhow::anyhow!("unknown backend {backend_flag:?} (auto|dense|fft|ski|freq)")
    })?;

    let server_cfg = ServerConfig {
        max_batch: args.usize_or("max-batch", 8),
        n,
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        queue_depth,
        buckets: rc.buckets.clone(),
        policy,
        deadline,
    };
    let batcher = Batcher::new(server_cfg);
    let (kind, pool_threads, exec) = substrate_exec(&batcher, requested, r, w, threads, true);

    let burst_clients = (clients / 2).max(1);
    let retry_clients = clients - burst_clients;
    let per_client = (requests / clients).max(1);
    println!(
        "soak: backend {} over {pool_threads} threads, {burst_clients} burst + {retry_clients} \
         retry clients × {per_client} requests, queue {queue_depth} ({}), deadline {:?}, chaos {}",
        kind.name(),
        policy.name(),
        deadline,
        if armed { "armed" } else { "off" },
    );

    let handle = batcher.handle();
    let mut workers = Vec::new();
    // Burst clients: submit the whole allotment without waiting (each
    // response channel holds its one slot), then drain — 10×-capacity
    // pressure plus a per-receiver exactly-once check.  Even-numbered
    // clients use the blocking-admission `submit` (a shed policy
    // answers the overflow with typed `Overloaded`), odd-numbered ones
    // the non-blocking `try_submit` (overflow rejected client-side as
    // `QueueFull`) — both disciplines hammer the same queue.
    for c in 0..burst_clients {
        let h = handle.clone();
        workers.push(std::thread::spawn(move || {
            let mut rng = ski_tnn::util::rng::Rng::new(seed ^ (0x9e37 + c as u64));
            let mut t = Tally::default();
            let mut pending = Vec::new();
            for _ in 0..per_client {
                let len = 8 + rng.below(n - 8);
                let ids: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                let submitted = if c % 2 == 0 {
                    h.submit(ids)
                } else {
                    h.try_submit(ids)
                };
                match submitted {
                    Ok(rx) => {
                        t.accepted += 1;
                        pending.push(rx);
                    }
                    Err(SubmitError::QueueFull) | Err(SubmitError::Stopped) => {
                        t.rejected_fast += 1;
                    }
                }
            }
            for rx in pending {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(resp) => {
                        t.responses += 1;
                        match resp.error {
                            None => t.ok += 1,
                            Some(ServeError::Overloaded) => t.overloaded += 1,
                            Some(ServeError::DeadlineExceeded) => t.deadline_exceeded += 1,
                            Some(ServeError::Exec(_)) => t.exec_failed += 1,
                        }
                        if rx.try_recv().is_ok() {
                            t.double_answered += 1;
                        }
                    }
                    Err(_) => t.lost += 1,
                }
            }
            t
        }));
    }
    // Retry clients: the jittered-backoff discipline a well-behaved
    // caller uses; retryable typed answers get re-attempted within the
    // budget.
    for c in 0..retry_clients {
        let h = handle.clone();
        workers.push(std::thread::spawn(move || {
            let mut rng = ski_tnn::util::rng::Rng::new(seed ^ (0x51ab + c as u64));
            let retry = RetryPolicy { seed: seed ^ (c as u64 + 1), ..RetryPolicy::default() };
            let mut t = Tally::default();
            for _ in 0..per_client {
                let len = 8 + rng.below(n - 8);
                let ids: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                match h.infer_with_retry(ids, &retry) {
                    Ok(_) => t.retry_ok += 1,
                    Err(_) => t.retry_gave_up += 1,
                }
            }
            t
        }));
    }
    drop(handle);

    let stats = batcher.run(exec)?;
    let mut tally = Tally::default();
    for worker in workers {
        tally.merge(&worker.join().expect("soak client thread"));
    }

    let adm = stats.admission;
    let counts = chaos::counts();
    let balanced = adm.balanced();
    let exactly_once =
        tally.lost == 0 && tally.double_answered == 0 && tally.responses == tally.accepted;
    let pass = balanced && exactly_once;
    let verdict = Json::obj(vec![
        (
            "chaos",
            Json::obj(vec![
                ("armed", Json::Bool(armed)),
                ("exec_failures", Json::num(counts.exec_failures as f64)),
                ("stalls", Json::num(counts.stalls as f64)),
                ("poisoned", Json::num(counts.poisoned as f64)),
            ]),
        ),
        (
            "admission",
            Json::obj(vec![
                ("policy", Json::str(policy.name())),
                ("queue_depth", Json::num(queue_depth as f64)),
                ("submitted", Json::num(adm.submitted as f64)),
                ("admitted", Json::num(adm.admitted as f64)),
                ("shed", Json::num(adm.shed as f64)),
                ("expired", Json::num(adm.expired as f64)),
                ("completed", Json::num(adm.completed as f64)),
                ("retries", Json::num(adm.retries as f64)),
                ("peak_depth", Json::num(adm.peak_depth as f64)),
            ]),
        ),
        (
            "client",
            Json::obj(vec![
                ("accepted", Json::num(tally.accepted as f64)),
                ("rejected_fast", Json::num(tally.rejected_fast as f64)),
                ("responses", Json::num(tally.responses as f64)),
                ("ok", Json::num(tally.ok as f64)),
                ("overloaded", Json::num(tally.overloaded as f64)),
                ("deadline_exceeded", Json::num(tally.deadline_exceeded as f64)),
                ("exec_failed", Json::num(tally.exec_failed as f64)),
                ("lost", Json::num(tally.lost as f64)),
                ("double_answered", Json::num(tally.double_answered as f64)),
                ("retry_ok", Json::num(tally.retry_ok as f64)),
                ("retry_gave_up", Json::num(tally.retry_gave_up as f64)),
            ]),
        ),
        ("balanced", Json::Bool(balanced)),
        ("exactly_once", Json::Bool(exactly_once)),
        ("pass", Json::Bool(pass)),
    ]);
    std::fs::write(&out, json::write(&verdict))?;
    println!(
        "soak verdict → {out}: {} ({} admitted, {} shed, {} expired; {} injected failures, {} \
         stalls)",
        if pass { "PASS" } else { "FAIL" },
        adm.admitted,
        adm.shed,
        adm.expired,
        counts.exec_failures,
        counts.stalls
    );
    anyhow::ensure!(
        balanced,
        "admission ledger unbalanced: {} submitted != {} admitted + {} shed, or {} admitted != \
         {} completed + {} expired",
        adm.submitted,
        adm.admitted,
        adm.shed,
        adm.admitted,
        adm.completed,
        adm.expired
    );
    anyhow::ensure!(
        exactly_once,
        "exactly-one-response violated: {} accepted, {} responses, {} lost, {} double-answered",
        tally.accepted,
        tally.responses,
        tally.lost,
        tally.double_answered
    );
    Ok(())
}
