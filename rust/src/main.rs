//! `ski-tnn` — the launcher CLI.
//!
//! Subcommands:
//!
//! * `list`  — show every artifact config in the manifest.
//! * `train` — run the training orchestrator on one config.
//! * `eval`  — evaluate a checkpoint (or fresh init) on the val split.
//! * `serve` — start the dynamic batcher on a config and drive it with
//!   synthetic client load, reporting latency percentiles.
//!
//! Shared flags come from [`ski_tnn::config::RunConfig`]
//! (`--config-file run.json` plus per-flag overrides).  Examples:
//!
//! ```text
//! ski-tnn list
//! ski-tnn train --config lm_fd_3l --steps 300 --out-dir runs/fd
//! ski-tnn eval  --config lm_fd_3l --resume runs/fd/lm_fd_3l_step300.ckpt
//! ski-tnn serve --config lra_text_fd --requests 200 --clients 4
//! ```

use anyhow::{bail, Result};

use ski_tnn::config::RunConfig;
use ski_tnn::coordinator::Trainer;
use ski_tnn::runtime::{Engine, ModelState};
use ski_tnn::server::{serve_model, Batcher, ServerConfig};
use ski_tnn::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(true);
    match args.subcommand.as_deref() {
        Some("list") => cmd_list(&args),
        Some("corpus") => cmd_corpus(&args),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => bail!("unknown subcommand {other:?} (try list|train|eval|serve)"),
        None => {
            eprintln!("usage: ski-tnn <list|train|eval|serve> [flags]");
            eprintln!("see `cargo doc` or README.md for the full flag set");
            Ok(())
        }
    }
}

/// Dump the synthetic corpus to a file (debugging / cross-language
/// experiments: the python side can train on the exact same bytes).
fn cmd_corpus(args: &Args) -> Result<()> {
    let bytes = args.usize_or("bytes", 1 << 20);
    let seed = args.u64_or("seed", 0);
    let out = args.str_or("out", "corpus.bin");
    let c = ski_tnn::data::Corpus::generate(seed, bytes);
    std::fs::write(&out, &c.bytes)?;
    println!("wrote {bytes} bytes (seed {seed}) to {out}");
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let engine = Engine::new(&rc.artifacts)?;
    println!("{:<22} {:>9} {:>7} {:>5} {:>6} {:>7}  entries", "config", "task", "variant", "n", "d", "params");
    for (name, cfg) in &engine.manifest().configs {
        println!(
            "{:<22} {:>9} {:>7} {:>5} {:>6} {:>6}k  {}",
            name,
            cfg.task.as_str(),
            cfg.variant.as_str(),
            cfg.n,
            cfg.d,
            cfg.param_count / 1000,
            cfg.entries.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let engine = Engine::new(&rc.artifacts)?;
    println!("platform: {}", engine.platform());
    let mut trainer = Trainer::new(&engine, rc)?;
    let stats = trainer.train()?;
    println!(
        "final: loss {:.4} ppl {:.2} acc {:.3}",
        stats.loss, stats.ppl, stats.acc
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let engine = Engine::new(&rc.artifacts)?;
    let mut trainer = Trainer::new(&engine, rc)?;
    let stats = trainer.eval()?;
    println!(
        "val: loss {:.4} ppl {:.2} acc {:.3}",
        stats.loss, stats.ppl, stats.acc
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let rc = RunConfig::from_args(args)?;
    let requests = args.usize_or("requests", 200);
    let clients = args.usize_or("clients", 4);
    let engine = Engine::new(&rc.artifacts)?;
    let cfg = engine.config(&rc.config)?.clone();
    let state = match &rc.resume {
        Some(p) => ModelState::load(&engine, p)?,
        None => ModelState::init(&engine, &rc.config, rc.seed as u32)?,
    };
    // warm the logits compile before load arrives
    engine.load(&rc.config, "logits")?;

    let server_cfg = ServerConfig {
        max_batch: cfg.batch,
        n: cfg.n,
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        queue_depth: args.usize_or("queue-depth", 64),
    };
    println!(
        "serving {} (batch {}, n {}) with {clients} clients × {} requests",
        rc.config,
        cfg.batch,
        cfg.n,
        requests / clients
    );
    let batcher = Batcher::new(server_cfg);
    let handle = batcher.handle();
    let per_client = requests / clients;
    let n = cfg.n;
    let seed = rc.seed;
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || -> Vec<f64> {
                let mut rng = ski_tnn::util::rng::Rng::new(seed + c as u64);
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let len = 8 + rng.below(n - 8);
                    let ids: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                    let t0 = std::time::Instant::now();
                    let _ = h.infer(ids).expect("infer");
                    lat.push(t0.elapsed().as_secs_f64());
                }
                lat
            })
        })
        .collect();
    drop(handle);
    let t0 = std::time::Instant::now();
    let stats = batcher.run(serve_model(&engine, &state))?;
    let total = t0.elapsed().as_secs_f64();
    let mut lats: Vec<f64> = workers.into_iter().flat_map(|w| w.join().unwrap()).collect();
    lats.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| lats[((lats.len() as f64 - 1.0) * p) as usize];
    println!(
        "served {} requests in {} batches ({:.1}% fill), {:.1} req/s",
        stats.requests,
        stats.batches,
        100.0 * stats.mean_batch_fill(cfg.batch),
        stats.requests as f64 / total
    );
    println!(
        "latency p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms  (exec {:.1}% of wall)",
        1e3 * pct(0.50),
        1e3 * pct(0.95),
        1e3 * pct(0.99),
        100.0 * stats.exec_seconds / total
    );
    Ok(())
}
