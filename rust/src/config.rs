//! Typed run configuration: JSON config files + CLI overrides.
//!
//! Every binary (the `ski-tnn` CLI, the examples, the benches) shares
//! this configuration surface.  Precedence is CLI flag > JSON config
//! file (`--config-file run.json`) > built-in default, mirroring the
//! launcher conventions of the big training frameworks.

use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// Configuration of one training / evaluation run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Manifest config name (e.g. `lm_fd_3l`, `lra_text_ski`).
    pub config: String,
    /// Artifact directory (default `artifacts/`).
    pub artifacts: PathBuf,
    /// Number of optimizer steps.
    pub steps: usize,
    /// Validation cadence in steps (0 = only at end).
    pub eval_every: usize,
    /// Batches per validation pass.
    pub eval_batches: usize,
    /// Global seed (corpus, init, batchers fork from this).
    pub seed: u64,
    /// Synthetic corpus size in bytes (LM tasks).
    pub corpus_bytes: usize,
    /// Output directory for metrics CSV/JSON + checkpoints.
    pub out_dir: Option<PathBuf>,
    /// Checkpoint cadence in steps (0 = only at end, if out_dir set).
    pub checkpoint_every: usize,
    /// Resume from this checkpoint path.
    pub resume: Option<PathBuf>,
    /// Console log cadence in steps.
    pub log_every: usize,
    /// Prefetch queue depth (batches prepared ahead on the worker).
    pub prefetch: usize,
    /// Toeplitz backend override: `auto|dense|fft|ski|freq`
    /// (see `toeplitz::BackendKind`).  `None` keeps each subsystem's
    /// default.  `generate` reads it (JSON or CLI) for the
    /// full-context oracle; `serve` switches to artifact-free
    /// substrate serving only on the explicit CLI flag, never from a
    /// config file.
    pub backend: Option<String>,
    /// Worker threads for the shard runtime (`runtime::pool`): batched
    /// Toeplitz applies and scheduler ticks shard across this many
    /// threads.  `0` = auto (`SKI_TNN_THREADS` env, else available
    /// parallelism); `1` = the serial reference.  Results are bitwise
    /// identical for every value.
    pub threads: usize,
    /// Length buckets for substrate serving (`serve --backend …`):
    /// each request pads only to the smallest bucket ≥ its length, so
    /// mixed-length traffic batches within buckets instead of padding
    /// everything to `n`.  Empty = single fixed width.  JSON array or
    /// CLI `--buckets 64,256,1024`.
    pub buckets: Vec<usize>,
    /// Enable the telemetry layer (`telemetry` module): span
    /// histograms on the request path, FFT plan-cache counters, the
    /// dispatch audit ring.  Equivalent to env `SKI_TNN_TELEMETRY=1`
    /// (either one turns it on).  JSON `"telemetry": true` or CLI
    /// `--telemetry`.
    pub telemetry: bool,
    /// Emit periodic JSON telemetry snapshots to this path
    /// (atomic-rename writes; a final snapshot lands on shutdown).
    /// Setting it implies `telemetry = true`.  CLI `--stats-json`.
    pub stats_json: Option<PathBuf>,
    /// Per-request deadline for `serve`/`generate`/`soak` in
    /// milliseconds: requests still queued past this budget are
    /// answered with a typed `DeadlineExceeded` error instead of being
    /// executed late.  `None` = no deadline.  JSON `"deadline_ms"` or
    /// CLI `--deadline-ms 250`.
    pub deadline_ms: Option<u64>,
    /// Admission policy for the serving queues
    /// (`block|shed-newest|shed-expired-first`, see
    /// `server::AdmissionPolicy`).  `None` keeps the default
    /// (`block`).  JSON `"admission"` or CLI `--admission shed-newest`.
    pub admission: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            config: "lm_fd_3l".into(),
            artifacts: PathBuf::from("artifacts"),
            steps: 200,
            eval_every: 50,
            eval_batches: 8,
            seed: 0,
            corpus_bytes: 1 << 20,
            out_dir: None,
            checkpoint_every: 0,
            resume: None,
            log_every: 10,
            prefetch: 4,
            backend: None,
            threads: 0,
            buckets: Vec::new(),
            telemetry: false,
            stats_json: None,
            deadline_ms: None,
            admission: None,
        }
    }
}

impl RunConfig {
    /// Merge a JSON object (from `--config-file`) into `self`.
    pub fn apply_json(&mut self, v: &Json) -> Result<()> {
        let obj = v.as_obj().ok_or_else(|| anyhow!("run config must be a JSON object"))?;
        for (k, val) in obj {
            match k.as_str() {
                "config" => self.config = val.as_str().context("config")?.to_string(),
                "artifacts" => self.artifacts = val.as_str().context("artifacts")?.into(),
                "steps" => self.steps = val.as_usize().context("steps")?,
                "eval_every" => self.eval_every = val.as_usize().context("eval_every")?,
                "eval_batches" => self.eval_batches = val.as_usize().context("eval_batches")?,
                "seed" => self.seed = val.as_f64().context("seed")? as u64,
                "corpus_bytes" => self.corpus_bytes = val.as_usize().context("corpus_bytes")?,
                "out_dir" => self.out_dir = Some(val.as_str().context("out_dir")?.into()),
                "checkpoint_every" => {
                    self.checkpoint_every = val.as_usize().context("checkpoint_every")?
                }
                "resume" => self.resume = Some(val.as_str().context("resume")?.into()),
                "log_every" => self.log_every = val.as_usize().context("log_every")?,
                "prefetch" => self.prefetch = val.as_usize().context("prefetch")?,
                "backend" => {
                    let s = val.as_str().context("backend")?;
                    crate::toeplitz::BackendKind::parse(s)
                        .ok_or_else(|| anyhow!("unknown backend {s:?} (auto|dense|fft|ski|freq)"))?;
                    self.backend = Some(s.to_string());
                }
                "threads" => self.threads = val.as_usize().context("threads")?,
                "buckets" => {
                    let arr = val.as_arr().ok_or_else(|| {
                        anyhow!("buckets must be a JSON array of widths, e.g. [64, 256]")
                    })?;
                    self.buckets = arr
                        .iter()
                        .map(|v| v.as_usize().context("buckets entry"))
                        .collect::<Result<Vec<usize>>>()?;
                }
                "telemetry" => {
                    self.telemetry = val.as_bool().context("telemetry")?;
                }
                "stats_json" => {
                    self.stats_json = Some(val.as_str().context("stats_json")?.into());
                }
                "deadline_ms" => {
                    self.deadline_ms = Some(val.as_usize().context("deadline_ms")? as u64);
                }
                "admission" => {
                    let s = val.as_str().context("admission")?;
                    crate::server::AdmissionPolicy::parse(s).ok_or_else(|| {
                        anyhow!(
                            "unknown admission policy {s:?} \
                             (block|shed-newest|shed-expired-first)"
                        )
                    })?;
                    self.admission = Some(s.to_string());
                }
                other => return Err(anyhow!("unknown run-config key {other:?}")),
            }
        }
        Ok(())
    }

    /// Apply CLI flags on top (only the ones present).
    pub fn apply_args(&mut self, a: &Args) {
        if let Some(v) = a.get("config") {
            self.config = v.to_string();
        }
        if let Some(v) = a.get("artifacts") {
            self.artifacts = v.into();
        }
        if let Some(v) = a.get("steps") {
            self.steps = v.parse().unwrap_or(self.steps);
        }
        if let Some(v) = a.get("eval-every") {
            self.eval_every = v.parse().unwrap_or(self.eval_every);
        }
        if let Some(v) = a.get("eval-batches") {
            self.eval_batches = v.parse().unwrap_or(self.eval_batches);
        }
        if let Some(v) = a.get("seed") {
            self.seed = v.parse().unwrap_or(self.seed);
        }
        if let Some(v) = a.get("corpus-bytes") {
            self.corpus_bytes = v.parse().unwrap_or(self.corpus_bytes);
        }
        if let Some(v) = a.get("out-dir") {
            self.out_dir = Some(v.into());
        }
        if let Some(v) = a.get("checkpoint-every") {
            self.checkpoint_every = v.parse().unwrap_or(self.checkpoint_every);
        }
        if let Some(v) = a.get("resume") {
            self.resume = Some(v.into());
        }
        if let Some(v) = a.get("log-every") {
            self.log_every = v.parse().unwrap_or(self.log_every);
        }
        if let Some(v) = a.get("prefetch") {
            self.prefetch = v.parse().unwrap_or(self.prefetch);
        }
        if let Some(v) = a.get("backend") {
            self.backend = Some(v.to_string());
        }
        if let Some(v) = a.get("threads") {
            self.threads = v.parse().unwrap_or(self.threads);
        }
        if let Some(v) = a.get("buckets") {
            let parsed: Option<Vec<usize>> =
                v.split(',').map(|s| s.trim().parse().ok()).collect();
            if let Some(ws) = parsed {
                self.buckets = ws;
            }
        }
        // `--telemetry` works bare or with an explicit value (the CLI
        // parser treats `--telemetry 1` as an option).
        if a.flag("telemetry") {
            self.telemetry = true;
        } else if let Some(v) = a.get("telemetry") {
            self.telemetry = matches!(v.trim().to_ascii_lowercase().as_str(), "1" | "true" | "on");
        }
        if let Some(v) = a.get("stats-json") {
            self.stats_json = Some(v.into());
        }
        if let Some(v) = a.get("deadline-ms") {
            self.deadline_ms = v.parse().ok().or(self.deadline_ms);
        }
        if let Some(v) = a.get("admission") {
            self.admission = Some(v.to_string());
        }
    }

    /// Parsed admission policy (default [`AdmissionPolicy::Block`]);
    /// errors on an unrecognised CLI value.
    pub fn admission_policy(&self) -> Result<crate::server::AdmissionPolicy> {
        match self.admission.as_deref() {
            None => Ok(crate::server::AdmissionPolicy::default()),
            Some(s) => crate::server::AdmissionPolicy::parse(s).ok_or_else(|| {
                anyhow!("unknown admission policy {s:?} (block|shed-newest|shed-expired-first)")
            }),
        }
    }

    /// Per-request deadline as a [`Duration`](std::time::Duration).
    pub fn deadline(&self) -> Option<std::time::Duration> {
        self.deadline_ms.map(std::time::Duration::from_millis)
    }

    /// Resolve from CLI: defaults ← `--config-file` ← flags.
    pub fn from_args(a: &Args) -> Result<RunConfig> {
        let mut rc = RunConfig::default();
        if let Some(path) = a.get("config-file") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config file {path}"))?;
            let v = json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
            rc.apply_json(&v)?;
        }
        rc.apply_args(a);
        Ok(rc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_then_cli_precedence() {
        let mut rc = RunConfig::default();
        let j = json::parse(r#"{"config": "lm_base_3l", "steps": 77, "seed": 5}"#).unwrap();
        rc.apply_json(&j).unwrap();
        assert_eq!(rc.config, "lm_base_3l");
        assert_eq!(rc.steps, 77);
        let args = Args::parse_from(
            ["--steps".to_string(), "99".to_string()],
            false,
        );
        rc.apply_args(&args);
        assert_eq!(rc.steps, 99, "CLI overrides JSON");
        assert_eq!(rc.seed, 5, "JSON survives where CLI silent");
    }

    #[test]
    fn backend_parsed_and_validated() {
        let mut rc = RunConfig::default();
        assert!(rc.backend.is_none());
        let j = json::parse(r#"{"backend": "ski"}"#).unwrap();
        rc.apply_json(&j).unwrap();
        assert_eq!(rc.backend.as_deref(), Some("ski"));
        let bad = json::parse(r#"{"backend": "simd"}"#).unwrap();
        assert!(rc.apply_json(&bad).is_err(), "unknown backend must be rejected");
        let args = Args::parse_from(["--backend".to_string(), "freq".to_string()], false);
        rc.apply_args(&args);
        assert_eq!(rc.backend.as_deref(), Some("freq"), "CLI overrides JSON");
    }

    #[test]
    fn threads_parsed_from_json_and_cli() {
        let mut rc = RunConfig::default();
        assert_eq!(rc.threads, 0, "default is auto");
        let j = json::parse(r#"{"threads": 2}"#).unwrap();
        rc.apply_json(&j).unwrap();
        assert_eq!(rc.threads, 2);
        let args = Args::parse_from(["--threads".to_string(), "8".to_string()], false);
        rc.apply_args(&args);
        assert_eq!(rc.threads, 8, "CLI overrides JSON");
    }

    #[test]
    fn buckets_parsed_from_json_and_cli() {
        let mut rc = RunConfig::default();
        assert!(rc.buckets.is_empty(), "default is unbucketed");
        let j = json::parse(r#"{"buckets": [64, 256]}"#).unwrap();
        rc.apply_json(&j).unwrap();
        assert_eq!(rc.buckets, vec![64, 256]);
        let bad = json::parse(r#"{"buckets": 64}"#).unwrap();
        assert!(rc.apply_json(&bad).is_err(), "non-array buckets must be rejected");
        let args = Args::parse_from(["--buckets".to_string(), "32,128,512".to_string()], false);
        rc.apply_args(&args);
        assert_eq!(rc.buckets, vec![32, 128, 512], "CLI overrides JSON");
    }

    #[test]
    fn telemetry_and_stats_json_parsed() {
        let mut rc = RunConfig::default();
        assert!(!rc.telemetry && rc.stats_json.is_none(), "telemetry defaults off");
        let j = json::parse(r#"{"telemetry": true, "stats_json": "run_stats.json"}"#).unwrap();
        rc.apply_json(&j).unwrap();
        assert!(rc.telemetry);
        assert_eq!(rc.stats_json.as_deref(), Some(std::path::Path::new("run_stats.json")));
        let bad = json::parse(r#"{"telemetry": "yes"}"#).unwrap();
        assert!(rc.apply_json(&bad).is_err(), "non-bool telemetry must be rejected");

        let mut rc = RunConfig::default();
        let args = Args::parse_from(
            ["--telemetry".to_string(), "--stats-json".to_string(), "s.json".to_string()],
            false,
        );
        rc.apply_args(&args);
        assert!(rc.telemetry, "bare --telemetry flag enables");
        assert_eq!(rc.stats_json.as_deref(), Some(std::path::Path::new("s.json")));

        let mut rc = RunConfig::default();
        let args = Args::parse_from(["--telemetry".to_string(), "off".to_string()], false);
        rc.apply_args(&args);
        assert!(!rc.telemetry, "--telemetry off stays disabled");
    }

    #[test]
    fn admission_and_deadline_parsed_and_validated() {
        let mut rc = RunConfig::default();
        assert!(rc.deadline_ms.is_none() && rc.admission.is_none());
        assert_eq!(
            rc.admission_policy().unwrap(),
            crate::server::AdmissionPolicy::Block,
            "default policy is block"
        );
        let j = json::parse(r#"{"deadline_ms": 250, "admission": "shed-newest"}"#).unwrap();
        rc.apply_json(&j).unwrap();
        assert_eq!(rc.deadline_ms, Some(250));
        assert_eq!(rc.deadline(), Some(std::time::Duration::from_millis(250)));
        assert_eq!(rc.admission_policy().unwrap(), crate::server::AdmissionPolicy::ShedNewest);
        let bad = json::parse(r#"{"admission": "drop-everything"}"#).unwrap();
        assert!(rc.apply_json(&bad).is_err(), "unknown policy must be rejected");

        let args = Args::parse_from(
            [
                "--deadline-ms".to_string(),
                "40".to_string(),
                "--admission".to_string(),
                "shed-expired-first".to_string(),
            ],
            false,
        );
        rc.apply_args(&args);
        assert_eq!(rc.deadline_ms, Some(40), "CLI overrides JSON");
        assert_eq!(
            rc.admission_policy().unwrap(),
            crate::server::AdmissionPolicy::ShedExpiredFirst
        );

        let args = Args::parse_from(["--admission".to_string(), "bogus".to_string()], false);
        rc.apply_args(&args);
        assert!(rc.admission_policy().is_err(), "bad CLI policy surfaces at resolve time");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut rc = RunConfig::default();
        let j = json::parse(r#"{"stesp": 1}"#).unwrap();
        assert!(rc.apply_json(&j).is_err());
    }
}
