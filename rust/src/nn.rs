//! Minimal host-side MLP — the analysis twin of `python/compile/rpe.py`.
//!
//! Used where the *paper's theory* is checked from Rust without going
//! through XLA: Proposition 1 (a scalar ReLU MLP with layer norm is
//! piecewise linear) and Theorems 2–4 (GeLU/SiLU/ReLU smoothness of the
//! frequency-response MLP implies super-exponential / super-polynomial
//! / square-summable time-domain decay — the `decay_analysis` example
//! reproducing Figs 4–6).  Structure matches the python RPE exactly:
//! hidden layers are `act(LayerNorm(W h + b))`, linear output.

use crate::util::rng::Rng;

/// Activation functions with the smoothness ladder from §4.2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    /// Entire (holomorphic everywhere) ⇒ super-exponential decay (Thm 2).
    Gelu,
    /// C^∞ ⇒ super-polynomial decay (Thm 3).
    Silu,
    /// C⁰ piecewise linear ⇒ square-summable signal (Thm 4 / Prop 1).
    Relu,
}

impl Act {
    pub fn parse(s: &str) -> Option<Act> {
        Some(match s {
            "gelu" => Act::Gelu,
            "silu" => Act::Silu,
            "relu" => Act::Relu,
            _ => return None,
        })
    }

    pub fn apply(&self, x: f64) -> f64 {
        match self {
            Act::Relu => x.max(0.0),
            Act::Silu => x / (1.0 + (-x).exp()),
            Act::Gelu => 0.5 * x * (1.0 + erf(x / std::f64::consts::SQRT_2)),
        }
    }
}

/// Abramowitz–Stegun 7.1.26 rational approximation of erf (|err| < 1.5e-7
/// — far below every tolerance in the analyses using it).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// One dense layer.
#[derive(Debug, Clone)]
struct Layer {
    w: Vec<f64>, // (fan_in, fan_out) row-major
    b: Vec<f64>,
    fan_out: usize,
    /// LayerNorm gain/bias (hidden layers only).
    ln: Option<(Vec<f64>, Vec<f64>)>,
}

/// A scalar-input MLP `R → R^out` matching `rpe.mlp_apply`.
#[derive(Debug, Clone)]
pub struct Mlp {
    layers: Vec<Layer>,
    pub act: Act,
}

impl Mlp {
    /// Random init mirroring `rpe.mlp_init` (1/√fan_in scaling,
    /// `out_scale` on the final layer).
    pub fn init(rng: &mut Rng, sizes: &[usize], act: Act, out_scale: f64) -> Mlp {
        assert!(sizes.len() >= 2 && sizes[0] == 1, "scalar-input MLP");
        let nl = sizes.len() - 1;
        let layers = (0..nl)
            .map(|i| {
                let (fi, fo) = (sizes[i], sizes[i + 1]);
                let mut scale = (1.0 / fi.max(1) as f64).sqrt();
                if i == nl - 1 {
                    scale *= out_scale;
                }
                // Random bias (PyTorch-style U(-1/√fan_in, 1/√fan_in)):
                // zero bias + LayerNorm makes the first hidden layer a
                // sign-like function of the scalar input with a
                // sqrt(eps)-wide kink at 0 — a spectral spike that
                // masks the smoothness⇒decay behaviour under test.
                let bscale = (1.0 / fi.max(1) as f64).sqrt();
                Layer {
                    w: (0..fi * fo).map(|_| scale * rng.normal() as f64).collect(),
                    b: (0..fo).map(|_| bscale * (2.0 * rng.f64() - 1.0)).collect(),
                    fan_out: fo,
                    ln: (i < nl - 1).then(|| (vec![1.0; fo], vec![0.0; fo])),
                }
            })
            .collect();
        Mlp { layers, act }
    }

    /// Forward one scalar input.
    pub fn forward(&self, x: f64) -> Vec<f64> {
        let mut h = vec![x];
        for (li, layer) in self.layers.iter().enumerate() {
            let mut out = layer.b.clone();
            for (j, o) in out.iter_mut().enumerate() {
                for (i, &hi) in h.iter().enumerate() {
                    *o += hi * layer.w[i * layer.fan_out + j];
                }
            }
            if let Some((g, b)) = &layer.ln {
                layer_norm(&mut out, g, b);
            }
            if li < self.layers.len() - 1 {
                for o in out.iter_mut() {
                    *o = self.act.apply(*o);
                }
            }
            h = out;
        }
        h
    }

    /// Forward a grid of scalar inputs; returns `(len(grid), out)` rows.
    pub fn forward_grid(&self, grid: &[f64]) -> Vec<Vec<f64>> {
        grid.iter().map(|&x| self.forward(x)).collect()
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().map(|l| l.fan_out).unwrap_or(0)
    }
}

/// Lag-domain kernel view of an RPE MLP — paper §3.2.1: under SKI the
/// RPE is evaluated **only at the r inducing points** (on the warped
/// axis), and every observation lag gets its value through the linear
/// interpolation SKI's `W` already encodes.  This adapter is the
/// bridge: `SparseLowRankOp::from_kernel_fn(n, r, w, |t| rpe.eval(t))`
/// builds the paper's sparse + low-rank operator from a learned RPE
/// with r MLP forwards instead of 2n-1.
#[derive(Debug, Clone)]
pub struct RpeKernel {
    pub mlp: Mlp,
    /// Inverse-time-warp decay rate (§3.2.2).
    pub lam: f64,
    /// Output channel of the MLP to read.
    pub dim: usize,
}

impl RpeKernel {
    pub fn new(mlp: Mlp, lam: f64, dim: usize) -> RpeKernel {
        assert!(dim < mlp.out_dim(), "channel {dim} out of range ({})", mlp.out_dim());
        assert!(lam > 0.0 && lam < 1.0, "warp rate must be in (0, 1), got {lam}");
        RpeKernel { mlp, lam, dim }
    }

    /// Kernel value at (real-valued) lag `t`: the MLP evaluated on the
    /// warped axis.
    pub fn eval(&self, t: f64) -> f32 {
        self.mlp.forward(crate::toeplitz::warp(t, self.lam))[self.dim] as f32
    }
}

fn layer_norm(x: &mut [f64], g: &[f64], b: &[f64]) {
    let n = x.len() as f64;
    let mu = x.iter().sum::<f64>() / n;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for (i, v) in x.iter_mut().enumerate() {
        *v = (*v - mu) * inv * g[i] + b[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn erf_reference_values() {
        for (x, want) in [(0.0, 0.0), (1.0, 0.8427007929), (-1.0, -0.8427007929), (2.0, 0.9953222650)] {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x})");
        }
    }

    #[test]
    fn prop1_relu_mlp_is_piecewise_linear() {
        // Proposition 1: on a fine grid, the second difference of a
        // ReLU+LN MLP is zero except at finitely many kink points.
        // Threshold: h²·f'' curvature from LayerNorm's input-dependent
        // statistics sits near 1e-6 relative at h = 1e-3 (LN of
        // piecewise-linear inputs is piecewise *rational*; Prop 1's
        // proof treats the normalisation as affine).  Genuine ReLU
        // slope changes are h·Δslope ≈ 1e-3 — two orders above the
        // 1e-4 cut used here; a piecewise-linear function triggers at
        // isolated points only.
        check("prop1 piecewise linear", |rng| {
            let mlp = Mlp::init(rng, &[1, 16, 16, 4], Act::Relu, 1.0);
            let grid: Vec<f64> = (0..2001).map(|i| -1.0 + i as f64 * 1e-3).collect();
            let rows = mlp.forward_grid(&grid);
            for d in 0..4 {
                let y: Vec<f64> = rows.iter().map(|r| r[d]).collect();
                let scale =
                    y.iter().map(|v| v.abs()).fold(1.0f64, f64::max);
                let mut kinks = 0;
                for w in y.windows(3) {
                    let dd = (w[2] - 2.0 * w[1] + w[0]).abs() / scale;
                    if dd > 1e-4 {
                        kinks += 1;
                    }
                }
                // far fewer kinks than grid points ⇒ piecewise linear
                assert!(kinks < 150, "too many kinks: {kinks}");
            }
        });
    }

    #[test]
    fn gelu_silu_are_smooth_on_grid() {
        // The activation functions themselves: a ReLU's worst second
        // difference on an h-grid is O(h) at its kink, a C² function's
        // is O(h²) — orders of magnitude smaller at h = 1e-3.  (Full
        // MLP smoothness is exercised through *decay rates* in the
        // decay_analysis example — LayerNorm keeps every activation
        // C^k-preserving but can inflate the constants arbitrarily, so
        // grid second-differences of whole nets are not a stable test.)
        let grid: Vec<f64> = (0..2001).map(|i| -1.0 + i as f64 * 1e-3).collect();
        let max_dd = |act: Act| -> f64 {
            let y: Vec<f64> = grid.iter().map(|&x| act.apply(x)).collect();
            y.windows(3)
                .map(|w| (w[2] - 2.0 * w[1] + w[0]).abs())
                .fold(0.0f64, f64::max)
        };
        let relu = max_dd(Act::Relu);
        for act in [Act::Gelu, Act::Silu] {
            let smooth = max_dd(act);
            assert!(
                smooth < relu / 100.0,
                "{act:?} max dd {smooth:.2e} not ≪ relu {relu:.2e}"
            );
        }
    }

    #[test]
    fn rpe_kernel_evaluates_mlp_on_warped_axis() {
        let mut rng = Rng::new(4);
        let mlp = Mlp::init(&mut rng, &[1, 8, 3], Act::Gelu, 0.5);
        let rpe = RpeKernel::new(mlp.clone(), 0.99, 1);
        for t in [-50.0, -1.0, 0.0, 2.5, 100.0] {
            let want = mlp.forward(crate::toeplitz::warp(t, 0.99))[1] as f32;
            assert_eq!(rpe.eval(t), want, "lag {t}");
        }
    }

    #[test]
    fn rpe_kernel_feeds_ski_inducing_points() {
        // End-to-end §3.2.1: a smooth (GeLU) RPE kernel through the
        // sparse + low-rank operator.  At r = n the inducing grid hits
        // every integer lag, so the decomposition reproduces the dense
        // RPE operator; a coarse rank is strictly worse but finite.
        use crate::toeplitz::{SparseLowRankOp, ToeplitzKernel, ToeplitzOp};
        let mut rng = Rng::new(8);
        let mlp = Mlp::init(&mut rng, &[1, 16, 16, 1], Act::Gelu, 0.5);
        let rpe = RpeKernel::new(mlp, 0.995, 0);
        let n = 128;
        let dense = ToeplitzKernel::from_fn(n, |lag| rpe.eval(lag as f64));
        let x: Vec<f32> = (0..n).map(|i| ((i * 29 % 13) as f32 - 6.0) / 6.0).collect();
        let exact = dense.apply_dense(&x);
        let err = |r: usize| {
            let op = SparseLowRankOp::from_kernel_fn(n, r, 5, |t| rpe.eval(t));
            exact
                .iter()
                .zip(op.apply(&x).iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let coarse = err(17);
        let full = err(n);
        assert!(full < 1e-2, "full-rank RPE decomposition must be near-exact: {full}");
        // The warped GeLU RPE is bounded, so even the coarse rank
        // stays on the operator's own scale — the band-edge
        // discontinuity the subtraction introduces smears at coarse
        // ranks (first inducing interval straddles it) but must not
        // blow up.
        let scale = exact.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        assert!(
            coarse.is_finite() && coarse < 2.0 * scale.max(1.0),
            "coarse rank diverged: {coarse} (scale {scale})"
        );
    }

    #[test]
    fn forward_is_deterministic() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::init(&mut rng, &[1, 8, 3], Act::Silu, 0.3);
        assert_eq!(mlp.forward(0.25), mlp.forward(0.25));
        assert_eq!(mlp.out_dim(), 3);
    }
}
