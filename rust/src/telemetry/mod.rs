//! Crate-wide observability: lock-free metrics, request-path span
//! timing, and a dispatcher cost-model audit trail.
//!
//! A request crossing the serving stack touches five subsystems
//! (batcher → length bucket → cost-model dispatch → sharded pool →
//! spectral plan); this module makes that path measurable without
//! perturbing it:
//!
//! * [`registry`](self) — atomic [`Counter`]s, [`Gauge`]s, and
//!   log₂-bucketed [`Histogram`]s keyed by name in a process-wide
//!   [`Registry`] ([`global`]).
//! * **Spans** — RAII timers over the named request-path sections
//!   (`span.queue_wait`, `span.bucket_gather`, `span.dispatch_decide`,
//!   `span.shard_exec`, `span.fft_forward`, `span.decode_tick`).
//! * **FFT engine counters** (declared in `dsp/fft.rs`) —
//!   `fft.plan_cache.{local_hit,hit,miss,size}` for the plan cache,
//!   and the real-transform routing family: `fft.real_fast_path`
//!   (any true real algorithm) split into `.packed` (even-length r2c
//!   at the half length) and `.odd` (odd-length half-spectrum
//!   chirp-z), with `fft.real_fallback` counting transforms that paid
//!   the full complex engine.
//! * **Dispatch audit** — a bounded ring of `Dispatch::plan` outcomes
//!   with predicted-vs-measured ns per shape ([`record_dispatch`]).
//! * **Export** — JSON snapshots ([`snapshot`], [`write_snapshot`],
//!   periodic [`StatsWriter`]), validation ([`check_snapshot`]) and
//!   pretty-printing ([`print_snapshot`]).
//!
//! Everything is gated on one global flag: set env
//! `SKI_TNN_TELEMETRY=1` (or `RunConfig.telemetry` / `--telemetry`)
//! to enable.  While disabled, instrumented call sites cost one
//! relaxed atomic load — no clock reads, no allocation, and nothing is
//! ever registered (the zero-overhead contract the unit tests pin).
//!
//! Call sites declare `static` [`LazyCounter`] / [`LazyGauge`] /
//! [`LazyHistogram`] handles next to the code they instrument; the
//! first enabled-mode use resolves the name against the global
//! registry once, after which every record is a couple of relaxed
//! atomic ops.

mod audit;
mod export;
mod registry;

pub use audit::{global_audit, record_dispatch, AuditRow, DispatchAudit, AUDIT_RING_CAP};
pub use export::{
    check_snapshot, print_snapshot, snapshot, snapshot_json, write_snapshot, write_snapshot_doc,
    StatsWriter, SNAPSHOT_VERSION,
};
pub use registry::{global, Counter, Gauge, Histogram, Registry, HIST_BUCKETS};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, Once, OnceLock, PoisonError};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

/// Whether telemetry is on.  The first call folds in the
/// `SKI_TNN_TELEMETRY` environment variable (`1`/`true`/`on`);
/// [`set_enabled`] overrides it either way.
pub fn enabled() -> bool {
    ENV_INIT.call_once(|| {
        if let Ok(v) = std::env::var("SKI_TNN_TELEMETRY") {
            let v = v.trim().to_ascii_lowercase();
            if v == "1" || v == "true" || v == "on" {
                ENABLED.store(true, Ordering::Relaxed);
            }
        }
    });
    ENABLED.load(Ordering::Relaxed)
}

/// Turn telemetry on or off for the whole process.
pub fn set_enabled(on: bool) {
    // Make sure the env init cannot race in afterwards and clobber us.
    enabled();
    ENABLED.store(on, Ordering::Relaxed);
}

/// Counter handle resolved against [`global`] on first enabled use.
/// `const`-constructible so call sites keep one in a `static`.
pub struct LazyCounter {
    name: &'static str,
    slot: OnceLock<Arc<Counter>>,
}

impl LazyCounter {
    pub const fn new(name: &'static str) -> LazyCounter {
        LazyCounter { name, slot: OnceLock::new() }
    }

    pub fn add(&self, delta: u64) {
        if enabled() {
            self.slot.get_or_init(|| global().counter(self.name)).add(delta);
        }
    }

    pub fn incr(&self) {
        self.add(1);
    }
}

/// Gauge handle resolved against [`global`] on first enabled use.
pub struct LazyGauge {
    name: &'static str,
    slot: OnceLock<Arc<Gauge>>,
}

impl LazyGauge {
    pub const fn new(name: &'static str) -> LazyGauge {
        LazyGauge { name, slot: OnceLock::new() }
    }

    pub fn set(&self, v: f64) {
        if enabled() {
            self.slot.get_or_init(|| global().gauge(self.name)).set(v);
        }
    }
}

/// Histogram handle resolved against [`global`] on first enabled use.
pub struct LazyHistogram {
    name: &'static str,
    slot: OnceLock<Arc<Histogram>>,
}

impl LazyHistogram {
    pub const fn new(name: &'static str) -> LazyHistogram {
        LazyHistogram { name, slot: OnceLock::new() }
    }

    pub fn record_ns(&self, ns: u64) {
        if enabled() {
            self.slot.get_or_init(|| global().histogram(self.name)).record(ns);
        }
    }
}

/// Time a request spends queued before its batch executes.
pub static SPAN_QUEUE_WAIT: LazyHistogram = LazyHistogram::new("span.queue_wait");
/// Partitioning one gathered batch into length buckets.
pub static SPAN_BUCKET_GATHER: LazyHistogram = LazyHistogram::new("span.bucket_gather");
/// One `Dispatch::plan` cost-model evaluation.
pub static SPAN_DISPATCH_DECIDE: LazyHistogram = LazyHistogram::new("span.dispatch_decide");
/// Executing one batch through the (possibly sharded) backend.
pub static SPAN_SHARD_EXEC: LazyHistogram = LazyHistogram::new("span.shard_exec");
/// One spectral-plan forward application (FFT → multiply → inverse).
pub static SPAN_FFT_FORWARD: LazyHistogram = LazyHistogram::new("span.fft_forward");
/// One decode scheduler tick (stepping every live session once).
pub static SPAN_DECODE_TICK: LazyHistogram = LazyHistogram::new("span.decode_tick");

/// RAII span timer from [`span`]: records elapsed ns into its series
/// on drop.  While telemetry is disabled it holds nothing and never
/// reads the clock.
pub struct SpanGuard {
    live: Option<(&'static LazyHistogram, Instant)>,
}

/// Start timing a span; keep the guard alive for the region's extent.
pub fn span(series: &'static LazyHistogram) -> SpanGuard {
    if enabled() {
        SpanGuard { live: Some((series, Instant::now())) }
    } else {
        SpanGuard { live: None }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((series, t0)) = self.live.take() {
            series.record_ns(t0.elapsed().as_nanos() as u64);
        }
    }
}

/// Serialises tests that flip the process-global enabled flag (unit
/// tests in one binary share the process).  Test support only.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_mode_creates_no_registry_entries() {
        let _g = test_guard();
        let was = enabled();
        set_enabled(false);
        static PROBE_H: LazyHistogram = LazyHistogram::new("test.disabled_probe_hist");
        static PROBE_C: LazyCounter = LazyCounter::new("test.disabled_probe_count");
        static PROBE_G: LazyGauge = LazyGauge::new("test.disabled_probe_gauge");
        let before = global().len();
        {
            let _s = span(&PROBE_H);
            PROBE_C.incr();
            PROBE_G.set(1.0);
            PROBE_H.record_ns(42);
        }
        assert_eq!(global().len(), before, "disabled telemetry must register nothing");
        set_enabled(was);
    }

    #[test]
    fn enabled_spans_record_into_global_registry() {
        let _g = test_guard();
        let was = enabled();
        set_enabled(true);
        static PROBE: LazyHistogram = LazyHistogram::new("test.enabled_probe");
        {
            let _s = span(&PROBE);
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
        set_enabled(was);
        let h = global().histogram("test.enabled_probe");
        assert_eq!(h.count(), 1);
        assert!(h.quantile(0.5) > 0.0);
    }

    #[test]
    fn lazy_handles_share_the_named_instrument() {
        let _g = test_guard();
        let was = enabled();
        set_enabled(true);
        static C: LazyCounter = LazyCounter::new("test.shared_counter");
        C.add(2);
        C.incr();
        set_enabled(was);
        assert_eq!(global().counter("test.shared_counter").get(), 3);
    }
}
