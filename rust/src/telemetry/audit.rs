//! Dispatch audit trail: predicted-vs-measured cost per executed shape.
//!
//! The cost-model dispatcher (`toeplitz::op::Dispatch`) picks a
//! backend from closed-form ns estimates.  This module keeps a bounded
//! ring of executed decisions — query shape, chosen backend, the
//! model's predicted ns, and the measured wall time — so a snapshot
//! can report per-shape calibration error and flag shapes where the
//! model is off by ≥ 2× (i.e. the dispatcher may be choosing a backend
//! that is ≥ 2× worse than what it would measure).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Mutex, OnceLock, PoisonError};

use crate::util::json::Json;

/// Most recent decisions kept (the ring is bounded; ~100 B per row).
pub const AUDIT_RING_CAP: usize = 512;

/// One executed dispatch decision.
#[derive(Debug, Clone)]
pub struct AuditRow {
    pub n: usize,
    pub r: usize,
    pub w: usize,
    pub causal: bool,
    pub threads: usize,
    /// Rows in the executed batch.
    pub rows: usize,
    /// `BackendKind::name()` of the chosen backend.
    pub backend: &'static str,
    /// Cost-model estimate for the whole batch, ns (0.0 when the model
    /// has no candidate for the forced backend).
    pub predicted_ns: f64,
    /// Measured wall time of the executed batch, ns.
    pub measured_ns: f64,
    /// The server [`PressureGauge`](crate::server::PressureGauge)
    /// reading at execution time (0.0 at sites with no gauge).
    pub pressure: f64,
    /// True when the executed backend was a graceful-degradation
    /// downshift of the unpressured plan
    /// ([`Dispatch::downshift`](crate::toeplitz::Dispatch::downshift)
    /// under pressure ≥ `PRESSURE_DOWNSHIFT`) — makes load shedding
    /// auditable after the fact.
    pub downshifted: bool,
}

impl AuditRow {
    /// Key the calibration summary groups by (batch size excluded:
    /// per-row cost is shape-determined, batch fill is traffic).
    fn shape(&self) -> String {
        format!(
            "backend={}/causal={}/n={}/r={}/threads={}/w={}",
            self.backend, self.causal, self.n, self.r, self.threads, self.w
        )
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("r", Json::num(self.r as f64)),
            ("w", Json::num(self.w as f64)),
            ("causal", Json::Bool(self.causal)),
            ("threads", Json::num(self.threads as f64)),
            ("rows", Json::num(self.rows as f64)),
            ("backend", Json::str(self.backend)),
            ("predicted_ns", Json::num(self.predicted_ns)),
            ("measured_ns", Json::num(self.measured_ns)),
            ("pressure", Json::num(self.pressure)),
            ("downshifted", Json::Bool(self.downshifted)),
        ])
    }
}

#[derive(Debug, Default)]
struct AuditInner {
    ring: VecDeque<AuditRow>,
    recorded: u64,
}

/// Bounded ring of [`AuditRow`]s with a per-shape calibration summary.
#[derive(Debug, Default)]
pub struct DispatchAudit {
    inner: Mutex<AuditInner>,
}

impl DispatchAudit {
    pub fn new() -> DispatchAudit {
        DispatchAudit::default()
    }

    pub fn record(&self, row: AuditRow) {
        let mut g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        if g.ring.len() >= AUDIT_RING_CAP {
            g.ring.pop_front();
        }
        g.ring.push_back(row);
        g.recorded += 1;
    }

    /// Rows currently held (≤ [`AUDIT_RING_CAP`]).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn rows(&self) -> Vec<AuditRow> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).ring.iter().cloned().collect()
    }

    /// `{recorded, rows, summary}` where `summary` aggregates the ring
    /// per shape: count, mean predicted/measured ns, the
    /// `measured_over_predicted` ratio, and `flagged` when that ratio
    /// is ≥ 2 (model far too optimistic) or ≤ 0.5 (far too
    /// pessimistic) — either way the dispatcher's ranking at that
    /// shape is untrustworthy.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        let rows: Vec<Json> = g.ring.iter().map(AuditRow::to_json).collect();
        let mut agg: BTreeMap<String, (u64, f64, f64)> = BTreeMap::new();
        for row in &g.ring {
            let e = agg.entry(row.shape()).or_insert((0, 0.0, 0.0));
            e.0 += 1;
            e.1 += row.predicted_ns;
            e.2 += row.measured_ns;
        }
        let summary: Vec<Json> = agg
            .into_iter()
            .map(|(shape, (count, pred, meas))| {
                let mean_pred = pred / count as f64;
                let mean_meas = meas / count as f64;
                let ratio = if mean_pred > 0.0 { mean_meas / mean_pred } else { 0.0 };
                let flagged = mean_pred > 0.0 && (ratio >= 2.0 || ratio <= 0.5);
                Json::obj(vec![
                    ("shape", Json::str(shape)),
                    ("count", Json::num(count as f64)),
                    ("mean_predicted_ns", Json::num(mean_pred)),
                    ("mean_measured_ns", Json::num(mean_meas)),
                    ("measured_over_predicted", Json::num(ratio)),
                    ("flagged", Json::Bool(flagged)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("recorded", Json::num(g.recorded as f64)),
            ("rows", Json::arr(rows)),
            ("summary", Json::arr(summary)),
        ])
    }
}

/// The process-wide audit ring.
pub fn global_audit() -> &'static DispatchAudit {
    static AUDIT: OnceLock<DispatchAudit> = OnceLock::new();
    AUDIT.get_or_init(DispatchAudit::new)
}

/// Record one executed decision into the global ring; no-op while
/// telemetry is disabled.
pub fn record_dispatch(row: AuditRow) {
    if super::enabled() {
        global_audit().record(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(predicted_ns: f64, measured_ns: f64) -> AuditRow {
        AuditRow {
            n: 256,
            r: 16,
            w: 9,
            causal: false,
            threads: 1,
            rows: 8,
            backend: "fft",
            predicted_ns,
            measured_ns,
            pressure: 0.0,
            downshifted: false,
        }
    }

    #[test]
    fn ring_is_bounded() {
        let a = DispatchAudit::new();
        for _ in 0..(AUDIT_RING_CAP + 40) {
            a.record(row(1000.0, 1100.0));
        }
        assert_eq!(a.len(), AUDIT_RING_CAP);
        let doc = a.to_json();
        assert_eq!(doc.get("recorded").and_then(Json::as_usize), Some(AUDIT_RING_CAP + 40));
        assert_eq!(doc.get("rows").and_then(Json::as_arr).map(|r| r.len()), Some(AUDIT_RING_CAP));
    }

    #[test]
    fn summary_flags_miscalibrated_shapes() {
        let a = DispatchAudit::new();
        a.record(row(1000.0, 1100.0));
        a.record(row(1000.0, 900.0));
        let doc = a.to_json();
        let summary = doc.get("summary").and_then(Json::as_arr).unwrap();
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].get("count").and_then(Json::as_usize), Some(2));
        assert_eq!(summary[0].get("flagged").and_then(Json::as_bool), Some(false));

        let b = DispatchAudit::new();
        b.record(row(100.0, 250.0));
        let doc = b.to_json();
        let summary = doc.get("summary").and_then(Json::as_arr).unwrap();
        assert_eq!(summary[0].get("flagged").and_then(Json::as_bool), Some(true));
        assert_eq!(
            summary[0].get("measured_over_predicted").and_then(Json::as_f64),
            Some(2.5)
        );
        assert!(!b.is_empty());
    }

    #[test]
    fn zero_prediction_never_flags_or_nans() {
        let a = DispatchAudit::new();
        a.record(row(0.0, 500.0));
        let doc = a.to_json();
        let summary = doc.get("summary").and_then(Json::as_arr).unwrap();
        assert_eq!(summary[0].get("flagged").and_then(Json::as_bool), Some(false));
        assert_eq!(summary[0].get("measured_over_predicted").and_then(Json::as_f64), Some(0.0));
    }
}
