//! Lock-free metric primitives and the process-wide registry.
//!
//! Three instrument kinds, all updatable from any thread without
//! taking a lock on the hot path:
//!
//! * [`Counter`] — monotonically increasing `u64` (one atomic add).
//! * [`Gauge`] — last-written `f64`, stored as bits in an `AtomicU64`.
//! * [`Histogram`] — log₂-bucketed latency distribution: 64 fixed
//!   buckets, so recording is two atomic adds plus one atomic add on
//!   the bucket.  Quantiles use the same nearest-rank convention as
//!   `util::bench::percentile`, interpolated inside the bucket, so an
//!   estimate is always within 2× of the exact order statistic.
//!
//! The [`Registry`] maps names to shared instruments; the name lookup
//! takes a `Mutex`, but call sites hold on to the returned `Arc` (see
//! the `Lazy*` handles in the module root) so that cost is paid once.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use crate::util::json::Json;

/// Fixed bucket count: bucket `i` holds values in `[2^(i-1), 2^i)` ns
/// (bucket 0 holds zero), which spans zero to ~584 years.
pub const HIST_BUCKETS: usize = 64;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn add(&self, delta: u64) {
        self.value.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-value gauge (an `f64` stored as its bit pattern).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log₂-bucketed latency histogram over nanosecond samples.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("mean_ns", &self.mean_ns())
            .field("p99_ns", &self.quantile(0.99))
            .finish()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Record one sample, in nanoseconds.
    pub fn record(&self, ns: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a sample given in seconds (negative clamps to zero).
    pub fn record_secs(&self, s: f64) {
        self.record((s.max(0.0) * 1e9) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples, ns.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean sample, ns (0.0 when empty).
    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Quantile estimate in ns: nearest-rank (the convention of
    /// `util::bench::percentile` — rank 0 is the min, rank `count-1`
    /// the max), linearly interpolated within the hit bucket.  The
    /// exact order statistic lives in the same bucket, so the estimate
    /// is within a factor of 2 of it.  0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * (total - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c > 0 && cum + c > rank {
                let lo = if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 };
                let hi = (1u64 << i) as f64;
                let frac = ((rank - cum) as f64 + 0.5) / c as f64;
                return lo + (hi - lo) * frac;
            }
            cum += c;
        }
        (1u64 << (HIST_BUCKETS - 1)) as f64
    }

    /// `{count, sum_ns, mean_ns, p50_ns, p90_ns, p99_ns}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("sum_ns", Json::num(self.sum() as f64)),
            ("mean_ns", Json::num(self.mean_ns())),
            ("p50_ns", Json::num(self.quantile(0.50))),
            ("p90_ns", Json::num(self.quantile(0.90))),
            ("p99_ns", Json::num(self.quantile(0.99))),
        ])
    }
}

/// Named instrument store.  Looking an instrument up (or creating it
/// on first use) locks the per-kind map; recording through the
/// returned `Arc` never does.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

fn obj_owned(pairs: Vec<(String, Json)>) -> Json {
    Json::Obj(pairs.into_iter().collect())
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut g = self.gauges.lock().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.histograms.lock().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    /// Total registered instruments across all three kinds.
    pub fn len(&self) -> usize {
        let c = self.counters.lock().unwrap_or_else(PoisonError::into_inner).len();
        let g = self.gauges.lock().unwrap_or_else(PoisonError::into_inner).len();
        let h = self.histograms.lock().unwrap_or_else(PoisonError::into_inner).len();
        c + g + h
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `{counters: {name: n}, gauges: {name: v}, histograms: {name: {...}}}`.
    pub fn to_json(&self) -> Json {
        let counters: Vec<(String, Json)> = self
            .counters
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(v.get() as f64)))
            .collect();
        let gauges: Vec<(String, Json)> = self
            .gauges
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(v.get())))
            .collect();
        let histograms: Vec<(String, Json)> = self
            .histograms
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .iter()
            .map(|(k, v)| (k.clone(), v.to_json()))
            .collect();
        Json::obj(vec![
            ("counters", obj_owned(counters)),
            ("gauges", obj_owned(gauges)),
            ("histograms", obj_owned(histograms)),
        ])
    }
}

/// The process-wide registry every instrumented call site records into.
pub fn global() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pool::{Task, ThreadPool};
    use crate::util::bench::stats_of;
    use crate::util::rng::Rng;

    #[test]
    fn histogram_percentiles_track_exact_stats() {
        let mut rng = Rng::new(7);
        let h = Histogram::new();
        let mut samples = Vec::new();
        for _ in 0..4000 {
            // Log-uniform latencies spanning 100ns..100ms.
            let ns = 10f64.powf(2.0 + 6.0 * (rng.below(1_000_000) as f64 / 1e6));
            samples.push(ns * 1e-9);
            h.record(ns as u64);
        }
        let s = stats_of(&samples);
        for (q, exact_s) in [(0.5, s.p50_s), (0.9, s.p90_s)] {
            let est_ns = h.quantile(q);
            let exact_ns = exact_s * 1e9;
            assert!(
                est_ns >= exact_ns / 2.05 && est_ns <= exact_ns * 2.05,
                "q={q}: bucketed estimate {est_ns} vs exact {exact_ns}"
            );
        }
    }

    #[test]
    fn histogram_empty_and_monotone() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean_ns(), 0.0);
        for v in [0u64, 1, 5, 100, 1000, 100_000] {
            h.record(v);
        }
        let (a, b, c) = (h.quantile(0.5), h.quantile(0.9), h.quantile(0.99));
        assert!(a <= b && b <= c, "{a} {b} {c}");
        assert!(h.mean_ns() > 0.0);
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 101_106);
    }

    #[test]
    fn concurrent_updates_lose_nothing() {
        let reg = Registry::new();
        let c = reg.counter("t.count");
        let h = reg.histogram("t.hist");
        let pool = ThreadPool::new(4);
        let tasks: Vec<Task> = (0..8u64)
            .map(|s| {
                let c = Arc::clone(&c);
                let h = Arc::clone(&h);
                let task: Task = Box::new(move || {
                    for i in 0..10_000u64 {
                        c.incr();
                        h.record(s * 10_000 + i);
                    }
                });
                task
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(c.get(), 80_000);
        assert_eq!(h.count(), 80_000);
        let expected: u64 = (0..80_000u64).sum();
        assert_eq!(h.sum(), expected);
    }

    #[test]
    fn registry_deduplicates_by_name() {
        let reg = Registry::new();
        reg.counter("a").add(2);
        reg.counter("a").add(3);
        assert_eq!(reg.counter("a").get(), 5);
        reg.gauge("g").set(1.5);
        assert_eq!(reg.gauge("g").get(), 1.5);
        assert_eq!(reg.len(), 2);
        assert!(!reg.is_empty());
    }
}
