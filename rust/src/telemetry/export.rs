//! Snapshot export: JSON assembly, atomic-rename file writes, the
//! periodic `--stats-json` writer thread, snapshot validation (the
//! `bench-check --stats-snapshot` gate), and the `ski-tnn stats`
//! pretty-printer.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, ensure, Result};

use super::audit::{global_audit, DispatchAudit};
use super::registry::{global, Registry};
use crate::util::json::{self, Json};

/// Schema version stamped into every snapshot document.
pub const SNAPSHOT_VERSION: f64 = 1.0;

/// Assemble a snapshot document from explicit parts.  [`snapshot`] is
/// the global-state convenience; this form keeps the schema
/// unit-testable against a local registry.
pub fn snapshot_json(reg: &Registry, audit: &DispatchAudit) -> Json {
    let sections = reg.to_json();
    let section = |k: &str| sections.get(k).cloned().unwrap_or(Json::Null);
    Json::obj(vec![
        ("version", Json::num(SNAPSHOT_VERSION)),
        ("enabled", Json::Bool(super::enabled())),
        ("counters", section("counters")),
        ("gauges", section("gauges")),
        ("histograms", section("histograms")),
        ("dispatch_audit", audit.to_json()),
    ])
}

/// Snapshot of the global registry + audit ring.
pub fn snapshot() -> Json {
    snapshot_json(global(), global_audit())
}

/// Write the global snapshot to `path` (see [`write_snapshot_doc`]).
pub fn write_snapshot(path: &Path) -> std::io::Result<()> {
    write_snapshot_doc(path, &snapshot())
}

/// Write `doc` to `path` via a sibling `.tmp` file and an atomic
/// rename, so concurrent readers never observe a torn document.
pub fn write_snapshot_doc(path: &Path, doc: &Json) -> std::io::Result<()> {
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    std::fs::write(&tmp, json::write(doc))?;
    std::fs::rename(&tmp, path)
}

/// Periodic snapshot emission: a background thread rewrites `path`
/// every `interval`, and dropping the writer emits one final snapshot
/// — so an interrupted run still leaves current numbers behind.
pub struct StatsWriter {
    path: PathBuf,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl StatsWriter {
    pub fn start(path: PathBuf, interval: Duration) -> StatsWriter {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let target = path.clone();
        let handle = std::thread::Builder::new()
            .name("ski-tnn-stats".into())
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    std::thread::park_timeout(interval);
                    if flag.load(Ordering::Relaxed) {
                        break;
                    }
                    let _ = write_snapshot(&target);
                }
            })
            .expect("spawning stats writer thread");
        StatsWriter { path, stop, handle: Some(handle) }
    }

    /// The snapshot path this writer maintains.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for StatsWriter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.thread().unpark();
            let _ = h.join();
        }
        let _ = write_snapshot(&self.path);
    }
}

/// Validate a snapshot document: the core series must be present
/// (queue-wait span with samples and a finite p99, `pool.workers`
/// gauge ≥ 1, at least one dispatch audit row, `plan.cache.{hit,miss}`
/// counters recording at least one lookup with the
/// `plan.cache.{size,bytes}` gauges alongside) and no number anywhere
/// in the document may be NaN/±inf.  `ski-tnn bench-check
/// --stats-snapshot` refuses files failing any of these.
pub fn check_snapshot(doc: &Json) -> Result<()> {
    ensure!(
        doc.get("version").and_then(Json::as_f64).is_some(),
        "snapshot missing \"version\""
    );
    let hists = doc
        .get("histograms")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("snapshot missing \"histograms\""))?;
    let qw = hists
        .get("span.queue_wait")
        .ok_or_else(|| anyhow!("snapshot missing the span.queue_wait series"))?;
    let count = qw.get("count").and_then(Json::as_f64).unwrap_or(0.0);
    ensure!(count > 0.0, "span.queue_wait has no samples");
    let p99 = qw
        .get("p99_ns")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("span.queue_wait missing p99_ns"))?;
    ensure!(p99.is_finite() && p99 >= 0.0, "span.queue_wait p99_ns is not a finite number");
    let workers = doc
        .get("gauges")
        .and_then(|g| g.get("pool.workers"))
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("snapshot missing the pool.workers gauge"))?;
    ensure!(workers >= 1.0, "pool.workers gauge is {workers}, want >= 1");
    let rows = doc
        .get("dispatch_audit")
        .and_then(|a| a.get("rows"))
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("snapshot missing dispatch_audit rows"))?;
    ensure!(!rows.is_empty(), "snapshot has no dispatch audit rows");
    // The execution-plan cache: every serve/decode path resolves its
    // operators through it, so a run that produced traffic must show
    // lookups (a `hit` counter may be absent when every lookup missed;
    // `miss` cannot be — the first build is always a miss) and the
    // occupancy gauges beside them.  `evict` is legitimately absent
    // under capacity.
    let counter = |k: &str| doc.get("counters").and_then(|c| c.get(k)).and_then(Json::as_f64);
    let plan_miss = counter("plan.cache.miss")
        .ok_or_else(|| anyhow!("snapshot missing the plan.cache.miss counter"))?;
    let plan_hits = counter("plan.cache.hit").unwrap_or(0.0);
    ensure!(plan_hits + plan_miss > 0.0, "plan.cache.{{hit,miss}} recorded no lookups");
    let plan_size = doc
        .get("gauges")
        .and_then(|g| g.get("plan.cache.size"))
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("snapshot missing the plan.cache.size gauge"))?;
    ensure!(plan_size >= 1.0, "plan.cache.size gauge is {plan_size}, want >= 1");
    let plan_bytes = doc
        .get("gauges")
        .and_then(|g| g.get("plan.cache.bytes"))
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("snapshot missing the plan.cache.bytes gauge"))?;
    ensure!(plan_bytes >= 0.0, "plan.cache.bytes gauge is {plan_bytes}, want >= 0");
    // Overload control: every serve/generate path admits through the
    // bounded admission queue, so a run that produced traffic must
    // show admissions and a pressure reading.  The shed/expired/retry
    // counters are legitimately absent on an uncontended run.
    let admitted = counter("server.admission.admitted")
        .ok_or_else(|| anyhow!("snapshot missing the server.admission.admitted counter"))?;
    ensure!(admitted >= 1.0, "server.admission.admitted is {admitted}, want >= 1");
    let pressure = doc
        .get("gauges")
        .and_then(|g| g.get("server.pressure"))
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("snapshot missing the server.pressure gauge"))?;
    ensure!(
        (0.0..=1.0).contains(&pressure),
        "server.pressure gauge is {pressure}, want within [0, 1]"
    );
    let mut bad = Vec::new();
    sweep_nonfinite("$", doc, &mut bad);
    ensure!(bad.is_empty(), "snapshot contains non-finite series: {}", bad.join(", "));
    Ok(())
}

fn sweep_nonfinite(path: &str, v: &Json, bad: &mut Vec<String>) {
    match v {
        Json::Num(n) if !n.is_finite() => bad.push(path.to_string()),
        Json::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                sweep_nonfinite(&format!("{path}[{i}]"), item, bad);
            }
        }
        Json::Obj(map) => {
            for (k, item) in map {
                sweep_nonfinite(&format!("{path}.{k}"), item, bad);
            }
        }
        _ => {}
    }
}

/// Pretty-print a snapshot (the `ski-tnn stats` subcommand): latency
/// series with percentiles, counters/gauges, the FFT plan-cache hit
/// rate, and the dispatch-audit calibration table.
pub fn print_snapshot(doc: &Json) {
    use crate::util::bench::{fmt_secs, Table};
    let enabled = doc.get("enabled").and_then(Json::as_bool).unwrap_or(false);
    println!(
        "telemetry snapshot (v{}, captured {})",
        doc.get("version").and_then(Json::as_f64).unwrap_or(0.0),
        if enabled { "enabled" } else { "disabled" }
    );

    if let Some(hists) = doc.get("histograms").and_then(Json::as_obj) {
        if !hists.is_empty() {
            let mut t =
                Table::new("latency series", &["series", "count", "mean", "p50", "p90", "p99"]);
            for (name, h) in hists {
                let f = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                t.row(&[
                    name.clone(),
                    format!("{}", f("count") as u64),
                    fmt_secs(f("mean_ns") * 1e-9),
                    fmt_secs(f("p50_ns") * 1e-9),
                    fmt_secs(f("p90_ns") * 1e-9),
                    fmt_secs(f("p99_ns") * 1e-9),
                ]);
            }
            t.print();
        }
    }

    for (title, section) in [("counters", "counters"), ("gauges", "gauges")] {
        if let Some(map) = doc.get(section).and_then(Json::as_obj) {
            if !map.is_empty() {
                let mut t = Table::new(title, &["name", "value"]);
                for (k, v) in map {
                    t.row(&[k.clone(), format!("{}", v.as_f64().unwrap_or(0.0))]);
                }
                t.print();
            }
        }
    }

    if let Some(cs) = doc.get("counters").and_then(Json::as_obj) {
        let c = |k: &str| cs.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let g = |k: &str| {
            doc.get("gauges").and_then(|g| g.get(k)).and_then(Json::as_f64).unwrap_or(0.0)
        };
        let miss = c("fft.plan_cache.miss");
        let looked = c("fft.plan_cache.hit") + c("fft.plan_cache.local_hit") + miss;
        if looked > 0.0 {
            println!(
                "\nfft plan cache: {:.1}% hit rate ({} lookups, {} plan builds, {} evictions)",
                100.0 * (looked - miss) / looked,
                looked as u64,
                miss as u64,
                c("fft.plan_cache.evict") as u64
            );
        }
        let admitted = c("server.admission.admitted");
        if admitted > 0.0 {
            println!(
                "admission: {} admitted, {} shed, {} expired, {} retries (pressure {:.2})",
                admitted as u64,
                c("server.admission.shed") as u64,
                c("server.admission.expired") as u64,
                c("server.admission.retries") as u64,
                g("server.pressure")
            );
        }
        let pmiss = c("plan.cache.miss");
        let plooked = c("plan.cache.hit") + pmiss;
        if plooked > 0.0 {
            println!(
                "execution-plan cache: {:.1}% hit rate ({} lookups, {} builds, {} evictions; \
                 {} plans resident, {} bytes)",
                100.0 * (plooked - pmiss) / plooked,
                plooked as u64,
                pmiss as u64,
                c("plan.cache.evict") as u64,
                g("plan.cache.size") as u64,
                g("plan.cache.bytes") as u64
            );
        }
    }

    let summary = doc
        .get("dispatch_audit")
        .and_then(|a| a.get("summary"))
        .and_then(Json::as_arr);
    if let Some(summary) = summary {
        if !summary.is_empty() {
            let mut t = Table::new(
                "dispatch audit (cost-model calibration)",
                &["shape", "count", "predicted", "measured", "meas/pred", "flag"],
            );
            for s in summary {
                let f = |k: &str| s.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                let flagged = s.get("flagged").and_then(Json::as_bool).unwrap_or(false);
                t.row(&[
                    s.get("shape").and_then(Json::as_str).unwrap_or("?").to_string(),
                    format!("{}", f("count") as u64),
                    fmt_secs(f("mean_predicted_ns") * 1e-9),
                    fmt_secs(f("mean_measured_ns") * 1e-9),
                    format!("{:.2}", f("measured_over_predicted")),
                    if flagged { "MISCALIBRATED".to_string() } else { String::new() },
                ]);
            }
            t.print();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::AuditRow;

    fn audit_row() -> AuditRow {
        AuditRow {
            n: 128,
            r: 8,
            w: 9,
            causal: false,
            threads: 2,
            rows: 4,
            backend: "ski",
            predicted_ns: 4000.0,
            measured_ns: 5000.0,
            pressure: 0.0,
            downshifted: false,
        }
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let reg = Registry::new();
        reg.counter("c.x").add(3);
        reg.gauge("g.y").set(2.5);
        reg.histogram("span.queue_wait").record(1500);
        let audit = DispatchAudit::new();
        audit.record(audit_row());
        let doc = snapshot_json(&reg, &audit);
        let parsed = json::parse(&json::write(&doc)).unwrap();
        assert_eq!(
            parsed.get("counters").and_then(|c| c.get("c.x")).and_then(Json::as_usize),
            Some(3)
        );
        assert_eq!(
            parsed.get("gauges").and_then(|g| g.get("g.y")).and_then(Json::as_f64),
            Some(2.5)
        );
        let h = parsed.get("histograms").and_then(|h| h.get("span.queue_wait")).unwrap();
        assert_eq!(h.get("count").and_then(Json::as_usize), Some(1));
        let row = parsed
            .get("dispatch_audit")
            .and_then(|a| a.get("rows"))
            .and_then(|r| r.idx(0))
            .unwrap();
        assert_eq!(row.get("backend").and_then(Json::as_str), Some("ski"));
        assert_eq!(row.get("predicted_ns").and_then(Json::as_f64), Some(4000.0));
        assert_eq!(row.get("measured_ns").and_then(Json::as_f64), Some(5000.0));
    }

    #[test]
    fn check_snapshot_requires_core_series() {
        let reg = Registry::new();
        let audit = DispatchAudit::new();
        assert!(check_snapshot(&snapshot_json(&reg, &audit)).is_err());
        reg.histogram("span.queue_wait").record(1000);
        assert!(check_snapshot(&snapshot_json(&reg, &audit)).is_err());
        reg.gauge("pool.workers").set(4.0);
        assert!(check_snapshot(&snapshot_json(&reg, &audit)).is_err(), "still no audit rows");
        audit.record(audit_row());
        assert!(
            check_snapshot(&snapshot_json(&reg, &audit)).is_err(),
            "still no plan.cache lookups"
        );
        reg.counter("plan.cache.miss").add(2);
        reg.counter("plan.cache.hit").add(6);
        assert!(
            check_snapshot(&snapshot_json(&reg, &audit)).is_err(),
            "still no plan.cache gauges"
        );
        reg.gauge("plan.cache.size").set(2.0);
        assert!(check_snapshot(&snapshot_json(&reg, &audit)).is_err(), "still no bytes gauge");
        reg.gauge("plan.cache.bytes").set(4096.0);
        assert!(
            check_snapshot(&snapshot_json(&reg, &audit)).is_err(),
            "still no admission counter"
        );
        reg.counter("server.admission.admitted").add(5);
        assert!(
            check_snapshot(&snapshot_json(&reg, &audit)).is_err(),
            "still no pressure gauge"
        );
        reg.gauge("server.pressure").set(1.5);
        assert!(
            check_snapshot(&snapshot_json(&reg, &audit)).is_err(),
            "pressure outside [0, 1] must be rejected"
        );
        reg.gauge("server.pressure").set(0.25);
        check_snapshot(&snapshot_json(&reg, &audit)).unwrap();
    }

    #[test]
    fn check_snapshot_rejects_nonfinite_numbers() {
        let reg = Registry::new();
        reg.histogram("span.queue_wait").record(1000);
        reg.gauge("pool.workers").set(2.0);
        reg.counter("plan.cache.miss").add(1);
        reg.gauge("plan.cache.size").set(1.0);
        reg.gauge("plan.cache.bytes").set(512.0);
        reg.counter("server.admission.admitted").add(1);
        reg.gauge("server.pressure").set(0.0);
        let audit = DispatchAudit::new();
        audit.record(audit_row());
        let mut doc = snapshot_json(&reg, &audit);
        if let Json::Obj(top) = &mut doc {
            if let Some(Json::Obj(gauges)) = top.get_mut("gauges") {
                gauges.insert("bad".to_string(), Json::Num(f64::NAN));
            }
        }
        let err = check_snapshot(&doc).unwrap_err().to_string();
        assert!(err.contains("non-finite"), "{err}");
        assert!(err.contains("gauges.bad"), "{err}");
    }

    #[test]
    fn write_snapshot_doc_lands_parseable_file() {
        let path =
            std::env::temp_dir().join(format!("ski_tnn_snap_unit_{}.json", std::process::id()));
        let doc = Json::obj(vec![("version", Json::num(1.0))]);
        write_snapshot_doc(&path, &doc).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let parsed = json::parse(&text).unwrap();
        assert_eq!(parsed.get("version").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn stats_writer_emits_final_snapshot_on_drop() {
        let path =
            std::env::temp_dir().join(format!("ski_tnn_writer_unit_{}.json", std::process::id()));
        {
            let w = StatsWriter::start(path.clone(), Duration::from_secs(60));
            assert_eq!(w.path(), path.as_path());
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let parsed = json::parse(&text).unwrap();
        assert!(parsed.get("version").is_some());
    }
}
