//! Run metrics: append-only log with CSV / JSON export.
//!
//! Each [`Record`] is one logged event (train step, eval pass).  The
//! log keeps everything in memory (runs here are ≤ thousands of steps)
//! and serializes on demand so examples and benches can emit both the
//! human table and machine-readable files for EXPERIMENTS.md.
//!
//! When telemetry is enabled each logged value is also mirrored into
//! the global [`crate::telemetry`] registry as a `{kind}.{key}` gauge
//! (latest value wins), so live stats snapshots carry training/eval
//! progress alongside the request-path series.  The log itself stays
//! the report of record.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One logged event: a step index, a kind tag and named values.
#[derive(Debug, Clone)]
pub struct Record {
    pub step: usize,
    pub kind: &'static str,
    pub values: Vec<(String, f64)>,
}

/// Append-only metrics log for one run.
#[derive(Debug, Default)]
pub struct MetricsLog {
    pub records: Vec<Record>,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn log(&mut self, step: usize, kind: &'static str, values: &[(&str, f64)]) {
        if crate::telemetry::enabled() {
            let reg = crate::telemetry::global();
            for (k, v) in values {
                reg.gauge(&format!("{kind}.{k}")).set(*v);
            }
            reg.gauge(&format!("{kind}.step")).set(step as f64);
            reg.counter("metrics.records").incr();
        }
        self.records.push(Record {
            step,
            kind,
            values: values.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// Values of one key across records of one kind, in step order.
    pub fn series(&self, kind: &str, key: &str) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .filter(|r| r.kind == kind)
            .filter_map(|r| {
                r.values.iter().find(|(k, _)| k == key).map(|(_, v)| (r.step, *v))
            })
            .collect()
    }

    /// Mean of one key over the last `k` records of a kind.
    pub fn recent_mean(&self, kind: &str, key: &str, k: usize) -> Option<f64> {
        let s = self.series(kind, key);
        if s.is_empty() {
            return None;
        }
        let tail = &s[s.len().saturating_sub(k)..];
        Some(tail.iter().map(|(_, v)| v).sum::<f64>() / tail.len() as f64)
    }

    /// CSV with the union of all value keys as columns.
    pub fn to_csv(&self) -> String {
        let mut keys: Vec<&str> = Vec::new();
        for r in &self.records {
            for (k, _) in &r.values {
                if !keys.contains(&k.as_str()) {
                    keys.push(k);
                }
            }
        }
        let mut out = String::from("step,kind");
        for k in &keys {
            out.push(',');
            out.push_str(k);
        }
        out.push('\n');
        for r in &self.records {
            out.push_str(&format!("{},{}", r.step, r.kind));
            for k in &keys {
                out.push(',');
                if let Some((_, v)) = r.values.iter().find(|(rk, _)| rk == k) {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    let mut m = BTreeMap::new();
                    m.insert("step".to_string(), Json::num(r.step as f64));
                    m.insert("kind".to_string(), Json::str(r.kind));
                    for (k, v) in &r.values {
                        m.insert(k.clone(), Json::num(*v));
                    }
                    Json::Obj(m)
                })
                .collect(),
        )
    }

    /// Write `<dir>/<stem>.csv` and `<dir>/<stem>.json`.
    pub fn write(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(
            dir.join(format!("{stem}.json")),
            crate::util::json::write(&self.to_json()),
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_and_recent_mean() {
        let mut m = MetricsLog::new();
        for s in 0..10 {
            m.log(s, "train", &[("loss", 10.0 - s as f64)]);
        }
        m.log(5, "eval", &[("val_loss", 3.0)]);
        assert_eq!(m.series("train", "loss").len(), 10);
        assert_eq!(m.series("eval", "val_loss"), vec![(5, 3.0)]);
        assert_eq!(m.recent_mean("train", "loss", 2), Some((1.0 + 2.0) / 2.0));
    }

    #[test]
    fn csv_has_union_header_and_blank_cells() {
        let mut m = MetricsLog::new();
        m.log(0, "train", &[("loss", 1.5)]);
        m.log(1, "eval", &[("acc", 0.5)]);
        let csv = m.to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("step,kind,loss,acc"));
        assert_eq!(lines.next(), Some("0,train,1.5,"));
        assert_eq!(lines.next(), Some("1,eval,,0.5"));
    }

    #[test]
    fn log_mirrors_into_telemetry_registry_when_enabled() {
        let _g = crate::telemetry::test_guard();
        let was = crate::telemetry::enabled();
        crate::telemetry::set_enabled(true);
        let mut m = MetricsLog::new();
        m.log(7, "train", &[("loss", 1.25)]);
        let reg = crate::telemetry::global();
        assert_eq!(reg.gauge("train.loss").get(), 1.25);
        assert_eq!(reg.gauge("train.step").get(), 7.0);
        assert!(reg.counter("metrics.records").get() >= 1);
        crate::telemetry::set_enabled(was);
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let mut m = MetricsLog::new();
        m.log(3, "train", &[("loss", 0.25)]);
        let text = crate::util::json::write(&m.to_json());
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.idx(0).unwrap().get("loss").unwrap().as_f64(), Some(0.25));
    }
}
