//! The training orchestrator: step loop over the fused AOT artifact.
//!
//! One [`Trainer`] owns the model state and runs the loop the paper's
//! experiments need: prefetch-fed fused steps, periodic deterministic
//! validation, perplexity/accuracy bookkeeping, checkpointing, and a
//! metrics log whose series become the Fig 7b/8/9 curves.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::config::RunConfig;
use crate::data::{
    BatchSource, CausalLmStream, ClsStream, Corpus, LraTask, MaskedLmStream, Split,
};
use crate::runtime::{Engine, HostTensor, ModelState, Task};

use super::metrics::MetricsLog;
use super::prefetch::Prefetcher;

/// Convert a host batch to XLA literals (runtime-thread only).
pub fn to_literals(batch: &[HostTensor]) -> Result<Vec<Literal>> {
    batch.iter().map(HostTensor::to_literal).collect()
}

/// Build the right batch source for a manifest config.
///
/// LM configs sample the synthetic grammar corpus; `cls` configs map
/// the config name (`lra_<task>_<variant>`) back to its generator.
pub fn batch_for(
    engine: &Engine,
    config: &str,
    split: Split,
    corpus: Option<Arc<Vec<i32>>>,
    seed: u64,
) -> Result<Box<dyn BatchSource>> {
    let cfg = engine.config(config)?;
    Ok(match cfg.task {
        Task::LmCausal => {
            let toks = corpus.context("causal LM needs a corpus")?;
            Box::new(CausalLmStream::new(toks, split, cfg.batch, cfg.n, seed))
        }
        Task::LmBidir => {
            let toks = corpus.context("masked LM needs a corpus")?;
            Box::new(MaskedLmStream::new(toks, split, cfg.batch, cfg.n, seed))
        }
        Task::Cls => {
            let task_name = config
                .strip_prefix("lra_")
                .and_then(|s| s.rsplit_once('_'))
                .map(|(t, _)| t)
                .with_context(|| format!("cannot infer LRA task from config {config:?}"))?;
            let task = LraTask::parse(task_name)
                .with_context(|| format!("unknown LRA task {task_name:?}"))?;
            // keep val stream distinct from train by seed-space split
            let s = match split {
                Split::Train => seed,
                Split::Val => seed ^ 0x5A5A_5A5A_5A5A_5A5A,
            };
            Box::new(ClsStream::new(task, cfg.batch, cfg.n, s))
        }
    })
}

/// Aggregated validation statistics.
#[derive(Debug, Clone, Copy)]
pub struct EvalStats {
    pub loss: f64,
    /// `exp(loss)` for LM tasks; `NaN` for cls.
    pub ppl: f64,
    /// Classification accuracy for cls tasks; `NaN` for LM.
    pub acc: f64,
}

/// Run a fixed validation pass through an eval entry (`fwd` or
/// `fwd_n{L}`), aggregating exactly (metric = token count for LM,
/// correct count for cls — see `model.loss_fn`).
///
/// Classification batches are weighted by the number of examples each
/// batch actually carries (its leading tensor dimension), not the
/// configured `cfg.batch` — the two only coincide when every stream
/// yields full batches.  An eval pass that evaluates nothing (zero
/// batches, or zero total weight) is an error, not a silently-rescaled
/// loss: the old `weight.max(1.0)` could report a dampened loss when
/// total weight fell below one.
pub fn evaluate(
    engine: &Engine,
    state: &ModelState,
    entry: &str,
    src: &mut dyn BatchSource,
    batches: usize,
) -> Result<EvalStats> {
    let cfg = &state.config;
    let mut loss_weighted = 0.0;
    let mut weight = 0.0;
    let mut correct = 0.0;
    let mut examples = 0.0;
    for _ in 0..batches {
        let host = src.next_batch();
        // Real example count for this batch: the leading dimension of
        // the inputs actually evaluated.
        let rows = host.first().map(|t| t.shape()[0]).unwrap_or(0) as f64;
        let batch = to_literals(&host)?;
        let (loss, metric) = state.fwd(engine, entry, &batch)?;
        match cfg.task {
            Task::LmCausal | Task::LmBidir => {
                // loss is per-token mean, metric the token count
                loss_weighted += f64::from(loss) * f64::from(metric);
                weight += f64::from(metric);
            }
            Task::Cls => {
                loss_weighted += f64::from(loss) * rows;
                weight += rows;
                correct += f64::from(metric);
                examples += rows;
            }
        }
    }
    if weight <= 0.0 {
        bail!(
            "empty eval pass: {batches} batch(es) through {entry:?} carried zero weight \
             (no tokens/examples evaluated)"
        );
    }
    let loss = loss_weighted / weight;
    Ok(EvalStats {
        loss,
        ppl: if cfg.task == Task::Cls { f64::NAN } else { loss.exp() },
        acc: if cfg.task == Task::Cls { correct / examples } else { f64::NAN },
    })
}

/// The end-to-end training driver.
pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub state: ModelState,
    pub run: RunConfig,
    pub metrics: MetricsLog,
    corpus: Option<Arc<Vec<i32>>>,
}

impl<'e> Trainer<'e> {
    /// Initialize (or resume) a run.  Generates the corpus if the
    /// config is an LM task.
    pub fn new(engine: &'e Engine, run: RunConfig) -> Result<Trainer<'e>> {
        let cfg = engine.config(&run.config)?.clone();
        let corpus = match cfg.task {
            Task::Cls => None,
            _ => Some(Arc::new(Corpus::generate(run.seed, run.corpus_bytes).tokens())),
        };
        let state = match &run.resume {
            Some(path) => {
                let st = ModelState::load(engine, path)?;
                if st.config.name != run.config {
                    bail!(
                        "checkpoint {} is for config {}, run wants {}",
                        path.display(),
                        st.config.name,
                        run.config
                    );
                }
                st
            }
            None => ModelState::init(engine, &run.config, run.seed as u32)?,
        };
        Ok(Trainer { engine, state, run, metrics: MetricsLog::new(), corpus })
    }

    /// Validation pass with a fresh deterministic val stream.
    pub fn eval(&mut self) -> Result<EvalStats> {
        let mut src = batch_for(
            self.engine,
            &self.run.config,
            Split::Val,
            self.corpus.clone(),
            self.run.seed + 1,
        )?;
        evaluate(self.engine, &self.state, "fwd", src.as_mut(), self.run.eval_batches)
    }

    /// Run the configured number of steps.  Returns final val stats.
    pub fn train(&mut self) -> Result<EvalStats> {
        let src = batch_for(
            self.engine,
            &self.run.config,
            Split::Train,
            self.corpus.clone(),
            self.run.seed + 2,
        )?;
        let prefetch = Prefetcher::spawn(src, self.run.prefetch);

        // warm the compile cache before the timed loop
        let _ = self.engine.load(&self.run.config, "step")?;
        let t_run = Instant::now();
        let mut steps_done = 0usize;
        for step in 1..=self.run.steps {
            let batch = to_literals(&prefetch.next()?)?;
            let t0 = Instant::now();
            let loss = self.state.step(&batch)?;
            let dt = t0.elapsed().as_secs_f64();
            steps_done += 1;
            if !loss.is_finite() {
                bail!("loss diverged at step {step}: {loss}");
            }
            self.metrics.log(step, "train", &[("loss", f64::from(loss)), ("step_s", dt)]);
            if self.run.log_every > 0 && step % self.run.log_every == 0 {
                let mean = self
                    .metrics
                    .recent_mean("train", "loss", self.run.log_every)
                    .unwrap_or(f64::from(loss));
                println!(
                    "[{}] step {step}/{} loss {mean:.4} ({:.0} ms/step)",
                    self.run.config,
                    self.run.steps,
                    1e3 * dt
                );
            }
            if self.run.eval_every > 0 && step % self.run.eval_every == 0 {
                let stats = self.eval()?;
                self.metrics.log(
                    step,
                    "eval",
                    &[("val_loss", stats.loss), ("val_ppl", stats.ppl), ("val_acc", stats.acc)],
                );
                println!(
                    "[{}] step {step}: val loss {:.4} ppl {:.2} acc {:.3}",
                    self.run.config, stats.loss, stats.ppl, stats.acc
                );
            }
            if self.run.checkpoint_every > 0 && step % self.run.checkpoint_every == 0 {
                self.checkpoint(step)?;
            }
        }
        let total = t_run.elapsed().as_secs_f64();
        let stats = self.eval()?;
        self.metrics.log(
            self.run.steps,
            "final",
            &[
                ("val_loss", stats.loss),
                ("val_ppl", stats.ppl),
                ("val_acc", stats.acc),
                ("steps_per_sec", steps_done as f64 / total.max(1e-9)),
            ],
        );
        if let Some(dir) = self.run.out_dir.clone() {
            self.metrics.write(&dir, &format!("{}_metrics", self.run.config))?;
            self.checkpoint(self.run.steps)?;
        }
        Ok(stats)
    }

    fn checkpoint(&self, step: usize) -> Result<()> {
        if let Some(dir) = &self.run.out_dir {
            std::fs::create_dir_all(dir)?;
            let path = dir.join(format!("{}_step{step}.ckpt", self.run.config));
            self.state.save(&path)?;
            println!("[{}] wrote {}", self.run.config, path.display());
        }
        Ok(())
    }
}
