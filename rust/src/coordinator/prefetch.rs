//! Batch prefetcher: overlap host-side data generation with device
//! execution.
//!
//! The XLA FFI handles are not `Send`, so the split is: the worker
//! thread runs the [`BatchSource`] (pure host work — corpus sampling,
//! masking, raster generation) and ships [`HostTensor`]s through a
//! bounded channel; the runtime thread converts them to literals right
//! before `execute`.  The bound gives natural backpressure: the worker
//! parks once `depth` batches are ready.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::data::BatchSource;
use crate::runtime::HostTensor;

/// Handle to a running prefetch thread.
pub struct Prefetcher {
    rx: Receiver<Vec<HostTensor>>,
    handle: Option<JoinHandle<()>>,
    desc: String,
}

impl Prefetcher {
    /// Spawn a worker producing batches from `src`, keeping up to
    /// `depth` ready.
    pub fn spawn(mut src: Box<dyn BatchSource>, depth: usize) -> Prefetcher {
        let desc = src.describe();
        let (tx, rx) = sync_channel(depth.max(1));
        let handle = std::thread::Builder::new()
            .name(format!("prefetch:{desc}"))
            .spawn(move || {
                loop {
                    let batch = src.next_batch();
                    // Receiver dropped ⇒ trainer is done; exit quietly.
                    if tx.send(batch).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn prefetch thread");
        Prefetcher { rx, handle: Some(handle), desc }
    }

    /// Next batch (blocks until the worker catches up).
    pub fn next(&self) -> Result<Vec<HostTensor>> {
        // A generous timeout converts a hung generator into a
        // diagnosable error instead of a silent stall.
        match self.rx.recv_timeout(Duration::from_secs(120)) {
            Ok(b) => Ok(b),
            Err(RecvTimeoutError::Timeout) => {
                Err(anyhow!("prefetcher {:?} stalled for 120s", self.desc))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(anyhow!("prefetcher {:?} worker died", self.desc))
            }
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Drain the channel so a blocked sender wakes and sees the
        // disconnect; then join.
        while self.rx.try_recv().is_ok() {}
        drop(std::mem::replace(&mut self.rx, {
            let (_tx, rx) = sync_channel(1);
            rx
        }));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counting {
        i: i32,
    }

    impl BatchSource for Counting {
        fn next_batch(&mut self) -> Vec<HostTensor> {
            self.i += 1;
            vec![HostTensor::i32(vec![1], vec![self.i])]
        }
        fn describe(&self) -> String {
            "counting".into()
        }
    }

    #[test]
    fn delivers_batches_in_order() {
        let p = Prefetcher::spawn(Box::new(Counting { i: 0 }), 2);
        for want in 1..=10 {
            let b = p.next().unwrap();
            assert_eq!(b[0].as_i32().unwrap(), &[want]);
        }
    }

    #[test]
    fn drop_terminates_worker() {
        let p = Prefetcher::spawn(Box::new(Counting { i: 0 }), 1);
        let _ = p.next().unwrap();
        drop(p); // must not hang
    }
}
