//! Coordinator — the Layer-3 training orchestrator.
//!
//! Drives the AOT `step`/`fwd` artifacts through the PJRT runtime:
//!
//! * [`Trainer`] — the step loop: prefetch-fed fused train steps,
//!   periodic validation, console + CSV/JSON metrics, checkpoints.
//! * [`Prefetcher`] — a worker thread producing [`HostTensor`] batches
//!   ahead of the runtime thread through a bounded channel (the XLA
//!   handles themselves never cross threads).
//! * [`MetricsLog`] — append-only run log with CSV and JSON export.
//! * [`batch_for`] / [`evaluate`] — helpers shared by examples and
//!   benches: build the right [`BatchSource`] for a manifest config,
//!   run a fixed validation pass.
//!
//! [`HostTensor`]: crate::runtime::HostTensor
//! [`BatchSource`]: crate::data::BatchSource

mod metrics;
mod prefetch;
mod trainer;

pub use metrics::{MetricsLog, Record};
pub use prefetch::Prefetcher;
pub use trainer::{batch_for, evaluate, to_literals, EvalStats, Trainer};
