//! # ski-tnn — "SKI to go Faster" full-system reproduction
//!
//! A three-layer reproduction of Moreno, Mei & Walters (2023),
//! *SKI to go Faster: Accelerating Toeplitz Neural Networks via
//! Asymmetric Kernels*:
//!
//! * **Layer 3 (this crate)** — the coordinator: config, CLI, data
//!   pipeline, training orchestrator, serving batcher, metrics,
//!   checkpoints, plus a pure-Rust Toeplitz/FFT/SKI substrate used for
//!   baselines, property tests and the paper's micro-benchmarks.
//! * **Layer 2 (`python/compile/`)** — the JAX TNN model (GTU/GLU
//!   blocks around four TNO variants), lowered once at build time to
//!   HLO-text artifacts by `python/compile/aot.py`.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels
//!   (interpret mode) for the TNO hot-spots: depthwise conv (sparse
//!   branch), fused `W A Wᵀ` SKI apply, inducing Toeplitz matvec, and
//!   frequency-domain complex modulation.
//!
//! Python never runs on the request path: [`runtime`] loads the AOT
//! artifacts through the PJRT CPU client (`xla` crate) and everything
//! downstream — training loops, evaluation, serving — is Rust.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`runtime`] | PJRT client, artifact manifest, executable cache, device buffers |
//! | [`coordinator`] | training orchestrator: step loop, prefetch, eval, checkpoints |
//! | [`server`] | dynamic batcher + request router, generation scheduler |
//! | [`decode`] | streaming decode: causal-Toeplitz→SSM, sessions, sampling |
//! | [`data`] | synthetic corpus + LRA-style task generators, batchers |
//! | [`plan`] | execution-plan layer: shape-keyed bounded PlanCache, build→warm→execute |
//! | [`toeplitz`] | pure-Rust Toeplitz/SKI substrate (oracles, baselines, App. B scan) |
//! | [`dsp`] | from-scratch FFT/rFFT + discrete Hilbert transform |
//! | [`linalg`] | dense f64 matrix helpers, Jacobi SVD, pseudo-inverse (Theorem 1 checks) |
//! | [`config`] | typed run configuration parsed from JSON + CLI overrides |
//! | [`telemetry`] | lock-free metrics registry, request-path spans, dispatch audit, stats export |
//! | [`util`] | JSON, RNG, CLI, mini-bench, property-test driver |

// Clippy policy (CI runs `cargo clippy -- -D warnings`): two style
// lints are allowed crate-wide because the "fix" fights the numeric-
// kernel idiom used throughout — indexed loops over several coupled
// buffers, and `Complex::{mul,add,sub}` as plain methods (the
// operator traits would add a reference/value impl matrix for no
// call-site gain in the FFT inner loops).
#![allow(clippy::needless_range_loop, clippy::should_implement_trait)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod decode;
pub mod dsp;
pub mod linalg;
pub mod nn;
pub mod plan;
pub mod runtime;
pub mod server;
pub mod telemetry;
pub mod toeplitz;
pub mod util;
