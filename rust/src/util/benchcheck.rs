//! Offline perf-regression gate over the `BENCH_*.json` artifacts.
//!
//! Every bench harness emits machine-readable rows through
//! [`super::bench::write_bench_json`] (median + p90 ns/op per cell).
//! `ski-tnn bench-check` compares those rows against a committed
//! `bench/baseline.json` and fails when a median regresses beyond the
//! baseline's threshold — the teeth of CI's `bench-smoke` job, usable
//! offline with zero extra tooling.
//!
//! Cross-machine noise is handled by **calibration scaling**: the
//! baseline records `calib_ns`, the median wall time of a fixed
//! reference workload ([`calibrate_ns`]) on the machine that wrote it;
//! at check time the same workload is re-measured and every baseline
//! median is scaled by `calib_now / calib_base` before comparing.  A
//! 2× slower CI runner therefore doesn't read as a 2× regression.
//!
//! Row identity is structural: every scalar field of a bench row that
//! is not a measurement (`n`, `r`, `w`, `backend`, `mode`, `batch`,
//! `threads`, …) becomes part of the key, so rows match across runs
//! without the checker knowing each bench's schema.  Refresh the
//! baseline with `ski-tnn bench-check --update` after running the
//! benches **in the same mode CI uses** (`SKI_TNN_BENCH_QUICK=1`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Fields that are measurements or per-run observations, not identity.
const NON_IDENTITY: [&str; 8] = [
    "med_ns",
    "p90_ns",
    "med_ns_per_token",
    "p90_ns_per_token",
    "rel_err",
    "winner",
    "dispatch",
    "causal_dispatch",
];

/// The gated metric: median ns/op under either emitted name.
const METRICS: [&str; 2] = ["med_ns", "med_ns_per_token"];

/// Whether a row participates in the regression gate.  Multi-worker
/// rows (`threads=N`, N > 1) are recorded and reported but never
/// gated: parallel speedup depends on the machine's core count, which
/// the serial calibration probe cannot observe, so comparing a
/// 10-core baseline against a 4-vCPU CI runner would fail without any
/// real regression.
fn gated_key(key: &str) -> bool {
    !key.split('/').any(|p| p.strip_prefix("threads=").map(|v| v != "1").unwrap_or(false))
}

/// `bench name → row key → median ns`.
pub type BenchMap = BTreeMap<String, BTreeMap<String, f64>>;

/// A parsed `bench/baseline.json`.
#[derive(Debug, Clone)]
pub struct Baseline {
    /// [`calibrate_ns`] on the machine that wrote the baseline.
    pub calib_ns: f64,
    /// Allowed median regression, percent (25 = fail beyond 1.25×).
    pub threshold_pct: f64,
    /// True while the medians are model estimates rather than
    /// measurements: the gate is advisory for nominal regressions and
    /// missing rows, failing only when measurements diverge beyond the
    /// threshold from the estimates (see [`verdict`]).  Cleared by
    /// `--update` or [`arm_from`] on real hardware.
    pub bootstrap: bool,
    /// Whether the baseline was recorded with `SKI_TNN_BENCH_QUICK=1`
    /// — quick and full mode emit different row sets, so a mismatch is
    /// the usual cause of "gated rows missing" and gets called out.
    pub quick: Option<bool>,
    pub benches: BenchMap,
}

/// One median that regressed beyond the limit.
#[derive(Debug, Clone)]
pub struct Regression {
    pub bench: String,
    pub key: String,
    /// Baseline median after calibration scaling, ns.
    pub base_ns: f64,
    pub now_ns: f64,
    pub limit_ns: f64,
}

/// Outcome of one comparison pass.
#[derive(Debug, Default)]
pub struct Report {
    pub compared: usize,
    /// Multi-worker rows recorded on both sides but excluded from the
    /// gate (see [`gated_key`]).
    pub ungated: usize,
    /// Rows present now but absent from the baseline (ungated).
    pub new_keys: usize,
    /// `bench/key` entries the baseline has but this run did not emit.
    pub missing: Vec<String>,
    pub regressions: Vec<Regression>,
    /// `calib_now / calib_base` applied to every baseline median.
    pub scale: f64,
    /// The threshold this pass gated with (override or baseline's).
    pub threshold_pct: f64,
    /// Largest |now/scaled_base − 1|, percent, over gated rows — in
    /// either direction.  Against a bootstrap (model-estimated)
    /// baseline this is the arming trigger: once measurements diverge
    /// from the estimates beyond the threshold, the estimates are
    /// proven stale and keeping them advisory would mask regressions,
    /// so the gate fails until the baseline is armed from a measured
    /// candidate (see [`arm_from`]).
    pub max_divergence_pct: f64,
}

/// Format a JSON number for a row key: integers without a trailing
/// `.0` so keys are stable and readable (`n=256`, not `n=256.0`).
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Structural identity of one bench row, or `None` when the row has
/// no gated metric.
fn row_key(row: &Json) -> Option<String> {
    let obj = row.as_obj()?;
    if !METRICS.iter().any(|m| obj.contains_key(*m)) {
        return None;
    }
    let parts: Vec<String> = obj
        .iter()
        .filter(|(k, _)| !NON_IDENTITY.contains(&k.as_str()))
        .filter_map(|(k, v)| {
            v.as_f64()
                .map(|n| format!("{k}={}", fmt_num(n)))
                .or_else(|| v.as_str().map(|s| format!("{k}={s}")))
        })
        .collect();
    if parts.is_empty() {
        None
    } else {
        Some(parts.join("/"))
    }
}

fn row_metric(row: &Json) -> Option<f64> {
    METRICS.iter().find_map(|m| row.get(m).and_then(Json::as_f64))
}

/// Parse one `BENCH_<name>.json` document into `(name, key → med ns)`.
pub fn parse_bench_doc(doc: &Json) -> Result<(String, BTreeMap<String, f64>)> {
    let name = doc
        .get("bench")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("bench doc missing \"bench\" name"))?
        .to_string();
    let rows = doc
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("bench doc {name} missing \"rows\""))?;
    let mut map = BTreeMap::new();
    for row in rows {
        if let (Some(key), Some(med)) = (row_key(row), row_metric(row)) {
            map.insert(key, med);
        }
    }
    Ok((name, map))
}

/// Scan `dir` for `BENCH_*.json` artifacts.
pub fn load_current(dir: &Path) -> Result<BenchMap> {
    let mut out = BenchMap::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry?.path();
        let Some(fname) = path.file_name().and_then(|f| f.to_str()) else { continue };
        if !(fname.starts_with("BENCH_") && fname.ends_with(".json")) {
            continue;
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let (name, map) = parse_bench_doc(&doc)?;
        out.insert(name, map);
    }
    Ok(out)
}

/// Median wall time (ns) of a fixed reference workload — one dense
/// Toeplitz apply at n = 256 — used to normalise baselines across
/// machines.  Must never change, or committed baselines lose meaning.
pub fn calibrate_ns() -> f64 {
    use crate::toeplitz::ToeplitzKernel;
    let n = 256;
    let kernel = ToeplitzKernel::from_fn(n, |lag| 1.0 / (1.0 + lag.abs() as f32));
    let x: Vec<f32> = (0..n).map(|i| ((i * 37) % 97) as f32 / 97.0 - 0.5).collect();
    let mut sink = 0.0f32;
    for _ in 0..2 {
        sink += kernel.apply_dense(&x)[0]; // warmup
    }
    // 15 samples, median: one scheduling hiccup on a noisy shared
    // runner shifts the median far less than it would a mean or a
    // small sample set — and this one number scales every gate limit.
    let mut samples = Vec::with_capacity(15);
    for _ in 0..15 {
        let t0 = std::time::Instant::now();
        sink += kernel.apply_dense(&x)[0];
        samples.push(1e9 * t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(sink);
    crate::util::bench::percentiles_of(&samples, &[0.5])[0]
}

pub fn parse_baseline(doc: &Json) -> Result<Baseline> {
    let calib_ns = doc
        .get("calib_ns")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("baseline missing calib_ns"))?;
    let threshold_pct = doc.get("threshold_pct").and_then(Json::as_f64).unwrap_or(25.0);
    let bootstrap = doc.get("bootstrap").and_then(Json::as_bool).unwrap_or(false);
    let quick = doc.get("quick").and_then(Json::as_bool);
    let mut benches = BenchMap::new();
    if let Some(bs) = doc.get("benches").and_then(Json::as_obj) {
        for (bench, rows) in bs {
            let rows = rows
                .as_obj()
                .ok_or_else(|| anyhow!("baseline bench {bench} is not an object"))?;
            let mut map = BTreeMap::new();
            for (key, v) in rows {
                let med = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("baseline {bench}/{key} is not a number"))?;
                map.insert(key.clone(), med);
            }
            benches.insert(bench.clone(), map);
        }
    }
    Ok(Baseline { calib_ns, threshold_pct, bootstrap, quick, benches })
}

pub fn baseline_to_json(b: &Baseline) -> Json {
    let benches: Vec<(String, Json)> = b
        .benches
        .iter()
        .map(|(bench, rows)| {
            let rows: Vec<(String, Json)> =
                rows.iter().map(|(k, &v)| (k.clone(), Json::num(v))).collect();
            (bench.clone(), obj_owned(rows))
        })
        .collect();
    let mut fields = vec![
        ("calib_ns", Json::num(b.calib_ns)),
        ("threshold_pct", Json::num(b.threshold_pct)),
        ("bootstrap", Json::Bool(b.bootstrap)),
    ];
    if let Some(q) = b.quick {
        fields.push(("quick", Json::Bool(q)));
    }
    fields.push(("benches", obj_owned(benches)));
    Json::obj(fields)
}

/// `Json::obj` takes `&str` keys; this is the owned-key variant.
fn obj_owned(pairs: Vec<(String, Json)>) -> Json {
    Json::obj(pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
}

/// Compare current medians against the (calibration-scaled) baseline.
pub fn compare(
    base: &Baseline,
    current: &BenchMap,
    calib_now: f64,
    threshold_override: Option<f64>,
) -> Report {
    let scale =
        if base.calib_ns > 0.0 && calib_now > 0.0 { calib_now / base.calib_ns } else { 1.0 };
    let threshold = threshold_override.unwrap_or(base.threshold_pct).max(0.0);
    let mut report = Report { scale, threshold_pct: threshold, ..Report::default() };
    for (bench, rows) in current {
        for (key, &now_ns) in rows {
            if !gated_key(key) {
                report.ungated += 1;
                continue;
            }
            match base.benches.get(bench).and_then(|b| b.get(key)) {
                None => report.new_keys += 1,
                Some(&raw_base) => {
                    report.compared += 1;
                    let base_ns = raw_base * scale;
                    let limit_ns = base_ns * (1.0 + threshold / 100.0);
                    if base_ns > 0.0 {
                        let dev = (now_ns / base_ns - 1.0).abs() * 100.0;
                        report.max_divergence_pct = report.max_divergence_pct.max(dev);
                    }
                    if now_ns > limit_ns {
                        report.regressions.push(Regression {
                            bench: bench.clone(),
                            key: key.clone(),
                            base_ns,
                            now_ns,
                            limit_ns,
                        });
                    }
                }
            }
        }
    }
    for (bench, rows) in &base.benches {
        for key in rows.keys().filter(|k| gated_key(k)) {
            if current.get(bench).map(|c| !c.contains_key(key)).unwrap_or(true) {
                report.missing.push(format!("{bench}/{key}"));
            }
        }
    }
    report
}

/// Gate decision for one comparison.  Regressions always fail; rows
/// the baseline gates but this run did not emit also fail (otherwise
/// renaming a key or shrinking the sweep silently disarms the gate)
/// unless `allow_missing`.  A `bootstrap` (model-estimated) baseline
/// is advisory — missing rows and nominal regressions don't fail — but
/// only while the measurements stay within the threshold of the
/// estimates: beyond that the estimates are demonstrably stale, and
/// the gate fails until the baseline is armed from a measured
/// candidate ([`arm_from`]).
pub fn verdict(base: &Baseline, report: &Report, allow_missing: bool) -> bool {
    if base.bootstrap {
        return report.max_divergence_pct <= report.threshold_pct;
    }
    report.regressions.is_empty() && (allow_missing || report.missing.is_empty())
}

/// File name of the measured candidate baseline that every comparison
/// run drops next to the bench artifacts, ready for [`arm_from`].
pub const ARMED_CANDIDATE: &str = "baseline_armed_candidate.json";

/// Promote a measured candidate baseline (written by a comparison run
/// as [`ARMED_CANDIDATE`]) into the committed baseline, dropping its
/// `"bootstrap": true` marker — the gate goes from advisory to armed
/// without re-running the benches.  CLI: `ski-tnn bench-check
/// --arm-from <candidate.json> --baseline bench/baseline.json`.
pub fn arm_from(candidate_path: &str, baseline_path: &str) -> Result<()> {
    let text = std::fs::read_to_string(candidate_path)
        .with_context(|| format!("reading candidate baseline {candidate_path}"))?;
    let doc = json::parse(&text).map_err(|e| anyhow!("{candidate_path}: {e}"))?;
    let mut candidate = parse_baseline(&doc)?;
    let rows: usize = candidate.benches.values().map(|b| b.len()).sum();
    if rows == 0 {
        bail!("candidate baseline {candidate_path} has no bench rows — refusing to arm");
    }
    candidate.bootstrap = false;
    if let Some(parent) = Path::new(baseline_path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(baseline_path, json::write(&baseline_to_json(&candidate)))
        .with_context(|| format!("writing {baseline_path}"))?;
    println!(
        "bench-check: armed {baseline_path} from {candidate_path} ({} benches, {rows} rows, \
         calib {:.0} ns, threshold {:.0}%) — the gate now fails on regressions",
        candidate.benches.len(),
        candidate.calib_ns,
        candidate.threshold_pct
    );
    Ok(())
}

/// Gate a telemetry stats snapshot (see [`crate::telemetry`]): the
/// file must parse and pass [`crate::telemetry::check_snapshot`] —
/// core series present, no missing or non-finite numbers.  Used by
/// `ski-tnn bench-check --stats-snapshot <path>` so CI refuses runs
/// whose observability output silently degraded.
pub fn check_stats_snapshot(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading stats snapshot {path}"))?;
    let doc = json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    crate::telemetry::check_snapshot(&doc).with_context(|| format!("stats snapshot {path}"))
}

/// CLI entry: load artifacts from `dir`, compare against (or, with
/// `update`, rewrite) the baseline at `baseline_path`.  Returns
/// whether the gate passed; prints the report either way.
pub fn run(
    baseline_path: &str,
    dir: &str,
    update: bool,
    threshold: Option<f64>,
    allow_missing: bool,
) -> Result<bool> {
    let current = load_current(Path::new(dir))?;
    if current.is_empty() {
        bail!(
            "no BENCH_*.json artifacts in {dir:?} — run the benches first \
             (e.g. `cargo bench --bench backend_matrix`)"
        );
    }
    let calib_now = calibrate_ns();
    if update {
        // Preserve a customized threshold across refreshes: explicit
        // --threshold wins, else whatever the armed baseline already
        // carried, else the 25% default.
        let prev_threshold = std::fs::read_to_string(baseline_path)
            .ok()
            .and_then(|t| json::parse(&t).ok())
            .and_then(|d| parse_baseline(&d).ok())
            .filter(|b| !b.bootstrap)
            .map(|b| b.threshold_pct);
        let baseline = Baseline {
            calib_ns: calib_now,
            threshold_pct: threshold.or(prev_threshold).unwrap_or(25.0),
            bootstrap: false,
            quick: Some(crate::util::bench::quick_mode()),
            benches: current,
        };
        if let Some(parent) = Path::new(baseline_path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(baseline_path, json::write(&baseline_to_json(&baseline)))
            .with_context(|| format!("writing {baseline_path}"))?;
        let rows: usize = baseline.benches.values().map(|b| b.len()).sum();
        println!(
            "bench-check: wrote {baseline_path} ({} benches, {rows} rows, calib {:.0} ns, \
             threshold {:.0}%)",
            baseline.benches.len(),
            baseline.calib_ns,
            baseline.threshold_pct
        );
        return Ok(true);
    }
    let text = std::fs::read_to_string(baseline_path).with_context(|| {
        format!("reading {baseline_path} (refresh with `ski-tnn bench-check --update`)")
    })?;
    let doc = json::parse(&text).map_err(|e| anyhow!("{baseline_path}: {e}"))?;
    let base = parse_baseline(&doc)?;
    let report = compare(&base, &current, calib_now, threshold);
    // Every comparison run leaves a measured candidate next to the
    // artifacts: a baseline-shaped doc still marked bootstrap (not yet
    // blessed) that `--arm-from` can promote without re-benching.
    let candidate = Baseline {
        calib_ns: calib_now,
        threshold_pct: report.threshold_pct,
        bootstrap: true,
        quick: Some(crate::util::bench::quick_mode()),
        benches: current.clone(),
    };
    let candidate_path = Path::new(dir).join(ARMED_CANDIDATE);
    std::fs::write(&candidate_path, json::write(&baseline_to_json(&candidate)))
        .with_context(|| format!("writing {}", candidate_path.display()))?;
    println!(
        "bench-check: {} medians compared (scale {:.2} = {:.0} ns now / {:.0} ns baseline), \
         {} multi-worker rows ungated, {} new, {} missing",
        report.compared,
        report.scale,
        calib_now,
        base.calib_ns,
        report.ungated,
        report.new_keys,
        report.missing.len()
    );
    for m in &report.missing {
        println!("  missing from this run: {m}");
    }
    for r in &report.regressions {
        println!(
            "  REGRESSION {}/{}: {:.0} ns vs scaled baseline {:.0} ns (limit {:.0} ns)",
            r.bench, r.key, r.now_ns, r.base_ns, r.limit_ns
        );
    }
    let passed = verdict(&base, &report, allow_missing);
    if base.bootstrap && !passed {
        println!(
            "bench-check: FAILED — baseline is BOOTSTRAP (model-estimated) but measured \
             medians diverge up to {:.0}% from the estimates (threshold {:.0}%): the \
             estimates are stale and can no longer stand in for a baseline.  Promote this \
             run's measured candidate:\n  ski-tnn bench-check --arm-from {} \
             --baseline {baseline_path}\nand commit the updated baseline.",
            report.max_divergence_pct,
            report.threshold_pct,
            candidate_path.display()
        );
    } else if base.bootstrap {
        println!(
            "bench-check: baseline is BOOTSTRAP (model-estimated) — advisory only \
             (max divergence {:.0}% within threshold {:.0}%); arm the gate with \
             `ski-tnn bench-check --arm-from {}`",
            report.max_divergence_pct,
            report.threshold_pct,
            candidate_path.display()
        );
    } else if passed {
        println!("bench-check: OK");
    } else if report.regressions.is_empty() {
        println!(
            "bench-check: FAILED — {} gated rows missing from this run (refresh the baseline \
             with --update, or pass --allow-missing)",
            report.missing.len()
        );
        if let Some(q) = base.quick {
            if q != crate::util::bench::quick_mode() {
                println!(
                    "  hint: the baseline was recorded with SKI_TNN_BENCH_QUICK={} but this \
                     run used SKI_TNN_BENCH_QUICK={} — quick and full mode emit different \
                     row sets",
                    if q { "1" } else { "0" },
                    if crate::util::bench::quick_mode() { "1" } else { "0" }
                );
            }
        }
    }
    Ok(passed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(n: usize, backend: &str, med: f64) -> Json {
        Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("backend", Json::str(backend)),
            ("med_ns", Json::num(med)),
            ("p90_ns", Json::num(med * 1.2)),
            ("winner", Json::str("fft")),
        ])
    }

    fn doc(rows: Vec<Json>) -> Json {
        Json::obj(vec![("bench", Json::str("t")), ("rows", Json::arr(rows))])
    }

    #[test]
    fn keys_drop_measurements_and_observations() {
        let (name, map) = parse_bench_doc(&doc(vec![row(256, "fft", 10.0)])).unwrap();
        assert_eq!(name, "t");
        assert_eq!(map.len(), 1);
        // BTreeMap field order: backend before n; winner/p90 excluded.
        assert_eq!(map.get("backend=fft/n=256"), Some(&10.0));
    }

    fn base_of(benches: BenchMap) -> Baseline {
        Baseline { calib_ns: 100.0, threshold_pct: 25.0, bootstrap: false, quick: None, benches }
    }

    #[test]
    fn compare_scales_by_calibration() {
        let (_, cur_rows) = parse_bench_doc(&doc(vec![row(256, "fft", 2000.0)])).unwrap();
        let mut current = BenchMap::new();
        current.insert("t".into(), cur_rows);
        let mut benches = BenchMap::new();
        benches.insert("t".into(), [("backend=fft/n=256".to_string(), 1000.0)].into());
        let base = base_of(benches);
        // Current machine is 2× slower: 2000 ns vs scaled base 2000 — pass.
        let r = compare(&base, &current, 200.0, None);
        assert_eq!(r.compared, 1);
        assert!(r.regressions.is_empty(), "{:?}", r.regressions);
        // Same machine speed: 2000 vs limit 1250 — regression.
        let r = compare(&base, &current, 100.0, None);
        assert_eq!(r.regressions.len(), 1);
        assert_eq!(r.regressions[0].key, "backend=fft/n=256");
        // Generous override threshold rescues it.
        let r = compare(&base, &current, 100.0, Some(150.0));
        assert!(r.regressions.is_empty());
    }

    #[test]
    fn missing_gated_rows_fail_the_verdict() {
        // Renaming a key or shrinking the sweep must not silently
        // disarm the gate: new keys are fine, missing ones fail.
        let (_, cur_rows) = parse_bench_doc(&doc(vec![row(512, "ski", 50.0)])).unwrap();
        let mut current = BenchMap::new();
        current.insert("t".into(), cur_rows);
        let mut benches = BenchMap::new();
        benches.insert("t".into(), [("backend=fft/n=256".to_string(), 1000.0)].into());
        let base = base_of(benches);
        let r = compare(&base, &current, 100.0, None);
        assert_eq!(r.compared, 0);
        assert_eq!(r.new_keys, 1);
        assert_eq!(r.missing, vec!["t/backend=fft/n=256".to_string()]);
        assert!(r.regressions.is_empty());
        assert!(!verdict(&base, &r, false), "missing gated rows must fail");
        assert!(verdict(&base, &r, true), "--allow-missing overrides");
        let bootstrap = Baseline { bootstrap: true, ..base };
        assert!(verdict(&bootstrap, &r, false), "bootstrap baseline is advisory");
    }

    #[test]
    fn regressions_fail_even_with_allow_missing() {
        let (_, cur_rows) = parse_bench_doc(&doc(vec![row(256, "fft", 5000.0)])).unwrap();
        let mut current = BenchMap::new();
        current.insert("t".into(), cur_rows);
        let mut benches = BenchMap::new();
        benches.insert("t".into(), [("backend=fft/n=256".to_string(), 1000.0)].into());
        let base = base_of(benches);
        let r = compare(&base, &current, 100.0, None);
        assert_eq!(r.regressions.len(), 1);
        assert!(!verdict(&base, &r, true));
    }

    #[test]
    fn baseline_roundtrips_through_json() {
        let mut benches = BenchMap::new();
        benches.insert(
            "backend_matrix".into(),
            [("backend=fft/n=256".to_string(), 123.5)].into(),
        );
        let b = Baseline {
            calib_ns: 6.5e4,
            threshold_pct: 25.0,
            bootstrap: true,
            quick: Some(true),
            benches,
        };
        let text = json::write(&baseline_to_json(&b));
        let parsed = parse_baseline(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed.calib_ns, b.calib_ns);
        assert_eq!(parsed.threshold_pct, b.threshold_pct);
        assert_eq!(parsed.bootstrap, b.bootstrap);
        assert_eq!(parsed.quick, b.quick);
        assert_eq!(parsed.benches, b.benches);
    }

    #[test]
    fn multi_worker_rows_are_never_gated() {
        assert!(gated_key("backend=fft/n=256/r=16/w=9"));
        assert!(gated_key("backend=fft/batch=8/n=1024/r=64/threads=1/w=9"));
        assert!(!gated_key("backend=fft/batch=8/n=1024/r=64/threads=4/w=9"));
        // A threads=4 regression must be reported in `ungated`, not
        // failed, and a missing threads=4 baseline row must not fail.
        let key1 = "backend=fft/batch=8/n=1024/r=64/threads=1/w=9".to_string();
        let key4 = "backend=fft/batch=8/n=1024/r=64/threads=4/w=9".to_string();
        let mut benches = BenchMap::new();
        benches.insert("t".into(), [(key1.clone(), 100.0), (key4.clone(), 30.0)].into());
        let base = base_of(benches);
        let mut current = BenchMap::new();
        current.insert("t".into(), [(key1, 100.0), (key4, 90.0)].into());
        let r = compare(&base, &current, 100.0, None);
        assert_eq!(r.compared, 1);
        assert_eq!(r.ungated, 1);
        assert!(r.regressions.is_empty() && r.missing.is_empty());
        assert!(verdict(&base, &r, false));
    }

    #[test]
    fn bootstrap_baseline_fails_once_measurements_diverge() {
        // Advisory only while measurements track the model estimates:
        // a 3× divergence proves the estimates stale, and the gate
        // must fail until the baseline is armed from a measured run.
        let (_, cur_rows) = parse_bench_doc(&doc(vec![row(256, "fft", 3000.0)])).unwrap();
        let mut current = BenchMap::new();
        current.insert("t".into(), cur_rows);
        let mut benches = BenchMap::new();
        benches.insert("t".into(), [("backend=fft/n=256".to_string(), 1000.0)].into());
        let base = Baseline { bootstrap: true, ..base_of(benches) };
        let r = compare(&base, &current, 100.0, None);
        assert!(r.max_divergence_pct > 100.0, "divergence {}", r.max_divergence_pct);
        assert!(!verdict(&base, &r, false), "stale bootstrap estimates must fail");
        // Divergence below the threshold (or faster-than-estimate
        // within it) keeps the bootstrap baseline advisory.
        let (_, ok_rows) = parse_bench_doc(&doc(vec![row(256, "fft", 1100.0)])).unwrap();
        let mut ok = BenchMap::new();
        ok.insert("t".into(), ok_rows);
        let r = compare(&base, &ok, 100.0, None);
        assert!(verdict(&base, &r, false));
        // A large *speedup* also counts as divergence: the estimate is
        // equally wrong in that direction.
        let (_, fast_rows) = parse_bench_doc(&doc(vec![row(256, "fft", 100.0)])).unwrap();
        let mut fast = BenchMap::new();
        fast.insert("t".into(), fast_rows);
        let r = compare(&base, &fast, 100.0, None);
        assert!(!verdict(&base, &r, false));
    }

    #[test]
    fn arm_from_promotes_a_candidate_and_drops_bootstrap() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let cand = dir.join(format!("ski_tnn_arm_cand_{pid}.json"));
        let dest = dir.join(format!("ski_tnn_arm_base_{pid}.json"));
        let mut benches = BenchMap::new();
        benches.insert(
            "backend_matrix".into(),
            [("backend=fft/n=256/threads=1".to_string(), 421.0)].into(),
        );
        let candidate = Baseline {
            calib_ns: 5.0e4,
            threshold_pct: 25.0,
            bootstrap: true,
            quick: Some(true),
            benches,
        };
        std::fs::write(&cand, json::write(&baseline_to_json(&candidate))).unwrap();
        arm_from(cand.to_str().unwrap(), dest.to_str().unwrap()).unwrap();
        let armed =
            parse_baseline(&json::parse(&std::fs::read_to_string(&dest).unwrap()).unwrap())
                .unwrap();
        assert!(!armed.bootstrap, "arming must drop the bootstrap marker");
        assert_eq!(armed.calib_ns, candidate.calib_ns);
        assert_eq!(armed.benches, candidate.benches);
        // An empty candidate must be refused — arming it would commit
        // a baseline that gates nothing.
        let empty = dir.join(format!("ski_tnn_arm_empty_{pid}.json"));
        let none = Baseline {
            calib_ns: 1.0,
            threshold_pct: 25.0,
            bootstrap: true,
            quick: None,
            benches: BenchMap::new(),
        };
        std::fs::write(&empty, json::write(&baseline_to_json(&none))).unwrap();
        assert!(arm_from(empty.to_str().unwrap(), dest.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(&cand);
        let _ = std::fs::remove_file(&dest);
        let _ = std::fs::remove_file(&empty);
    }

    #[test]
    fn calibration_is_positive_and_stable_order() {
        let a = calibrate_ns();
        assert!(a > 0.0 && a.is_finite());
    }

    #[test]
    fn stats_snapshot_gate_refuses_incomplete_files() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let bad = dir.join(format!("ski_tnn_gate_bad_{pid}.json"));
        std::fs::write(&bad, "{\"version\": 1}").unwrap();
        let err = check_stats_snapshot(bad.to_str().unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("stats snapshot"), "{err:#}");

        // A snapshot with the core series — span histogram, pool
        // gauge, audit rows, plan-cache counters/gauges — passes.
        let reg = crate::telemetry::Registry::default();
        reg.histogram("span.queue_wait").record(1_000);
        reg.gauge("pool.workers").set(2.0);
        reg.counter("plan.cache.miss").add(1);
        reg.counter("plan.cache.hit").add(3);
        reg.gauge("plan.cache.size").set(1.0);
        reg.gauge("plan.cache.bytes").set(2048.0);
        reg.counter("server.admission.admitted").add(4);
        reg.gauge("server.pressure").set(0.1);
        let audit = crate::telemetry::DispatchAudit::new();
        audit.record(crate::telemetry::AuditRow {
            n: 64,
            r: 8,
            w: 9,
            causal: false,
            threads: 1,
            rows: 4,
            backend: "fft",
            predicted_ns: 1000.0,
            measured_ns: 1200.0,
            pressure: 0.0,
            downshifted: false,
        });
        let good = dir.join(format!("ski_tnn_gate_good_{pid}.json"));
        std::fs::write(&good, json::write(&crate::telemetry::snapshot_json(&reg, &audit)))
            .unwrap();
        check_stats_snapshot(good.to_str().unwrap()).unwrap();
        let _ = std::fs::remove_file(&bad);
        let _ = std::fs::remove_file(&good);
    }
}
