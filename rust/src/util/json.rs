//! Minimal JSON parser / writer.
//!
//! The offline crate registry only carries the `xla` closure, so the
//! artifact manifest (`artifacts/manifest.json`) and all metrics output
//! are handled by this hand-rolled implementation: a recursive-descent
//! parser over the full JSON grammar (strings with escapes, numbers,
//! bools, null, arrays, objects) plus a compact writer.  Object key
//! order is preserved (insertion order) so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps deterministic ordering for writing & comparison.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Builder helper: JSON object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Parse error with byte offset for debugging manifests.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { msg: msg.into(), offset: self.pos })
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        self.skip_ws();
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit(b"true", Json::Bool(true)),
            Some(b'f') => self.lit(b"false", Json::Bool(false)),
            Some(b'n') => self.lit(b"null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, pat: &[u8], val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(pat) {
            self.pos += pat.len();
            Ok(val)
        } else {
            self.err("bad literal")
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number {s:?}")),
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("eof in \\u escape"),
            };
            let d = (c as char).to_digit(16);
            match d {
                Some(d) => v = v * 16 + d,
                None => return self.err("bad hex digit"),
            }
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("eof in string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut cp = self.hex4()?;
                        // surrogate pair
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("unpaired surrogate");
                            }
                            let lo = self.hex4()?;
                            cp = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                        }
                        match char::from_u32(cp) {
                            Some(c) => out.push(c),
                            None => return self.err("bad codepoint"),
                        }
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        match std::str::from_utf8(&self.b[start..self.pos]) {
                            Ok(s) => out.push_str(s),
                            Err(_) => return self.err("bad utf8"),
                        }
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return self.err("expected , or }"),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

/// Parse a complete JSON document.
pub fn parse(s: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Serialize compactly (no whitespace).
pub fn write(v: &Json) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(v, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null},"e":"q\"w"}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }
}
