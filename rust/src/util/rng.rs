//! Deterministic PRNG substrate (splitmix64 + xoshiro256**).
//!
//! `rand` is not available offline, so data generation, initialization
//! fallbacks and the property-test driver share this implementation.
//! Streams are seeded explicitly everywhere (no global state) so every
//! dataset split, benchmark workload and test case is reproducible.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-worker / per-split rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 40_000;
        let xs: Vec<f32> = r.normals(n);
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 0.0, 9.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
