//! Mini benchmark harness (criterion is not resolvable offline).
//!
//! Provides warmup + timed iterations with mean / p50 / p95 / stddev
//! reporting, wall-clock budgets for expensive end-to-end benches, and
//! a tabular reporter used by every `rust/benches/*` target to print
//! the paper's tables/figures as aligned rows.

use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

/// Statistics over per-iteration wall-clock samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
    pub total_s: f64,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Benchmark runner with per-measurement budgets.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 50,
            budget: Duration::from_secs(10),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            budget: Duration::from_secs(5),
        }
    }

    /// Run `f` repeatedly and collect timing statistics.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < self.min_iters || start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        stats_of(&samples)
    }
}

pub fn stats_of(samples: &[f64]) -> Stats {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len().max(1);
    let total: f64 = sorted.iter().sum();
    let mean = total / n as f64;
    let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    let pct = |p: f64| sorted[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Stats {
        iters: sorted.len(),
        mean_s: mean,
        p50_s: if sorted.is_empty() { 0.0 } else { pct(0.50) },
        p90_s: if sorted.is_empty() { 0.0 } else { pct(0.90) },
        p95_s: if sorted.is_empty() { 0.0 } else { pct(0.95) },
        std_s: var.sqrt(),
        total_s: total,
    }
}

/// Fixed-width table reporter: prints rows that mirror the paper's
/// tables so bench output can be pasted into EXPERIMENTS.md directly.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Percentile of an ascending-sorted sample set (nearest-rank on the
/// inclusive scale; 0.0 for an empty set).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize)
        .min(sorted.len() - 1);
    sorted[idx]
}

/// Percentiles of an unsorted sample set: sorts one copy, then reads
/// every requested point (shared by the server-side latency reports).
pub fn percentiles_of(samples: &[f64], ps: &[f64]) -> Vec<f64> {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    ps.iter().map(|&p| percentile(&sorted, p)).collect()
}

/// Append to a bounded sample window: grows to `cap`, then overwrites
/// in arrival order so the window always holds the most recent `cap`
/// samples.  Keeps long-lived servers' latency accounting O(1) in
/// request count; `seen` is the total ever recorded.
pub fn push_sample(samples: &mut Vec<f64>, cap: usize, seen: usize, v: f64) {
    if samples.len() < cap {
        samples.push(v);
    } else {
        samples[seen % cap] = v;
    }
}

/// Write a machine-readable `BENCH_<name>.json` artifact in the
/// current directory (`{"bench": name, "rows": [...]}`), so the perf
/// trajectory is tracked across PRs instead of living only in table
/// stdout.  Returns the path written.
pub fn write_bench_json(name: &str, rows: Vec<Json>) -> std::io::Result<String> {
    let path = format!("BENCH_{name}.json");
    let doc = Json::obj(vec![("bench", Json::str(name)), ("rows", Json::arr(rows))]);
    std::fs::write(&path, json::write(&doc))?;
    Ok(path)
}

/// True when `SKI_TNN_BENCH_QUICK=1`: bench harnesses shrink their
/// sizes/iterations so CI's `bench-smoke` job finishes in seconds.
/// `bench/baseline.json` is recorded in this mode — refresh it with
/// the same flag set (`ski-tnn bench-check --update`).
pub fn quick_mode() -> bool {
    std::env::var("SKI_TNN_BENCH_QUICK").map(|v| v.trim() == "1").unwrap_or(false)
}

/// Format seconds human-readably (ms below 1s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = stats_of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert!((s.mean_s - 3.0).abs() < 1e-12);
        assert!((s.p50_s - 3.0).abs() < 1e-12);
        assert!(s.p90_s >= s.p50_s && s.p95_s >= s.p90_s);
        assert!(s.p95_s >= 4.0);
    }

    #[test]
    fn bench_json_roundtrips() {
        let rows = vec![Json::obj(vec![("n", Json::num(256.0)), ("med_ns", Json::num(12.5))])];
        let path = write_bench_json("unit_test_tmp", rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("bench").and_then(Json::as_str), Some("unit_test_tmp"));
        let row = v.get("rows").and_then(|r| r.idx(0)).unwrap();
        assert_eq!(row.get("n").and_then(Json::as_usize), Some(256));
    }

    #[test]
    fn bencher_runs_min_iters() {
        let b = Bencher { warmup_iters: 0, min_iters: 4, max_iters: 8, budget: Duration::ZERO };
        let mut count = 0;
        let s = b.run(|| count += 1);
        assert!(s.iters >= 4);
        assert_eq!(count, s.iters);
    }

    #[test]
    fn percentiles_of_unsorted() {
        let ps = percentiles_of(&[5.0, 1.0, 3.0, 2.0, 4.0], &[0.0, 0.5, 1.0]);
        assert_eq!(ps, vec![1.0, 3.0, 5.0]);
        assert_eq!(percentiles_of(&[], &[0.5]), vec![0.0]);
    }

    #[test]
    fn push_sample_caps_and_wraps() {
        let mut v = Vec::new();
        for i in 0..10 {
            push_sample(&mut v, 4, i, i as f64);
        }
        assert_eq!(v.len(), 4, "window must stay at cap");
        // Most recent 4 samples survive (ring order, not sorted).
        let mut got = v.clone();
        got.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(got, vec![6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn table_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // should not panic
    }
}
