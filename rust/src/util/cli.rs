//! Tiny CLI argument parser (clap is not resolvable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed accessors and a generated usage
//! string.  Used by the `ski-tnn` binary, the examples and the bench
//! harnesses.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (post-argv0).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I, expect_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        if expect_subcommand {
            if let Some(first) = iter.peek() {
                if !first.starts_with('-') {
                    out.subcommand = iter.next();
                }
            }
        }
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(body.to_string(), iter.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse(expect_subcommand: bool) -> Args {
        Args::parse_from(std::env::args().skip(1), expect_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str], sub: bool) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()), sub)
    }

    #[test]
    fn subcommand_and_options() {
        let a = args(&["train", "--config", "lm_fd_3l", "--steps=100", "--verbose"], true);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("lm_fd_3l"));
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn positional() {
        let a = args(&["eval", "ckpt.bin", "--n", "64"], true);
        assert_eq!(a.positional, vec!["ckpt.bin"]);
        assert_eq!(a.usize_or("n", 0), 64);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args(&["--quick", "--deep"], false);
        assert!(a.flag("quick") && a.flag("deep"));
        assert!(a.subcommand.is_none());
    }

    #[test]
    fn list_parsing() {
        let a = args(&["--variants", "tnn, fd ,ski"], false);
        assert_eq!(a.list_or("variants", &[]), vec!["tnn", "fd", "ski"]);
        assert_eq!(a.list_or("missing", &["x"]), vec!["x"]);
    }
}
