//! Offline substrate utilities.
//!
//! The offline cargo registry carries only the `xla` crate closure and
//! `anyhow`, so the usual ecosystem crates are hand-rolled here and
//! tested like any other module:
//!
//! * [`json`]  — full-grammar JSON parser/writer (serde stand-in) for
//!   the artifact manifest and metrics output.
//! * [`rng`]   — splittable xoshiro-style PRNG (rand stand-in) used by
//!   every data generator and property test; fully deterministic.
//! * [`cli`]   — flag/option argument parser (clap stand-in).
//! * [`bench`] — warmup+iters micro-benchmark harness with mean/p50/p95
//!   stats and aligned-table output (criterion stand-in).
//! * [`benchcheck`] — offline perf-regression gate comparing
//!   `BENCH_*.json` artifacts against `bench/baseline.json`
//!   (calibration-scaled; the `ski-tnn bench-check` subcommand).
//! * [`prop`]  — property-test driver: seeded case generation, failure
//!   reporting with the reproducing seed (proptest stand-in).

pub mod bench;
pub mod benchcheck;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
