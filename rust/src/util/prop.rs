//! Property-test driver (proptest is not resolvable offline).
//!
//! A small randomized-testing harness: generate `CASES` random inputs
//! from explicit generators, run the property, and on failure report
//! the failing seed so the case is exactly reproducible with
//! `PROP_SEED=<n> cargo test`.  No shrinking — generators are kept
//! small-biased instead (sizes drawn log-uniformly) which in practice
//! yields near-minimal counterexamples for the invariants we check
//! (FFT round-trips, Toeplitz algebra, SKI error bounds, batcher
//! invariants).

use super::rng::Rng;

/// Number of random cases per property (override with PROP_CASES).
pub fn cases() -> usize {
    std::env::var("PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(32)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0xC0FFEE)
}

/// Run `prop` over `cases()` randomized cases. The closure receives a
/// per-case RNG; panic (assert) inside to fail. The failing case's seed
/// is printed before the panic propagates.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, prop: F) {
    let n = cases();
    for case in 0..n {
        let seed = base_seed().wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed on case {case}/{n} (PROP_SEED={seed} reproduces)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Log-uniform size in [lo, hi] — biases towards small structures.
pub fn size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    debug_assert!(lo >= 1 && hi >= lo);
    let (llo, lhi) = ((lo as f64).ln(), (hi as f64 + 1.0).ln());
    ((llo + rng.f64() * (lhi - llo)).exp() as usize).clamp(lo, hi)
}

/// Random f32 vector with entries ~ N(0, 1).
pub fn vecf(rng: &mut Rng, n: usize) -> Vec<f32> {
    rng.normals(n)
}

/// Assert element-wise closeness with a combined abs/rel tolerance.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0_f32.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}: mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..200 {
            let s = size(&mut r, 2, 64);
            assert!((2..=64).contains(&s));
        }
    }

    #[test]
    fn check_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        check("count", |_rng| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), cases());
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fail", |rng| {
            assert!(rng.f32() < 2.0); // always true...
            panic!("boom");
        });
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, "eq");
    }
}
