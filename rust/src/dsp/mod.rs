//! Signal-processing substrate: complex FFT, real FFT, Hilbert transform.
//!
//! A from-scratch iterative radix-2 Cooley–Tukey FFT (no external
//! crates are resolvable offline).  This powers the pure-Rust Toeplitz
//! oracle (`crate::toeplitz`), the decay-analysis example (paper Figs
//! 4–6) and the property tests that cross-check the AOT'd HLO numerics.

mod fft;
mod hilbert;

pub use fft::{fft, ifft, irfft, rfft, Complex};
pub use hilbert::{analytic_window, causal_spectrum, hilbert_of_real};
