//! Signal-processing substrate: complex FFT, real FFT, Hilbert transform.
//!
//! A from-scratch planned FFT engine (no external crates are
//! resolvable offline): iterative radix-2 for powers of two, factored
//! mixed-radix Cooley–Tukey for smooth composites, Bluestein for big
//! primes — any length `n ≥ 1`, behind a per-process plan cache
//! ([`FftPlan`]).  This powers the pure-Rust Toeplitz oracle
//! (`crate::toeplitz`), the decay-analysis example (paper Figs 4–6)
//! and the property tests that cross-check the AOT'd HLO numerics.

mod fft;
mod hilbert;

pub use fft::{
    fft, fft_work_units, good_conv_size, ifft, irfft, plan_cache_stats, rfft, rfft_work_units,
    Complex, FftPlan, RealFftPlan, FFT_PLAN_CACHE_CAP,
};
pub use hilbert::{analytic_window, causal_spectrum, hilbert_of_real};
