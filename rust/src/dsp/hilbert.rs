//! Discrete Hilbert transform and causal-spectrum construction.
//!
//! The frequency-domain causality machinery of FD-TNO (paper §3.3.1,
//! Algorithm 2), mirrored in Rust so the substrate tests can verify the
//! AOT'd HLO numerics and so the decay-analysis example runs without
//! Python: given real (even) frequency-response samples on the rFFT
//! grid `ω_m = mπ/n`, produce the causal spectrum `k̂ - i·H{k̂}` whose
//! inverse transform is supported on `t ∈ [0, n]`.

use super::fft::{irfft, rfft, Complex};

/// The one-sided "analytic" window over the 2n-point time axis:
/// `[1, 2, …, 2, 1, 0, …, 0]` — keeps t = 0 and t = n once, doubles
/// strictly-positive lags, zeroes the negative-lag half.
pub fn analytic_window(n: usize) -> Vec<f32> {
    let mut w = vec![0.0f32; 2 * n];
    w[0] = 1.0;
    for v in w.iter_mut().take(n).skip(1) {
        *v = 2.0;
    }
    w[n] = 1.0;
    w
}

/// Causal spectrum from real (even) response samples.
///
/// `khat_r` holds n+1 real samples at `ω_m = mπ/n`; the result is the
/// complex causal spectrum (n+1 bins), real part equal to the input
/// and imaginary part `-H{k̂}`.
pub fn causal_spectrum(khat_r: &[f32]) -> Vec<Complex> {
    let n = khat_r.len() - 1;
    assert!(n >= 1, "causal spectrum needs at least 2 response samples");
    // Real even response ⇒ real even time kernel.  Any grid size works
    // (the 2n-point transforms run on the mixed-radix/Bluestein
    // engine), not just powers of two.
    let spec: Vec<Complex> = khat_r.iter().map(|&v| Complex::new(v as f64, 0.0)).collect();
    let kt = irfft(&spec, 2 * n);
    let w = analytic_window(n);
    let kc: Vec<f32> = kt.iter().zip(w.iter()).map(|(a, b)| a * b).collect();
    rfft(&kc)
}

/// Discrete Hilbert transform of real (even) frequency samples:
/// returns `H{k̂}` on the same n+1 grid (the negated imaginary part of
/// `causal_spectrum`).
pub fn hilbert_of_real(khat_r: &[f32]) -> Vec<f32> {
    causal_spectrum(khat_r).iter().map(|c| -c.im as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, size, vecf};

    #[test]
    fn window_shape() {
        let w = analytic_window(4);
        assert_eq!(w, vec![1.0, 2.0, 2.0, 2.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn prop_causal_spectrum_is_causal() {
        // Any grid size, not just powers of two: the construction is
        // grid-agnostic now that the FFT engine is.
        check("causal spectrum causality", |rng| {
            let n = size(rng, 4, 700);
            let khat = vecf(rng, n + 1);
            let spec = causal_spectrum(&khat);
            let kt = irfft(&spec, 2 * n);
            let peak = kt.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1e-3);
            for (t, v) in kt.iter().enumerate().skip(n + 1) {
                assert!(
                    v.abs() < 1e-4 * peak.max(1.0),
                    "acausal energy at t={t}: {v} (peak {peak})"
                );
            }
        });
    }

    #[test]
    fn prop_real_part_preserved() {
        check("causal spectrum keeps real part", |rng| {
            let n = size(rng, 4, 700);
            let khat = vecf(rng, n + 1);
            let spec = causal_spectrum(&khat);
            for (a, c) in khat.iter().zip(spec.iter()) {
                assert!((*a as f64 - c.re).abs() < 1e-4, "{a} vs {}", c.re);
            }
        });
    }

    #[test]
    fn causal_spectrum_exact_on_awkward_grids() {
        // The geometric minimum-phase reference at non-power-of-two
        // grid sizes (smooth composite and prime): the Hilbert
        // construction must recover the analytic spectrum on any grid.
        let a = 0.5f64;
        for n in [96usize, 360, 769, 1000] {
            let re: Vec<f32> = (0..=n)
                .map(|m| {
                    let w = std::f64::consts::PI * m as f64 / n as f64;
                    let den = 1.0 - 2.0 * a * w.cos() + a * a;
                    ((1.0 - a * w.cos()) / den) as f32
                })
                .collect();
            let spec = causal_spectrum(&re);
            for (m, c) in spec.iter().enumerate() {
                let w = std::f64::consts::PI * m as f64 / n as f64;
                let den = 1.0 - 2.0 * a * w.cos() + a * a;
                let want_re = (1.0 - a * w.cos()) / den;
                let want_im = -a * w.sin() / den;
                assert!((c.re - want_re).abs() < 1e-4, "n={n} bin {m}: re {} vs {want_re}", c.re);
                assert!((c.im - want_im).abs() < 1e-4, "n={n} bin {m}: im {} vs {want_im}", c.im);
            }
        }
    }

    #[test]
    fn causal_spectrum_matches_geometric_minimum_phase_reference() {
        // Analytic minimum-phase reference: the causal kernel
        // k[t] = a^t (t ≥ 0) has DTFT 1/(1 - a e^{-iω}) with
        //   Re = (1 - a cos ω)/den,  Im = -a sin ω/den,
        //   den = 1 - 2a cos ω + a².
        // Feeding only the real part through the Hilbert construction
        // must recover the full complex spectrum (round-trip), up to
        // the a^n truncation tail (≈ 1e-77 at a = 0.5, n = 256).
        let n = 256usize;
        let a = 0.5f64;
        let re: Vec<f32> = (0..=n)
            .map(|m| {
                let w = std::f64::consts::PI * m as f64 / n as f64;
                let den = 1.0 - 2.0 * a * w.cos() + a * a;
                ((1.0 - a * w.cos()) / den) as f32
            })
            .collect();
        let spec = causal_spectrum(&re);
        for (m, c) in spec.iter().enumerate() {
            let w = std::f64::consts::PI * m as f64 / n as f64;
            let den = 1.0 - 2.0 * a * w.cos() + a * a;
            let want_re = (1.0 - a * w.cos()) / den;
            let want_im = -a * w.sin() / den;
            assert!((c.re - want_re).abs() < 1e-4, "bin {m}: re {} vs {want_re}", c.re);
            assert!((c.im - want_im).abs() < 1e-4, "bin {m}: im {} vs {want_im}", c.im);
        }
        // And the recovered time kernel is the geometric sequence.
        let kt = irfft(&spec, 2 * n);
        for (t, v) in kt.iter().enumerate().take(12) {
            let want = a.powi(t as i32) as f32;
            assert!((v - want).abs() < 1e-4, "tap {t}: {v} vs {want}");
        }
    }

    #[test]
    fn hilbert_of_cosine_is_sine() {
        // k̂(ω) = cos(ω) on the grid ⇒ time kernel is a unit lag-1 impulse
        // pair; its causal one-siding gives spectrum e^{-iω} whose
        // imaginary part is -sin(ω) ⇒ H{cos} = sin.
        let n = 64usize;
        let khat: Vec<f32> =
            (0..=n).map(|m| (std::f64::consts::PI * m as f64 / n as f64).cos() as f32).collect();
        let h = hilbert_of_real(&khat);
        for (m, v) in h.iter().enumerate() {
            let want = (std::f64::consts::PI * m as f64 / n as f64).sin() as f32;
            assert!((v - want).abs() < 1e-4, "bin {m}: {v} vs {want}");
        }
    }
}
