//! Iterative radix-2 Cooley–Tukey FFT with rFFT/irFFT wrappers.
//!
//! Sizes must be powers of two — every transform in this system runs on
//! the `2n` circulant embedding of a power-of-two sequence length, so
//! this is not a practical restriction (asserted at call sites).
//! Twiddles are computed per stage with a recurrence seeded from
//! `sin`/`cos` per block, which keeps the implementation allocation-free
//! beyond the in-place buffer and accurate to ~1e-6 relative for the
//! n ≤ 2²⁰ range the benches touch.

/// Minimal complex number (no external num crate offline).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    pub fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }

    pub fn mul(self, o: Complex) -> Self {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }

    pub fn add(self, o: Complex) -> Self {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }

    pub fn sub(self, o: Complex) -> Self {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }

    pub fn scale(self, s: f64) -> Self {
        Complex { re: self.re * s, im: self.im * s }
    }

    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

fn bit_reverse_permute(buf: &mut [Complex]) {
    let n = buf.len();
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            buf.swap(i, j);
        }
    }
}

/// In-place forward FFT (sign -1 convention: X[k] = Σ x[t] e^{-2πikt/n}).
pub fn fft(buf: &mut [Complex]) {
    fft_dir(buf, false);
}

/// In-place inverse FFT, including the 1/n normalisation.
pub fn ifft(buf: &mut [Complex]) {
    fft_dir(buf, true);
    let n = buf.len() as f64;
    for v in buf.iter_mut() {
        *v = v.scale(1.0 / n);
    }
}

fn fft_dir(buf: &mut [Complex], inverse: bool) {
    let n = buf.len();
    assert!(n.is_power_of_two(), "fft size {n} must be a power of two");
    if n <= 1 {
        return;
    }
    bit_reverse_permute(buf);
    // §Perf iteration 1 (EXPERIMENTS.md): per-stage twiddles via the
    // w·wlen recurrence cost a complex multiply per butterfly *and*
    // accumulate rounding over long stages.  A cached half-size table
    // of exact twiddles (stride-indexed per stage) removes both: ~1.6×
    // on the n=4096 apply_fft microbench, and tail accuracy improves.
    TWIDDLES.with(|cell| {
        let mut cache = cell.borrow_mut();
        if cache.len() < n / 2 || cache.capacity_for != n {
            cache.fill_for(n);
        }
        let tw = &cache.fwd;
        let mut len = 2;
        while len <= n {
            let stride = n / len;
            let mut i = 0;
            while i < n {
                for j in 0..len / 2 {
                    let mut w = tw[j * stride];
                    if inverse {
                        w = w.conj();
                    }
                    let u = buf[i + j];
                    let v = buf[i + j + len / 2].mul(w);
                    buf[i + j] = u.add(v);
                    buf[i + j + len / 2] = u.sub(v);
                }
                i += len;
            }
            len <<= 1;
        }
    });
}

/// Thread-local forward-twiddle cache: `fwd[k] = e^{-2πik/n}` for
/// `k < n/2`, rebuilt only when a larger (or different) `n` appears.
struct TwiddleCache {
    fwd: Vec<Complex>,
    capacity_for: usize,
}

impl TwiddleCache {
    fn len(&self) -> usize {
        self.fwd.len()
    }

    fn fill_for(&mut self, n: usize) {
        self.fwd = (0..n / 2)
            .map(|k| {
                let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
                Complex::new(ang.cos(), ang.sin())
            })
            .collect();
        self.capacity_for = n;
    }
}

thread_local! {
    static TWIDDLES: std::cell::RefCell<TwiddleCache> =
        std::cell::RefCell::new(TwiddleCache { fwd: Vec::new(), capacity_for: 0 });
}

/// Real-input FFT: returns the n/2+1 non-redundant bins.
pub fn rfft(x: &[f32]) -> Vec<Complex> {
    let n = x.len();
    assert!(
        n.is_power_of_two(),
        "rfft size {n} is not a power of two — pad the signal to {} first",
        n.next_power_of_two()
    );
    let mut buf: Vec<Complex> =
        x.iter().map(|&v| Complex::new(v as f64, 0.0)).collect();
    fft(&mut buf);
    buf.truncate(n / 2 + 1);
    buf
}

/// Inverse of `rfft`: reconstructs the length-n real signal from the
/// n/2+1 spectrum bins (Hermitian symmetry implied).
pub fn irfft(spec: &[Complex], n: usize) -> Vec<f32> {
    assert!(
        n.is_power_of_two(),
        "irfft size {n} is not a power of two — pad the signal to {} first",
        n.next_power_of_two()
    );
    assert_eq!(spec.len(), n / 2 + 1, "irfft: spectrum/size mismatch");
    let mut buf = vec![Complex::ZERO; n];
    buf[..spec.len()].copy_from_slice(spec);
    for k in 1..n / 2 {
        buf[n - k] = spec[k].conj();
    }
    ifft(&mut buf);
    buf.iter().map(|c| c.re as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check, size, vecf};

    fn dft_naive(x: &[Complex]) -> Vec<Complex> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut acc = Complex::ZERO;
                for (t, v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                    acc = acc.add(v.mul(Complex::new(ang.cos(), ang.sin())));
                }
                acc
            })
            .collect()
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = crate::util::rng::Rng::new(10);
        for &n in &[2usize, 4, 8, 16, 64] {
            let x: Vec<Complex> = (0..n)
                .map(|_| Complex::new(rng.normal() as f64, rng.normal() as f64))
                .collect();
            let mut got = x.clone();
            fft(&mut got);
            let want = dft_naive(&x);
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g.re - w.re).abs() < 1e-6 * (n as f64), "{g:?} vs {w:?}");
                assert!((g.im - w.im).abs() < 1e-6 * (n as f64));
            }
        }
    }

    #[test]
    fn prop_fft_roundtrip() {
        check("fft roundtrip", |rng| {
            let n = 1 << size(rng, 1, 12);
            let x: Vec<Complex> = (0..n)
                .map(|_| Complex::new(rng.normal() as f64, rng.normal() as f64))
                .collect();
            let mut buf = x.clone();
            fft(&mut buf);
            ifft(&mut buf);
            for (a, b) in x.iter().zip(buf.iter()) {
                assert!((a.re - b.re).abs() < 1e-8, "{a:?} vs {b:?}");
                assert!((a.im - b.im).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn prop_rfft_roundtrip() {
        check("rfft roundtrip", |rng| {
            let n = 1 << size(rng, 1, 12);
            let x = vecf(rng, n);
            let back = irfft(&rfft(&x), n);
            assert_close(&x, &back, 1e-5, "rfft/irfft");
        });
    }

    #[test]
    fn parseval() {
        let mut rng = crate::util::rng::Rng::new(3);
        let n = 256;
        let x = rng.normals(n);
        let time: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        let mut buf: Vec<Complex> =
            x.iter().map(|&v| Complex::new(v as f64, 0.0)).collect();
        fft(&mut buf);
        let freq: f64 = buf.iter().map(|c| c.abs().powi(2)).sum::<f64>() / n as f64;
        assert!((time - freq).abs() < 1e-6 * time, "{time} vs {freq}");
    }

    #[test]
    fn impulse_is_flat() {
        let mut x = vec![0.0f32; 16];
        x[0] = 1.0;
        let spec = rfft(&x);
        for c in spec {
            assert!((c.re - 1.0).abs() < 1e-9 && c.im.abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        let mut buf = vec![Complex::ZERO; 12];
        fft(&mut buf);
    }

    #[test]
    #[should_panic(expected = "rfft size 12 is not a power of two")]
    fn rfft_rejects_non_power_of_two_cleanly() {
        // The guard must fire at the rfft entry with the offending
        // size, not surface as garbage output or an index panic.
        let _ = rfft(&[0.0f32; 12]);
    }

    #[test]
    #[should_panic(expected = "irfft size 12 is not a power of two")]
    fn irfft_rejects_non_power_of_two_cleanly() {
        let _ = irfft(&[Complex::ZERO; 7], 12);
    }

    #[test]
    #[should_panic(expected = "spectrum/size mismatch")]
    fn irfft_rejects_wrong_bin_count() {
        let _ = irfft(&[Complex::ZERO; 5], 16);
    }
}
