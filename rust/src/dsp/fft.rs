//! Planned FFT engine: mixed-radix Cooley–Tukey + Bluestein fallback.
//!
//! Any size `n ≥ 1` transforms exactly:
//!
//! * **pow2** — the original iterative radix-2 kernel with a per-plan
//!   twiddle table (the hot paths that were already power-of-two run
//!   the same butterflies as before, minus the thread-local cache
//!   lookup);
//! * **mixed** — factored Cooley–Tukey for smooth composites: hardcoded
//!   radix-3/radix-5 butterflies (group twiddles + small-DFT kernels),
//!   a generic O(r²) kernel for primes ≤ 13, and the iterative radix-2
//!   kernel on the power-of-two tail;
//! * **bluestein** — chirp-z through a power-of-two convolution for
//!   sizes with a prime factor > 13 (exact for primes, unlike padding).
//!
//! [`FftPlan`] owns its twiddle/chirp tables and is immutable after
//! construction, so one plan is shared lock-free by any number of
//! threads ([`FftPlan::shared`] memoises plans per process).  The free
//! [`fft`]/[`ifft`]/[`rfft`]/[`irfft`] wrappers go through the cache
//! and now accept any length.  [`good_conv_size`] picks the cheapest
//! 5-smooth transform length ≥ a bound — how the Toeplitz circulant
//! plans avoid ever paying Bluestein — and [`fft_work_units`] /
//! [`rfft_work_units`] are the cost-model hooks that price an actual
//! factorization.
//!
//! ## Real-input fast path
//!
//! Every transform in this crate's hot paths is real-valued, so
//! [`RealFftPlan`] adds the standard r2c half-complex packing: an even
//! length n packs its n reals into n/2 complex points, runs the
//! **half-length** complex plan, and unpacks to the n/2+1 non-redundant
//! bins with an O(n) split/twiddle post-pass — about half the
//! butterfly work and memory traffic of transforming a zero-padded
//! complex buffer.  Odd Bluestein-class lengths (any prime factor
//! > 13) take a dedicated half-spectrum chirp: only the `(n+1)/2`
//! non-redundant bins are produced, through a *smooth* convolution
//! length `≥ n + n/2` picked by [`good_conv_size`] — strictly cheaper
//! than the complex engine's own pow2 `≥ 2n-1` Bluestein embedding.
//! Odd smooth lengths keep the full complex engine (one mixed
//! transform at n beats two chirp convolutions at ~1.5n; they only
//! arise from `good_conv_size` at tiny n).  Each fast-path transform
//! bumps `fft.real_fast_path`, split into `.packed` / `.odd` shares,
//! and `fft.real_fallback` counts the complex-engine remainder —
//! making the discount (and which route served it) observable in
//! stats snapshots.
//!
//! ## Plan-cache memory model
//!
//! Both process plan maps (complex and real) are **bounded**: at most
//! [`FFT_PLAN_CACHE_CAP`] sizes each, LRU-evicted past that
//! (`plan::LruCore` — the same primitive behind the execution-plan
//! cache), so mixed-length traffic over many distinct n holds
//! residency at `cap × O(n)` table bytes instead of growing forever.
//! The thread-local front caches add one `Arc` per (thread, size) and
//! are cleared whenever they outgrow the same cap.  An evicted plan
//! that is still in use (an `Arc` held by an operator or a front
//! cache) stays alive until its holders drop; the next `shared()` for
//! that size simply rebuilds.  With telemetry enabled
//! (`SKI_TNN_TELEMETRY=1`) the caches account for themselves in every
//! stats snapshot: `fft.plan_cache.local_hit` / `.hit` / `.miss` /
//! `.evict` counters (front-cache hit, process-map hit, plan build,
//! LRU displacement) and the `fft.plan_cache.size` /
//! `fft.plan_cache.bytes` gauges (resident entries and their
//! twiddle/chirp table bytes across both maps), making growth — and
//! now eviction churn — observable instead of silent.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use crate::plan::LruCore;
use crate::telemetry::{LazyCounter, LazyGauge};

/// Most distinct transform sizes each process map keeps (complex and
/// real maps are bounded separately).
pub const FFT_PLAN_CACHE_CAP: usize = 64;

/// Thread-local front-cache hits (no lock taken).
static PLAN_CACHE_LOCAL_HIT: LazyCounter = LazyCounter::new("fft.plan_cache.local_hit");
/// Process-map hits (lock taken, no plan built).
static PLAN_CACHE_HIT: LazyCounter = LazyCounter::new("fft.plan_cache.hit");
/// Misses — each one builds a plan (O(n) table memory retained).
static PLAN_CACHE_MISS: LazyCounter = LazyCounter::new("fft.plan_cache.miss");
/// LRU displacements from either bounded process map.
static PLAN_CACHE_EVICT: LazyCounter = LazyCounter::new("fft.plan_cache.evict");
/// Distinct sizes resident across both process-wide maps.
static PLAN_CACHE_SIZE: LazyGauge = LazyGauge::new("fft.plan_cache.size");
/// Twiddle/chirp table bytes resident across both process-wide maps.
static PLAN_CACHE_BYTES: LazyGauge = LazyGauge::new("fft.plan_cache.bytes");

/// Last published (entries, bytes) of the complex / real maps, so one
/// map's mutation republishes a coherent cross-map gauge total.
static COMPLEX_RESIDENT: (AtomicUsize, AtomicUsize) = (AtomicUsize::new(0), AtomicUsize::new(0));
static REAL_RESIDENT: (AtomicUsize, AtomicUsize) = (AtomicUsize::new(0), AtomicUsize::new(0));

/// Publish one map's freshly computed residency and set the cross-map
/// `fft.plan_cache.{size,bytes}` gauges.
fn publish_residency(slot: &(AtomicUsize, AtomicUsize), entries: usize, bytes: usize) {
    slot.0.store(entries, Ordering::Relaxed);
    slot.1.store(bytes, Ordering::Relaxed);
    let size = COMPLEX_RESIDENT.0.load(Ordering::Relaxed) + REAL_RESIDENT.0.load(Ordering::Relaxed);
    let total = COMPLEX_RESIDENT.1.load(Ordering::Relaxed) + REAL_RESIDENT.1.load(Ordering::Relaxed);
    PLAN_CACHE_SIZE.set(size as f64);
    PLAN_CACHE_BYTES.set(total as f64);
}

/// (resident plans, resident table bytes) across both process maps —
/// diagnostics and the bounded-cache tests.
#[doc(hidden)]
pub fn plan_cache_stats() -> (usize, usize) {
    (
        COMPLEX_RESIDENT.0.load(Ordering::Relaxed) + REAL_RESIDENT.0.load(Ordering::Relaxed),
        COMPLEX_RESIDENT.1.load(Ordering::Relaxed) + REAL_RESIDENT.1.load(Ordering::Relaxed),
    )
}
/// Transforms served by a real fast path — packed even r2c/c2r or the
/// odd-length half-spectrum chirp (one per direction per apply — a
/// spectral apply at even m counts two).
static REAL_FAST_PATH: LazyCounter = LazyCounter::new("fft.real_fast_path");
/// The packed-even share of `fft.real_fast_path`.
static REAL_FAST_PATH_PACKED: LazyCounter = LazyCounter::new("fft.real_fast_path.packed");
/// The odd-length chirp share of `fft.real_fast_path`.
static REAL_FAST_PATH_ODD: LazyCounter = LazyCounter::new("fft.real_fast_path.odd");
/// Transforms that fell back to the full complex engine (odd smooth
/// sizes where one mixed transform beats two chirp convolutions).
static REAL_FALLBACK: LazyCounter = LazyCounter::new("fft.real_fallback");

/// Minimal complex number (no external num crate offline).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    pub fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }

    pub fn mul(self, o: Complex) -> Self {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }

    pub fn add(self, o: Complex) -> Self {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }

    pub fn sub(self, o: Complex) -> Self {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }

    pub fn scale(self, s: f64) -> Self {
        Complex { re: self.re * s, im: self.im * s }
    }

    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// Largest odd prime the mixed-radix engine handles in-line; anything
/// bigger routes the whole transform through Bluestein.
const MAX_GENERIC_RADIX: usize = 13;

/// `n = 2^k · Πfactors` with the odd prime factors ascending.  `None`
/// factors ⇒ some odd prime exceeds [`MAX_GENERIC_RADIX`] (Bluestein).
fn factorize(mut n: usize) -> (Option<Vec<usize>>, usize) {
    let mut base = 1usize;
    while n % 2 == 0 {
        n /= 2;
        base *= 2;
    }
    let mut factors = Vec::new();
    let mut p = 3usize;
    while p * p <= n {
        while n % p == 0 {
            factors.push(p);
            n /= p;
        }
        p += 2;
    }
    if n > 1 {
        factors.push(n);
    }
    if factors.iter().any(|&f| f > MAX_GENERIC_RADIX) {
        return (None, base);
    }
    (Some(factors), base)
}

/// Modeled butterfly work of one `m`-point transform under the actual
/// factorization this engine would use, in radix-2-butterfly units:
/// pow2 = `m/2·log2 m`, each odd-radix level a calibrated multiple of
/// `m`, Bluestein three pow2 transforms at the embedding size plus the
/// chirp multiplies.  Relative pricing only — the dispatch cost model
/// multiplies by its per-unit nanoseconds.
pub fn fft_work_units(m: usize) -> f64 {
    if m <= 1 {
        return 0.0;
    }
    let (factors, base) = factorize(m);
    let Some(factors) = factors else {
        let big = (2 * m - 1).next_power_of_two() as f64;
        return 3.0 * 0.5 * big * big.log2() + 2.0 * m as f64 + big;
    };
    let mut units = 0.5 * (m as f64) * (base as f64).log2();
    for &r in &factors {
        // Per-point cost of one radix-r level: hardcoded kernels for
        // 3/5, the generic O(r²)-per-group loop above that.
        let per_point = match r {
            3 => 1.0,
            5 => 1.6,
            7 => 2.2,
            11 => 3.0,
            _ => 3.5,
        };
        units += m as f64 * per_point;
    }
    units
}

/// Modeled butterfly work of one **real-input** `m`-point transform
/// through [`RealFftPlan`]: even lengths run one half-length complex
/// transform plus the O(m) split/twiddle pass (priced like one extra
/// radix-2 level); odd lengths take the cheaper of the half-spectrum
/// chirp (two smooth convolution transforms — wins for Bluestein-class
/// sizes) and the full complex engine (wins for odd smooth sizes).
/// The dispatch cost model uses this to give spectral backends their
/// r2c discount.
pub fn rfft_work_units(m: usize) -> f64 {
    if m >= 2 && m % 2 == 0 {
        fft_work_units(m / 2) + 0.5 * m as f64
    } else if m >= 3 {
        odd_chirp_units(m, good_conv_size(m + m / 2)).min(fft_work_units(m))
    } else {
        fft_work_units(m)
    }
}

/// The cheapest 5-smooth (2^a·3^b·5^c) transform length `≥ min` by
/// [`fft_work_units`] — never worse than `min.next_power_of_two()`,
/// which is itself a candidate.  Circulant-embedding plans use this to
/// turn "awkward n" into "nearby smooth m" instead of Bluestein.
pub fn good_conv_size(min: usize) -> usize {
    let min = min.max(1);
    let bound = min.next_power_of_two();
    let mut best = bound;
    let mut best_units = fft_work_units(bound);
    let mut p5 = 1usize;
    while p5 <= bound {
        let mut p35 = p5;
        while p35 <= bound {
            let mut m = p35;
            while m < min {
                m *= 2;
            }
            if m <= bound {
                let u = fft_work_units(m);
                if u < best_units || (u == best_units && m < best) {
                    best = m;
                    best_units = u;
                }
            }
            match p35.checked_mul(3) {
                Some(v) => p35 = v,
                None => break,
            }
        }
        match p5.checked_mul(5) {
            Some(v) => p5 = v,
            None => break,
        }
    }
    best
}

/// Forward twiddle table `tw[k] = e^{-2πik/n}` for `k < len`.
fn twiddle_table(n: usize, len: usize) -> Vec<Complex> {
    (0..len)
        .map(|k| {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            Complex::new(ang.cos(), ang.sin())
        })
        .collect()
}

fn bit_reverse_permute(buf: &mut [Complex]) {
    let n = buf.len();
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            buf.swap(i, j);
        }
    }
}

/// The iterative radix-2 kernel (the pre-existing hot loop), over a
/// caller-supplied half-size twiddle table for `buf.len()`.
///
/// The butterfly is written over split lo/hi half-slices with scalar
/// re/im arithmetic and the inverse's twiddle conjugation hoisted to a
/// sign outside the loop, so the inner loop is branch-free
/// straight-line code the autovectorizer can keep lanes full on.  The
/// float operations are value-for-value those of the classic
/// `u ± w·v` form, so outputs are bitwise identical to the original
/// branching loop.
fn pow2_fft(buf: &mut [Complex], tw: &[Complex], inverse: bool) {
    let n = buf.len();
    debug_assert!(n.is_power_of_two());
    if n <= 1 {
        return;
    }
    bit_reverse_permute(buf);
    let im_sign = if inverse { -1.0 } else { 1.0 };
    let mut len = 2;
    while len <= n {
        let stride = n / len;
        let half = len / 2;
        let mut i = 0;
        while i < n {
            let (lo, hi) = buf[i..i + len].split_at_mut(half);
            for (j, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                let w = tw[j * stride];
                let (w_re, w_im) = (w.re, im_sign * w.im);
                let (u_re, u_im) = (l.re, l.im);
                let v_re = h.re * w_re - h.im * w_im;
                let v_im = h.re * w_im + h.im * w_re;
                *l = Complex { re: u_re + v_re, im: u_im + v_im };
                *h = Complex { re: u_re - v_re, im: u_im - v_im };
            }
            i += len;
        }
        len <<= 1;
    }
}

// Exact small-radix kernel constants (cos/sin of 2π/3, 2π/5, 4π/5);
// `radix_constants_are_trig_exact` pins them against the libm values.
const SQRT3_2: f64 = 0.866_025_403_784_438_6;
const C72: f64 = 0.309_016_994_374_947_45;
const C144: f64 = -0.809_016_994_374_947_5;
const S72: f64 = 0.951_056_516_295_153_5;
const S144: f64 = 0.587_785_252_292_473_1;

/// Factored Cooley–Tukey over odd factors with a pow2 tail.
#[derive(Debug)]
struct MixedPlan {
    /// Odd prime factors (ascending, with multiplicity).
    factors: Vec<usize>,
    /// Full n-point twiddle table (`tw[t] = e^{-2πit/n}`).
    tw: Vec<Complex>,
    /// Half-size table for the pow2-tail kernel (`base/2` entries).
    tw2: Vec<Complex>,
}

impl MixedPlan {
    /// Decimation-in-time recursion: `out` receives the `n'`-point DFT
    /// of the `n'` input elements at `inp[offset + i·stride]`.  The
    /// combine step works column-by-column through a stack buffer, so
    /// no scratch beyond the top-level input copy is needed.
    fn rec(
        &self,
        n: usize,
        inp: &[Complex],
        offset: usize,
        stride: usize,
        out: &mut [Complex],
        depth: usize,
    ) {
        let np = out.len();
        if depth == self.factors.len() {
            // pow2 tail: gather the strided input, radix-2 in place.
            for (i, o) in out.iter_mut().enumerate() {
                *o = inp[offset + i * stride];
            }
            if np > 1 {
                pow2_fft(out, &self.tw2, false);
            }
            return;
        }
        let r = self.factors[depth];
        let m = np / r;
        for j in 0..r {
            let sub = &mut out[j * m..(j + 1) * m];
            self.rec(n, inp, offset + j * stride, stride * r, sub, depth + 1);
        }
        // Combine: u_j = sub_j[k1]·ω_{n'}^{j·k1}, then an r-point DFT
        // over the u's lands all r outputs of column k1 — which occupy
        // exactly the slots the u's were read from, so the combine is
        // in place per column.
        let tstride = n / np;
        let mut u = [Complex::ZERO; MAX_GENERIC_RADIX];
        for k1 in 0..m {
            u[0] = out[k1];
            for j in 1..r {
                u[j] = out[j * m + k1].mul(self.tw[j * k1 * tstride]);
            }
            match r {
                3 => {
                    let t = u[1].add(u[2]);
                    let d = u[1].sub(u[2]);
                    // -i·(√3/2)·d
                    let isd = Complex::new(SQRT3_2 * d.im, -SQRT3_2 * d.re);
                    let half = u[0].sub(t.scale(0.5));
                    out[k1] = u[0].add(t);
                    out[m + k1] = half.add(isd);
                    out[2 * m + k1] = half.sub(isd);
                }
                5 => {
                    let t1 = u[1].add(u[4]);
                    let t2 = u[2].add(u[3]);
                    let t3 = u[1].sub(u[4]);
                    let t4 = u[2].sub(u[3]);
                    let a1 = u[0].add(t1.scale(C72)).add(t2.scale(C144));
                    let a2 = u[0].add(t1.scale(C144)).add(t2.scale(C72));
                    let b1 = t3.scale(S72).add(t4.scale(S144));
                    let b2 = t3.scale(S144).sub(t4.scale(S72));
                    let ib1 = Complex::new(b1.im, -b1.re); // -i·b1
                    let ib2 = Complex::new(b2.im, -b2.re); // -i·b2
                    out[k1] = u[0].add(t1).add(t2);
                    out[m + k1] = a1.add(ib1);
                    out[2 * m + k1] = a2.add(ib2);
                    out[3 * m + k1] = a2.sub(ib2);
                    out[4 * m + k1] = a1.sub(ib1);
                }
                _ => {
                    // Generic small-prime DFT: u_j already carries
                    // ω^{j·k1}, the remaining factor is ω^{j·c·m}.
                    let mut res = [Complex::ZERO; MAX_GENERIC_RADIX];
                    for (c, slot) in res.iter_mut().enumerate().take(r) {
                        let mut acc = u[0];
                        for (j, uj) in u.iter().enumerate().take(r).skip(1) {
                            acc = acc.add(uj.mul(self.tw[((j * c * m) % np) * tstride]));
                        }
                        *slot = acc;
                    }
                    for (c, v) in res.iter().enumerate().take(r) {
                        out[c * m + k1] = *v;
                    }
                }
            }
        }
    }
}

/// Chirp-z (Bluestein) through a pow2 convolution: exact DFT at sizes
/// whose factorization the mixed engine does not handle (big primes).
#[derive(Debug)]
struct BluesteinPlan {
    /// pow2 convolution length `≥ 2n - 1`.
    m: usize,
    /// `chirp[j] = e^{-iπ j²/n}`.
    chirp: Vec<Complex>,
    /// m-point spectrum of the (symmetric) conjugate-chirp sequence.
    bspec: Vec<Complex>,
    /// The inner pow2 plan of size `m`.
    inner: Box<FftPlan>,
}

impl BluesteinPlan {
    fn new(n: usize) -> BluesteinPlan {
        let m = (2 * n - 1).next_power_of_two();
        let chirp: Vec<Complex> = (0..n)
            .map(|j| {
                // j² mod 2n keeps the angle small (e^{-iπj²/n} has
                // period 2n in j²) — u128 so j² cannot overflow.
                let q = ((j as u128 * j as u128) % (2 * n as u128)) as f64;
                let ang = -std::f64::consts::PI * q / n as f64;
                Complex::new(ang.cos(), ang.sin())
            })
            .collect();
        let inner = Box::new(FftPlan::new(m));
        let mut bbuf = vec![Complex::ZERO; m];
        bbuf[0] = chirp[0].conj();
        for j in 1..n {
            let b = chirp[j].conj();
            bbuf[j] = b;
            bbuf[m - j] = b;
        }
        inner.fft(&mut bbuf);
        BluesteinPlan { m, chirp, bspec: bbuf, inner }
    }

    fn run(&self, buf: &mut [Complex]) {
        let n = buf.len();
        let mut y = vec![Complex::ZERO; self.m];
        for (j, (yj, &xj)) in y.iter_mut().zip(buf.iter()).enumerate().take(n) {
            *yj = xj.mul(self.chirp[j]);
        }
        self.inner.fft(&mut y);
        for (v, b) in y.iter_mut().zip(self.bspec.iter()) {
            *v = v.mul(*b);
        }
        self.inner.ifft(&mut y);
        for (k, (out, &zk)) in buf.iter_mut().zip(y.iter()).enumerate().take(n) {
            *out = zk.mul(self.chirp[k]);
        }
    }
}

#[derive(Debug)]
enum PlanKind {
    /// n ≤ 1.
    Trivial,
    /// Iterative radix-2 with a half-size twiddle table.
    Pow2 { tw: Vec<Complex> },
    Mixed(MixedPlan),
    Bluestein(BluesteinPlan),
}

/// An immutable transform plan for one size: twiddle/chirp tables plus
/// the strategy choice.  Share freely across threads (no interior
/// mutability); [`FftPlan::shared`] memoises one per size per process.
#[derive(Debug)]
pub struct FftPlan {
    n: usize,
    kind: PlanKind,
}

thread_local! {
    /// Input copy for the mixed-radix recursion (its DIT gather reads
    /// the original input while writing the caller's buffer in place).
    static MIXED_INPUT: std::cell::RefCell<Vec<Complex>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl FftPlan {
    pub fn new(n: usize) -> FftPlan {
        let kind = if n <= 1 {
            PlanKind::Trivial
        } else if n.is_power_of_two() {
            PlanKind::Pow2 { tw: twiddle_table(n, n / 2) }
        } else {
            match factorize(n) {
                (Some(factors), base) => PlanKind::Mixed(MixedPlan {
                    factors,
                    tw: twiddle_table(n, n),
                    tw2: twiddle_table(base, (base / 2).max(1)),
                }),
                (None, _) => PlanKind::Bluestein(BluesteinPlan::new(n)),
            }
        };
        FftPlan { n, kind }
    }

    /// The memoised per-process plan for size `n`.  A thread-local
    /// front cache makes the steady-state lookup lock-free (the
    /// sharded SKI gram path resolves plans per row — it must never
    /// serialize workers on a process mutex); the bounded process map
    /// behind it deduplicates plan construction across threads, and
    /// plans are built **outside** its lock so a first-touch Bluestein
    /// build cannot stall every other size's lookup.  The front cache
    /// clears itself past [`FFT_PLAN_CACHE_CAP`] so per-thread
    /// residency stays bounded too.
    pub fn shared(n: usize) -> Arc<FftPlan> {
        thread_local! {
            static LOCAL: std::cell::RefCell<HashMap<usize, Arc<FftPlan>>> =
                std::cell::RefCell::new(HashMap::new());
        }
        LOCAL.with(|l| {
            if let Some(p) = l.borrow().get(&n) {
                PLAN_CACHE_LOCAL_HIT.incr();
                return Arc::clone(p);
            }
            let p = FftPlan::shared_global(n);
            let mut front = l.borrow_mut();
            if front.len() >= FFT_PLAN_CACHE_CAP {
                front.clear();
            }
            front.insert(n, Arc::clone(&p));
            p
        })
    }

    fn shared_global(n: usize) -> Arc<FftPlan> {
        static CACHE: OnceLock<Mutex<LruCore<usize, Arc<FftPlan>>>> = OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(LruCore::new(FFT_PLAN_CACHE_CAP)));
        let lock = |c: &'static Mutex<LruCore<usize, Arc<FftPlan>>>| {
            c.lock().unwrap_or_else(PoisonError::into_inner)
        };
        if let Some(p) = lock(cache).get(&n) {
            PLAN_CACHE_HIT.incr();
            return Arc::clone(p);
        }
        // Miss: build with no lock held (two racing threads may both
        // build; the map keeps the first, the loser's copy is dropped).
        PLAN_CACHE_MISS.incr();
        let built = Arc::new(FftPlan::new(n));
        let mut g = lock(cache);
        let p = if let Some(existing) = g.get(&n) {
            Arc::clone(existing)
        } else {
            let evicted = g.insert(n, Arc::clone(&built));
            PLAN_CACHE_EVICT.add(evicted.len() as u64);
            built
        };
        let bytes = g.values().map(|p| p.table_bytes()).sum();
        publish_residency(&COMPLEX_RESIDENT, g.len(), bytes);
        p
    }

    /// Bytes of this plan's owned twiddle/chirp tables (a Bluestein
    /// plan includes its owned inner pow2 plan).
    pub fn table_bytes(&self) -> usize {
        let c = std::mem::size_of::<Complex>();
        match &self.kind {
            PlanKind::Trivial => 0,
            PlanKind::Pow2 { tw } => tw.capacity() * c,
            PlanKind::Mixed(mp) => (mp.tw.capacity() + mp.tw2.capacity()) * c,
            PlanKind::Bluestein(bp) => {
                (bp.chirp.capacity() + bp.bspec.capacity()) * c + bp.inner.table_bytes()
            }
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Which engine this size runs on: `trivial|pow2|mixed|bluestein`.
    pub fn strategy(&self) -> &'static str {
        match &self.kind {
            PlanKind::Trivial => "trivial",
            PlanKind::Pow2 { .. } => "pow2",
            PlanKind::Mixed(_) => "mixed",
            PlanKind::Bluestein(_) => "bluestein",
        }
    }

    /// In-place forward DFT (sign -1: `X[k] = Σ x[t] e^{-2πikt/n}`).
    pub fn fft(&self, buf: &mut [Complex]) {
        assert_eq!(buf.len(), self.n, "plan is for n={}, buffer has {}", self.n, buf.len());
        match &self.kind {
            PlanKind::Trivial => {}
            PlanKind::Pow2 { tw } => pow2_fft(buf, tw, false),
            PlanKind::Mixed(mp) => MIXED_INPUT.with(|cell| {
                let mut inp = cell.borrow_mut();
                inp.clear();
                inp.extend_from_slice(buf);
                mp.rec(self.n, &inp, 0, 1, buf, 0);
            }),
            PlanKind::Bluestein(bp) => bp.run(buf),
        }
    }

    /// In-place inverse DFT, including the 1/n normalisation.
    pub fn ifft(&self, buf: &mut [Complex]) {
        assert_eq!(buf.len(), self.n, "plan is for n={}, buffer has {}", self.n, buf.len());
        let scale = 1.0 / self.n as f64;
        match &self.kind {
            PlanKind::Trivial => {}
            PlanKind::Pow2 { tw } => {
                // Conjugated-twiddle butterflies: the pre-existing
                // inverse, numerically unchanged on pow2 sizes.
                pow2_fft(buf, tw, true);
                for v in buf.iter_mut() {
                    *v = v.scale(scale);
                }
            }
            _ => {
                // ifft(x) = conj(fft(conj(x)))/n for the other engines.
                for v in buf.iter_mut() {
                    *v = v.conj();
                }
                self.fft(buf);
                for v in buf.iter_mut() {
                    *v = v.conj().scale(scale);
                }
            }
        }
    }
}

/// In-place forward FFT of any length (plan-cached).
pub fn fft(buf: &mut [Complex]) {
    if buf.len() <= 1 {
        return;
    }
    FftPlan::shared(buf.len()).fft(buf);
}

/// In-place inverse FFT of any length, including the 1/n normalisation.
pub fn ifft(buf: &mut [Complex]) {
    if buf.len() <= 1 {
        return;
    }
    FftPlan::shared(buf.len()).ifft(buf);
}

/// Half-spectrum chirp-z for **odd** real lengths: compute only the
/// `h+1 = (n+1)/2` non-redundant bins as a convolution at a *smooth*
/// length `m ≥ n + h` (picked by [`good_conv_size`], so the inner
/// transforms run the mixed/pow2 engines, never a pow2 `≥ 2n-1`
/// Bluestein embedding).  Both directions reuse one chirp table and
/// one inner plan; the only per-call state is the caller's scratch.
///
/// Forward (`w = e^{-2πi/n}`, `c[t] = e^{-iπt²/n}`, so
/// `w^{jk} = c[j]·c[k]·conj(c[j-k])`):
/// `X[k] = c[k] · Σ_j (x[j]·c[j]) · conj(c[k-j])` for `k ∈ [0, h]` —
/// the input multiply is a *real* scale (x is real), and the kernel
/// `conj(c)` has support `k-j ∈ [-(n-1), h]`, which fits a length-m
/// circular convolution exactly when `m ≥ n + h`.
///
/// Inverse: with `S[j] = Σ_{k=0}^{h} X[k] e^{+2πijk/n}
///   = conj(c[j]) · Σ_k (X[k]·conj(c[k])) · c[j-k]`,
/// Hermitian symmetry gives `x[j] = (2·Re S[j] − X[0]) / n` (odd n has
/// no Nyquist bin), and the inverse kernel `c[j-k]` has support
/// `[-h, n-1]` — the same `m ≥ n + h` bound.
#[derive(Debug)]
struct OddRealPlan {
    /// Smooth circular-convolution length `≥ n + h`.
    m: usize,
    /// `chirp[t] = e^{-iπ t²/n}` for `t < n` (even in t, so negative
    /// kernel indices read the same table).
    chirp: Vec<Complex>,
    /// m-point spectrum of the forward kernel `conj(chirp)`.
    fwd_spec: Vec<Complex>,
    /// m-point spectrum of the inverse kernel `chirp`.
    inv_spec: Vec<Complex>,
    /// The inner smooth plan of size `m`.
    inner: Arc<FftPlan>,
}

impl OddRealPlan {
    fn new(n: usize, m: usize) -> OddRealPlan {
        debug_assert!(n % 2 == 1 && n >= 3);
        let h = n / 2;
        debug_assert!(m >= n + h);
        let chirp: Vec<Complex> = (0..n)
            .map(|j| {
                // j² mod 2n keeps the angle small — u128 so j² cannot
                // overflow (same trick as the complex Bluestein plan).
                let q = ((j as u128 * j as u128) % (2 * n as u128)) as f64;
                let ang = -std::f64::consts::PI * q / n as f64;
                Complex::new(ang.cos(), ang.sin())
            })
            .collect();
        let inner = FftPlan::shared(m);
        // Forward kernel b[t] = conj(c[t]) for t ∈ [-(n-1), h],
        // negatives wrapped to the top of the m-grid.
        let mut fwd = vec![Complex::ZERO; m];
        for (t, f) in fwd.iter_mut().enumerate().take(h + 1) {
            *f = chirp[t].conj();
        }
        for u in 1..n {
            fwd[m - u] = chirp[u].conj();
        }
        inner.fft(&mut fwd);
        // Inverse kernel k[t] = c[t] for t ∈ [-h, n-1].
        let mut inv = vec![Complex::ZERO; m];
        for (t, f) in inv.iter_mut().enumerate().take(n) {
            *f = chirp[t];
        }
        for u in 1..=h {
            inv[m - u] = chirp[u];
        }
        inner.fft(&mut inv);
        OddRealPlan { m, chirp, fwd_spec: fwd, inv_spec: inv, inner }
    }
}

/// Modeled cost of the odd half-spectrum chirp at length `n` with
/// inner convolution length `m`: two m-point transforms plus the O(n)
/// chirp multiplies.  The plan (and [`rfft_work_units`]) takes the
/// chirp route only when this undercuts one full-length complex
/// transform — true exactly when `n` itself would route through
/// Bluestein, whose pow2 embedding is `≥ 2n-1` and pays *three*
/// transforms' worth of work.
fn odd_chirp_units(n: usize, m: usize) -> f64 {
    2.0 * fft_work_units(m) + 2.0 * n as f64
}

/// How a [`RealFftPlan`] runs one size.
#[derive(Debug)]
enum RealKind {
    /// n ≤ 1: `X[0] = x[0]`.
    Trivial,
    /// Even n: pack n reals into n/2 complex points, transform at the
    /// **half** length, split/twiddle unpack to the n/2+1 bins.  `tw`
    /// holds `e^{-2πik/n}` for `k ≤ n/4` — all either direction needs,
    /// since the unpack walks conjugate pairs `(k, n/2-k)`.
    Packed { half: Arc<FftPlan>, tw: Vec<Complex> },
    /// Odd n in the Bluestein class: half-spectrum chirp through a
    /// smooth convolution (strictly cheaper than the complex engine's
    /// own pow2 chirp embedding).
    OddChirp(OddRealPlan),
    /// Odd smooth n: full-length complex transform — one mixed
    /// transform at n beats two chirp convolutions at ~1.5n, so the
    /// fallback is the *fast* route for these (only tiny
    /// `good_conv_size` picks are odd — every serving grid is even).
    Fallback(Arc<FftPlan>),
}

/// A real-input transform plan: `n` reals ↔ the `n/2+1` non-redundant
/// spectrum bins, through caller-provided buffers with **zero steady-
/// state allocations** (buffers grow once, then are reused).
///
/// Even sizes take the half-complex packed route — one complex
/// transform at n/2 instead of n, ~2x less butterfly work and memory
/// traffic (the `fft.real_fast_path` counter records each packed
/// transform).  Like [`FftPlan`], a built plan is immutable and shared
/// lock-free; [`RealFftPlan::shared`] memoises one per size per
/// process (the inner complex plans come from [`FftPlan::shared`], so
/// the existing `fft.plan_cache.*` counters account for them).
#[derive(Debug)]
pub struct RealFftPlan {
    n: usize,
    kind: RealKind,
}

impl RealFftPlan {
    pub fn new(n: usize) -> RealFftPlan {
        let kind = if n <= 1 {
            RealKind::Trivial
        } else if n % 2 == 0 {
            RealKind::Packed { half: FftPlan::shared(n / 2), tw: twiddle_table(n, n / 4 + 1) }
        } else {
            let m = good_conv_size(n + n / 2);
            if odd_chirp_units(n, m) < fft_work_units(n) {
                RealKind::OddChirp(OddRealPlan::new(n, m))
            } else {
                RealKind::Fallback(FftPlan::shared(n))
            }
        };
        RealFftPlan { n, kind }
    }

    /// The memoised per-process plan for size `n` (same two-level
    /// cache discipline as [`FftPlan::shared`]: lock-free thread-local
    /// front — cleared past [`FFT_PLAN_CACHE_CAP`] — bounded process
    /// map behind it, plans built outside the lock).
    pub fn shared(n: usize) -> Arc<RealFftPlan> {
        thread_local! {
            static LOCAL: std::cell::RefCell<HashMap<usize, Arc<RealFftPlan>>> =
                std::cell::RefCell::new(HashMap::new());
        }
        LOCAL.with(|l| {
            if let Some(p) = l.borrow().get(&n) {
                PLAN_CACHE_LOCAL_HIT.incr();
                return Arc::clone(p);
            }
            let p = RealFftPlan::shared_global(n);
            let mut front = l.borrow_mut();
            if front.len() >= FFT_PLAN_CACHE_CAP {
                front.clear();
            }
            front.insert(n, Arc::clone(&p));
            p
        })
    }

    fn shared_global(n: usize) -> Arc<RealFftPlan> {
        static CACHE: OnceLock<Mutex<LruCore<usize, Arc<RealFftPlan>>>> = OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(LruCore::new(FFT_PLAN_CACHE_CAP)));
        let lock = |c: &'static Mutex<LruCore<usize, Arc<RealFftPlan>>>| {
            c.lock().unwrap_or_else(PoisonError::into_inner)
        };
        if let Some(p) = lock(cache).get(&n) {
            PLAN_CACHE_HIT.incr();
            return Arc::clone(p);
        }
        PLAN_CACHE_MISS.incr();
        let built = Arc::new(RealFftPlan::new(n));
        let mut g = lock(cache);
        let p = if let Some(existing) = g.get(&n) {
            Arc::clone(existing)
        } else {
            let evicted = g.insert(n, Arc::clone(&built));
            PLAN_CACHE_EVICT.add(evicted.len() as u64);
            built
        };
        let bytes = g.values().map(|p| p.table_bytes()).sum();
        publish_residency(&REAL_RESIDENT, g.len(), bytes);
        p
    }

    /// Bytes of this plan's owned twiddle/chirp tables.  Inner complex
    /// plans obtained from [`FftPlan::shared`] are *not* counted here —
    /// they are resident (and accounted) in the complex map.
    pub fn table_bytes(&self) -> usize {
        let c = std::mem::size_of::<Complex>();
        match &self.kind {
            RealKind::Trivial | RealKind::Fallback(_) => 0,
            RealKind::Packed { tw, .. } => tw.capacity() * c,
            RealKind::OddChirp(op) => {
                (op.chirp.capacity() + op.fwd_spec.capacity() + op.inv_spec.capacity()) * c
            }
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of non-redundant spectrum bins (`n/2 + 1`).
    pub fn bins(&self) -> usize {
        self.n / 2 + 1
    }

    /// Whether this size takes the packed half-complex fast path.
    pub fn is_packed(&self) -> bool {
        matches!(self.kind, RealKind::Packed { .. })
    }

    /// Whether this size takes the odd-length half-spectrum chirp path.
    pub fn is_odd_real(&self) -> bool {
        matches!(self.kind, RealKind::OddChirp(_))
    }

    /// Which complex engine backs this plan (`trivial` | `pow2` |
    /// `mixed` | `bluestein`) — for the packed route, the strategy of
    /// the **half-length** plan every transform actually runs on; for
    /// the odd chirp route, the strategy of the smooth inner
    /// convolution plan.
    pub fn strategy(&self) -> &'static str {
        match &self.kind {
            RealKind::Trivial => "trivial",
            RealKind::Packed { half, .. } => half.strategy(),
            RealKind::OddChirp(op) => op.inner.strategy(),
            RealKind::Fallback(plan) => plan.strategy(),
        }
    }

    /// Forward r2c: the `n/2+1` non-redundant bins of the length-n real
    /// signal `x`, into `out` (resized; no allocation once capacity is
    /// warm).  `scratch` is only touched on the odd-length routes (the
    /// chirp convolution buffer, or the fallback's complex copy).
    pub fn rfft_into(&self, x: &[f32], out: &mut Vec<Complex>, scratch: &mut Vec<Complex>) {
        assert_eq!(x.len(), self.n, "rfft_into: signal/plan size mismatch");
        out.clear();
        // One exact reservation up front: the packed arm's extend(h) +
        // push would otherwise reserve exactly h and then pay a second,
        // doubling reallocation for the Nyquist slot — overshooting the
        // high-water mark the steady-state capacity pins at.
        out.reserve(self.bins());
        match &self.kind {
            RealKind::Trivial => {
                out.push(Complex::new(x.first().copied().unwrap_or(0.0) as f64, 0.0));
            }
            RealKind::Packed { half, tw } => {
                let h = self.n / 2;
                // Pack: z[j] = x[2j] + i·x[2j+1], half-length transform.
                out.extend(x.chunks_exact(2).map(|p| Complex::new(p[0] as f64, p[1] as f64)));
                out.push(Complex::ZERO); // bin h, filled by the unpack
                half.fft(&mut out[..h]);
                // Split/twiddle unpack, in place over the h+1 slots:
                // with A/B the even/odd-sample half-spectra recovered
                // from conjugate pairs of Z, X[k] = A + W^k·B and
                // X[h-k] = conj(A - W^k·B), W = e^{-2πi/n}.
                let z0 = out[0];
                out[h] = Complex::new(z0.re - z0.im, 0.0);
                out[0] = Complex::new(z0.re + z0.im, 0.0);
                for k in 1..(h + 1) / 2 {
                    let zk = out[k];
                    let zhk = out[h - k];
                    let a = Complex::new(0.5 * (zk.re + zhk.re), 0.5 * (zk.im - zhk.im));
                    let b = Complex::new(0.5 * (zk.im + zhk.im), 0.5 * (zhk.re - zk.re));
                    let t = tw[k].mul(b);
                    out[k] = a.add(t);
                    out[h - k] = a.sub(t).conj();
                }
                if h % 2 == 0 && h >= 2 {
                    out[h / 2] = out[h / 2].conj();
                }
                REAL_FAST_PATH.incr();
                REAL_FAST_PATH_PACKED.incr();
            }
            RealKind::OddChirp(op) => {
                let h = self.n / 2;
                scratch.clear();
                scratch.resize(op.m, Complex::ZERO);
                // Chirp the input — a *real* scale, x is real.
                for (s, (&xj, c)) in scratch.iter_mut().zip(x.iter().zip(op.chirp.iter())) {
                    *s = c.scale(xj as f64);
                }
                op.inner.fft(scratch);
                for (v, b) in scratch.iter_mut().zip(op.fwd_spec.iter()) {
                    *v = v.mul(*b);
                }
                op.inner.ifft(scratch);
                out.extend((0..=h).map(|k| op.chirp[k].mul(scratch[k])));
                REAL_FAST_PATH.incr();
                REAL_FAST_PATH_ODD.incr();
            }
            RealKind::Fallback(plan) => {
                scratch.clear();
                scratch.extend(x.iter().map(|&v| Complex::new(v as f64, 0.0)));
                plan.fft(scratch);
                out.extend_from_slice(&scratch[..self.n / 2 + 1]);
                REAL_FALLBACK.incr();
            }
        }
    }

    /// Inverse c2r: reconstruct the length-n real signal from its
    /// `n/2+1` bins (Hermitian symmetry implied) into `out`, which must
    /// be exactly n long.  `scratch` holds the complex work buffer
    /// (n/2 packed, the smooth convolution length on the odd chirp
    /// route, n on the odd-length fallback); no allocation once its
    /// capacity is warm.
    pub fn irfft_into(&self, spec: &[Complex], out: &mut [f32], scratch: &mut Vec<Complex>) {
        assert_eq!(spec.len(), self.bins(), "irfft_into: spectrum/size mismatch");
        assert_eq!(out.len(), self.n, "irfft_into: output/plan size mismatch");
        match &self.kind {
            RealKind::Trivial => {
                if let Some(o) = out.first_mut() {
                    *o = spec[0].re as f32;
                }
            }
            RealKind::Packed { half, tw } => {
                let h = self.n / 2;
                scratch.clear();
                scratch.resize(h, Complex::ZERO);
                // Rebuild the packed half-length spectrum Z from the
                // real bins: Z[k] = A + i·B with A/B recovered from the
                // conjugate pair (X[k], X[h-k]) — the exact inverse of
                // the forward unpack, then one half-length IFFT (its
                // 1/h normalisation is already the right one).
                let x0 = spec[0].re;
                let xh = spec[h].re;
                scratch[0] = Complex::new(0.5 * (x0 + xh), 0.5 * (x0 - xh));
                for k in 1..(h + 1) / 2 {
                    let xk = spec[k];
                    let xc = spec[h - k].conj();
                    let a = xk.add(xc).scale(0.5);
                    let b = tw[k].conj().mul(xk.sub(xc).scale(0.5));
                    scratch[k] = Complex::new(a.re - b.im, a.im + b.re);
                    scratch[h - k] = Complex::new(a.re + b.im, b.re - a.im);
                }
                if h % 2 == 0 && h >= 2 {
                    scratch[h / 2] = spec[h / 2].conj();
                }
                half.ifft(scratch);
                for (pair, z) in out.chunks_exact_mut(2).zip(scratch.iter()) {
                    pair[0] = z.re as f32;
                    pair[1] = z.im as f32;
                }
                REAL_FAST_PATH.incr();
                REAL_FAST_PATH_PACKED.incr();
            }
            RealKind::OddChirp(op) => {
                scratch.clear();
                scratch.resize(op.m, Complex::ZERO);
                for ((s, sp), c) in scratch.iter_mut().zip(spec.iter()).zip(op.chirp.iter()) {
                    *s = sp.mul(c.conj());
                }
                op.inner.fft(scratch);
                for (v, b) in scratch.iter_mut().zip(op.inv_spec.iter()) {
                    *v = v.mul(*b);
                }
                op.inner.ifft(scratch);
                // x[j] = (2·Re S[j] − X[0]) / n with S[j] =
                // conj(chirp[j])·conv[j] — only the real part matters.
                let x0 = spec[0].re;
                let inv_n = 1.0 / self.n as f64;
                for ((o, s), c) in out.iter_mut().zip(scratch.iter()).zip(op.chirp.iter()) {
                    let re = c.re * s.re + c.im * s.im;
                    *o = ((2.0 * re - x0) * inv_n) as f32;
                }
                REAL_FAST_PATH.incr();
                REAL_FAST_PATH_ODD.incr();
            }
            RealKind::Fallback(plan) => {
                let n = self.n;
                scratch.clear();
                scratch.resize(n, Complex::ZERO);
                scratch[..spec.len()].copy_from_slice(spec);
                for k in 1..n.div_ceil(2) {
                    scratch[n - k] = spec[k].conj();
                }
                plan.ifft(scratch);
                for (o, c) in out.iter_mut().zip(scratch.iter()) {
                    *o = c.re as f32;
                }
                REAL_FALLBACK.incr();
            }
        }
    }
}

/// Real-input FFT: returns the n/2+1 non-redundant bins (any n ≥ 1).
/// Even lengths ride the [`RealFftPlan`] half-complex fast path; odd
/// Bluestein-class lengths ride its half-spectrum chirp.
pub fn rfft(x: &[f32]) -> Vec<Complex> {
    let n = x.len();
    if n == 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut scratch = Vec::new();
    RealFftPlan::shared(n).rfft_into(x, &mut out, &mut scratch);
    out
}

/// Inverse of `rfft`: reconstructs the length-n real signal from the
/// n/2+1 spectrum bins (Hermitian symmetry implied; any n ≥ 1).
pub fn irfft(spec: &[Complex], n: usize) -> Vec<f32> {
    assert!(n >= 1, "irfft needs n >= 1");
    assert_eq!(spec.len(), n / 2 + 1, "irfft: spectrum/size mismatch");
    let mut out = vec![0.0f32; n];
    let mut scratch = Vec::new();
    RealFftPlan::shared(n).irfft_into(spec, &mut out, &mut scratch);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check, size, vecf};

    fn dft_naive(x: &[Complex]) -> Vec<Complex> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut acc = Complex::ZERO;
                for (t, v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                    acc = acc.add(v.mul(Complex::new(ang.cos(), ang.sin())));
                }
                acc
            })
            .collect()
    }

    fn assert_matches_naive(n: usize, tol: f64) {
        let mut rng = crate::util::rng::Rng::new(10 + n as u64);
        let x: Vec<Complex> = (0..n)
            .map(|_| Complex::new(rng.normal() as f64, rng.normal() as f64))
            .collect();
        let mut got = x.clone();
        fft(&mut got);
        let want = dft_naive(&x);
        let plan = FftPlan::shared(n);
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g.re - w.re).abs() < tol * (n as f64),
                "n={n} ({}) bin {i}: {g:?} vs {w:?}",
                plan.strategy()
            );
            assert!((g.im - w.im).abs() < tol * (n as f64), "n={n} bin {i}");
        }
    }

    #[test]
    fn matches_naive_dft_pow2() {
        for n in [2usize, 4, 8, 16, 64] {
            assert_matches_naive(n, 1e-6);
        }
    }

    #[test]
    fn matches_naive_dft_awkward_sizes() {
        // The satellite contract: mixed-radix and Bluestein pinned
        // against the naive DFT at the acceptance sizes (96 = 2⁵·3,
        // 360 = 2³·3²·5, 769 prime, 1000 = 2³·5³) plus small odds,
        // generic-radix primes, and prime powers.
        for n in [3usize, 5, 6, 7, 9, 11, 12, 13, 15, 45, 49, 77, 96, 100, 143, 169, 360, 769, 1000]
        {
            assert_matches_naive(n, 1e-6);
        }
    }

    #[test]
    fn strategy_selection() {
        assert_eq!(FftPlan::new(1).strategy(), "trivial");
        assert_eq!(FftPlan::new(64).strategy(), "pow2");
        assert_eq!(FftPlan::new(96).strategy(), "mixed");
        assert_eq!(FftPlan::new(1000).strategy(), "mixed");
        assert_eq!(FftPlan::new(91).strategy(), "mixed"); // 7·13 generic radices
        assert_eq!(FftPlan::new(769).strategy(), "bluestein");
        assert_eq!(FftPlan::new(34).strategy(), "bluestein"); // 2·17
    }

    #[test]
    fn plan_cache_memoises() {
        let a = FftPlan::shared(360);
        let b = FftPlan::shared(360);
        assert!(Arc::ptr_eq(&a, &b), "same size must share one plan");
        assert_eq!(a.n(), 360);
    }

    #[test]
    fn plan_cache_is_bounded_under_mixed_length_traffic() {
        // Mixed-length traffic over more distinct sizes than the cap:
        // the process maps must stay bounded (LRU eviction), residency
        // accounting must stay finite, and every plan must still work.
        for i in 0..(2 * FFT_PLAN_CACHE_CAP) {
            let n = 2_000 + 2 * i; // distinct even sizes
            let p = RealFftPlan::shared(n);
            assert_eq!(p.n(), n);
            let _ = FftPlan::shared(n);
        }
        let (entries, bytes) = plan_cache_stats();
        assert!(
            entries <= 2 * FFT_PLAN_CACHE_CAP,
            "resident plans {entries} exceed both caps combined"
        );
        assert!(bytes > 0, "resident plans must account table bytes");
        // Evicted-then-requested sizes simply rebuild and still agree.
        let x: Vec<f32> = (0..2_000).map(|i| (i % 13) as f32 - 6.0).collect();
        let back = irfft(&rfft(&x), 2_000);
        assert_close(&x, &back, 1e-5, "rebuilt-after-evict plan");
    }

    #[test]
    fn good_conv_size_prefers_cheap_smooth_lengths() {
        // ≥ the bound, ≤ the next power of two, and cheaper (or equal)
        // by the work model.
        for min in [1usize, 2, 7, 100, 191, 719, 1537, 1999, 4095] {
            let m = good_conv_size(min);
            assert!(m >= min, "good_conv_size({min}) = {m} below bound");
            assert!(m <= min.next_power_of_two());
            assert!(fft_work_units(m) <= fft_work_units(min.next_power_of_two()));
        }
        // Pinned picks (also verified by the python reference model):
        // 192 = 2⁶·3 beats 256, 768 = 2⁸·3 beats 1024, 1600 = 2⁶·5²
        // beats 2048; just under a power of two, the pow2 size wins.
        assert_eq!(good_conv_size(191), 192);
        assert_eq!(good_conv_size(719), 768);
        assert_eq!(good_conv_size(1537), 1600);
        assert_eq!(good_conv_size(1999), 2048);
        assert_eq!(good_conv_size(128), 128);
    }

    #[test]
    fn radix_constants_are_trig_exact() {
        let pi = std::f64::consts::PI;
        assert!((SQRT3_2 - (3.0f64).sqrt() / 2.0).abs() < 1e-15);
        assert!((C72 - (2.0 * pi / 5.0).cos()).abs() < 1e-15);
        assert!((C144 - (4.0 * pi / 5.0).cos()).abs() < 1e-15);
        assert!((S72 - (2.0 * pi / 5.0).sin()).abs() < 1e-15);
        assert!((S144 - (4.0 * pi / 5.0).sin()).abs() < 1e-15);
    }

    #[test]
    fn prop_fft_roundtrip_any_length() {
        check("fft roundtrip (any n)", |rng| {
            let n = size(rng, 1, 3000);
            let x: Vec<Complex> = (0..n)
                .map(|_| Complex::new(rng.normal() as f64, rng.normal() as f64))
                .collect();
            let mut buf = x.clone();
            fft(&mut buf);
            ifft(&mut buf);
            for (a, b) in x.iter().zip(buf.iter()) {
                assert!((a.re - b.re).abs() < 1e-8, "n={n}: {a:?} vs {b:?}");
                assert!((a.im - b.im).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn prop_rfft_roundtrip_any_length() {
        check("rfft roundtrip (any n)", |rng| {
            let n = size(rng, 1, 3000);
            let x = vecf(rng, n);
            let back = irfft(&rfft(&x), n);
            assert_close(&x, &back, 1e-5, "rfft/irfft");
        });
    }

    #[test]
    fn parseval() {
        let mut rng = crate::util::rng::Rng::new(3);
        for n in [256usize, 360, 769] {
            let x = rng.normals(n);
            let time: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
            let mut buf: Vec<Complex> = x.iter().map(|&v| Complex::new(v as f64, 0.0)).collect();
            fft(&mut buf);
            let freq: f64 = buf.iter().map(|c| c.abs().powi(2)).sum::<f64>() / n as f64;
            assert!((time - freq).abs() < 1e-6 * time, "n={n}: {time} vs {freq}");
        }
    }

    #[test]
    fn impulse_is_flat() {
        for n in [16usize, 15, 31] {
            let mut x = vec![0.0f32; n];
            x[0] = 1.0;
            let spec = rfft(&x);
            assert_eq!(spec.len(), n / 2 + 1);
            for c in spec {
                assert!((c.re - 1.0).abs() < 1e-9 && c.im.abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "spectrum/size mismatch")]
    fn irfft_rejects_wrong_bin_count() {
        let _ = irfft(&[Complex::ZERO; 5], 16);
    }

    /// The full-complex reference the r2c path must reproduce: transform
    /// the reals at length n, keep the first n/2+1 bins.
    fn rfft_reference(x: &[f32]) -> Vec<Complex> {
        let n = x.len();
        let mut buf: Vec<Complex> = x.iter().map(|&v| Complex::new(v as f64, 0.0)).collect();
        fft(&mut buf);
        buf.truncate(n / 2 + 1);
        buf
    }

    fn assert_real_plan_matches_complex(n: usize, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let x = rng.normals(n);
        let want = rfft_reference(&x);
        let plan = RealFftPlan::new(n);
        assert_eq!(plan.bins(), n / 2 + 1);
        assert_eq!(plan.is_packed(), n >= 2 && n % 2 == 0, "n={n}");
        // Odd Bluestein-class sizes must take the half-spectrum chirp
        // (never the full complex engine); odd smooth sizes keep the
        // mixed fallback, which is cheaper for them.
        let bluestein_class = n >= 3 && n % 2 == 1 && FftPlan::shared(n).strategy() == "bluestein";
        assert_eq!(plan.is_odd_real(), bluestein_class, "n={n}");
        let (mut got, mut scratch) = (Vec::new(), Vec::new());
        plan.rfft_into(&x, &mut got, &mut scratch);
        assert_eq!(got.len(), want.len(), "n={n}");
        let scale = 1.0f64.max(want.iter().map(|c| c.abs()).fold(0.0, f64::max));
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g.re - w.re).abs() < 1e-9 * scale && (g.im - w.im).abs() < 1e-9 * scale,
                "n={n} ({}) bin {i}: {g:?} vs {w:?}",
                plan.strategy()
            );
        }
        // And back: c2r of the reference spectrum recovers the signal.
        let mut back = vec![0.0f32; n];
        plan.irfft_into(&want, &mut back, &mut scratch);
        assert_close(&x, &back, 1e-5, "irfft_into");
    }

    #[test]
    fn real_plan_matches_complex_path_at_pinned_sizes() {
        // The satellite contract: even/odd/prime acceptance sizes plus
        // powers of two — 96 = 2⁵·3, 360 = 2³·3²·5, 769 prime (packed
        // half 384; the free-function path at odd n falls back), 1000 =
        // 2³·5³, 2^k up to 4096, and the h-odd/h-even parity cases.
        for (i, n) in [1usize, 2, 4, 6, 10, 16, 34, 96, 360, 769, 1000, 1024, 4096]
            .into_iter()
            .enumerate()
        {
            assert_real_plan_matches_complex(n, 40 + i as u64);
        }
    }

    #[test]
    fn prop_real_plan_matches_complex_path() {
        check("r2c vs complex path (any n)", |rng| {
            let n = size(rng, 1, 2000);
            let x = vecf(rng, n);
            let want = rfft_reference(&x);
            let plan = RealFftPlan::shared(n);
            let (mut got, mut scratch) = (Vec::new(), Vec::new());
            plan.rfft_into(&x, &mut got, &mut scratch);
            let scale = 1.0f64.max(want.iter().map(|c| c.abs()).fold(0.0, f64::max));
            for (g, w) in got.iter().zip(want.iter()) {
                assert!(
                    (g.re - w.re).abs() < 1e-8 * scale && (g.im - w.im).abs() < 1e-8 * scale,
                    "n={n}: {g:?} vs {w:?}"
                );
            }
            let mut back = vec![0.0f32; n];
            plan.irfft_into(&got, &mut back, &mut scratch);
            assert_close(&x, &back, 1e-5, "r2c roundtrip");
        });
    }

    #[test]
    fn real_plan_buffers_are_reused_without_growth() {
        // The zero-allocation contract: once warm, repeated transforms
        // through the same buffers never grow capacity.
        let plan = RealFftPlan::shared(256);
        let mut rng = crate::util::rng::Rng::new(9);
        let (mut out, mut scratch) = (Vec::new(), Vec::new());
        let mut back = vec![0.0f32; 256];
        plan.rfft_into(&rng.normals(256), &mut out, &mut scratch);
        plan.irfft_into(&out.clone(), &mut back, &mut scratch);
        let (co, cs) = (out.capacity(), scratch.capacity());
        for _ in 0..4 {
            plan.rfft_into(&rng.normals(256), &mut out, &mut scratch);
            plan.irfft_into(&out.clone(), &mut back, &mut scratch);
        }
        assert_eq!(out.capacity(), co);
        assert_eq!(scratch.capacity(), cs);
    }

    #[test]
    fn real_plan_counts_fast_path_transforms() {
        let _g = crate::telemetry::test_guard();
        let was = crate::telemetry::enabled();
        crate::telemetry::set_enabled(true);
        let plan = RealFftPlan::shared(128);
        let series = crate::telemetry::global().counter("fft.real_fast_path");
        let before = series.get();
        let (mut out, mut scratch) = (Vec::new(), Vec::new());
        plan.rfft_into(&vec![1.0f32; 128], &mut out, &mut scratch);
        let mut back = vec![0.0f32; 128];
        plan.irfft_into(&out, &mut back, &mut scratch);
        assert_eq!(series.get() - before, 2, "one forward + one inverse packed transform");
        crate::telemetry::set_enabled(was);
    }

    #[test]
    fn real_plan_matches_complex_path_at_pinned_odd_sizes() {
        // The satellite contract: odd acceptance sizes pinned against
        // the complex reference — 97 prime, 361 = 19², 769 prime (all
        // Bluestein-class → half-spectrum chirp), 1001 = 7·11·13 (odd
        // smooth → the mixed fallback is the cheaper route).
        for (i, n) in [97usize, 361, 769, 1001].into_iter().enumerate() {
            assert_real_plan_matches_complex(n, 70 + i as u64);
        }
    }

    #[test]
    fn prop_odd_real_roundtrip() {
        check("odd r2c roundtrip (random odd n)", |rng| {
            let n = 2 * size(rng, 1, 1200) + 1;
            let x = vecf(rng, n);
            let plan = RealFftPlan::shared(n);
            let (mut spec, mut scratch) = (Vec::new(), Vec::new());
            plan.rfft_into(&x, &mut spec, &mut scratch);
            assert_eq!(spec.len(), n / 2 + 1);
            let mut back = vec![0.0f32; n];
            plan.irfft_into(&spec, &mut back, &mut scratch);
            assert_close(&x, &back, 1e-5, "odd r2c roundtrip");
        });
    }

    #[test]
    fn real_plan_counts_odd_real_path_not_fallback() {
        let _g = crate::telemetry::test_guard();
        let was = crate::telemetry::enabled();
        crate::telemetry::set_enabled(true);
        let plan = RealFftPlan::shared(769);
        assert!(plan.is_odd_real(), "769 is Bluestein-class and must take the chirp route");
        let fast = crate::telemetry::global().counter("fft.real_fast_path");
        let odd = crate::telemetry::global().counter("fft.real_fast_path.odd");
        let fallback = crate::telemetry::global().counter("fft.real_fallback");
        let (f0, o0, b0) = (fast.get(), odd.get(), fallback.get());
        let (mut out, mut scratch) = (Vec::new(), Vec::new());
        plan.rfft_into(&vec![1.0f32; 769], &mut out, &mut scratch);
        let mut back = vec![0.0f32; 769];
        plan.irfft_into(&out, &mut back, &mut scratch);
        assert_eq!(fast.get() - f0, 2, "odd chirp transforms count as fast-path");
        assert_eq!(odd.get() - o0, 2, "…attributed to the odd share");
        assert_eq!(fallback.get() - b0, 0, "odd n must not route through the complex fallback");
        crate::telemetry::set_enabled(was);
    }

    #[test]
    fn shared_scratch_capacity_pins_at_high_water_across_widths() {
        // The bucketed-serving shape: one scratch/out pair shared by a
        // shrinking-then-growing width sequence.  After one full pass
        // establishes the high-water mark, repeated passes (including
        // regrowth after the smallest width) must never reallocate.
        fn roundtrip(
            n: usize,
            rng: &mut crate::util::rng::Rng,
            out: &mut Vec<Complex>,
            scratch: &mut Vec<Complex>,
            back: &mut Vec<f32>,
        ) {
            let plan = RealFftPlan::shared(n);
            let x = rng.normals(n);
            plan.rfft_into(&x, out, scratch);
            back.clear();
            back.resize(n, 0.0);
            plan.irfft_into(out, back, scratch);
        }
        let widths = [1024usize, 256, 96, 769, 1024, 97, 360, 1024];
        let mut rng = crate::util::rng::Rng::new(21);
        let (mut out, mut scratch) = (Vec::new(), Vec::new());
        let mut back = Vec::new();
        for &n in &widths {
            roundtrip(n, &mut rng, &mut out, &mut scratch, &mut back);
        }
        let (co, cs) = (out.capacity(), scratch.capacity());
        for _ in 0..3 {
            for &n in &widths {
                roundtrip(n, &mut rng, &mut out, &mut scratch, &mut back);
            }
        }
        assert_eq!(out.capacity(), co, "spectrum buffer grew past its high-water mark");
        assert_eq!(scratch.capacity(), cs, "scratch grew past its high-water mark");
    }
}
