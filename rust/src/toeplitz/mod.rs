//! Pure-Rust Toeplitz substrate: the paper's operators as CPU oracles.
//!
//! Everything the JAX/Pallas layer computes on the request path exists
//! here too, independently implemented: dense and FFT Toeplitz matvec,
//! the asymmetric-SKI factorisation (both the mathematically
//! O(n + r log r) sparse path and the practically-faster dense-matmul
//! path the paper ships), the inverse time warp, decay bias, and the
//! Appendix-B causal-SKI cumulative-sum scan.  Uses:
//!
//! * cross-checking the AOT artifacts' numerics from Rust,
//! * the Theorem 1 error-bound property tests (with `crate::linalg`),
//! * the fig10/fig11/App-B micro-benchmarks where the paper's
//!   asymptotic arguments are measured directly.

mod kernels;
mod op;
pub mod parallel;
mod ski;

pub use kernels::{decay_bias, gaussian_kernel, rational_kernel, warp, TableKernel};
pub use op::{
    apply_causal_plan, apply_causal_plan_into, apply_causal_plan_with, apply_causal_taps, build_op,
    with_scratch, BackendKind, CostModel, DenseOp, Dispatch, DispatchQuery, FftOp, FreqCausalOp,
    OpScratch, SparseLowRankOp, SpectralPlan, ToeplitzOp, PRESSURE_DOWNSHIFT,
};
pub use parallel::{apply_batch_flat_sharded, apply_batch_sharded};
pub use ski::{causal_ski_scan, inducing_grid, interp_weights, Ski};

use crate::dsp::{irfft, rfft, Complex};

/// Lags representation of one Toeplitz matrix `T_ij = k[i-j]`:
/// `lags[t + n - 1] = k[t]` for `t in -(n-1)..=(n-1)`.
#[derive(Debug, Clone)]
pub struct ToeplitzKernel {
    pub n: usize,
    pub lags: Vec<f32>,
}

impl ToeplitzKernel {
    pub fn from_fn(n: usize, f: impl Fn(i64) -> f32) -> Self {
        let lags = (-(n as i64 - 1)..=(n as i64 - 1)).map(f).collect();
        ToeplitzKernel { n, lags }
    }

    pub fn at(&self, lag: i64) -> f32 {
        self.lags[(lag + self.n as i64 - 1) as usize]
    }

    /// Kernel value at a real-valued lag by linear interpolation of
    /// the stored integer lags (clamped at the ends) — how a kernel
    /// known only as a lag table is evaluated at SKI inducing-point
    /// differences (§3.2.1).
    pub fn at_real(&self, lag: f64) -> f32 {
        let max = (self.n - 1) as f64;
        let t = lag.clamp(-max, max);
        let lo = t.floor();
        let frac = (t - lo) as f32;
        let lo_i = lo as i64;
        if frac == 0.0 {
            return self.at(lo_i);
        }
        (1.0 - frac) * self.at(lo_i) + frac * self.at(lo_i + 1)
    }

    /// Zero all negative lags (causal masking).
    pub fn causal(mut self) -> Self {
        for t in 0..self.n - 1 {
            self.lags[t] = 0.0;
        }
        self
    }

    /// Build a causal kernel from its non-negative lags
    /// (`taps[t] = k[t]`, all negative lags zero).
    pub fn from_causal_taps(taps: &[f32]) -> Self {
        let n = taps.len();
        assert!(n >= 1, "causal kernel needs at least the lag-0 tap");
        let mut lags = vec![0.0f32; 2 * n - 1];
        lags[n - 1..].copy_from_slice(taps);
        ToeplitzKernel { n, lags }
    }

    /// Non-negative lags `k[0..n-1]` — the taps a causal (streaming)
    /// decoder needs.  Lag order matches [`ToeplitzKernel::at`]:
    /// `causal_taps()[t] == at(t)`.
    pub fn causal_taps(&self) -> Vec<f32> {
        self.lags[self.n - 1..].to_vec()
    }

    /// True when every strictly-negative lag is zero, i.e. the operator
    /// is lower-triangular and can be decoded autoregressively.
    pub fn is_causal(&self) -> bool {
        self.lags[..self.n - 1].iter().all(|&v| v == 0.0)
    }

    /// Dense O(n²) action `y = T x`.
    pub fn apply_dense(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.n];
        self.apply_dense_into(x, &mut y);
        y
    }

    /// [`apply_dense`](Self::apply_dense) into a caller-provided row —
    /// the flat-batch ABI's allocation-free path.  Same accumulation
    /// order, so the two are bitwise identical.
    pub fn apply_dense_into(&self, x: &[f32], out: &mut [f32]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert_eq!(out.len(), n);
        for (i, o) in out.iter_mut().enumerate() {
            *o = (0..n).map(|j| self.at(i as i64 - j as i64) * x[j]).sum();
        }
    }

    /// O(n log n) action via the 2n circulant embedding — any n ≥ 1
    /// (the FFT engine handles arbitrary lengths; callers wanting the
    /// cheapest transform length should hold a `SpectralPlan`).
    pub fn apply_fft(&self, x: &[f32]) -> Vec<f32> {
        let n = self.n;
        assert_eq!(x.len(), n);
        // circulant first column: [k_0..k_{n-1}, 0, k_{-(n-1)}..k_{-1}]
        let mut c = vec![0.0f32; 2 * n];
        for t in 0..n {
            c[t] = self.at(t as i64);
        }
        for t in 1..n {
            c[n + t] = self.at(t as i64 - n as i64);
        }
        let ch = rfft(&c);
        let mut xp = vec![0.0f32; 2 * n];
        xp[..n].copy_from_slice(x);
        let xh = rfft(&xp);
        let yh: Vec<Complex> = ch.iter().zip(xh.iter()).map(|(a, b)| a.mul(*b)).collect();
        let y = irfft(&yh, 2 * n);
        y[..n].to_vec()
    }

    /// Dense matrix form (for the linalg-based error analyses).
    pub fn dense(&self) -> crate::linalg::Mat {
        crate::linalg::Mat::from_fn(self.n, self.n, |i, j| {
            self.at(i as i64 - j as i64) as f64
        })
    }
}

/// Depthwise 1-D convolution — the sparse component's action.
/// `causal`: taps cover lags `0..m-1`; otherwise centred (lag `t-m/2`).
pub fn conv1d(x: &[f32], w: &[f32], causal: bool) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    conv1d_into(x, w, causal, &mut y);
    y
}

/// [`conv1d`] into a caller-provided row (same accumulation order —
/// bitwise identical; the flat-batch ABI's allocation-free path).
pub fn conv1d_into(x: &[f32], w: &[f32], causal: bool, out: &mut [f32]) {
    let n = x.len();
    assert_eq!(out.len(), n, "conv1d_into: output length mismatch");
    let m = w.len();
    let c = if causal { 0 } else { (m / 2) as i64 };
    for (i, o) in out.iter_mut().enumerate() {
        let i = i as i64;
        let mut acc = 0.0;
        for (t, &wt) in w.iter().enumerate() {
            let j = i - (t as i64 - c);
            if (0..n as i64).contains(&j) {
                acc += wt * x[j as usize];
            }
        }
        *o = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check, size, vecf};

    #[test]
    fn prop_fft_matches_dense() {
        // Any n — the 2n circulant embedding no longer needs 2n to be
        // a power of two.
        check("toeplitz fft == dense (any n)", |rng| {
            let n = size(rng, 1, 400);
            let k = ToeplitzKernel { n, lags: vecf(rng, 2 * n - 1) };
            let x = vecf(rng, n);
            assert_close(&k.apply_fft(&x), &k.apply_dense(&x), 1e-4, "fft vs dense");
        });
    }

    #[test]
    fn prop_causal_masks_future() {
        check("causal toeplitz ignores future", |rng| {
            let n = 1 << size(rng, 2, 7);
            let k = ToeplitzKernel { n, lags: vecf(rng, 2 * n - 1) }.causal();
            let mut x = vecf(rng, n);
            let y0 = k.apply_dense(&x);
            let cut = n / 2;
            for v in x.iter_mut().skip(cut) {
                *v = 1e3;
            }
            let y1 = k.apply_dense(&x);
            assert_close(&y0[..cut], &y1[..cut], 1e-5, "prefix changed");
        });
    }

    #[test]
    fn prop_causal_taps_roundtrip() {
        check("causal taps roundtrip", |rng| {
            let n = size(rng, 1, 128);
            let taps = vecf(rng, n);
            let k = ToeplitzKernel::from_causal_taps(&taps);
            assert!(k.is_causal());
            assert_eq!(k.causal_taps(), taps);
            for (t, &v) in taps.iter().enumerate() {
                assert_eq!(k.at(t as i64), v);
            }
        });
    }

    #[test]
    fn prop_causal_masking_reaches_taps() {
        check("causal() then causal_taps == positive lags", |rng| {
            let n = size(rng, 2, 64);
            let k = ToeplitzKernel { n, lags: vecf(rng, 2 * n - 1) };
            let taps: Vec<f32> = (0..n as i64).map(|t| k.at(t)).collect();
            let masked = k.causal();
            assert!(masked.is_causal());
            assert_eq!(masked.causal_taps(), taps);
        });
    }

    #[test]
    fn prop_at_real_interpolates_lags() {
        check("at_real hits and interpolates integer lags", |rng| {
            let n = size(rng, 2, 64);
            let k = ToeplitzKernel { n, lags: vecf(rng, 2 * n - 1) };
            for lag in -(n as i64 - 1)..=(n as i64 - 1) {
                assert_eq!(k.at_real(lag as f64), k.at(lag), "grid point {lag}");
            }
            let max = (n - 1) as f64;
            // Clamped beyond the stored range.
            assert_eq!(k.at_real(max + 5.0), k.at(n as i64 - 1));
            assert_eq!(k.at_real(-max - 5.0), k.at(-(n as i64 - 1)));
            // Midpoints are the average of the neighbours.
            for lag in -(n as i64 - 1)..(n as i64 - 1) {
                let mid = k.at_real(lag as f64 + 0.5);
                let want = 0.5 * (k.at(lag) + k.at(lag + 1));
                assert!((mid - want).abs() < 1e-5, "midpoint {lag}: {mid} vs {want}");
            }
        });
    }

    #[test]
    fn conv_matches_toeplitz_band() {
        check("conv1d == banded toeplitz", |rng| {
            let n = 1 << size(rng, 2, 7);
            let m = size(rng, 1, 9).min(n);
            let w = vecf(rng, m);
            let causal = rng.bool(0.5);
            let c = if causal { 0 } else { (m / 2) as i64 };
            let k = ToeplitzKernel::from_fn(n, |lag| {
                // y[i] += w[t] x[i - (t - c)] => lag t - c carries w[t]
                let t = lag + c;
                if (0..m as i64).contains(&t) {
                    w[t as usize]
                } else {
                    0.0
                }
            });
            let x = vecf(rng, n);
            assert_close(&conv1d(&x, &w, causal), &k.apply_dense(&x), 1e-4, "conv");
        });
    }
}
