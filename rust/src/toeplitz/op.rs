//! Unified Toeplitz operator backends — the one interface every
//! forward path in the crate goes through.
//!
//! The paper ships two headline accelerations that were previously
//! disconnected fragments here: the sparse + low-rank decomposition
//! with asymmetric SKI for bidirectional models (§3.2) and the
//! frequency-domain causal kernel whose imaginary part comes from a
//! Hilbert transform of the real part (§3.3).  [`ToeplitzOp`] makes
//! them (and the dense / FFT baselines) interchangeable behind one
//! `apply` surface, and [`Dispatch`] picks the cheapest backend for a
//! given `(n, r, w, causal, batch)` shape from a calibrated cost
//! model — per-workload instead of per-callsite.
//!
//! | backend | operator | complexity |
//! |---|---|---|
//! | [`DenseOp`] | dense matvec oracle | O(n²) |
//! | [`FftOp`] | circulant embedding at the cheapest smooth length ≥ 2n-1, cached spectrum + scratch | O(n log n) |
//! | [`SparseLowRankOp`] | width-w band + asymmetric SKI `W A Wᵀ` | O(nw + n + r log r) |
//! | [`FreqCausalOp`] | Hilbert-completed causal spectrum (§3.3.1) | O(n log n), one fewer FFT |
//!
//! Every backend accepts **any n ≥ 1**: the spectral paths run on the
//! mixed-radix/Bluestein plan engine (`dsp::FftPlan`), and the cost
//! model prices each shape's actual transform factorization instead of
//! special-casing powers of two.

use std::cell::RefCell;
use std::sync::Arc;

use crate::dsp::{
    causal_spectrum, good_conv_size, irfft, rfft, rfft_work_units, Complex, FftPlan, RealFftPlan,
};

use super::{conv1d_into, Ski, ToeplitzKernel};

/// Reusable scratch for lock-free spectral applies.  Every thread —
/// pool workers and plain callers alike — owns one arena
/// ([`with_scratch`]), so the hot path of [`FftOp`] /
/// [`FreqCausalOp`] / [`SparseLowRankOp`] never locks and never
/// allocates in steady state.  Buffers grow on demand and are kept.
#[derive(Debug, Default)]
pub struct OpScratch {
    /// Half-spectrum bins (`m/2 + 1`) of the transformed signal.
    pub cbuf: Vec<Complex>,
    /// Packed half-length complex work buffer for the r2c engine.
    pub half: Vec<Complex>,
    /// m-point zero-padded real signal, reused as the inverse output.
    pub xpad: Vec<f32>,
    /// SKI inducing-space vectors (`u = Wᵀx`, `v = A u`).
    pub u: Vec<f32>,
    pub v: Vec<f32>,
    /// Per-channel gather buffer for the decode oracle's flat forward.
    pub row: Vec<f32>,
}

thread_local! {
    /// One scratch arena per thread, reused for the life of the thread.
    static ARENA: RefCell<OpScratch> = RefCell::new(OpScratch::default());
}

/// Run `f` with this thread's persistent scratch arena.  **Not
/// re-entrant**: `f` must not call `with_scratch` again.  The
/// discipline that keeps this safe: only scratch-less entry points
/// ([`ToeplitzOp::apply`], [`ToeplitzOp::apply_batch`],
/// [`apply_causal_plan`], [`Ski::apply_sparse`], the shard workers)
/// borrow the arena; everything taking `&mut OpScratch` never does.
pub fn with_scratch<R>(f: impl FnOnce(&mut OpScratch) -> R) -> R {
    ARENA.with(|a| f(&mut a.borrow_mut()))
}

/// One Toeplitz operator action `y = T x`, backend-agnostic.
///
/// `Send + Sync` so trait objects ride the server executor closures
/// and `apply_batch` can be shared across client threads.
pub trait ToeplitzOp: Send + Sync {
    /// Sequence length the operator acts on.
    fn n(&self) -> usize;

    /// Short stable name (`dense`/`fft`/`ski`/`freq`) for reports.
    fn name(&self) -> &'static str;

    /// Rough multiply-add count of one `apply` — the structural input
    /// to [`Dispatch`]'s cost model and the bench reports.
    fn flops_estimate(&self) -> f64;

    /// Estimated bytes of operator-owned tables (kernel lags, band
    /// taps, cached spectra) — the per-plan memory accounting behind
    /// the `plan.cache.bytes` gauge.  Shared process-wide FFT twiddle
    /// tables are *not* counted here; the `fft.plan_cache.bytes` gauge
    /// accounts for those.
    fn resident_bytes(&self) -> usize {
        4 * self.n()
    }

    /// The spectral transform length this operator applies on, when it
    /// has one (`None` for time-domain backends).
    fn transform_len(&self) -> Option<usize> {
        None
    }

    /// Which complex engine backs the spectral path
    /// (`trivial|pow2|mixed|bluestein`), when there is one.
    fn transform_strategy(&self) -> Option<&'static str> {
        None
    }

    /// `y = T x` for one length-n signal.
    fn apply(&self, x: &[f32]) -> Vec<f32>;

    /// `y = T x` through caller-owned scratch.  Bitwise identical to
    /// [`apply`](Self::apply); backends whose `apply` locks internal
    /// shared scratch override this so the shard runtime's per-worker
    /// arenas keep the hot path lock-free.
    fn apply_with_scratch(&self, x: &[f32], _scratch: &mut OpScratch) -> Vec<f32> {
        self.apply(x)
    }

    /// Apply to every row; backends override to amortise plan/scratch.
    /// (The parallel counterpart is
    /// [`apply_batch_sharded`](super::apply_batch_sharded).)
    fn apply_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| self.apply(x)).collect()
    }

    /// `ys = T xs` over `rows` length-n signals packed row-major in
    /// flat buffers — the zero-allocation batch ABI the serving path
    /// runs on (no per-row `Vec`s).  Every backend overrides the
    /// default per-row fallback with an in-place row loop; each row's
    /// arithmetic is identical to
    /// [`apply_with_scratch`](Self::apply_with_scratch), so flat and
    /// per-row execution agree bitwise.  (The parallel counterpart is
    /// [`apply_batch_flat_sharded`](super::apply_batch_flat_sharded).)
    fn apply_batch_flat(&self, xs: &[f32], rows: usize, out: &mut [f32], scratch: &mut OpScratch) {
        let n = self.n();
        assert_eq!(xs.len(), rows * n, "apply_batch_flat: input shape mismatch");
        assert_eq!(out.len(), rows * n, "apply_batch_flat: output shape mismatch");
        for (x, y) in xs.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            y.copy_from_slice(&self.apply_with_scratch(x, scratch));
        }
    }
}

/// The dense O(n²) oracle — exact, cache-friendly at small n, and the
/// reference every other backend is tested against.
#[derive(Debug, Clone)]
pub struct DenseOp {
    pub kernel: ToeplitzKernel,
}

impl ToeplitzOp for DenseOp {
    fn n(&self) -> usize {
        self.kernel.n
    }

    fn name(&self) -> &'static str {
        "dense"
    }

    fn flops_estimate(&self) -> f64 {
        2.0 * (self.kernel.n as f64) * (self.kernel.n as f64)
    }

    fn resident_bytes(&self) -> usize {
        self.kernel.lags.len() * std::mem::size_of::<f32>()
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.kernel.apply_dense(x)
    }

    fn apply_batch_flat(&self, xs: &[f32], rows: usize, out: &mut [f32], _scratch: &mut OpScratch) {
        let n = self.kernel.n;
        assert_eq!(xs.len(), rows * n, "apply_batch_flat: input shape mismatch");
        assert_eq!(out.len(), rows * n, "apply_batch_flat: output shape mismatch");
        for (x, y) in xs.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            self.kernel.apply_dense_into(x, y);
        }
    }
}

/// An immutable circulant-multiply plan: the kernel spectrum on an
/// `m ≥ 2n-1` transform grid with **no attached scratch**, so one plan
/// is shared lock-free by any number of workers, each supplying its
/// own [`OpScratch`].  The decode oracle keeps one plan per channel;
/// [`FftOp`] wraps one plan with a `Mutex` scratch for plain
/// single-caller use.
///
/// Any `n ≥ 1` works: [`SpectralPlan::new`] picks the cheapest smooth
/// transform length `m = good_conv_size(2n-1)` (the circulant
/// embedding is exact for every `m ≥ 2n-1`), so awkward and prime `n`
/// pay a nearby mixed-radix size instead of either Bluestein or the
/// old panic.
#[derive(Debug, Clone)]
pub struct SpectralPlan {
    n: usize,
    /// Transform length (`good_conv_size(2n-1)`, or exactly `2n` when
    /// built from rFFT bins on the 2n grid).
    m: usize,
    /// Kernel **half-spectrum** (`m/2 + 1` non-redundant bins of the
    /// circulant first column), split into re/im planes so the
    /// pointwise multiply runs on contiguous f64 lanes.  Conjugate
    /// symmetry makes these bins the whole product: both operands are
    /// real, so the full-spectrum multiply is determined by its first
    /// half.
    spec_re: Vec<f64>,
    spec_im: Vec<f64>,
    /// The shared r2c transform plan for `m` (lock-free after build).
    rplan: Arc<RealFftPlan>,
}

impl SpectralPlan {
    pub fn new(kernel: &ToeplitzKernel) -> SpectralPlan {
        let n = kernel.n;
        assert!(n >= 1, "SpectralPlan needs n >= 1");
        let m = good_conv_size(2 * n - 1);
        // Circulant first column on the m grid: positive lags at the
        // front, negative lags wrapped to the back (m ≥ 2n-1 keeps the
        // two ranges disjoint, so the embedding stays exact).
        let mut c = vec![0.0f32; m];
        for (t, v) in c.iter_mut().enumerate().take(n) {
            *v = kernel.at(t as i64);
        }
        for t in 1..n {
            c[m - t] = kernel.at(-(t as i64));
        }
        Self::from_half_spectrum(n, m, &rfft(&c))
    }

    /// Build from the n+1 non-redundant rFFT bins of a 2n circulant
    /// column.  This is how [`FreqCausalOp`] consumes the
    /// Hilbert-completed causal spectrum directly — no time-domain
    /// kernel materialisation, no kernel FFT, and since the engine
    /// multiplies in the half-spectrum the bins are stored as-is (the
    /// old full-spectrum Hermitian completion is gone).  The transform
    /// length is pinned to `2n` (the grid the bins live on); any
    /// `n ≥ 1` works.
    pub fn from_rfft_bins(n: usize, bins: &[Complex]) -> SpectralPlan {
        assert!(n >= 1, "SpectralPlan needs n >= 1");
        assert_eq!(bins.len(), n + 1, "need n+1 rFFT bins for a 2n circulant");
        Self::from_half_spectrum(n, 2 * n, bins)
    }

    fn from_half_spectrum(n: usize, m: usize, bins: &[Complex]) -> SpectralPlan {
        debug_assert_eq!(bins.len(), m / 2 + 1);
        SpectralPlan {
            n,
            m,
            spec_re: bins.iter().map(|c| c.re).collect(),
            spec_im: bins.iter().map(|c| c.im).collect(),
            rplan: RealFftPlan::shared(m),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The transform length this plan runs on (`≥ 2n - 1`).
    pub fn transform_len(&self) -> usize {
        self.m
    }

    /// Which complex engine the shared transform plan runs on
    /// (`trivial|pow2|mixed|bluestein`).
    pub fn strategy(&self) -> &'static str {
        self.rplan.strategy()
    }

    /// Bytes of plan-owned spectrum tables (the shared r2c transform
    /// plan is accounted by the FFT plan cache, not here).
    pub fn resident_bytes(&self) -> usize {
        (self.spec_re.capacity() + self.spec_im.capacity()) * std::mem::size_of::<f64>()
    }

    /// One circulant apply through caller buffers — the lock-free,
    /// allocation-free hot path (scratch grows once, then every apply
    /// reuses it).  Accepts any prefix `x.len() ≤ n`, zero-padded to
    /// the transform grid (the decode oracle applies causal plans to
    /// growing prefixes); `out` receives exactly `x.len()` values.
    /// Output is a pure function of `(self, x)`: scratch contents are
    /// fully overwritten, so results are bitwise identical whichever
    /// thread's arena is used.
    pub fn apply_into(&self, x: &[f32], out: &mut [f32], scratch: &mut OpScratch) {
        let _span = crate::telemetry::span(&crate::telemetry::SPAN_FFT_FORWARD);
        assert!(
            x.len() <= self.n,
            "SpectralPlan size mismatch: x has {} values, plan n={}",
            x.len(),
            self.n
        );
        assert_eq!(out.len(), x.len(), "SpectralPlan apply_into: output length mismatch");
        scratch.xpad.clear();
        scratch.xpad.extend_from_slice(x);
        scratch.xpad.resize(self.m, 0.0);
        self.rplan.rfft_into(&scratch.xpad, &mut scratch.cbuf, &mut scratch.half);
        for (v, (&sr, &si)) in
            scratch.cbuf.iter_mut().zip(self.spec_re.iter().zip(self.spec_im.iter()))
        {
            let (re, im) = (v.re, v.im);
            v.re = re * sr - im * si;
            v.im = re * si + im * sr;
        }
        self.rplan.irfft_into(&scratch.cbuf, &mut scratch.xpad, &mut scratch.half);
        out.copy_from_slice(&scratch.xpad[..out.len()]);
    }

    /// [`apply_into`](Self::apply_into) for a full-length signal,
    /// returning a fresh output row (the per-row `Vec` ABI).
    pub fn apply_with(&self, x: &[f32], scratch: &mut OpScratch) -> Vec<f32> {
        let n = self.n;
        assert_eq!(x.len(), n, "SpectralPlan size mismatch: x has {} values, plan n={n}", x.len());
        let mut y = vec![0.0f32; n];
        self.apply_into(x, &mut y, scratch);
        y
    }
}

/// O(n log n) circulant-embedding apply with the kernel's
/// half-spectrum computed **once** at construction (a
/// [`SpectralPlan`]), running two packed r2c transforms per apply.
/// Scratch-less calls borrow the calling thread's arena
/// ([`with_scratch`]) — no `Mutex`, so casual single-threaded callers
/// never contend and the hot path allocates nothing beyond the output
/// row (nothing at all on the flat ABI).
pub struct FftOp {
    plan: Arc<SpectralPlan>,
}

impl FftOp {
    pub fn new(kernel: &ToeplitzKernel) -> FftOp {
        FftOp::from_plan(SpectralPlan::new(kernel))
    }

    /// See [`SpectralPlan::from_rfft_bins`].
    pub fn from_rfft_bins(n: usize, bins: &[Complex]) -> FftOp {
        FftOp::from_plan(SpectralPlan::from_rfft_bins(n, bins))
    }

    pub fn from_plan(plan: SpectralPlan) -> FftOp {
        FftOp { plan: Arc::new(plan) }
    }

    /// Wrap an already-shared plan without copying its spectrum — how
    /// an `ExecutionPlan` and its operator share one set of tables.
    pub fn from_shared(plan: Arc<SpectralPlan>) -> FftOp {
        FftOp { plan }
    }

    /// The shareable lock-free plan inside this operator.
    pub fn plan(&self) -> &SpectralPlan {
        &self.plan
    }
}

impl ToeplitzOp for FftOp {
    fn n(&self) -> usize {
        self.plan.n
    }

    fn name(&self) -> &'static str {
        "fft"
    }

    fn flops_estimate(&self) -> f64 {
        // Two r2c transforms at the plan's actual factorization (10
        // flops per modeled radix-2-butterfly unit) plus the bin
        // multiply.
        let m = self.plan.transform_len();
        2.0 * 10.0 * rfft_work_units(m) + 6.0 * m as f64
    }

    fn resident_bytes(&self) -> usize {
        self.plan.resident_bytes()
    }

    fn transform_len(&self) -> Option<usize> {
        Some(self.plan.transform_len())
    }

    fn transform_strategy(&self) -> Option<&'static str> {
        Some(self.plan.strategy())
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        with_scratch(|s| self.plan.apply_with(x, s))
    }

    fn apply_with_scratch(&self, x: &[f32], scratch: &mut OpScratch) -> Vec<f32> {
        self.plan.apply_with(x, scratch)
    }

    fn apply_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        // One arena borrow, the whole batch.
        with_scratch(|s| xs.iter().map(|x| self.plan.apply_with(x, s)).collect())
    }

    fn apply_batch_flat(&self, xs: &[f32], rows: usize, out: &mut [f32], scratch: &mut OpScratch) {
        let n = self.plan.n;
        assert_eq!(xs.len(), rows * n, "apply_batch_flat: input shape mismatch");
        assert_eq!(out.len(), rows * n, "apply_batch_flat: output shape mismatch");
        for (x, y) in xs.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            self.plan.apply_into(x, y, scratch);
        }
    }
}

/// Paper §3.2: `T ≈ B + W A Wᵀ` — a width-`w` banded convolution for
/// the spiky near-diagonal mass plus asymmetric SKI for the smooth
/// remainder, with the band **subtracted from the SKI kernel fit** so
/// the two components never double-count a lag.
pub struct SparseLowRankOp {
    n: usize,
    /// Centred band taps: `band[j]` carries lag `j - w/2`.
    band: Vec<f32>,
    ski: Ski,
}

impl SparseLowRankOp {
    /// Build from a kernel function over real-valued lags (an RPE, a
    /// [`TableKernel`](super::TableKernel), or
    /// [`ToeplitzKernel::at_real`]): the band samples integer lags
    /// `|t| ≤ w/2`, the SKI Gram samples the band-subtracted remainder
    /// at inducing-point differences (§3.2.1).
    pub fn from_kernel_fn(n: usize, r: usize, w: usize, k: impl Fn(f64) -> f32) -> Self {
        assert!(w % 2 == 1, "band width must be odd (centred), got {w}");
        let half = (w / 2) as i64;
        let band: Vec<f32> = (-half..=half).map(|t| k(t as f64)).collect();
        let ski = Ski::from_kernel(n, r, move |t| {
            if t.abs() <= half as f64 {
                0.0
            } else {
                k(t)
            }
        });
        SparseLowRankOp { n, band, ski }
    }

    /// Build from a lag table by linear interpolation at the inducing
    /// points (kernels known only as learned lags).
    pub fn from_kernel(kernel: &ToeplitzKernel, r: usize, w: usize) -> Self {
        Self::from_kernel_fn(kernel.n, r, w, |t| kernel.at_real(t))
    }

    pub fn rank(&self) -> usize {
        self.ski.r
    }

    pub fn band_width(&self) -> usize {
        self.band.len()
    }

    pub fn ski(&self) -> &Ski {
        &self.ski
    }

    /// `out = (B + W A Wᵀ) x` through caller scratch — the
    /// allocation-free core every apply surface funnels into: the band
    /// convolution writes `out`, the SKI term accumulates on top
    /// ([`Ski::apply_sparse_add`]).
    fn apply_into(&self, x: &[f32], out: &mut [f32], scratch: &mut OpScratch) {
        assert_eq!(x.len(), self.n, "SparseLowRankOp size mismatch");
        conv1d_into(x, &self.band, false, out);
        self.ski.apply_sparse_add(x, out, scratch);
    }
}

impl ToeplitzOp for SparseLowRankOp {
    fn n(&self) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "ski"
    }

    fn flops_estimate(&self) -> f64 {
        let n = self.n as f64;
        let r = self.ski.r;
        // The inducing-Gram multiply takes whichever path is cheaper
        // at this rank (decided once at Ski construction) — any r, not
        // just powers of two, prices the spectral route now.  The
        // spectral side is a cached-spectrum [`SpectralPlan`] on the
        // gram's own smooth grid: two r2c transforms per call.
        let a = if self.ski.gram_fft {
            let m = good_conv_size(2 * r.max(1) - 1);
            2.0 * 10.0 * rfft_work_units(m) + 6.0 * m as f64
        } else {
            2.0 * (r as f64) * (r as f64)
        };
        2.0 * n * self.band.len() as f64 + 8.0 * n + a
    }

    fn resident_bytes(&self) -> usize {
        self.band.capacity() * std::mem::size_of::<f32>() + self.ski.resident_bytes()
    }

    fn transform_len(&self) -> Option<usize> {
        self.ski.gram_fft.then(|| good_conv_size(2 * self.ski.r.max(1) - 1))
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        with_scratch(|s| self.apply_with_scratch(x, s))
    }

    fn apply_with_scratch(&self, x: &[f32], scratch: &mut OpScratch) -> Vec<f32> {
        assert_eq!(x.len(), self.n, "SparseLowRankOp size mismatch");
        let mut y = vec![0.0f32; self.n];
        self.apply_into(x, &mut y, scratch);
        y
    }

    fn apply_batch_flat(&self, xs: &[f32], rows: usize, out: &mut [f32], scratch: &mut OpScratch) {
        let n = self.n;
        assert_eq!(xs.len(), rows * n, "apply_batch_flat: input shape mismatch");
        assert_eq!(out.len(), rows * n, "apply_batch_flat: output shape mismatch");
        for (x, y) in xs.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
            self.apply_into(x, y, scratch);
        }
    }
}

/// Paper §3.3: the causal operator built **in the frequency domain** —
/// the RPE models only the real (even) frequency response, the
/// discrete Hilbert transform supplies the imaginary part
/// (`dsp::causal_spectrum`), and the resulting n+1 bins are consumed
/// directly as the circulant multiply spectrum.  No explicit decay
/// bias, and one fewer FFT than materialising the time kernel first.
pub struct FreqCausalOp {
    /// Causal time-domain taps (`taps[τ] = k[τ]`) — the oracle view
    /// used by equivalence tests and the streaming decode planner.
    taps: Vec<f32>,
    fft: FftOp,
}

impl FreqCausalOp {
    /// From n+1 real frequency-response samples on `ω_m = mπ/n`.
    pub fn from_response(khat_r: &[f32]) -> FreqCausalOp {
        assert!(khat_r.len() >= 3, "need at least 3 response samples");
        let n = khat_r.len() - 1;
        let spec = causal_spectrum(khat_r);
        let kt = irfft(&spec, 2 * n);
        let taps = kt[..n].to_vec();
        // Consuming the bins directly pins every apply to the exact 2n
        // transform grid.  When that grid factorizes well (the common
        // case) it saves the kernel FFT; when it would run Bluestein,
        // one construction-time kernel FFT at the plan's own smooth
        // length is cheaper than paying the chirp-z embedding on every
        // request — the first n outputs are identical either way (the
        // dropped t = n tap only ever lands past the truncation).  The
        // 2n grid is even, so the r2c engine runs the **half-length**
        // plan at n — that is the strategy to probe (2n and n share
        // every odd prime factor, so the verdict matches the old
        // full-grid check).
        let fft = if FftPlan::shared(n).strategy() == "bluestein" {
            FftOp::new(&ToeplitzKernel::from_causal_taps(&taps))
        } else {
            FftOp::from_rfft_bins(n, &spec)
        };
        FreqCausalOp { taps, fft }
    }

    /// From an already-causal time kernel (the degenerate case where
    /// the taps are known: the Hilbert step is unnecessary and the
    /// spectrum comes from one kernel FFT).
    pub fn from_causal_kernel(kernel: &ToeplitzKernel) -> FreqCausalOp {
        assert!(kernel.is_causal(), "FreqCausalOp needs a causal kernel");
        FreqCausalOp { taps: kernel.causal_taps(), fft: FftOp::new(kernel) }
    }

    /// The causal taps as a [`ToeplitzKernel`] (oracles, SSM planning).
    pub fn kernel(&self) -> ToeplitzKernel {
        ToeplitzKernel::from_causal_taps(&self.taps)
    }

    pub fn causal_taps(&self) -> &[f32] {
        &self.taps
    }
}

impl ToeplitzOp for FreqCausalOp {
    fn n(&self) -> usize {
        self.fft.n()
    }

    fn name(&self) -> &'static str {
        "freq"
    }

    fn flops_estimate(&self) -> f64 {
        self.fft.flops_estimate()
    }

    fn resident_bytes(&self) -> usize {
        self.taps.capacity() * std::mem::size_of::<f32>() + self.fft.resident_bytes()
    }

    fn transform_len(&self) -> Option<usize> {
        self.fft.transform_len()
    }

    fn transform_strategy(&self) -> Option<&'static str> {
        self.fft.transform_strategy()
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.fft.apply(x)
    }

    fn apply_with_scratch(&self, x: &[f32], scratch: &mut OpScratch) -> Vec<f32> {
        self.fft.apply_with_scratch(x, scratch)
    }

    fn apply_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.fft.apply_batch(xs)
    }

    fn apply_batch_flat(&self, xs: &[f32], rows: usize, out: &mut [f32], scratch: &mut OpScratch) {
        self.fft.apply_batch_flat(xs, rows, out, scratch);
    }
}

/// Backend selector — `auto` defers to [`Dispatch`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Auto,
    Dense,
    Fft,
    Ski,
    Freq,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "auto" => BackendKind::Auto,
            "dense" => BackendKind::Dense,
            "fft" => BackendKind::Fft,
            "ski" => BackendKind::Ski,
            "freq" => BackendKind::Freq,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Dense => "dense",
            BackendKind::Fft => "fft",
            BackendKind::Ski => "ski",
            BackendKind::Freq => "freq",
        }
    }
}

/// Per-primitive wall-clock constants (ns), calibrated on this
/// container by `benches/backend_matrix.rs` (its JSON artifact records
/// the re-measured values every run).  The defaults reproduce the
/// measured crossovers: dense wins below n ≈ 64 (the r2c discount
/// pulled this down from n ≈ 128), the spectral paths above, and
/// sparse+low-rank beats FFT whenever r ≤ n/16.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// ns per dense multiply-add (tight n² inner loop).
    pub dense_mac_ns: f64,
    /// ns per FFT butterfly point (scalar f64 radix-2).
    pub fft_point_ns: f64,
    /// ns per sparse interpolation point (scatter/gather with weight
    /// recomputation).
    pub ski_point_ns: f64,
    /// ns per banded-convolution multiply-add.
    pub band_mac_ns: f64,
    /// ns of fixed overhead per shard submitted to the thread pool
    /// (queue push + worker wake + completion latch) — what makes
    /// small batches prefer the serial path.
    pub shard_overhead_ns: f64,
    /// Parallel-scalable fraction of each backend's batch work
    /// (Amdahl-style contention: the dense matvec streams the whole
    /// kernel table, so concurrent workers fight for memory bandwidth;
    /// the FFT butterflies are compute-dense and scale almost
    /// linearly; SKI's gather/scatter sits in between).
    pub dense_par: f64,
    pub fft_par: f64,
    pub ski_par: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            dense_mac_ns: 1.0,
            fft_point_ns: 6.0,
            ski_point_ns: 2.5,
            band_mac_ns: 1.2,
            shard_overhead_ns: 2_000.0,
            dense_par: 0.60,
            fft_par: 0.95,
            ski_par: 0.75,
        }
    }
}

impl CostModel {
    pub fn dense_cost(&self, n: usize) -> f64 {
        self.dense_mac_ns * (n as f64) * (n as f64)
    }

    /// Spectral apply cost at the transform length a [`SpectralPlan`]
    /// would actually pick for this `n`, priced by the real
    /// factorization of the **r2c fast path** (`rfft_work_units`):
    /// even grids pay one half-length transform plus the O(m)
    /// split/twiddle pass per direction — the ~2x discount that moves
    /// the dense→spectral crossover down to n ≈ 64 — while odd grids
    /// fall back to the full complex price, Bluestein penalty
    /// included.
    pub fn fft_cost(&self, n: usize) -> f64 {
        let m = good_conv_size(2 * n.max(1) - 1);
        self.fft_point_ns * (4.0 * rfft_work_units(m) + m as f64)
    }

    /// What `Ski::apply_sparse`'s spectral gram route actually costs.
    /// The gram multiply now runs a cached-spectrum [`SpectralPlan`]
    /// over the r-point inducing kernel — the same code as
    /// [`fft_cost`](Self::fft_cost) prices (two r2c transforms on the
    /// plan's own smooth grid; the kernel spectrum is built once at
    /// construction, not per call), so the two formulas are kept
    /// literally identical.
    pub fn gram_fft_cost(&self, r: usize) -> f64 {
        self.fft_cost(r)
    }

    pub fn ski_cost(&self, n: usize, r: usize, w: usize) -> f64 {
        let a = if super::ski::gram_prefers_fft(r) {
            self.gram_fft_cost(r)
        } else {
            self.dense_cost(r)
        };
        self.ski_point_ns * 4.0 * n as f64 + a + self.band_mac_ns * (n * w.max(1)) as f64
    }

    /// Wall-clock model of a **sharded** `apply_batch`: `rows`
    /// independent per-row applies of `row_ns` each, split into
    /// contiguous shards across `threads` workers.  The critical path
    /// is the fullest shard, with each concurrent row inflated by the
    /// backend's non-`scalable` fraction (memory-bound work does not
    /// speed up `threads`-fold) plus per-shard dispatch overhead.
    /// `threads <= 1` is exactly the serial cost.
    pub fn sharded_cost(&self, row_ns: f64, rows: usize, threads: usize, scalable: f64) -> f64 {
        let rows_f = rows.max(1) as f64;
        let t = (threads.max(1) as f64).min(rows_f);
        if t <= 1.0 {
            return row_ns * rows_f;
        }
        let contended = row_ns * (1.0 + (1.0 - scalable) * (t - 1.0));
        (rows_f / t).ceil() * contended + self.shard_overhead_ns * t
    }
}

/// The shape of one apply site — everything the dispatcher looks at.
#[derive(Debug, Clone, Copy)]
pub struct DispatchQuery {
    /// Sequence length.
    pub n: usize,
    /// SKI rank available (0 ⇒ no smooth kernel fit ⇒ SKI ineligible).
    pub r: usize,
    /// Band width for the sparse component (0 ⇒ no band).
    pub w: usize,
    /// Causal sites exclude SKI (Appendix B: the causal scan's
    /// sequential dependency negates its speedup) and prefer the
    /// Hilbert-built spectrum over FFT-with-decay-bias.
    pub causal: bool,
    /// Rows per `apply_batch` call.
    pub batch: usize,
    /// Worker threads available to shard the batch across (1 =
    /// serial).  Parallelism shifts the crossovers: backends whose
    /// work is compute-dense (spectral) scale better across workers
    /// than memory-bound ones (dense), so the dense→spectral crossover
    /// moves to smaller `n` as `threads` grows.
    pub threads: usize,
}

/// Queue pressure (see `server::admission::PressureGauge`, in [0, 1])
/// at which [`Dispatch::plan_pressured`] starts downshifting the
/// chosen backend one rung down the cost ladder: past this the server
/// is trading throughput for survival, below it the cost model's
/// accuracy-preferred pick stands.
pub const PRESSURE_DOWNSHIFT: f64 = 0.6;

/// Cost-model auto-dispatcher: picks the cheapest eligible backend
/// for a query.  Construct with a re-calibrated [`CostModel`] to
/// shift the crossovers for a different machine.
#[derive(Debug, Clone, Copy, Default)]
pub struct Dispatch {
    pub cost: CostModel,
}

impl Dispatch {
    pub fn new(cost: CostModel) -> Dispatch {
        Dispatch { cost }
    }

    /// Eligible `(kind, per-row ns, scalable fraction)` candidates.
    fn candidates(&self, q: &DispatchQuery) -> Vec<(BackendKind, f64, f64)> {
        let mut v = vec![(BackendKind::Dense, self.cost.dense_cost(q.n), self.cost.dense_par)];
        // The spectral paths are eligible at every n — `fft_cost`
        // prices the plan's actual transform length/factorization, so
        // non-pow2 shapes compete on real numbers instead of being
        // excluded.  Causal sites get the Hilbert-built spectrum
        // (whose win over the biased FFT — one fewer FFT, no decay
        // bias — is at construction, §3.3).
        let kind = if q.causal { BackendKind::Freq } else { BackendKind::Fft };
        v.push((kind, self.cost.fft_cost(q.n), self.cost.fft_par));
        if !q.causal && q.r >= 2 {
            // Causal sites exclude SKI (Appendix B: the causal scan's
            // sequential dependency negates its speedup).
            v.push((BackendKind::Ski, self.cost.ski_cost(q.n, q.r, q.w), self.cost.ski_par));
        }
        v
    }

    /// The cheapest eligible execution plan for this shape: which
    /// backend, and whether sharding the batch across `q.threads`
    /// workers beats running it serially.
    pub fn plan(&self, q: &DispatchQuery) -> (BackendKind, bool) {
        let (kind, parallel, _) = self.plan_costed(q);
        (kind, parallel)
    }

    /// [`plan`](Self::plan) plus the winning plan's predicted total ns
    /// for the whole batch — the number the telemetry dispatch audit
    /// compares against measured wall time.
    pub fn plan_costed(&self, q: &DispatchQuery) -> (BackendKind, bool, f64) {
        let _span = crate::telemetry::span(&crate::telemetry::SPAN_DISPATCH_DECIDE);
        let rows = q.batch.max(1);
        let mut best: Option<(BackendKind, f64, bool)> = None;
        for (kind, row_ns, scalable) in self.candidates(q) {
            let serial = row_ns * rows as f64;
            let sharded = self.cost.sharded_cost(row_ns, rows, q.threads, scalable);
            let parallel = sharded < serial;
            let cost = if parallel { sharded } else { serial };
            if best.map(|(_, c, _)| cost < c).unwrap_or(true) {
                best = Some((kind, cost, parallel));
            }
        }
        let (kind, cost, parallel) = best.expect("dense is always eligible");
        (kind, parallel, cost)
    }

    /// Predicted total ns for executing `q.batch` rows on a **given**
    /// backend (taking the cheaper of serial and sharded), or `None`
    /// when the backend is ineligible at this shape.  Used by the
    /// telemetry audit to price forced backends.
    pub fn predicted_ns(&self, kind: BackendKind, q: &DispatchQuery) -> Option<f64> {
        let rows = q.batch.max(1);
        self.candidates(q)
            .into_iter()
            .find(|(k, _, _)| *k == kind)
            .map(|(_, row_ns, scalable)| {
                let serial = row_ns * rows as f64;
                let sharded = self.cost.sharded_cost(row_ns, rows, q.threads, scalable);
                serial.min(sharded)
            })
    }

    /// The cheapest eligible backend for this shape (never `Auto`).
    pub fn select(&self, q: &DispatchQuery) -> BackendKind {
        self.plan(q).0
    }

    /// One rung **down** the paper's cost ladder from `kind` at this
    /// shape, or `None` when there is nowhere cheaper to go: the only
    /// admissible downshift is fft → SKI (O(n log n) → O(n)), and only
    /// where SKI is numerically eligible — non-causal sites with a
    /// usable rank (causal sites exclude SKI, Appendix B, and `Freq`
    /// is already the cheapest causal plan).  Dense never downshifts
    /// here: at dense-winning shapes dense is already the cheapest, so
    /// a "cheaper" rung does not exist.
    pub fn downshift(&self, kind: BackendKind, q: &DispatchQuery) -> Option<BackendKind> {
        match kind {
            BackendKind::Fft if !q.causal && q.r >= 2 => Some(BackendKind::Ski),
            _ => None,
        }
    }

    /// [`plan`](Self::plan) with graceful degradation: past
    /// [`PRESSURE_DOWNSHIFT`] the chosen backend steps one rung down
    /// the cost ladder where [`downshift`](Self::downshift) allows,
    /// trading the cost model's accuracy pick for strictly lower
    /// asymptotic work while the serving queue is the bottleneck.
    /// Below the threshold this is exactly `plan`.
    pub fn plan_pressured(&self, q: &DispatchQuery, pressure: f64) -> (BackendKind, bool) {
        let (kind, parallel) = self.plan(q);
        if pressure < PRESSURE_DOWNSHIFT {
            return (kind, parallel);
        }
        match self.downshift(kind, q) {
            Some(down) => (down, self.should_shard(down, q)),
            None => (kind, parallel),
        }
    }

    /// Whether sharding `q.batch` rows of a **given** backend across
    /// `q.threads` workers beats running them serially — the per-call
    /// gate for executors whose backend was forced rather than chosen
    /// by [`plan`](Self::plan).  Unknown/ineligible kinds answer
    /// `false` (serial is always safe).
    pub fn should_shard(&self, kind: BackendKind, q: &DispatchQuery) -> bool {
        let rows = q.batch.max(1);
        self.candidates(q)
            .into_iter()
            .find(|(k, _, _)| *k == kind)
            .map(|(_, row_ns, scalable)| {
                let serial = row_ns * rows as f64;
                self.cost.sharded_cost(row_ns, rows, q.threads, scalable) < serial
            })
            .unwrap_or(false)
    }
}

/// Build a boxed backend over a lag-table kernel.  `Auto` consults
/// [`Dispatch`] with the kernel's own shape; `r`/`w` parameterise the
/// SKI decomposition (ignored by the other backends).
pub fn build_op(
    kernel: &ToeplitzKernel,
    kind: BackendKind,
    r: usize,
    w: usize,
) -> Box<dyn ToeplitzOp> {
    match kind {
        BackendKind::Auto => {
            let q = DispatchQuery {
                n: kernel.n,
                r,
                w,
                causal: kernel.is_causal(),
                batch: 1,
                threads: 1,
            };
            build_op(kernel, Dispatch::default().select(&q), r, w)
        }
        BackendKind::Dense => Box::new(DenseOp { kernel: kernel.clone() }),
        BackendKind::Fft => Box::new(FftOp::new(kernel)),
        BackendKind::Ski => Box::new(SparseLowRankOp::from_kernel(kernel, r.max(2), w | 1)),
        BackendKind::Freq => Box::new(FreqCausalOp::from_causal_kernel(kernel)),
    }
}

/// Apply a causal spectral plan to a prefix no longer than the plan's
/// size, through caller scratch: zero-pad, one cached-spectrum
/// circulant apply, truncate.  Plan-holding callers (the decode
/// oracle's per-channel [`SpectralPlan`]s, applied on the shard
/// runtime's per-worker arenas) use this; [`apply_causal_taps`] is the
/// one-shot entry that builds a throwaway plan per call.
pub fn apply_causal_plan_with(plan: &SpectralPlan, x: &[f32], scratch: &mut OpScratch) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    apply_causal_plan_into(plan, x, &mut y, scratch);
    y
}

/// [`apply_causal_plan_with`] into a caller-provided output row — the
/// flat-batch form (the decode oracle's sharded channel loop writes
/// each channel's column straight into one flat buffer, so a
/// full-context forward allocates no per-channel vectors).
pub fn apply_causal_plan_into(
    plan: &SpectralPlan,
    x: &[f32],
    out: &mut [f32],
    scratch: &mut OpScratch,
) {
    let p = plan.n();
    assert!(x.len() <= p, "prefix {} longer than plan n={p}", x.len());
    plan.apply_into(x, out, scratch);
}

/// [`apply_causal_plan_with`] through the calling thread's arena
/// (single-caller convenience; [`with_scratch`] entry point).
pub fn apply_causal_plan(plan: &FftOp, x: &[f32]) -> Vec<f32> {
    with_scratch(|s| apply_causal_plan_with(&plan.plan, x, s))
}

/// Causal convolution of a length-`x.len()` prefix through the chosen
/// backend (`taps[τ]` at lag τ).  Spectral backends build a native
/// `t_len`-point plan (no power-of-two padding — the plan picks its
/// own smooth transform length) but still pay a per-call kernel FFT —
/// callers with fixed taps should hold an [`FftOp`] and use
/// [`apply_causal_plan`]; the dense path is bit-identical to the
/// direct nested loop it replaced.
pub fn apply_causal_taps(taps: &[f32], x: &[f32], kind: BackendKind) -> Vec<f32> {
    let t_len = x.len();
    if t_len == 0 {
        return Vec::new();
    }
    let kind = match kind {
        BackendKind::Auto => {
            // The two real costs here: the direct loop at t_len vs the
            // spectral path, both priced at the actual prefix length
            // (the old version compared against the padded power of
            // two, overcharging up to 4× just past one).
            let cost = CostModel::default();
            if cost.dense_cost(t_len) <= cost.fft_cost(t_len) {
                BackendKind::Dense
            } else {
                BackendKind::Freq
            }
        }
        k => k,
    };
    match kind {
        // SKI has no causal fast path (Appendix B); serve it densely.
        BackendKind::Dense | BackendKind::Ski => {
            let mut y = vec![0.0f32; t_len];
            for (i, yi) in y.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (tau, &k) in taps.iter().enumerate().take(i + 1) {
                    acc += k * x[i - tau];
                }
                *yi = acc;
            }
            y
        }
        _ => {
            let m = taps.len().min(t_len);
            let mut tp = vec![0.0f32; t_len];
            tp[..m].copy_from_slice(&taps[..m]);
            let plan = FftOp::new(&ToeplitzKernel::from_causal_taps(&tp));
            apply_causal_plan(&plan, x)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::kernels::gaussian_kernel;
    use super::*;
    use crate::util::prop::{assert_close, check, size, vecf};

    fn random_kernel(rng: &mut crate::util::rng::Rng, n: usize) -> ToeplitzKernel {
        ToeplitzKernel { n, lags: vecf(rng, 2 * n - 1) }
    }

    #[test]
    fn prop_fft_op_matches_dense() {
        // Any n, not just powers of two: the plan picks its own smooth
        // transform length ≥ 2n-1.
        check("FftOp == dense oracle (any n)", |rng| {
            let n = size(rng, 2, 600);
            let k = random_kernel(rng, n);
            let op = FftOp::new(&k);
            let x = vecf(rng, n);
            assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-4, "fft op");
        });
    }

    #[test]
    fn backends_agree_with_dense_oracle_at_awkward_sizes() {
        // The acceptance sizes: smooth composites (96, 360, 1000) and
        // a prime (769).  fft/freq are exact to FFT roundoff; SKI at
        // full rank reassembles the kernel (inducing grid on every
        // lag) so it is held to the same tolerance.
        for n in [96usize, 360, 769, 1000] {
            let mut rng = crate::util::rng::Rng::new(n as u64);
            let k = random_kernel(&mut rng, n);
            let x = vecf(&mut rng, n);
            let want = k.apply_dense(&x);
            let fft_op = FftOp::new(&k);
            assert_close(&fft_op.apply(&x), &want, 1e-4, "fft at awkward n");
            let causal = k.clone().causal();
            let freq = FreqCausalOp::from_causal_kernel(&causal);
            assert_close(&freq.apply(&x), &causal.apply_dense(&x), 1e-4, "freq at awkward n");
            let ski = SparseLowRankOp::from_kernel(&k, n, 3);
            assert_close(&ski.apply(&x), &want, 1e-3, "full-rank ski at awkward n");
        }
    }

    #[test]
    fn fft_op_scratch_reuse_is_deterministic() {
        // Back-to-back applies through the shared scratch must agree
        // bit-for-bit, including across an interleaved other input.
        let mut rng = crate::util::rng::Rng::new(11);
        let k = random_kernel(&mut rng, 128);
        let op = FftOp::new(&k);
        let x = vecf(&mut rng, 128);
        let z = vecf(&mut rng, 128);
        let first = op.apply(&x);
        let _ = op.apply(&z);
        assert_eq!(first, op.apply(&x), "scratch reuse changed results");
        let batch = op.apply_batch(&[x.clone(), z.clone()]);
        assert_eq!(batch[0], first);
        assert_eq!(batch[1], op.apply(&z));
    }

    #[test]
    fn sparse_low_rank_exact_at_full_rank() {
        // With r = n the inducing grid hits every integer lag, linear
        // interpolation is exact there, and band + SKI reassemble the
        // original kernel to FFT roundoff.
        check("sparse+low-rank exact at r=n", |rng| {
            let n = size(rng, 8, 128);
            let k = random_kernel(rng, n);
            let op = SparseLowRankOp::from_kernel(&k, n, 3);
            let x = vecf(rng, n);
            assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-3, "full-rank ski");
        });
    }

    #[test]
    fn sparse_low_rank_error_shrinks_with_rank() {
        // Theorem-1 regime: smooth kernel, error driven by the linear
        // interpolation of the band-subtracted remainder.
        let n = 256;
        let k = |t: f64| gaussian_kernel(t, 40.0);
        let kernel = ToeplitzKernel::from_fn(n, |lag| k(lag as f64));
        let x: Vec<f32> = (0..n).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        let exact = kernel.apply_dense(&x);
        let errs: Vec<f64> = [9usize, 17, 65, 256]
            .iter()
            .map(|&r| {
                let op = SparseLowRankOp::from_kernel_fn(n, r, 5, k);
                exact
                    .iter()
                    .zip(op.apply(&x).iter())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        assert!(errs[3] <= errs[0] * 0.5, "rank sweep not improving: {errs:?}");
        assert!(errs[3] < 1e-2, "full-rank residual too large: {errs:?}");
    }

    #[test]
    fn sparse_low_rank_band_catches_spike() {
        // A spiky near-diagonal + smooth tail: the band must absorb
        // the spike so low-rank SKI stays accurate where SKI alone
        // (band width 1) visibly is not.
        let n = 128;
        let spike = |t: f64| if t.abs() < 3.0 { (3.0 - t.abs()) as f32 } else { 0.0 };
        let k = move |t: f64| gaussian_kernel(t, 32.0) + spike(t);
        let kernel = ToeplitzKernel::from_fn(n, |lag| k(lag as f64));
        let x: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
        let exact = kernel.apply_dense(&x);
        let err = |w: usize| {
            let op = SparseLowRankOp::from_kernel_fn(n, 17, w, k);
            exact
                .iter()
                .zip(op.apply(&x).iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let banded = err(7);
        let bandless = err(1);
        assert!(
            banded < bandless * 0.5,
            "band should absorb the spike: w=7 err {banded} vs w=1 err {bandless}"
        );
    }

    #[test]
    fn prop_freq_causal_matches_dense_oracle() {
        check("freq-causal == dense of its taps", |rng| {
            let n = 1 << size(rng, 2, 9);
            let khat = vecf(rng, n + 1);
            let op = FreqCausalOp::from_response(&khat);
            let k = op.kernel();
            assert!(k.is_causal());
            let x = vecf(rng, n);
            assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-4, "freq op");
        });
    }

    #[test]
    fn prop_freq_causal_prefix_unaffected_by_future() {
        // Satellite: causality check.  The operator's taps are
        // structurally causal, so the dense-oracle view is
        // **bit-identical** on the prefix under future perturbation
        // (masked lags contribute exact ±0.0 terms); the spectral
        // apply tracks the same prefix to FFT roundoff.
        check("freq-causal ignores the future", |rng| {
            let n = 1 << size(rng, 3, 8);
            let khat = vecf(rng, n + 1);
            let op = FreqCausalOp::from_response(&khat);
            let k = op.kernel();
            let x = vecf(rng, n);
            let cut = n / 2;
            let mut xp = x.clone();
            for v in xp.iter_mut().skip(cut) {
                *v += 1e3;
            }
            let y0 = k.apply_dense(&x);
            let y1 = k.apply_dense(&xp);
            assert_eq!(&y0[..cut], &y1[..cut], "prefix must be bit-identical");
            let s0 = op.apply(&x);
            let s1 = op.apply(&xp);
            assert_close(&s0, &y0, 1e-4, "spectral vs dense");
            // Spectral leakage from the perturbed future is pure FFT
            // roundoff — far below the 1e3 perturbation scale.
            for (i, (a, b)) in s0.iter().zip(s1.iter()).take(cut).enumerate() {
                assert!((a - b).abs() < 1e-2, "position {i}: {a} vs {b}");
            }
        });
    }

    #[test]
    fn freq_from_response_avoids_bluestein_at_prime_n() {
        // 2n = 1538 = 2·769 would pin every apply to a chirp-z
        // transform; from_response must fall back to one kernel FFT on
        // a smooth grid instead, with identical outputs.
        let mut rng = crate::util::rng::Rng::new(769);
        let n = 769usize;
        let khat = vecf(&mut rng, n + 1);
        let op = FreqCausalOp::from_response(&khat);
        assert_ne!(op.fft.plan().transform_len(), 2 * n, "must not serve on the Bluestein grid");
        let k = op.kernel();
        assert!(k.is_causal());
        let x = vecf(&mut rng, n);
        assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-4, "freq at prime n");
    }

    #[test]
    fn freq_causal_from_kernel_roundtrips() {
        let mut rng = crate::util::rng::Rng::new(5);
        let taps = vecf(&mut rng, 64);
        let k = ToeplitzKernel::from_causal_taps(&taps);
        let op = FreqCausalOp::from_causal_kernel(&k);
        assert_eq!(op.causal_taps(), taps.as_slice());
        let x = vecf(&mut rng, 64);
        assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-4, "freq from kernel");
    }

    /// Serial query shorthand (threads = 1, the pre-pool behaviour).
    fn q1(n: usize, r: usize, w: usize, causal: bool) -> DispatchQuery {
        DispatchQuery { n, r, w, causal, batch: 1, threads: 1 }
    }

    #[test]
    fn dispatch_crossovers() {
        let d = Dispatch::default();
        // Tiny bidirectional: dense.
        assert_eq!(d.select(&q1(16, 0, 0, false)), BackendKind::Dense);
        // Large bidirectional, no SKI rank: FFT.
        assert_eq!(d.select(&q1(4096, 0, 0, false)), BackendKind::Fft);
        // Large bidirectional with a smooth-kernel rank: SKI.
        assert_eq!(d.select(&q1(4096, 256, 9, false)), BackendKind::Ski);
        // Causal: SKI ineligible, Hilbert spectrum preferred.
        assert_eq!(d.select(&q1(4096, 256, 9, true)), BackendKind::Freq);
        // Non-power-of-two with a usable rank: SKI still cheapest.
        assert_eq!(d.select(&q1(3000, 64, 9, false)), BackendKind::Ski);
        // Non-power-of-two with no rank: the spectral path is now
        // eligible (priced at its smooth transform length) and beats
        // dense — the shape that used to fall back to O(n²).
        assert_eq!(d.select(&q1(3000, 0, 0, false)), BackendKind::Fft);
        assert_eq!(d.select(&q1(1000, 0, 0, true)), BackendKind::Freq);
    }

    #[test]
    fn sharded_cost_model_shape() {
        let c = CostModel::default();
        // threads=1 is exactly serial, whatever the fraction.
        assert_eq!(c.sharded_cost(1e4, 8, 1, 0.9), 8e4);
        // Perfectly scalable work at t == rows: one row + overhead.
        let p = c.sharded_cost(1e4, 8, 8, 1.0);
        assert!((p - (1e4 + 8.0 * c.shard_overhead_ns)).abs() < 1e-6, "{p}");
        // Zero-scalable work gains nothing but still pays overhead.
        let z = c.sharded_cost(1e4, 8, 4, 0.0);
        assert!(z >= 8e4, "{z}");
        // More threads never increase the fully-scalable critical path.
        assert!(c.sharded_cost(1e4, 64, 8, 1.0) < c.sharded_cost(1e4, 64, 2, 1.0));
    }

    #[test]
    fn dispatch_crossover_shifts_with_threads() {
        let d = Dispatch::default();
        // n=64, batch=8: serially dense wins (4.1k vs 6.9k ns/row; the
        // r2c discount moved this pin down from the old n=128, where
        // the spectral path now wins even serially)…
        let serial = DispatchQuery { n: 64, r: 0, w: 0, causal: false, batch: 8, threads: 1 };
        assert_eq!(d.select(&serial), BackendKind::Dense);
        // …but across 4 workers the memory-bound dense rows contend
        // while the FFT rows scale (26.0k vs 23.9k total ns), so the
        // spectral path takes over.
        let par = DispatchQuery { threads: 4, ..serial };
        assert_eq!(d.select(&par), BackendKind::Fft);
        // Same shift on the causal side (dense loop vs Hilbert plan).
        let causal = DispatchQuery { causal: true, ..par };
        assert_eq!(d.select(&causal), BackendKind::Freq);
    }

    #[test]
    fn fft_cost_prices_the_r2c_discount() {
        let c = CostModel::default();
        // n=64 runs on the m=128 grid: one 64-point complex transform
        // (192 units) plus the 0.5·m split pass (64) per direction —
        // 6 ns × (4·256 + 128) = 6912, vs 11520 for the old full
        // complex price.  The serial dense→spectral crossover lands
        // between n=64 and n=128 as a result.
        assert!((c.fft_cost(64) - 6912.0).abs() < 1e-9, "{}", c.fft_cost(64));
        assert!(c.dense_cost(64) < c.fft_cost(64));
        assert!(c.dense_cost(128) > c.fft_cost(128));
        // The gram route prices the same cached-plan code path.
        assert_eq!(c.gram_fft_cost(64), c.fft_cost(64));
    }

    #[test]
    fn dispatch_plan_gates_parallelism_by_size() {
        let d = Dispatch::default();
        // Tiny batch of tiny rows: sharding cannot amortise the
        // per-shard overhead — serial plan.
        let (_, par) =
            d.plan(&DispatchQuery { n: 16, r: 0, w: 0, causal: false, batch: 2, threads: 8 });
        assert!(!par, "16-wide rows must not be sharded");
        // Big batch of big rows: sharding wins.
        let (kind, par) =
            d.plan(&DispatchQuery { n: 4096, r: 0, w: 0, causal: false, batch: 8, threads: 4 });
        assert_eq!(kind, BackendKind::Fft);
        assert!(par, "4096-wide batch of 8 must be sharded");
        // threads=1 never parallelises.
        let (_, par) =
            d.plan(&DispatchQuery { n: 4096, r: 0, w: 0, causal: false, batch: 8, threads: 1 });
        assert!(!par);
    }

    #[test]
    fn should_shard_gates_forced_backends() {
        let d = Dispatch::default();
        let big = DispatchQuery { n: 4096, r: 0, w: 0, causal: false, batch: 8, threads: 4 };
        assert!(d.should_shard(BackendKind::Fft, &big));
        let tiny = DispatchQuery { n: 16, r: 0, w: 0, causal: false, batch: 2, threads: 8 };
        assert!(!d.should_shard(BackendKind::Dense, &tiny));
        // Freq is only a candidate under a causal query.
        let causal = DispatchQuery { causal: true, ..big };
        assert!(d.should_shard(BackendKind::Freq, &causal));
        assert!(!d.should_shard(BackendKind::Freq, &big), "ineligible kind answers serial");
        // threads=1 never shards.
        assert!(!d.should_shard(BackendKind::Fft, &DispatchQuery { threads: 1, ..big }));
    }

    #[test]
    fn downshift_is_fft_to_ski_where_admissible() {
        let d = Dispatch::default();
        let q = DispatchQuery { n: 4096, r: 8, w: 400, causal: false, batch: 1, threads: 1 };
        assert_eq!(d.downshift(BackendKind::Fft, &q), Some(BackendKind::Ski));
        // No usable rank → SKI ineligible → nowhere to go.
        assert_eq!(d.downshift(BackendKind::Fft, &DispatchQuery { r: 0, ..q }), None);
        assert_eq!(d.downshift(BackendKind::Fft, &DispatchQuery { r: 1, ..q }), None);
        // Causal sites exclude SKI entirely.
        assert_eq!(d.downshift(BackendKind::Fft, &DispatchQuery { causal: true, ..q }), None);
        assert_eq!(d.downshift(BackendKind::Freq, &DispatchQuery { causal: true, ..q }), None);
        // Already at (or below) the bottom of the ladder.
        assert_eq!(d.downshift(BackendKind::Ski, &q), None);
        assert_eq!(d.downshift(BackendKind::Dense, &q), None);
    }

    #[test]
    fn plan_pressured_downshifts_past_threshold_only() {
        let d = Dispatch::default();
        // Wide band: SKI prices above fft, so the unpressured plan is
        // fft — the interesting shape, where pressure changes the
        // answer.
        let q = DispatchQuery { n: 4096, r: 8, w: 400, causal: false, batch: 1, threads: 1 };
        assert_eq!(d.plan(&q).0, BackendKind::Fft, "precondition: fft wins unpressured");
        assert_eq!(d.plan_pressured(&q, 0.0), d.plan(&q));
        assert_eq!(d.plan_pressured(&q, PRESSURE_DOWNSHIFT - 1e-9), d.plan(&q));
        assert_eq!(d.plan_pressured(&q, PRESSURE_DOWNSHIFT).0, BackendKind::Ski);
        assert_eq!(d.plan_pressured(&q, 1.0).0, BackendKind::Ski);
        // Where the ladder has no lower rung, pressure changes nothing.
        let causal = DispatchQuery { causal: true, ..q };
        assert_eq!(d.plan_pressured(&causal, 1.0), d.plan(&causal));
        let ski_wins = DispatchQuery { w: 3, ..q };
        assert_eq!(d.plan(&ski_wins).0, BackendKind::Ski, "precondition: ski wins at w=3");
        assert_eq!(d.plan_pressured(&ski_wins, 1.0), d.plan(&ski_wins));
    }

    #[test]
    fn plan_costed_and_predicted_ns_agree_with_plan() {
        let d = Dispatch::default();
        for q in [
            DispatchQuery { n: 16, r: 0, w: 0, causal: false, batch: 2, threads: 8 },
            DispatchQuery { n: 4096, r: 64, w: 9, causal: false, batch: 8, threads: 4 },
            DispatchQuery { n: 512, r: 16, w: 5, causal: true, batch: 4, threads: 2 },
        ] {
            let (kind, parallel, cost) = d.plan_costed(&q);
            assert_eq!((kind, parallel), d.plan(&q), "plan_costed must match plan");
            assert!(cost > 0.0 && cost.is_finite());
            // The winner's cost equals its own predicted_ns, and no
            // eligible backend predicts cheaper.
            assert_eq!(d.predicted_ns(kind, &q), Some(cost));
            for k in [BackendKind::Dense, BackendKind::Fft, BackendKind::Ski, BackendKind::Freq] {
                if let Some(p) = d.predicted_ns(k, &q) {
                    assert!(p >= cost, "{k:?} predicted {p} under winner {cost}");
                }
            }
        }
        // Ineligible backends price as None.
        let q = DispatchQuery { n: 64, r: 0, w: 0, causal: false, batch: 1, threads: 1 };
        assert_eq!(d.predicted_ns(BackendKind::Freq, &q), None);
        assert_eq!(d.predicted_ns(BackendKind::Ski, &q), None);
    }

    #[test]
    fn apply_with_scratch_is_bitwise_identical() {
        // Caller-owned scratch must equal the thread-local arena path
        // exactly, for both spectral backends, across reused scratch.
        let mut rng = crate::util::rng::Rng::new(21);
        let k = random_kernel(&mut rng, 64);
        let op = FftOp::new(&k);
        let khat = vecf(&mut rng, 65);
        let freq = FreqCausalOp::from_response(&khat);
        let mut scratch = OpScratch::default();
        for _ in 0..4 {
            let x = vecf(&mut rng, 64);
            assert_eq!(op.apply(&x), op.apply_with_scratch(&x, &mut scratch));
            assert_eq!(freq.apply(&x), freq.apply_with_scratch(&x, &mut scratch));
        }
    }

    #[test]
    fn apply_batch_flat_is_bitwise_per_row_for_every_backend() {
        // The flat ABI is the same per-row arithmetic as
        // apply_with_scratch, whatever the backend — including at a
        // non-pow2 grid (odd transform lengths exercise the r2c
        // fallback inside the engine).
        for n in [64usize, 96] {
            let mut rng = crate::util::rng::Rng::new(n as u64 + 100);
            let kernel = random_kernel(&mut rng, n);
            let causal = kernel.clone().causal();
            let rows = 5usize;
            let xs = vecf(&mut rng, rows * n);
            for (kind, k) in [
                (BackendKind::Dense, &kernel),
                (BackendKind::Fft, &kernel),
                (BackendKind::Ski, &kernel),
                (BackendKind::Freq, &causal),
            ] {
                let op = build_op(k, kind, 8, 5);
                let mut out = vec![0.0f32; rows * n];
                let mut scratch = OpScratch::default();
                op.apply_batch_flat(&xs, rows, &mut out, &mut scratch);
                let mut per_row = OpScratch::default();
                for (x, y) in xs.chunks_exact(n).zip(out.chunks_exact(n)) {
                    assert_eq!(
                        y,
                        op.apply_with_scratch(x, &mut per_row).as_slice(),
                        "{} backend at n={n}",
                        op.name()
                    );
                }
                // And again through the same scratch: reuse is clean.
                let mut again = vec![0.0f32; rows * n];
                op.apply_batch_flat(&xs, rows, &mut again, &mut scratch);
                assert_eq!(out, again, "{} backend, scratch reuse", op.name());
            }
        }
    }

    #[test]
    fn spectral_plan_prefix_apply_matches_zero_padded_full_apply() {
        // apply_into on a short prefix is the zero-padded full apply,
        // truncated — the contract the causal decode oracle relies on.
        let mut rng = crate::util::rng::Rng::new(17);
        let k = random_kernel(&mut rng, 100).causal();
        let plan = SpectralPlan::new(&k);
        let mut scratch = OpScratch::default();
        for len in [1usize, 37, 64, 100] {
            let x = vecf(&mut rng, len);
            let mut got = vec![0.0f32; len];
            plan.apply_into(&x, &mut got, &mut scratch);
            let mut xp = vec![0.0f32; 100];
            xp[..len].copy_from_slice(&x);
            let full = plan.apply_with(&xp, &mut scratch);
            assert_eq!(got.as_slice(), &full[..len], "prefix len {len}");
        }
    }

    #[test]
    fn prop_apply_causal_taps_backends_agree() {
        check("causal taps: dense == fft path", |rng| {
            let t_len = size(rng, 2, 200);
            let n_taps = size(rng, 1, 256);
            let taps = vecf(rng, n_taps);
            let x = vecf(rng, t_len);
            let dense = apply_causal_taps(&taps, &x, BackendKind::Dense);
            let fftp = apply_causal_taps(&taps, &x, BackendKind::Fft);
            let auto = apply_causal_taps(&taps, &x, BackendKind::Auto);
            assert_close(&dense, &fftp, 1e-4, "dense vs fft causal");
            assert_close(&dense, &auto, 1e-4, "dense vs auto causal");
        });
    }

    #[test]
    fn build_op_names_and_shapes() {
        let mut rng = crate::util::rng::Rng::new(3);
        let k = random_kernel(&mut rng, 64);
        for (kind, name) in
            [(BackendKind::Dense, "dense"), (BackendKind::Fft, "fft"), (BackendKind::Ski, "ski")]
        {
            let op = build_op(&k, kind, 16, 5);
            assert_eq!(op.name(), name);
            assert_eq!(op.n(), 64);
            assert!(op.flops_estimate() > 0.0);
        }
        let causal = k.clone().causal();
        let op = build_op(&causal, BackendKind::Freq, 0, 0);
        assert_eq!(op.name(), "freq");
        // Auto on a causal kernel must pick a causal-capable backend.
        let auto = build_op(&causal, BackendKind::Auto, 16, 5);
        assert!(auto.name() == "dense" || auto.name() == "freq", "got {}", auto.name());
    }
}
