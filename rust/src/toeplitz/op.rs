//! Unified Toeplitz operator backends — the one interface every
//! forward path in the crate goes through.
//!
//! The paper ships two headline accelerations that were previously
//! disconnected fragments here: the sparse + low-rank decomposition
//! with asymmetric SKI for bidirectional models (§3.2) and the
//! frequency-domain causal kernel whose imaginary part comes from a
//! Hilbert transform of the real part (§3.3).  [`ToeplitzOp`] makes
//! them (and the dense / FFT baselines) interchangeable behind one
//! `apply` surface, and [`Dispatch`] picks the cheapest backend for a
//! given `(n, r, w, causal, batch)` shape from a calibrated cost
//! model — per-workload instead of per-callsite.
//!
//! | backend | operator | complexity |
//! |---|---|---|
//! | [`DenseOp`] | dense matvec oracle | O(n²) |
//! | [`FftOp`] | 2n circulant embedding, cached spectrum + scratch | O(n log n) |
//! | [`SparseLowRankOp`] | width-w band + asymmetric SKI `W A Wᵀ` | O(nw + n + r log r) |
//! | [`FreqCausalOp`] | Hilbert-completed causal spectrum (§3.3.1) | O(n log n), one fewer FFT |

use std::sync::Mutex;

use crate::dsp::{causal_spectrum, fft, ifft, irfft, Complex};

use super::{conv1d, Ski, ToeplitzKernel};

/// One Toeplitz operator action `y = T x`, backend-agnostic.
///
/// `Send + Sync` so trait objects ride the server executor closures
/// and `apply_batch` can be shared across client threads.
pub trait ToeplitzOp: Send + Sync {
    /// Sequence length the operator acts on.
    fn n(&self) -> usize;

    /// Short stable name (`dense`/`fft`/`ski`/`freq`) for reports.
    fn name(&self) -> &'static str;

    /// Rough multiply-add count of one `apply` — the structural input
    /// to [`Dispatch`]'s cost model and the bench reports.
    fn flops_estimate(&self) -> f64;

    /// `y = T x` for one length-n signal.
    fn apply(&self, x: &[f32]) -> Vec<f32>;

    /// Apply to every row; backends override to amortise plan/scratch.
    fn apply_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| self.apply(x)).collect()
    }
}

/// The dense O(n²) oracle — exact, cache-friendly at small n, and the
/// reference every other backend is tested against.
#[derive(Debug, Clone)]
pub struct DenseOp {
    pub kernel: ToeplitzKernel,
}

impl ToeplitzOp for DenseOp {
    fn n(&self) -> usize {
        self.kernel.n
    }

    fn name(&self) -> &'static str {
        "dense"
    }

    fn flops_estimate(&self) -> f64 {
        2.0 * (self.kernel.n as f64) * (self.kernel.n as f64)
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.kernel.apply_dense(x)
    }
}

/// O(n log n) circulant-embedding apply with the kernel's 2n-point
/// spectrum computed **once** at construction and a reusable complex
/// scratch buffer, so repeated applies pay two FFTs and zero
/// allocations beyond the output (the old `apply_fft` re-FFT'd the
/// kernel and allocated four temporaries per call).
pub struct FftOp {
    n: usize,
    /// Full 2n-point spectrum of the circulant first column.
    spec: Vec<Complex>,
    /// Reusable 2n-point transform buffer (one apply at a time).
    scratch: Mutex<Vec<Complex>>,
}

impl FftOp {
    pub fn new(kernel: &ToeplitzKernel) -> FftOp {
        let n = kernel.n;
        assert!(n.is_power_of_two(), "FftOp needs power-of-two n, got {n}");
        let mut c = vec![Complex::ZERO; 2 * n];
        for (t, v) in c.iter_mut().enumerate().take(n) {
            v.re = kernel.at(t as i64) as f64;
        }
        for t in 1..n {
            c[n + t].re = kernel.at(t as i64 - n as i64) as f64;
        }
        fft(&mut c);
        FftOp { n, spec: c, scratch: Mutex::new(vec![Complex::ZERO; 2 * n]) }
    }

    /// Build from the n+1 non-redundant rFFT bins of a 2n circulant
    /// column (Hermitian completion).  This is how [`FreqCausalOp`]
    /// consumes the Hilbert-completed causal spectrum directly —
    /// no time-domain kernel materialisation, no kernel FFT.
    pub fn from_rfft_bins(n: usize, bins: &[Complex]) -> FftOp {
        assert!(n.is_power_of_two(), "FftOp needs power-of-two n, got {n}");
        assert_eq!(bins.len(), n + 1, "need n+1 rFFT bins for a 2n circulant");
        let mut spec = vec![Complex::ZERO; 2 * n];
        spec[..=n].copy_from_slice(bins);
        for k in 1..n {
            spec[2 * n - k] = bins[k].conj();
        }
        FftOp { n, spec, scratch: Mutex::new(vec![Complex::ZERO; 2 * n]) }
    }

    fn apply_into(&self, x: &[f32], buf: &mut Vec<Complex>) -> Vec<f32> {
        let n = self.n;
        assert_eq!(x.len(), n, "FftOp size mismatch: x has {} values, op n={n}", x.len());
        buf.clear();
        buf.extend(x.iter().map(|&v| Complex::new(v as f64, 0.0)));
        buf.resize(2 * n, Complex::ZERO);
        fft(buf);
        for (v, s) in buf.iter_mut().zip(self.spec.iter()) {
            *v = v.mul(*s);
        }
        ifft(buf);
        buf[..n].iter().map(|c| c.re as f32).collect()
    }
}

impl ToeplitzOp for FftOp {
    fn n(&self) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "fft"
    }

    fn flops_estimate(&self) -> f64 {
        let m = 2.0 * self.n as f64;
        2.0 * 5.0 * m * m.log2() + 6.0 * m
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        let mut buf = self.scratch.lock().unwrap();
        self.apply_into(x, &mut buf)
    }

    fn apply_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        // One lock, one scratch, the whole batch.
        let mut buf = self.scratch.lock().unwrap();
        xs.iter().map(|x| self.apply_into(x, &mut buf)).collect()
    }
}

/// Paper §3.2: `T ≈ B + W A Wᵀ` — a width-`w` banded convolution for
/// the spiky near-diagonal mass plus asymmetric SKI for the smooth
/// remainder, with the band **subtracted from the SKI kernel fit** so
/// the two components never double-count a lag.
pub struct SparseLowRankOp {
    n: usize,
    /// Centred band taps: `band[j]` carries lag `j - w/2`.
    band: Vec<f32>,
    ski: Ski,
}

impl SparseLowRankOp {
    /// Build from a kernel function over real-valued lags (an RPE, a
    /// [`TableKernel`](super::TableKernel), or
    /// [`ToeplitzKernel::at_real`]): the band samples integer lags
    /// `|t| ≤ w/2`, the SKI Gram samples the band-subtracted remainder
    /// at inducing-point differences (§3.2.1).
    pub fn from_kernel_fn(n: usize, r: usize, w: usize, k: impl Fn(f64) -> f32) -> Self {
        assert!(w % 2 == 1, "band width must be odd (centred), got {w}");
        let half = (w / 2) as i64;
        let band: Vec<f32> = (-half..=half).map(|t| k(t as f64)).collect();
        let ski = Ski::from_kernel(n, r, move |t| {
            if t.abs() <= half as f64 {
                0.0
            } else {
                k(t)
            }
        });
        SparseLowRankOp { n, band, ski }
    }

    /// Build from a lag table by linear interpolation at the inducing
    /// points (kernels known only as learned lags).
    pub fn from_kernel(kernel: &ToeplitzKernel, r: usize, w: usize) -> Self {
        Self::from_kernel_fn(kernel.n, r, w, |t| kernel.at_real(t))
    }

    pub fn rank(&self) -> usize {
        self.ski.r
    }

    pub fn band_width(&self) -> usize {
        self.band.len()
    }

    pub fn ski(&self) -> &Ski {
        &self.ski
    }
}

impl ToeplitzOp for SparseLowRankOp {
    fn n(&self) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "ski"
    }

    fn flops_estimate(&self) -> f64 {
        let n = self.n as f64;
        let r = self.ski.r;
        let a = if r.is_power_of_two() {
            let m = 2.0 * r as f64;
            2.0 * 5.0 * m * m.log2() + 6.0 * m
        } else {
            2.0 * (r as f64) * (r as f64)
        };
        2.0 * n * self.band.len() as f64 + 8.0 * n + a
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n, "SparseLowRankOp size mismatch");
        let mut y = conv1d(x, &self.band, false);
        for (yi, si) in y.iter_mut().zip(self.ski.apply_sparse(x)) {
            *yi += si;
        }
        y
    }
}

/// Paper §3.3: the causal operator built **in the frequency domain** —
/// the RPE models only the real (even) frequency response, the
/// discrete Hilbert transform supplies the imaginary part
/// (`dsp::causal_spectrum`), and the resulting n+1 bins are consumed
/// directly as the circulant multiply spectrum.  No explicit decay
/// bias, and one fewer FFT than materialising the time kernel first.
pub struct FreqCausalOp {
    /// Causal time-domain taps (`taps[τ] = k[τ]`) — the oracle view
    /// used by equivalence tests and the streaming decode planner.
    taps: Vec<f32>,
    fft: FftOp,
}

impl FreqCausalOp {
    /// From n+1 real frequency-response samples on `ω_m = mπ/n`.
    pub fn from_response(khat_r: &[f32]) -> FreqCausalOp {
        assert!(khat_r.len() >= 3, "need at least 3 response samples");
        let n = khat_r.len() - 1;
        let spec = causal_spectrum(khat_r);
        let kt = irfft(&spec, 2 * n);
        let taps = kt[..n].to_vec();
        FreqCausalOp { taps, fft: FftOp::from_rfft_bins(n, &spec) }
    }

    /// From an already-causal time kernel (the degenerate case where
    /// the taps are known: the Hilbert step is unnecessary and the
    /// spectrum comes from one kernel FFT).
    pub fn from_causal_kernel(kernel: &ToeplitzKernel) -> FreqCausalOp {
        assert!(kernel.is_causal(), "FreqCausalOp needs a causal kernel");
        FreqCausalOp { taps: kernel.causal_taps(), fft: FftOp::new(kernel) }
    }

    /// The causal taps as a [`ToeplitzKernel`] (oracles, SSM planning).
    pub fn kernel(&self) -> ToeplitzKernel {
        ToeplitzKernel::from_causal_taps(&self.taps)
    }

    pub fn causal_taps(&self) -> &[f32] {
        &self.taps
    }
}

impl ToeplitzOp for FreqCausalOp {
    fn n(&self) -> usize {
        self.fft.n
    }

    fn name(&self) -> &'static str {
        "freq"
    }

    fn flops_estimate(&self) -> f64 {
        self.fft.flops_estimate()
    }

    fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.fft.apply(x)
    }

    fn apply_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.fft.apply_batch(xs)
    }
}

/// Backend selector — `auto` defers to [`Dispatch`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Auto,
    Dense,
    Fft,
    Ski,
    Freq,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "auto" => BackendKind::Auto,
            "dense" => BackendKind::Dense,
            "fft" => BackendKind::Fft,
            "ski" => BackendKind::Ski,
            "freq" => BackendKind::Freq,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Dense => "dense",
            BackendKind::Fft => "fft",
            BackendKind::Ski => "ski",
            BackendKind::Freq => "freq",
        }
    }
}

/// Per-primitive wall-clock constants (ns), calibrated on this
/// container by `benches/backend_matrix.rs` (its JSON artifact records
/// the re-measured values every run).  The defaults reproduce the
/// measured crossovers: dense wins below n ≈ 128, the spectral paths
/// above, and sparse+low-rank beats FFT whenever r ≤ n/16.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// ns per dense multiply-add (tight n² inner loop).
    pub dense_mac_ns: f64,
    /// ns per FFT butterfly point (scalar f64 radix-2).
    pub fft_point_ns: f64,
    /// ns per sparse interpolation point (scatter/gather with weight
    /// recomputation).
    pub ski_point_ns: f64,
    /// ns per banded-convolution multiply-add.
    pub band_mac_ns: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { dense_mac_ns: 1.0, fft_point_ns: 6.0, ski_point_ns: 2.5, band_mac_ns: 1.2 }
    }
}

impl CostModel {
    pub fn dense_cost(&self, n: usize) -> f64 {
        self.dense_mac_ns * (n as f64) * (n as f64)
    }

    pub fn fft_cost(&self, n: usize) -> f64 {
        let m = 2.0 * n as f64; // circulant embedding length
        2.0 * self.fft_point_ns * m * m.log2() + self.fft_point_ns * m
    }

    pub fn ski_cost(&self, n: usize, r: usize, w: usize) -> f64 {
        let a = if r.is_power_of_two() { self.fft_cost(r) } else { self.dense_cost(r) };
        self.ski_point_ns * 4.0 * n as f64 + a + self.band_mac_ns * (n * w.max(1)) as f64
    }
}

/// The shape of one apply site — everything the dispatcher looks at.
#[derive(Debug, Clone, Copy)]
pub struct DispatchQuery {
    /// Sequence length.
    pub n: usize,
    /// SKI rank available (0 ⇒ no smooth kernel fit ⇒ SKI ineligible).
    pub r: usize,
    /// Band width for the sparse component (0 ⇒ no band).
    pub w: usize,
    /// Causal sites exclude SKI (Appendix B: the causal scan's
    /// sequential dependency negates its speedup) and prefer the
    /// Hilbert-built spectrum over FFT-with-decay-bias.
    pub causal: bool,
    /// Rows per `apply_batch` call (scales every candidate equally
    /// today; kept explicit so batch-aware backends can bid lower).
    pub batch: usize,
}

/// Cost-model auto-dispatcher: picks the cheapest eligible backend
/// for a query.  Construct with a re-calibrated [`CostModel`] to
/// shift the crossovers for a different machine.
#[derive(Debug, Clone, Copy, Default)]
pub struct Dispatch {
    pub cost: CostModel,
}

impl Dispatch {
    pub fn new(cost: CostModel) -> Dispatch {
        Dispatch { cost }
    }

    /// The cheapest eligible backend for this shape (never `Auto`).
    pub fn select(&self, q: &DispatchQuery) -> BackendKind {
        let b = q.batch.max(1) as f64;
        let mut best = (BackendKind::Dense, b * self.cost.dense_cost(q.n));
        if q.n.is_power_of_two() {
            // Same apply cost either way; causal sites get the
            // Hilbert-built spectrum (whose win over the biased FFT —
            // one fewer FFT, no decay bias — is at construction, §3.3).
            let kind = if q.causal { BackendKind::Freq } else { BackendKind::Fft };
            let cost = b * self.cost.fft_cost(q.n);
            if cost < best.1 {
                best = (kind, cost);
            }
        }
        if !q.causal && q.r >= 2 {
            let cost = b * self.cost.ski_cost(q.n, q.r, q.w);
            if cost < best.1 {
                best = (BackendKind::Ski, cost);
            }
        }
        best.0
    }
}

/// Build a boxed backend over a lag-table kernel.  `Auto` consults
/// [`Dispatch`] with the kernel's own shape; `r`/`w` parameterise the
/// SKI decomposition (ignored by the other backends).
pub fn build_op(
    kernel: &ToeplitzKernel,
    kind: BackendKind,
    r: usize,
    w: usize,
) -> Box<dyn ToeplitzOp> {
    match kind {
        BackendKind::Auto => {
            let q = DispatchQuery { n: kernel.n, r, w, causal: kernel.is_causal(), batch: 1 };
            build_op(kernel, Dispatch::default().select(&q), r, w)
        }
        BackendKind::Dense => Box::new(DenseOp { kernel: kernel.clone() }),
        BackendKind::Fft => Box::new(FftOp::new(kernel)),
        BackendKind::Ski => Box::new(SparseLowRankOp::from_kernel(kernel, r.max(2), w | 1)),
        BackendKind::Freq => Box::new(FreqCausalOp::from_causal_kernel(kernel)),
    }
}

/// Apply a causal spectral plan to a prefix no longer than the plan's
/// size: zero-pad, one cached-spectrum circulant apply, truncate.
/// Plan-holding callers (the decode oracle's per-channel cached
/// [`FftOp`]s) use this; [`apply_causal_taps`] is the one-shot entry
/// that builds a throwaway plan per call.
pub fn apply_causal_plan(plan: &FftOp, x: &[f32]) -> Vec<f32> {
    let p = plan.n();
    assert!(x.len() <= p, "prefix {} longer than plan n={p}", x.len());
    let mut xp = vec![0.0f32; p];
    xp[..x.len()].copy_from_slice(x);
    let mut y = plan.apply(&xp);
    y.truncate(x.len());
    y
}

/// Causal convolution of a length-`x.len()` prefix through the chosen
/// backend (`taps[τ]` at lag τ).  Spectral backends pad to the next
/// power of two and pay a per-call kernel FFT — callers with fixed
/// taps should hold an [`FftOp`] and use [`apply_causal_plan`]; the
/// dense path is bit-identical to the direct nested loop it replaced.
pub fn apply_causal_taps(taps: &[f32], x: &[f32], kind: BackendKind) -> Vec<f32> {
    let t_len = x.len();
    if t_len == 0 {
        return Vec::new();
    }
    let kind = match kind {
        BackendKind::Auto => {
            // The two real costs here: the direct loop at t_len vs the
            // spectral path at the padded power of two (a query through
            // `Dispatch::select` would cost dense at the padded size
            // too, overcharging it up to 4× just past a power of two).
            let cost = CostModel::default();
            let p = t_len.next_power_of_two();
            if cost.dense_cost(t_len) <= cost.fft_cost(p) {
                BackendKind::Dense
            } else {
                BackendKind::Freq
            }
        }
        k => k,
    };
    match kind {
        // SKI has no causal fast path (Appendix B); serve it densely.
        BackendKind::Dense | BackendKind::Ski => {
            let mut y = vec![0.0f32; t_len];
            for (i, yi) in y.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (tau, &k) in taps.iter().enumerate().take(i + 1) {
                    acc += k * x[i - tau];
                }
                *yi = acc;
            }
            y
        }
        _ => {
            let p = t_len.next_power_of_two();
            let m = taps.len().min(t_len);
            let mut tp = vec![0.0f32; p];
            tp[..m].copy_from_slice(&taps[..m]);
            let plan = FftOp::new(&ToeplitzKernel::from_causal_taps(&tp));
            apply_causal_plan(&plan, x)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::kernels::gaussian_kernel;
    use super::*;
    use crate::util::prop::{assert_close, check, size, vecf};

    fn random_kernel(rng: &mut crate::util::rng::Rng, n: usize) -> ToeplitzKernel {
        ToeplitzKernel { n, lags: vecf(rng, 2 * n - 1) }
    }

    #[test]
    fn prop_fft_op_matches_dense() {
        check("FftOp == dense oracle", |rng| {
            let n = 1 << size(rng, 1, 9);
            let k = random_kernel(rng, n);
            let op = FftOp::new(&k);
            let x = vecf(rng, n);
            assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-4, "fft op");
        });
    }

    #[test]
    fn fft_op_scratch_reuse_is_deterministic() {
        // Back-to-back applies through the shared scratch must agree
        // bit-for-bit, including across an interleaved other input.
        let mut rng = crate::util::rng::Rng::new(11);
        let k = random_kernel(&mut rng, 128);
        let op = FftOp::new(&k);
        let x = vecf(&mut rng, 128);
        let z = vecf(&mut rng, 128);
        let first = op.apply(&x);
        let _ = op.apply(&z);
        assert_eq!(first, op.apply(&x), "scratch reuse changed results");
        let batch = op.apply_batch(&[x.clone(), z.clone()]);
        assert_eq!(batch[0], first);
        assert_eq!(batch[1], op.apply(&z));
    }

    #[test]
    fn sparse_low_rank_exact_at_full_rank() {
        // With r = n the inducing grid hits every integer lag, linear
        // interpolation is exact there, and band + SKI reassemble the
        // original kernel to FFT roundoff.
        check("sparse+low-rank exact at r=n", |rng| {
            let n = size(rng, 8, 128);
            let k = random_kernel(rng, n);
            let op = SparseLowRankOp::from_kernel(&k, n, 3);
            let x = vecf(rng, n);
            assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-3, "full-rank ski");
        });
    }

    #[test]
    fn sparse_low_rank_error_shrinks_with_rank() {
        // Theorem-1 regime: smooth kernel, error driven by the linear
        // interpolation of the band-subtracted remainder.
        let n = 256;
        let k = |t: f64| gaussian_kernel(t, 40.0);
        let kernel = ToeplitzKernel::from_fn(n, |lag| k(lag as f64));
        let x: Vec<f32> = (0..n).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        let exact = kernel.apply_dense(&x);
        let errs: Vec<f64> = [9usize, 17, 65, 256]
            .iter()
            .map(|&r| {
                let op = SparseLowRankOp::from_kernel_fn(n, r, 5, k);
                exact
                    .iter()
                    .zip(op.apply(&x).iter())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        assert!(errs[3] <= errs[0] * 0.5, "rank sweep not improving: {errs:?}");
        assert!(errs[3] < 1e-2, "full-rank residual too large: {errs:?}");
    }

    #[test]
    fn sparse_low_rank_band_catches_spike() {
        // A spiky near-diagonal + smooth tail: the band must absorb
        // the spike so low-rank SKI stays accurate where SKI alone
        // (band width 1) visibly is not.
        let n = 128;
        let spike = |t: f64| if t.abs() < 3.0 { (3.0 - t.abs()) as f32 } else { 0.0 };
        let k = move |t: f64| gaussian_kernel(t, 32.0) + spike(t);
        let kernel = ToeplitzKernel::from_fn(n, |lag| k(lag as f64));
        let x: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
        let exact = kernel.apply_dense(&x);
        let err = |w: usize| {
            let op = SparseLowRankOp::from_kernel_fn(n, 17, w, k);
            exact
                .iter()
                .zip(op.apply(&x).iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let banded = err(7);
        let bandless = err(1);
        assert!(
            banded < bandless * 0.5,
            "band should absorb the spike: w=7 err {banded} vs w=1 err {bandless}"
        );
    }

    #[test]
    fn prop_freq_causal_matches_dense_oracle() {
        check("freq-causal == dense of its taps", |rng| {
            let n = 1 << size(rng, 2, 9);
            let khat = vecf(rng, n + 1);
            let op = FreqCausalOp::from_response(&khat);
            let k = op.kernel();
            assert!(k.is_causal());
            let x = vecf(rng, n);
            assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-4, "freq op");
        });
    }

    #[test]
    fn prop_freq_causal_prefix_unaffected_by_future() {
        // Satellite: causality check.  The operator's taps are
        // structurally causal, so the dense-oracle view is
        // **bit-identical** on the prefix under future perturbation
        // (masked lags contribute exact ±0.0 terms); the spectral
        // apply tracks the same prefix to FFT roundoff.
        check("freq-causal ignores the future", |rng| {
            let n = 1 << size(rng, 3, 8);
            let khat = vecf(rng, n + 1);
            let op = FreqCausalOp::from_response(&khat);
            let k = op.kernel();
            let x = vecf(rng, n);
            let cut = n / 2;
            let mut xp = x.clone();
            for v in xp.iter_mut().skip(cut) {
                *v += 1e3;
            }
            let y0 = k.apply_dense(&x);
            let y1 = k.apply_dense(&xp);
            assert_eq!(&y0[..cut], &y1[..cut], "prefix must be bit-identical");
            let s0 = op.apply(&x);
            let s1 = op.apply(&xp);
            assert_close(&s0, &y0, 1e-4, "spectral vs dense");
            // Spectral leakage from the perturbed future is pure FFT
            // roundoff — far below the 1e3 perturbation scale.
            for (i, (a, b)) in s0.iter().zip(s1.iter()).take(cut).enumerate() {
                assert!((a - b).abs() < 1e-2, "position {i}: {a} vs {b}");
            }
        });
    }

    #[test]
    fn freq_causal_from_kernel_roundtrips() {
        let mut rng = crate::util::rng::Rng::new(5);
        let taps = vecf(&mut rng, 64);
        let k = ToeplitzKernel::from_causal_taps(&taps);
        let op = FreqCausalOp::from_causal_kernel(&k);
        assert_eq!(op.causal_taps(), taps.as_slice());
        let x = vecf(&mut rng, 64);
        assert_close(&op.apply(&x), &k.apply_dense(&x), 1e-4, "freq from kernel");
    }

    #[test]
    fn dispatch_crossovers() {
        let d = Dispatch::default();
        // Tiny bidirectional: dense.
        assert_eq!(
            d.select(&DispatchQuery { n: 16, r: 0, w: 0, causal: false, batch: 1 }),
            BackendKind::Dense
        );
        // Large bidirectional, no SKI rank: FFT.
        assert_eq!(
            d.select(&DispatchQuery { n: 4096, r: 0, w: 0, causal: false, batch: 1 }),
            BackendKind::Fft
        );
        // Large bidirectional with a smooth-kernel rank: SKI.
        assert_eq!(
            d.select(&DispatchQuery { n: 4096, r: 256, w: 9, causal: false, batch: 1 }),
            BackendKind::Ski
        );
        // Causal: SKI ineligible, Hilbert spectrum preferred.
        assert_eq!(
            d.select(&DispatchQuery { n: 4096, r: 256, w: 9, causal: true, batch: 1 }),
            BackendKind::Freq
        );
        // Non-power-of-two: spectral paths ineligible, SKI still fine.
        assert_eq!(
            d.select(&DispatchQuery { n: 3000, r: 64, w: 9, causal: false, batch: 1 }),
            BackendKind::Ski
        );
    }

    #[test]
    fn prop_apply_causal_taps_backends_agree() {
        check("causal taps: dense == fft path", |rng| {
            let t_len = size(rng, 2, 200);
            let n_taps = size(rng, 1, 256);
            let taps = vecf(rng, n_taps);
            let x = vecf(rng, t_len);
            let dense = apply_causal_taps(&taps, &x, BackendKind::Dense);
            let fftp = apply_causal_taps(&taps, &x, BackendKind::Fft);
            let auto = apply_causal_taps(&taps, &x, BackendKind::Auto);
            assert_close(&dense, &fftp, 1e-4, "dense vs fft causal");
            assert_close(&dense, &auto, 1e-4, "dense vs auto causal");
        });
    }

    #[test]
    fn build_op_names_and_shapes() {
        let mut rng = crate::util::rng::Rng::new(3);
        let k = random_kernel(&mut rng, 64);
        for (kind, name) in
            [(BackendKind::Dense, "dense"), (BackendKind::Fft, "fft"), (BackendKind::Ski, "ski")]
        {
            let op = build_op(&k, kind, 16, 5);
            assert_eq!(op.name(), name);
            assert_eq!(op.n(), 64);
            assert!(op.flops_estimate() > 0.0);
        }
        let causal = k.clone().causal();
        let op = build_op(&causal, BackendKind::Freq, 0, 0);
        assert_eq!(op.name(), "freq");
        // Auto on a causal kernel must pick a causal-capable backend.
        let auto = build_op(&causal, BackendKind::Auto, 16, 5);
        assert!(auto.name() == "dense" || auto.name() == "freq", "got {}", auto.name());
    }
}
