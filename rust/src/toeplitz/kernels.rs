//! Stationary (non-SPD) kernels and the SKI RPE machinery:
//! inverse time warp, learned-table lookup, decay bias.
//!
//! Mirrors `python/compile/rpe.py` exactly — the substrate tests assert
//! parity between this code and the lowered HLO.

/// Exponential decay bias `λ^{|t|}` (the baseline TNN's explicit bias).
pub fn decay_bias(t: i64, lam: f32) -> f32 {
    lam.powf(t.abs() as f32)
}

/// Inverse time warp `x(t) = sign(t) λ^{|t|}` — maps R onto [-1, 1]
/// with long lags compressed towards zero (paper §3.2.2).
pub fn warp(t: f64, lam: f64) -> f64 {
    t.signum() * lam.powf(t.abs())
}

/// Smooth analytic test kernel: Gaussian bump with asymmetric tilt.
/// (Infinitely differentiable — used where Theorem 1 assumes N+1
/// continuous derivatives.)
pub fn gaussian_kernel(t: f64, scale: f64) -> f32 {
    let z = t / scale;
    ((-0.5 * z * z).exp() * (1.0 + 0.3 * z)) as f32
}

/// Rational decay kernel 1/(1+|t|/s) with sign asymmetry; C⁰ at 0.
pub fn rational_kernel(t: f64, scale: f64) -> f32 {
    let a = 1.0 / (1.0 + t.abs() / scale);
    (if t < 0.0 { 0.7 * a } else { a }) as f32
}

/// The SKI RPE: a learned piecewise-linear function on [-1, 1] (the
/// warped axis), represented by an odd-sized value table whose centre
/// is pinned to zero so `k(0) = 0` and `k(±∞) → 0`.
#[derive(Debug, Clone)]
pub struct TableKernel {
    pub values: Vec<f32>, // odd length; centre forced 0 at eval
    pub lam: f64,
}

impl TableKernel {
    pub fn new(values: Vec<f32>, lam: f64) -> Self {
        assert!(values.len() % 2 == 1, "table must be odd-sized");
        TableKernel { values, lam }
    }

    /// Evaluate the kernel at (real-valued) lag `t`.
    pub fn eval(&self, t: f64) -> f32 {
        self.lookup(warp(t, self.lam))
    }

    /// Linear interpolation of the table on [-1, 1], centre pinned to 0.
    pub fn lookup(&self, x: f64) -> f32 {
        let tbl = self.values.len();
        let centre = tbl / 2;
        let val = |i: usize| if i == centre { 0.0 } else { self.values[i] };
        let g = (x + 1.0) * 0.5 * (tbl as f64 - 1.0);
        let lo = (g.floor() as i64).clamp(0, tbl as i64 - 2) as usize;
        let frac = (g - lo as f64) as f32;
        (1.0 - frac) * val(lo) + frac * val(lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, size};

    #[test]
    fn warp_bounds_and_signs() {
        check("warp in [-1,1], odd", |rng| {
            let lam = 0.9 + 0.099 * rng.f64();
            let t = rng.normal() as f64 * 100.0;
            let w = warp(t, lam);
            assert!((-1.0..=1.0).contains(&w), "warp({t})={w}");
            assert!((warp(-t, lam) + w).abs() < 1e-12, "odd symmetry");
        });
    }

    #[test]
    fn warp_monotone_decay() {
        // |warp| decreases with |t| — long lags compress to the centre.
        let lam = 0.97;
        let mut prev = warp(0.5, lam).abs();
        for t in 1..200 {
            let cur = warp(t as f64, lam).abs();
            assert!(cur < prev, "not decaying at t={t}");
            prev = cur;
        }
    }

    #[test]
    fn table_centre_pinned() {
        check("table centre zero", |rng| {
            let tbl = 2 * size(rng, 2, 32) + 1;
            let k = TableKernel::new(rng.normals(tbl), 0.99);
            assert_eq!(k.lookup(0.0), 0.0);
            // eval at huge lags → warp ~0 → value ~0
            assert!(k.eval(5000.0).abs() < 1e-3);
            assert!(k.eval(-5000.0).abs() < 1e-3);
        });
    }

    #[test]
    fn table_interp_hits_grid_points() {
        let vals = vec![1.0, -2.0, 0.0, 3.0, 4.0]; // centre index 2 pinned
        let k = TableKernel::new(vals.clone(), 0.99);
        let tbl = 5;
        for (i, &v) in vals.iter().enumerate() {
            let x = -1.0 + 2.0 * i as f64 / (tbl as f64 - 1.0);
            let want = if i == 2 { 0.0 } else { v };
            assert!((k.lookup(x) - want).abs() < 1e-6, "grid point {i}");
        }
    }

    #[test]
    fn decay_bias_basic() {
        assert_eq!(decay_bias(0, 0.9), 1.0);
        assert!((decay_bias(2, 0.9) - 0.81).abs() < 1e-6);
        assert_eq!(decay_bias(-2, 0.9), decay_bias(2, 0.9));
    }
}
