//! Sharded batch execution for [`ToeplitzOp`] backends.
//!
//! [`apply_batch_sharded`] splits the rows of one `apply_batch` into
//! contiguous shards — fixed boundaries of `ceil(rows / threads)` rows
//! each — and runs every shard on the [`ThreadPool`] (the submitting
//! thread participates).  Each row is computed by **exactly the same
//! per-row code as the serial path** and written into its own output
//! slot; no reduction ever crosses a shard boundary.  Output is
//! therefore bitwise identical for any worker count, and
//! `--threads 1` is the reference.
//!
//! Per-worker scratch lives in a thread-local arena
//! ([`with_scratch`](super::with_scratch), owned by `op.rs` alongside
//! [`OpScratch`](super::OpScratch)) that persists across shards and
//! batches — zero lock traffic, zero transform-buffer allocations in
//! steady state.
//!
//! [`apply_batch_flat_sharded`] is the flat-ABI counterpart: rows live
//! packed in one input and one output buffer, shards are **row-aligned
//! ranges** of those buffers, and each worker runs the backend's
//! allocation-free [`ToeplitzOp::apply_batch_flat`] over its range.
//! Shards dispatch through [`ThreadPool::scope_fn`] — no task boxes,
//! no task `Vec`, the pool's recycled batch state — and each worker's
//! scratch arena persists across ticks, so a steady-state sharded
//! serve tick allocates nothing at all.

use crate::runtime::pool::ThreadPool;

use super::op::{with_scratch, CostModel, ToeplitzOp};

/// Whether sharding this batch is worth the pool's per-shard dispatch
/// overhead — the one gate every `apply_batch_sharded` entry point
/// shares (the server adapters, the CLI, the benches' sweep).  Mirrors
/// [`CostModel::sharded_cost`] with the operator's own flop estimate
/// as the per-row cost proxy (≈1 multiply-add/ns; an underestimate on
/// real hardware, which only makes the gate conservatively serial for
/// small shapes — the correct direction).
fn worth_sharding(op: &dyn ToeplitzOp, rows: usize, threads: usize) -> bool {
    let cost = CostModel::default();
    let scalable = match op.name() {
        "dense" => cost.dense_par,
        "ski" => cost.ski_par,
        _ => cost.fft_par,
    };
    let row_ns = op.flops_estimate();
    cost.sharded_cost(row_ns, rows, threads, scalable) < row_ns * rows as f64
}

/// `op.apply_batch(xs)`, sharded across `pool`.  Bitwise identical to
/// the serial result for every `pool.threads()`; falls back to the
/// serial path when the pool is size 1, the batch has a single row,
/// or the modeled shard overhead exceeds the parallel win
/// ([`worth_sharding`]).
pub fn apply_batch_sharded(
    op: &dyn ToeplitzOp,
    xs: &[Vec<f32>],
    pool: &ThreadPool,
) -> Vec<Vec<f32>> {
    let rows = xs.len();
    if pool.threads().min(rows) <= 1 || !worth_sharding(op, rows, pool.threads()) {
        return op.apply_batch(xs);
    }
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); rows];
    pool.shard_mut(&mut out, |start, shard_out| {
        with_scratch(|s| {
            for (j, y) in shard_out.iter_mut().enumerate() {
                *y = op.apply_with_scratch(&xs[start + j], s);
            }
        })
    });
    out
}

/// Flat-ABI counterpart of [`apply_batch_sharded`]: `rows` signals of
/// length `op.n()` packed row-major in `xs`, results written row-major
/// into `out`.  Shards are row-aligned ranges of the two flat buffers
/// (a raw element split would cut rows in half), each executed by the
/// backend's allocation-free [`ToeplitzOp::apply_batch_flat`] with the
/// worker's thread-local scratch arena (which persists across calls
/// and ticks).  Dispatch rides [`ThreadPool::scope_fn`] — shard
/// indices from the pool's recycled batch cursor, no per-shard boxes —
/// so once every arena is warm a call allocates **nothing**.  Bitwise
/// identical to the serial flat path for every worker count.
pub fn apply_batch_flat_sharded(
    op: &dyn ToeplitzOp,
    xs: &[f32],
    rows: usize,
    out: &mut [f32],
    pool: &ThreadPool,
) {
    let n = op.n();
    assert_eq!(xs.len(), rows * n, "apply_batch_flat_sharded: input shape mismatch");
    assert_eq!(out.len(), rows * n, "apply_batch_flat_sharded: output shape mismatch");
    if rows == 0 || n == 0 {
        return;
    }
    let shards = pool.threads().min(rows);
    if shards <= 1 || !worth_sharding(op, rows, pool.threads()) {
        with_scratch(|s| op.apply_batch_flat(xs, rows, out, s));
        return;
    }
    let chunk_rows = rows.div_ceil(shards);
    let nshards = rows.div_ceil(chunk_rows);
    // usize-laundered base pointer: each claimed shard index carves its
    // own disjoint `&mut` row range out of the flat output.
    let out_base = out.as_mut_ptr() as usize;
    pool.scope_fn(nshards, &|shard| {
        let r0 = shard * chunk_rows;
        let shard_rows = chunk_rows.min(rows - r0);
        let shard_xs = &xs[r0 * n..(r0 + shard_rows) * n];
        // SAFETY: shard indices are claimed exactly once and the row
        // ranges are disjoint, so each `&mut` is exclusive; the flat
        // buffer outlives the scope (scope_fn blocks until all run).
        let shard_out = unsafe {
            std::slice::from_raw_parts_mut((out_base as *mut f32).add(r0 * n), shard_rows * n)
        };
        with_scratch(|s| op.apply_batch_flat(shard_xs, shard_rows, shard_out, s));
    });
}

#[cfg(test)]
mod tests {
    use super::super::kernels::gaussian_kernel;
    use super::super::op::{build_op, BackendKind};
    use super::super::ToeplitzKernel;
    use super::*;
    use crate::util::rng::Rng;

    fn batch(rng: &mut Rng, rows: usize, n: usize) -> Vec<Vec<f32>> {
        (0..rows).map(|_| rng.normals(n)).collect()
    }

    #[test]
    fn sharded_is_bitwise_serial_for_every_backend() {
        let n = 64;
        let mut rng = Rng::new(7);
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 12.0));
        let causal = kernel.clone().causal();
        // 13 rows: deliberately not divisible by any worker count.
        let xs = batch(&mut rng, 13, n);
        for (kind, k) in [
            (BackendKind::Dense, &kernel),
            (BackendKind::Fft, &kernel),
            (BackendKind::Ski, &kernel),
            (BackendKind::Freq, &causal),
        ] {
            let op = build_op(k, kind, 8, 5);
            let reference = op.apply_batch(&xs);
            for threads in [1usize, 2, 3, 8] {
                let pool = ThreadPool::new(threads);
                let got = apply_batch_sharded(op.as_ref(), &xs, &pool);
                assert_eq!(got, reference, "{} backend, {threads} threads", op.name());
                // Again through the same pool: arenas are reused.
                let again = apply_batch_sharded(op.as_ref(), &xs, &pool);
                assert_eq!(again, reference, "{} backend, reuse", op.name());
            }
        }
    }

    #[test]
    fn flat_sharded_is_bitwise_per_row_for_every_backend() {
        let n = 64;
        let mut rng = Rng::new(11);
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 12.0));
        let causal = kernel.clone().causal();
        let rows = 13; // deliberately not divisible by any worker count
        let xs: Vec<f32> = (0..rows).flat_map(|_| rng.normals(n)).collect();
        for (kind, k) in [
            (BackendKind::Dense, &kernel),
            (BackendKind::Fft, &kernel),
            (BackendKind::Ski, &kernel),
            (BackendKind::Freq, &causal),
        ] {
            let op = build_op(k, kind, 8, 5);
            let reference: Vec<f32> = xs.chunks(n).flat_map(|x| op.apply(x)).collect();
            let mut out = vec![0.0f32; rows * n];
            for threads in [1usize, 2, 3, 8] {
                let pool = ThreadPool::new(threads);
                out.fill(f32::NAN);
                apply_batch_flat_sharded(op.as_ref(), &xs, rows, &mut out, &pool);
                assert_eq!(out, reference, "{} backend, {threads} threads", op.name());
                // Again through the same pool: arenas are reused.
                out.fill(f32::NAN);
                apply_batch_flat_sharded(op.as_ref(), &xs, rows, &mut out, &pool);
                assert_eq!(out, reference, "{} backend, reuse", op.name());
            }
        }
    }

    #[test]
    fn flat_sharded_handles_empty_batch() {
        let n = 32;
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 6.0));
        let op = build_op(&kernel, BackendKind::Fft, 0, 0);
        let pool = ThreadPool::new(4);
        let mut out: Vec<f32> = Vec::new();
        apply_batch_flat_sharded(op.as_ref(), &[], 0, &mut out, &pool);
        assert!(out.is_empty());
    }

    #[test]
    fn sharded_handles_more_workers_than_rows() {
        let n = 32;
        let mut rng = Rng::new(3);
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 6.0));
        let op = build_op(&kernel, BackendKind::Fft, 0, 0);
        let pool = ThreadPool::new(16);
        for rows in [0usize, 1, 2] {
            let xs = batch(&mut rng, rows, n);
            assert_eq!(apply_batch_sharded(op.as_ref(), &xs, &pool), op.apply_batch(&xs));
        }
    }
}
