//! Sharded batch execution for [`ToeplitzOp`] backends.
//!
//! [`apply_batch_sharded`] splits the rows of one `apply_batch` into
//! contiguous shards — fixed boundaries of `ceil(rows / threads)` rows
//! each — and runs every shard on the [`ThreadPool`] (the submitting
//! thread participates).  Each row is computed by **exactly the same
//! per-row code as the serial path** and written into its own output
//! slot; no reduction ever crosses a shard boundary.  Output is
//! therefore bitwise identical for any worker count, and
//! `--threads 1` is the reference.
//!
//! Per-worker scratch lives in a thread-local [`OpScratch`] arena that
//! persists across shards and batches, so the spectral backends
//! ([`FftOp`](super::FftOp) / [`FreqCausalOp`](super::FreqCausalOp))
//! never touch their shared fallback `Mutex` scratch on this path —
//! zero lock traffic, zero transform-buffer allocations in steady
//! state.

use std::cell::RefCell;

use crate::runtime::pool::ThreadPool;

use super::op::{CostModel, OpScratch, ToeplitzOp};

thread_local! {
    /// One scratch arena per thread — pool workers and submitting
    /// callers alike — reused for the life of the thread.
    static ARENA: RefCell<OpScratch> = RefCell::new(OpScratch::default());
}

/// Run `f` with this thread's persistent scratch arena.  Not
/// re-entrant: `f` must not call `with_scratch` again (no backend
/// does).
pub fn with_scratch<R>(f: impl FnOnce(&mut OpScratch) -> R) -> R {
    ARENA.with(|a| f(&mut a.borrow_mut()))
}

/// Whether sharding this batch is worth the pool's per-shard dispatch
/// overhead — the one gate every `apply_batch_sharded` entry point
/// shares (the server adapters, the CLI, the benches' sweep).  Mirrors
/// [`CostModel::sharded_cost`] with the operator's own flop estimate
/// as the per-row cost proxy (≈1 multiply-add/ns; an underestimate on
/// real hardware, which only makes the gate conservatively serial for
/// small shapes — the correct direction).
fn worth_sharding(op: &dyn ToeplitzOp, rows: usize, threads: usize) -> bool {
    let cost = CostModel::default();
    let scalable = match op.name() {
        "dense" => cost.dense_par,
        "ski" => cost.ski_par,
        _ => cost.fft_par,
    };
    let row_ns = op.flops_estimate();
    cost.sharded_cost(row_ns, rows, threads, scalable) < row_ns * rows as f64
}

/// `op.apply_batch(xs)`, sharded across `pool`.  Bitwise identical to
/// the serial result for every `pool.threads()`; falls back to the
/// serial path when the pool is size 1, the batch has a single row,
/// or the modeled shard overhead exceeds the parallel win
/// ([`worth_sharding`]).
pub fn apply_batch_sharded(
    op: &dyn ToeplitzOp,
    xs: &[Vec<f32>],
    pool: &ThreadPool,
) -> Vec<Vec<f32>> {
    let rows = xs.len();
    if pool.threads().min(rows) <= 1 || !worth_sharding(op, rows, pool.threads()) {
        return op.apply_batch(xs);
    }
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); rows];
    pool.shard_mut(&mut out, |start, shard_out| {
        with_scratch(|s| {
            for (j, y) in shard_out.iter_mut().enumerate() {
                *y = op.apply_with_scratch(&xs[start + j], s);
            }
        })
    });
    out
}

#[cfg(test)]
mod tests {
    use super::super::kernels::gaussian_kernel;
    use super::super::op::{build_op, BackendKind};
    use super::super::ToeplitzKernel;
    use super::*;
    use crate::util::rng::Rng;

    fn batch(rng: &mut Rng, rows: usize, n: usize) -> Vec<Vec<f32>> {
        (0..rows).map(|_| rng.normals(n)).collect()
    }

    #[test]
    fn sharded_is_bitwise_serial_for_every_backend() {
        let n = 64;
        let mut rng = Rng::new(7);
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 12.0));
        let causal = kernel.clone().causal();
        // 13 rows: deliberately not divisible by any worker count.
        let xs = batch(&mut rng, 13, n);
        for (kind, k) in [
            (BackendKind::Dense, &kernel),
            (BackendKind::Fft, &kernel),
            (BackendKind::Ski, &kernel),
            (BackendKind::Freq, &causal),
        ] {
            let op = build_op(k, kind, 8, 5);
            let reference = op.apply_batch(&xs);
            for threads in [1usize, 2, 3, 8] {
                let pool = ThreadPool::new(threads);
                let got = apply_batch_sharded(op.as_ref(), &xs, &pool);
                assert_eq!(got, reference, "{} backend, {threads} threads", op.name());
                // Again through the same pool: arenas are reused.
                let again = apply_batch_sharded(op.as_ref(), &xs, &pool);
                assert_eq!(again, reference, "{} backend, reuse", op.name());
            }
        }
    }

    #[test]
    fn sharded_handles_more_workers_than_rows() {
        let n = 32;
        let mut rng = Rng::new(3);
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, 6.0));
        let op = build_op(&kernel, BackendKind::Fft, 0, 0);
        let pool = ThreadPool::new(16);
        for rows in [0usize, 1, 2] {
            let xs = batch(&mut rng, rows, n);
            assert_eq!(apply_batch_sharded(op.as_ref(), &xs, &pool), op.apply_batch(&xs));
        }
    }
}
