//! Asymmetric structured kernel interpolation (SKI) — paper §3.2.1.
//!
//! `T ≈ W A Wᵀ` with `W` the sparse hat-function interpolation matrix
//! onto `r` uniform inducing points and `A` the (Toeplitz) inducing
//! Gram matrix.  Two apply paths are implemented:
//!
//! * [`Ski::apply_sparse`] — the mathematically `O(n + r log r)` path:
//!   sparse `Wᵀx` scatter, FFT Toeplitz matvec for `A`, sparse gather.
//! * [`Ski::apply_dense`]  — the paper's practical path: dense `(n,r)`
//!   matmuls (their observation that sparse-tensor data movement loses
//!   to dense matmul below n ≈ 512 is re-measured in
//!   `benches/fig11_sparse_vs_lowrank`).
//!
//! Plus [`causal_ski_scan`] — Appendix B's causally-masked SKI via the
//! sequential cumulative sum `s_i = Σ_{j≤i} w_j x_j`,
//! `x'_i = [W A]_i ᵀ s_i`, which is what shows that causal masking
//! negates SKI's speedup.

use super::op::{with_scratch, OpScratch, SpectralPlan};
use super::ToeplitzKernel;

/// Whether the r-point inducing-Gram multiply is cheaper through the
/// spectral path than the dense r² matvec, per the calibrated cost
/// model — priced at what the spectral route *actually runs* (a
/// cached-spectrum [`SpectralPlan`] on the gram's own smooth grid,
/// two r2c transforms per call), so the crossover sits near r = 128
/// rather than the old per-call-kernel-FFT break-even at r = 512.
/// Shared by [`Ski::new`], `SparseLowRankOp::flops_estimate`, and
/// `CostModel::ski_cost` so the three always agree on the route.
pub(crate) fn gram_prefers_fft(r: usize) -> bool {
    let cost = super::op::CostModel::default();
    cost.gram_fft_cost(r) < cost.dense_cost(r)
}

/// `r` uniform inducing points covering `[0, n-1]`.
///
/// The hat-function interpolation needs at least two inducing points
/// (every observation sits between a left and a right neighbour); the
/// spacing `h = (n-1)/(r-1)` is degenerate below that, so `r < 2` is a
/// caller bug and asserts rather than returning NaN/∞ grids.
pub fn inducing_grid(n: usize, r: usize) -> Vec<f64> {
    assert!(r >= 2, "SKI needs at least 2 inducing points, got r={r}");
    assert!(n >= 1, "inducing grid over an empty axis");
    let h = (n as f64 - 1.0) / (r as f64 - 1.0);
    (0..r).map(|j| j as f64 * h).collect()
}

/// Sparse interpolation weights for observation point `i`:
/// returns (left inducing index, weight of left, weight of right).
pub fn interp_weights(i: usize, n: usize, r: usize) -> (usize, f32, f32) {
    assert!(r >= 2, "SKI needs at least 2 inducing points, got r={r}");
    assert!(i < n, "observation index {i} out of range (n={n})");
    let h = (n as f64 - 1.0) / (r as f64 - 1.0);
    let g = if h > 0.0 { i as f64 / h } else { 0.0 };
    let lo = (g.floor() as usize).min(r - 2);
    let frac = (g - lo as f64) as f32;
    (lo, 1.0 - frac, frac)
}

/// The SKI factorisation of one Toeplitz operator.
#[derive(Debug, Clone)]
pub struct Ski {
    pub n: usize,
    pub r: usize,
    /// Inducing Gram taps: `A_ij = taps[i-j+r-1]` (lag -(r-1)..=(r-1)).
    pub a: ToeplitzKernel,
    /// Whether the Gram multiply takes the spectral route — decided
    /// once here (see [`gram_prefers_fft`]); `apply_sparse` is the
    /// per-row hot path and must not re-derive it.
    pub gram_fft: bool,
    /// Cached circulant plan over `a` when the spectral route won:
    /// the gram spectrum is built once here instead of re-FFT'd on
    /// every apply.
    gram_plan: Option<SpectralPlan>,
}

impl Ski {
    /// Assemble from an explicit inducing Gram kernel (`a.n` must be
    /// `r`), deciding the gram-multiply route once.
    pub fn new(n: usize, r: usize, a: ToeplitzKernel) -> Self {
        assert!(r >= 2, "SKI needs at least 2 inducing points, got r={r}");
        assert_eq!(a.n, r, "inducing Gram kernel must be r-point");
        let gram_fft = gram_prefers_fft(r);
        let gram_plan = gram_fft.then(|| SpectralPlan::new(&a));
        Ski { n, r, a, gram_fft, gram_plan }
    }

    /// Build from a kernel function over real-valued lags: the Gram
    /// matrix of the kernel at inducing-point differences `(i-j)·h`.
    pub fn from_kernel(n: usize, r: usize, k: impl Fn(f64) -> f32) -> Self {
        assert!(r >= 2, "SKI needs at least 2 inducing points, got r={r}");
        let h = (n as f64 - 1.0) / (r as f64 - 1.0);
        let a = ToeplitzKernel::from_fn(r, |lag| k(lag as f64 * h));
        Ski::new(n, r, a)
    }

    /// Bytes of factorisation-owned tables: the inducing Gram lags
    /// plus the cached gram spectrum when the spectral route won.
    pub fn resident_bytes(&self) -> usize {
        self.a.lags.capacity() * std::mem::size_of::<f32>()
            + self.gram_plan.as_ref().map_or(0, SpectralPlan::resident_bytes)
    }

    /// `u = Wᵀ x` — sparse scatter, O(n).
    pub fn wt_apply(&self, x: &[f32]) -> Vec<f32> {
        let mut u = vec![0.0f32; self.r];
        for (i, &xi) in x.iter().enumerate() {
            let (lo, wl, wr) = interp_weights(i, self.n, self.r);
            u[lo] += wl * xi;
            u[lo + 1] += wr * xi;
        }
        u
    }

    /// `y = W v` — sparse gather, O(n).
    pub fn w_apply(&self, v: &[f32]) -> Vec<f32> {
        (0..self.n)
            .map(|i| {
                let (lo, wl, wr) = interp_weights(i, self.n, self.r);
                wl * v[lo] + wr * v[lo + 1]
            })
            .collect()
    }

    /// O(n + r log r) apply through the calling thread's arena
    /// ([`with_scratch`] entry point — don't call from inside another
    /// arena borrow; use [`apply_sparse_add`](Self::apply_sparse_add)
    /// there).
    pub fn apply_sparse(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.n];
        with_scratch(|s| self.apply_sparse_add(x, &mut y, s));
        y
    }

    /// `out += W A Wᵀ x` through caller scratch — the allocation-free
    /// core of the sparse path: O(n) scatter into `scratch.u`, the
    /// inducing-Gram multiply into `scratch.v` (the cached spectral
    /// plan whenever the cost model priced it below the dense r²
    /// matvec — any r, not just powers of two), O(n) gather-accumulate
    /// into `out`.
    pub fn apply_sparse_add(&self, x: &[f32], out: &mut [f32], scratch: &mut OpScratch) {
        assert_eq!(x.len(), self.n, "Ski size mismatch");
        assert_eq!(out.len(), self.n, "Ski output size mismatch");
        // Take u/v out of the arena so the gram plan can borrow the
        // rest of it for its own transform buffers.
        let mut u = std::mem::take(&mut scratch.u);
        let mut v = std::mem::take(&mut scratch.v);
        u.clear();
        u.resize(self.r, 0.0);
        for (i, &xi) in x.iter().enumerate() {
            let (lo, wl, wr) = interp_weights(i, self.n, self.r);
            u[lo] += wl * xi;
            u[lo + 1] += wr * xi;
        }
        v.clear();
        v.resize(self.r, 0.0);
        match &self.gram_plan {
            Some(plan) => plan.apply_into(&u, &mut v, scratch),
            None => self.a.apply_dense_into(&u, &mut v),
        }
        for (i, o) in out.iter_mut().enumerate() {
            let (lo, wl, wr) = interp_weights(i, self.n, self.r);
            *o += wl * v[lo] + wr * v[lo + 1];
        }
        scratch.u = u;
        scratch.v = v;
    }

    /// The paper's practical path: materialised dense `W` matmuls
    /// (O(n·r) matvec here; O(n r²)-style batched matmul on GPU).
    pub fn apply_dense(&self, x: &[f32]) -> Vec<f32> {
        let wd = self.w_dense();
        // u = Wᵀ x
        let mut u = vec![0.0f32; self.r];
        for i in 0..self.n {
            for j in 0..self.r {
                u[j] += wd[i * self.r + j] * x[i];
            }
        }
        let v = self.a.apply_dense(&u);
        let mut y = vec![0.0f32; self.n];
        for i in 0..self.n {
            for j in 0..self.r {
                y[i] += wd[i * self.r + j] * v[j];
            }
        }
        y
    }

    /// Dense `W` (row-major n×r) — hat-function rows.
    pub fn w_dense(&self) -> Vec<f32> {
        let mut wd = vec![0.0f32; self.n * self.r];
        for i in 0..self.n {
            let (lo, wl, wr) = interp_weights(i, self.n, self.r);
            wd[i * self.r + lo] = wl;
            wd[i * self.r + lo + 1] = wr;
        }
        wd
    }

    /// Dense `W A Wᵀ` as a matrix (error analyses).
    pub fn dense(&self) -> crate::linalg::Mat {
        let wd = self.w_dense();
        let w = crate::linalg::Mat::from_fn(self.n, self.r, |i, j| {
            wd[i * self.r + j] as f64
        });
        w.matmul(&self.a.dense()).matmul(&w.t())
    }
}

/// Appendix B: causally-masked SKI action via the sequential scan.
///
/// `x'_i = Σ_{j≤i} wᵢᵀ A wⱼ xⱼ = [W A]ᵢᵀ sᵢ`, `sᵢ = s_{i-1} + wᵢ xᵢ`.
/// O(n·r) work but strictly sequential in `i` — the data dependency
/// that makes causal SKI slower than the baseline FFT in practice.
pub fn causal_ski_scan(ski: &Ski, x: &[f32]) -> Vec<f32> {
    let n = ski.n;
    let r = ski.r;
    // Precompute WA rows: wa[i] = (W A)_i  (n×r).
    let a = &ski.a;
    let mut wa = vec![0.0f32; n * r];
    for i in 0..n {
        let (lo, wl, wr) = interp_weights(i, n, r);
        for j in 0..r {
            wa[i * r + j] = wl * a.at(lo as i64 - j as i64) + wr * a.at(lo as i64 + 1 - j as i64);
        }
    }
    let mut s = vec![0.0f32; r];
    let mut out = vec![0.0f32; n];
    for i in 0..n {
        let (lo, wl, wr) = interp_weights(i, n, r);
        s[lo] += wl * x[i];
        s[lo + 1] += wr * x[i];
        let row = &wa[i * r..(i + 1) * r];
        out[i] = row.iter().zip(s.iter()).map(|(a, b)| a * b).sum();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::toeplitz::kernels::gaussian_kernel;
    use crate::util::prop::{assert_close, check, size, vecf};

    #[test]
    fn weights_partition_unity() {
        check("hat weights sum to 1", |rng| {
            let n = size(rng, 8, 512);
            let r = size(rng, 2, 32).min(n);
            for i in 0..n {
                let (lo, wl, wr) = interp_weights(i, n, r);
                assert!(lo + 1 < r);
                assert!((wl + wr - 1.0).abs() < 1e-5);
                assert!(wl >= -1e-6 && wr >= -1e-6);
            }
        });
    }

    #[test]
    fn prop_weights_partition_unity_randomized_ranks() {
        // Satellite contract over fully randomized (i, n, r): the hat
        // weights are a partition of unity, non-negative, and the left
        // index always leaves room for its right neighbour — including
        // at the r = 2 floor, r = n (grid on every lag), r > n, and
        // the endpoints i = 0 / i = n-1.
        check("hat weights partition of unity (randomized)", |rng| {
            let n = size(rng, 1, 1024);
            let r = size(rng, 2, 2 * n.max(2));
            for _ in 0..16 {
                let i = rng.below(n);
                let (lo, wl, wr) = interp_weights(i, n, r);
                assert!(lo + 1 < r, "lo={lo} leaves no right neighbour (n={n}, r={r})");
                assert!((wl + wr - 1.0).abs() < 1e-5, "i={i}: {wl} + {wr} != 1");
                assert!(wl >= -1e-6 && wr >= -1e-6, "negative weight at i={i}");
            }
            for i in [0, n - 1] {
                let (lo, wl, wr) = interp_weights(i, n, r);
                assert!(lo + 1 < r);
                assert!((wl + wr - 1.0).abs() < 1e-5, "endpoint i={i}");
            }
        });
    }

    #[test]
    fn prop_sparse_matches_dense_pinned_1e5() {
        // Satellite contract: the O(n + r log r) sparse path and the
        // dense-matmul path are the same operator to 1e-5 — tighter
        // than the generic 1e-4 substrate tolerance, pinning down the
        // f64-FFT + f32-accumulate numerics.
        check("ski sparse == dense @1e-5", |rng| {
            let n = size(rng, 4, 128);
            let r = size(rng, 2, 16).min(n);
            // Unit-scale data: the contract pins the *path* difference
            // (f64-FFT vs f32 matvec summation order), so keep the
            // accumulation magnitudes O(1) rather than letting the
            // generic N(0,1)·√(n/r) growth eat the tolerance.
            let lags: Vec<f32> = vecf(rng, 2 * r - 1).iter().map(|v| 0.5 * v).collect();
            let ski = Ski::new(n, r, ToeplitzKernel { n: r, lags });
            let x: Vec<f32> = vecf(rng, n).iter().map(|v| 0.25 * v).collect();
            assert_close(&ski.apply_sparse(&x), &ski.apply_dense(&x), 1e-5, "pinned paths");
        });
    }

    #[test]
    fn grid_endpoints() {
        let g = inducing_grid(100, 5);
        assert!((g[0] - 0.0).abs() < 1e-12);
        assert!((g[4] - 99.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "at least 2 inducing points")]
    fn grid_rejects_rank_one() {
        let _ = inducing_grid(100, 1);
    }

    #[test]
    #[should_panic(expected = "at least 2 inducing points")]
    fn weights_reject_rank_one() {
        let _ = interp_weights(0, 100, 1);
    }

    #[test]
    #[should_panic(expected = "at least 2 inducing points")]
    fn from_kernel_rejects_rank_zero() {
        let _ = Ski::from_kernel(64, 0, |_| 1.0);
    }

    #[test]
    fn minimum_rank_two_works_end_to_end() {
        // The r = 2 floor previously divided by r-1 = 1 (fine) but the
        // guard also protects lo+1 indexing; make sure the minimum
        // rank actually runs every apply path.
        let ski = Ski::from_kernel(16, 2, |t| gaussian_kernel(t, 8.0));
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
        let a = ski.apply_sparse(&x);
        let b = ski.apply_dense(&x);
        assert_close(&a, &b, 1e-4, "r=2 sparse vs dense");
        for i in 0..16 {
            let (lo, wl, wr) = interp_weights(i, 16, 2);
            assert_eq!(lo, 0);
            assert!((wl + wr - 1.0).abs() < 1e-6);
        }
        // Degenerate-but-legal n = 1 observation axis.
        let (lo, wl, _) = interp_weights(0, 1, 2);
        assert_eq!((lo, wl), (0, 1.0));
    }

    #[test]
    fn prop_sparse_matches_dense_path() {
        check("ski sparse == dense path", |rng| {
            let n = size(rng, 8, 256);
            let r = size(rng, 3, 24).min(n);
            let a = ToeplitzKernel { n: r, lags: vecf(rng, 2 * r - 1) };
            let ski = Ski::new(n, r, a);
            let x = vecf(rng, n);
            assert_close(&ski.apply_sparse(&x), &ski.apply_dense(&x), 1e-4, "paths");
        });
    }

    #[test]
    fn ski_exact_for_affine_kernel() {
        // Linear interpolation reproduces affine functions exactly, so
        // for k(t) = a·t + b the SKI approximation equals T exactly.
        check("ski exact on affine kernels", |rng| {
            let n = size(rng, 8, 128);
            let r = size(rng, 2, 16).min(n);
            let (a, b) = (rng.normal() as f64 * 0.1, rng.normal() as f64);
            let k = |t: f64| (a * t + b) as f32;
            let ski = Ski::from_kernel(n, r, k);
            let t = ToeplitzKernel::from_fn(n, |lag| k(lag as f64));
            let x = vecf(rng, n);
            assert_close(&ski.apply_dense(&x), &t.apply_dense(&x), 2e-3, "affine");
        });
    }

    #[test]
    fn ski_error_shrinks_with_rank() {
        let n = 128;
        let x: Vec<f32> = (0..n).map(|i| ((i * 37 % 17) as f32 - 8.0) / 8.0).collect();
        let k = |t: f64| gaussian_kernel(t, 24.0);
        let t = ToeplitzKernel::from_fn(n, |lag| k(lag as f64));
        let exact = t.apply_dense(&x);
        let errs: Vec<f64> = [5usize, 9, 17, 33, 65]
            .iter()
            .map(|&r| {
                let approx = Ski::from_kernel(n, r, k).apply_dense(&x);
                exact
                    .iter()
                    .zip(approx.iter())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] * 1.05, "error not shrinking: {errs:?}");
        }
        assert!(errs.last().unwrap() < &(errs[0] * 0.05), "{errs:?}");
    }

    #[test]
    fn prop_causal_scan_matches_masked_dense() {
        check("causal ski scan == lower-tri(W A Wt)", |rng| {
            let n = size(rng, 4, 96);
            let r = size(rng, 3, 12).min(n);
            let a = ToeplitzKernel { n: r, lags: vecf(rng, 2 * r - 1) };
            let ski = Ski::new(n, r, a);
            let x = vecf(rng, n);
            let got = causal_ski_scan(&ski, &x);
            // reference: dense W A Wᵀ, lower-triangular masked
            let dense = ski.dense();
            let want: Vec<f32> = (0..n)
                .map(|i| {
                    (0..=i).map(|j| dense[(i, j)] * x[j] as f64).sum::<f64>() as f32
                })
                .collect();
            assert_close(&got, &want, 1e-3, "causal scan");
        });
    }
}
