//! Dtype-tagged host tensors — the batch/output currency of the system.
//!
//! Batches are produced on worker threads (`data::*`, the coordinator's
//! prefetcher) as plain `HostTensor`s and converted to XLA [`Literal`]s
//! only on the runtime thread, right before execution — the `xla` FFI
//! handles are not `Send`, so nothing device-facing ever crosses a
//! thread boundary.

use anyhow::{bail, Result};
use xla::Literal;

use super::manifest::{Dtype, IoDesc};

/// A host-resident tensor: shape + flat data in row-major order.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "f32 tensor shape/data");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "i32 tensor shape/data");
        HostTensor::I32 { shape, data }
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "u32 tensor shape/data");
        HostTensor::U32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_u32(v: u32) -> Self {
        HostTensor::U32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. }
            | HostTensor::I32 { shape, .. }
            | HostTensor::U32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
            HostTensor::U32 { .. } => Dtype::U32,
        }
    }

    pub fn elem_count(&self) -> usize {
        self.shape().iter().product()
    }

    /// Check this tensor against a manifest I/O descriptor.
    pub fn check(&self, desc: &IoDesc) -> Result<()> {
        if self.shape() != desc.shape.as_slice() || self.dtype() != desc.dtype {
            bail!(
                "tensor mismatch for {}: have {:?} {:?}, manifest wants {:?} {:?}",
                desc.name,
                self.dtype(),
                self.shape(),
                desc.dtype,
                desc.shape
            );
        }
        Ok(())
    }

    /// Convert into an XLA literal (host→host copy).
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => Literal::vec1(data).reshape(&dims)?,
            HostTensor::I32 { data, .. } => Literal::vec1(data).reshape(&dims)?,
            HostTensor::U32 { data, .. } => Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(match shape.ty() {
            xla::ElementType::F32 => HostTensor::F32 { shape: dims, data: lit.to_vec()? },
            xla::ElementType::S32 => HostTensor::I32 { shape: dims, data: lit.to_vec()? },
            xla::ElementType::U32 => HostTensor::U32 { shape: dims, data: lit.to_vec()? },
            other => bail!("unsupported literal element type {other:?}"),
        })
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => bail!("expected f32 tensor, have {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            other => bail!("expected i32 tensor, have {:?}", other.dtype()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_scalar_shapes() {
        let t = HostTensor::i32(vec![4], vec![-1, 0, 7, 1 << 20]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let s = HostTensor::scalar_u32(42);
        let back = HostTensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn check_rejects_wrong_shape() {
        let t = HostTensor::f32(vec![2, 2], vec![0.0; 4]);
        let desc =
            IoDesc { name: "x".into(), shape: vec![2, 3], dtype: Dtype::F32 };
        assert!(t.check(&desc).is_err());
    }
}
