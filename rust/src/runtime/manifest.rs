//! Typed view of `artifacts/manifest.json` — the AOT contract.
//!
//! The manifest is written by `python/compile/aot.py` and is the single
//! source of truth for every shape the coordinator touches: flat
//! parameter order (jax tree order), entrypoint I/O signatures, and the
//! model hyper-parameters the Rust side needs (batch, n, vocab, …).
//! Nothing here is re-derived — if python and rust disagree the loader
//! fails loudly at startup rather than silently mis-addressing buffers.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Element dtype of one artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unknown dtype {other:?} in manifest"),
        })
    }
}

/// One named input/output of an artifact entrypoint.
#[derive(Debug, Clone)]
pub struct IoDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoDesc {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<Self> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("io desc missing name"))?
            .to_string();
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("io desc {name}: missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            v.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("io desc {name}: missing dtype"))?,
        )?;
        Ok(IoDesc { name, shape, dtype })
    }
}

/// One lowered entrypoint (`init` / `step` / `fwd` / `logits` / `fwd_n*`).
#[derive(Debug, Clone)]
pub struct Entry {
    pub file: String,
    pub inputs: Vec<IoDesc>,
    pub outputs: Vec<IoDesc>,
}

/// Training objective of a config (mirrors `configs.py` `task`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Causal next-token LM (Wikitext-style pre-training, Table 1).
    LmCausal,
    /// Masked/bidirectional LM (RoBERTa-style pre-training, Figs 8–9).
    LmBidir,
    /// Sequence classification (LRA, Table 2).
    Cls,
}

impl Task {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lm_causal" => Task::LmCausal,
            "lm_bidir" => Task::LmBidir,
            "cls" => Task::Cls,
            other => bail!("unknown task {other:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Task::LmCausal => "lm_causal",
            Task::LmBidir => "lm_bidir",
            Task::Cls => "cls",
        }
    }
}

/// TNO variant of a config (the paper's comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Baseline TNN (Qin et al. 2023): MLP RPE × decay bias, FFT apply.
    Base,
    /// Paper §3.2: sparse conv + asymmetric-SKI low rank + time warp.
    Ski,
    /// Paper §3.3: frequency-domain RPE (Hilbert-causal or complex).
    Fd,
}

impl Variant {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "base" => Variant::Base,
            "ski" => Variant::Ski,
            "fd" => Variant::Fd,
            other => bail!("unknown variant {other:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Base => "base",
            Variant::Ski => "ski",
            Variant::Fd => "fd",
        }
    }
}

/// One model configuration and its artifact family.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub task: Task,
    pub variant: Variant,
    pub vocab: usize,
    pub n: usize,
    pub d: usize,
    pub blocks: usize,
    pub batch: usize,
    pub rpe_layers: usize,
    pub num_classes: usize,
    pub r: usize,
    pub m: usize,
    pub lam: f64,
    pub lr: f64,
    pub warmup: usize,
    pub param_count: usize,
    /// Flat parameter descriptors in jax tree order — buffer addressing.
    pub params: Vec<IoDesc>,
    pub entries: BTreeMap<String, Entry>,
    /// Extra `fwd_n{L}` eval lengths lowered for Fig 7a.
    pub eval_lens: Vec<usize>,
}

impl ModelConfig {
    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("config {} has no entry {name:?}", self.name))
    }

    /// Batch input descriptors of the `step` entry (everything after
    /// params, m, v, t in its signature).
    pub fn batch_inputs(&self) -> Result<Vec<IoDesc>> {
        let step = self.entry("step")?;
        let skip = 3 * self.params.len() + 1;
        Ok(step.inputs[skip..].to_vec())
    }
}

/// The whole `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub configs: BTreeMap<String, ModelConfig>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let configs = root
            .get("configs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing configs object"))?;
        let mut out = BTreeMap::new();
        for (name, cfg) in configs {
            out.insert(name.clone(), Self::parse_config(name, cfg)?);
        }
        Ok(Manifest { configs: out })
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("no config {name:?} in manifest (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()))
    }

    fn parse_config(name: &str, v: &Json) -> Result<ModelConfig> {
        let us =
            |k: &str| v.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("{name}: bad {k}"));
        let fl =
            |k: &str| v.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("{name}: bad {k}"));
        let st =
            |k: &str| v.get(k).and_then(Json::as_str).ok_or_else(|| anyhow!("{name}: bad {k}"));

        let params = v
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: missing params"))?
            .iter()
            .map(IoDesc::parse)
            .collect::<Result<Vec<_>>>()?;

        let mut entries = BTreeMap::new();
        for (ename, ev) in v
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("{name}: missing entries"))?
        {
            let file = ev
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}.{ename}: missing file"))?
                .to_string();
            let parse_ios = |key: &str| -> Result<Vec<IoDesc>> {
                ev.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}.{ename}: missing {key}"))?
                    .iter()
                    .map(IoDesc::parse)
                    .collect()
            };
            entries.insert(
                ename.clone(),
                Entry { file, inputs: parse_ios("inputs")?, outputs: parse_ios("outputs")? },
            );
        }

        let eval_lens = v
            .get("eval_lens")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();

        Ok(ModelConfig {
            name: name.to_string(),
            task: Task::parse(st("task")?)?,
            variant: Variant::parse(st("variant")?)?,
            vocab: us("vocab")?,
            n: us("n")?,
            d: us("d")?,
            blocks: us("blocks")?,
            batch: us("batch")?,
            rpe_layers: us("rpe_layers")?,
            num_classes: us("num_classes")?,
            r: us("r")?,
            m: us("m")?,
            lam: fl("lam")?,
            lr: fl("lr")?,
            warmup: us("warmup")?,
            param_count: us("param_count")?,
            params,
            entries,
            eval_lens,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load the manifest, or `None` when the AOT artifacts haven't
    /// been built (these tests validate python⇄rust contract files,
    /// not the Rust substrate itself).
    fn manifest() -> Option<Manifest> {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping artifact-gated test: no artifacts/manifest.json");
            return None;
        }
        Some(Manifest::load(&artifacts_dir()).expect("manifest"))
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let Some(m) = manifest() else { return };
        assert!(m.configs.len() >= 4, "expected many configs");
        for (name, cfg) in &m.configs {
            // step signature = params + m + v + t + batch → params' m' v' t' loss
            let step = cfg.entry("step").unwrap();
            let p = cfg.params.len();
            assert!(step.inputs.len() > 3 * p + 1, "{name}: step inputs");
            assert_eq!(step.outputs.len(), 3 * p + 2, "{name}: step outputs");
            // init: seed → params, same shapes in same order
            let init = cfg.entry("init").unwrap();
            assert_eq!(init.outputs.len(), p, "{name}: init outputs");
            for (a, b) in init.outputs.iter().zip(cfg.params.iter()) {
                assert_eq!(a.shape, b.shape, "{name}: param shape mismatch {}", a.name);
            }
            // declared param_count matches the descriptors
            let total: usize = cfg.params.iter().map(IoDesc::elem_count).sum();
            assert_eq!(total, cfg.param_count, "{name}: param_count");
            // every artifact file exists
            for e in cfg.entries.values() {
                assert!(artifacts_dir().join(&e.file).exists(), "{name}: missing {}", e.file);
            }
        }
    }

    #[test]
    fn batch_inputs_match_task() {
        let Some(m) = manifest() else { return };
        for cfg in m.configs.values() {
            let bi = cfg.batch_inputs().unwrap();
            match cfg.task {
                Task::LmCausal => {
                    assert_eq!(bi.len(), 1);
                    assert_eq!(bi[0].shape, vec![cfg.batch, cfg.n + 1]);
                }
                Task::LmBidir => {
                    assert_eq!(bi.len(), 3);
                    assert_eq!(bi[0].shape, vec![cfg.batch, cfg.n]);
                }
                Task::Cls => {
                    assert_eq!(bi.len(), 2);
                    assert_eq!(bi[1].shape, vec![cfg.batch]);
                }
            }
        }
    }
}
