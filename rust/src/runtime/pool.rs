//! Fixed-size shard thread pool — the parallel substrate of the crate.
//!
//! Std-only (threads + channels-free: one shared injector deque behind
//! a `Mutex`/`Condvar` pair).  Design goals, in order:
//!
//! 1. **Determinism**: the pool never reorders *data*.  Callers submit
//!    a batch of shard tasks via [`ThreadPool::scope`]; each task
//!    writes to its own disjoint output, so results are bitwise
//!    identical for any worker count.  Scheduling order is free.
//! 2. **No idle caller**: the submitting thread drains the injector
//!    while it waits (it "steals" shards back), so a pool of size `t`
//!    really applies `t` threads — `t-1` workers plus the caller.
//! 3. **Panic containment**: a panicking task never takes a worker
//!    down or hangs the latch; the first payload is re-thrown on the
//!    submitting thread after every task of the batch has finished.
//! 4. **Allocation-free steady state**: a scope does not box tasks.
//!    [`ThreadPool::scope_fn`] shares one borrowed closure and hands
//!    out shard *indices* from an atomic cursor; the per-batch state
//!    (cursor + latch) is recycled through a pool-owned arena, so a
//!    warm pool runs whole batches without touching the allocator
//!    (the injector ring buffer keeps its capacity across scopes).
//!
//! Sizing comes from `SKI_TNN_THREADS` (env) or the machine's
//! available parallelism — see [`default_threads`] — with
//! `RunConfig.threads` / `--threads` overriding per run.  `threads: 1`
//! spawns no workers at all and runs shards inline on the caller: the
//! serial reference every determinism test compares against.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once, OnceLock};
use std::thread::JoinHandle;

/// Resolved pool parallelism, exported as a telemetry gauge whenever a
/// pool is built (latest pool wins — in practice the per-run pool).
static POOL_WORKERS: crate::telemetry::LazyGauge = crate::telemetry::LazyGauge::new("pool.workers");

/// A borrowed shard task, alive only for the duration of one
/// [`ThreadPool::scope`] call.  Hot paths prefer
/// [`ThreadPool::scope_fn`], which needs no per-task boxes at all.
pub type Task<'a> = Box<dyn FnOnce() + Send + 'a>;

/// A lifetime-erased `&(dyn Fn(usize) + Sync)` as carried by injector
/// entries.  Sound to dereference only behind a successful cursor
/// claim: the originating `scope_fn` call blocks until every index has
/// run, and once a batch is finished its cursor stays exhausted, so a
/// stale entry popped later can never claim an index (and therefore
/// never touches the dead closure).
#[derive(Clone, Copy)]
struct ErasedFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` and the dereference discipline above
// confines every call to the borrow's true lifetime.
unsafe impl Send for ErasedFn {}

/// One injector entry: a batch handle some worker should help drain.
/// Entries are many-per-batch (one per worker that could usefully
/// join); the cursor in `state` makes consuming a stale or surplus
/// entry a no-op.
struct BatchEntry {
    f: ErasedFn,
    state: Arc<BatchState>,
}

/// Per-batch claim cursor + completion latch, recycled through the
/// pool's arena so steady-state scopes allocate nothing.
struct BatchState {
    /// Next unclaimed shard index (`fetch_add` to claim; `>= count`
    /// means the batch is fully claimed — or the entry was stale).
    next: AtomicUsize,
    /// Number of shard indices in the current batch.
    count: AtomicUsize,
    /// Indices not yet *completed* (claimed ≠ done — the scope only
    /// returns once every claimed index has finished running).
    remaining: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

impl BatchState {
    fn new() -> BatchState {
        BatchState {
            next: AtomicUsize::new(0),
            count: AtomicUsize::new(0),
            remaining: Mutex::new(0),
            done: Condvar::new(),
            panic: Mutex::new(None),
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<BatchEntry>>,
    work: Condvar,
    shutdown: AtomicBool,
}

/// The fixed worker pool.  Dropping it joins every worker (pending
/// jobs finish first); the process-wide instance from [`global_pool`]
/// simply lives forever.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Recycled batch states.  An entry is reused only when its
    /// `Arc::strong_count` is back to 1 — i.e. no stale injector entry
    /// still references it — which makes the reset race-free.
    arena: Mutex<Vec<Arc<BatchState>>>,
}

/// Cap on recycled batch states kept alive (more than a handful means
/// deeply overlapped scopes; let the extras drop).
const ARENA_CAP: usize = 8;

impl ThreadPool {
    /// A pool applying `threads` threads of parallelism: `threads - 1`
    /// spawned workers plus the calling thread (which participates in
    /// every `scope`).  `threads <= 1` spawns nothing and makes
    /// `scope` a plain serial loop.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        POOL_WORKERS.set(threads as f64);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (1..threads)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ski-tnn-pool-{i}"))
                    .spawn(move || worker_loop(&s))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { shared, workers, threads, arena: Mutex::new(Vec::new()) }
    }

    /// Configured parallelism (spawned workers + the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0) … f(count-1)` to completion, using the workers *and*
    /// the calling thread, without boxing anything: workers claim
    /// indices from a shared atomic cursor, and the per-batch state is
    /// recycled through the pool arena — a warm pool executes whole
    /// scopes with **zero** allocations.  Returns once every index has
    /// finished.  If any call panicked, the first payload is re-thrown
    /// here — after the whole batch has drained, so no borrow escapes
    /// the scope.
    pub fn scope_fn(&self, count: usize, f: &(dyn Fn(usize) + Sync)) {
        if count == 0 {
            return;
        }
        if self.threads == 1 || count == 1 {
            // Serial reference path: in order, on the caller.
            for i in 0..count {
                f(i);
            }
            return;
        }
        let state = self.arena_take(count);
        // SAFETY: the erased borrow is only dereferenced behind a
        // successful cursor claim, and this call does not return until
        // `remaining` hits zero — every claim has finished by then,
        // and later (stale) claims fail the cursor check.
        let erased = ErasedFn(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        });
        let copies = (self.threads - 1).min(count);
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..copies {
                q.push_back(BatchEntry { f: erased, state: Arc::clone(&state) });
            }
            self.shared.work.notify_all();
        }
        // The caller works too: claim indices from its own batch
        // instead of blocking immediately.
        run_batch(&state, erased);
        let mut rem = state.remaining.lock().unwrap();
        while *rem > 0 {
            rem = state.done.wait(rem).unwrap();
        }
        drop(rem);
        let panic = state.panic.lock().unwrap().take();
        self.arena_put(state);
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }

    /// Run every task to completion (see [`scope_fn`](Self::scope_fn)
    /// — this boxed form exists for callers whose shards are genuinely
    /// heterogeneous; it pays one `Vec` of take-once cells per call).
    pub fn scope<'a>(&self, tasks: Vec<Task<'a>>) {
        if tasks.is_empty() {
            return;
        }
        if self.threads == 1 || tasks.len() == 1 {
            // Serial reference path: in order, on the caller.
            for t in tasks {
                t();
            }
            return;
        }
        let cells: Vec<Mutex<Option<Task<'a>>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        self.scope_fn(cells.len(), &|i| {
            // Each index is claimed exactly once; the take is belt and
            // braces for that invariant.
            if let Some(t) = cells[i].lock().unwrap().take() {
                t();
            }
        });
    }

    /// A recycled (or fresh) batch state, reset for `count` indices.
    fn arena_take(&self, count: usize) -> Arc<BatchState> {
        let mut arena = self.arena.lock().unwrap();
        let reusable = arena.iter().position(|s| Arc::strong_count(s) == 1);
        let state = match reusable {
            Some(i) => arena.swap_remove(i),
            None => Arc::new(BatchState::new()),
        };
        drop(arena);
        // Publication to workers happens through the queue mutex, so
        // these resets are visible before any entry is popped.
        state.next.store(0, Ordering::Release);
        state.count.store(count, Ordering::Release);
        *state.remaining.lock().unwrap() = count;
        *state.panic.lock().unwrap() = None;
        state
    }

    fn arena_put(&self, state: Arc<BatchState>) {
        let mut arena = self.arena.lock().unwrap();
        if arena.len() < ARENA_CAP {
            arena.push(state);
        }
    }
}

/// Drain one batch: claim indices until the cursor is exhausted.  Both
/// workers (via popped entries) and the submitting caller run this;
/// stale entries fall straight through the cursor check without ever
/// dereferencing `f`.
fn run_batch(state: &BatchState, f: ErasedFn) {
    let count = state.count.load(Ordering::Acquire);
    loop {
        let i = state.next.fetch_add(1, Ordering::AcqRel);
        if i >= count {
            return;
        }
        // SAFETY: a successful claim means the owning `scope_fn` is
        // still blocked on the latch, so the borrow is alive.
        let task = unsafe { &*f.0 };
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| task(i))) {
            let mut slot = state.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        let mut rem = state.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            state.done.notify_all();
        }
    }
}

impl ThreadPool {
    /// Shard `items` into fixed contiguous chunks of
    /// `ceil(len / threads)` and run `f(start_index, chunk)` for each
    /// on the pool — the one chunking policy every parallel path in
    /// the crate shares (batched applies, scheduler ticks, oracle
    /// channels).  With one thread (or one item) `f` runs once, inline
    /// on the caller, over the whole slice; either way each element is
    /// visited exactly once, so callers are bitwise worker-count-
    /// independent as long as `f` is element-wise.
    pub fn shard_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let rows = items.len();
        if rows == 0 {
            return;
        }
        let shards = self.threads().min(rows);
        if shards <= 1 {
            f(0, items);
            return;
        }
        let chunk = rows.div_ceil(shards);
        let nchunks = rows.div_ceil(chunk);
        // Raw-split the slice so the shared scope closure can hand each
        // claimed index its own `&mut` chunk (usize-laundered pointer:
        // raw pointers are not Sync).
        let base = items.as_mut_ptr() as usize;
        self.scope_fn(nchunks, &|s| {
            let start = s * chunk;
            let len = chunk.min(rows - start);
            // SAFETY: indices are claimed exactly once and chunks are
            // disjoint, so each `&mut` is exclusive; the backing slice
            // outlives the scope (scope_fn blocks until all run).
            let slice = unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(start), len) };
            f(start, slice);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // The store + notify must happen under the queue mutex:
            // a worker checks `shutdown` while holding it, and an
            // unlocked store could land in the window between that
            // check and its `wait()`, losing the wakeup and hanging
            // the join below forever.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.work.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let entry = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(e) = q.pop_front() {
                    break e;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        // `run_batch` catches per-index panics itself, so a worker can
        // never die and strand a latch; stale entries no-op.
        run_batch(&entry.state, entry.f);
    }
}

/// Parse one `SKI_TNN_THREADS` value: `Some(t)` for a positive
/// integer, `None` for anything else (empty counts as unset and is
/// not an error; zero and garbage are).
fn parse_threads(v: &str) -> Option<usize> {
    let v = v.trim();
    if v.is_empty() {
        return None;
    }
    match v.parse::<usize>() {
        Ok(t) if t >= 1 => Some(t),
        _ => {
            warn_bad_threads(v);
            None
        }
    }
}

/// An unusable `SKI_TNN_THREADS` used to be silently ignored; warn
/// once per process so a typo'd CI matrix or shell export is visible.
fn warn_bad_threads(v: &str) {
    static WARNED: Once = Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "warning: ignoring SKI_TNN_THREADS={v:?} (want a positive integer); \
             falling back to available parallelism"
        );
    });
}

/// Parallelism the pool defaults to: `SKI_TNN_THREADS` when set to a
/// positive integer (anything else warns once to stderr and falls
/// through), else the machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Some(t) = std::env::var("SKI_TNN_THREADS").ok().and_then(|v| parse_threads(&v)) {
        return t;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a configured thread count: explicit values pass through,
/// `0` means "auto" ([`default_threads`]).
pub fn resolve_threads(configured: usize) -> usize {
    if configured >= 1 {
        configured
    } else {
        default_threads()
    }
}

/// The process-wide pool (sized once from [`default_threads`]); used
/// by call sites with no per-run thread configuration.
pub fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_write_disjoint_slots() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 32];
        let tasks: Vec<Task> = out
            .chunks_mut(5)
            .enumerate()
            .map(|(s, chunk)| {
                let task: Task = Box::new(move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = s * 100 + i;
                    }
                });
                task
            })
            .collect();
        pool.scope(tasks);
        for (j, &v) in out.iter().enumerate() {
            assert_eq!(v, (j / 5) * 100 + j % 5, "slot {j}");
        }
    }

    #[test]
    fn single_thread_pool_runs_inline_in_order() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let seen = Mutex::new(Vec::new());
        let tasks: Vec<Task> = (0..4)
            .map(|i| {
                let seen = &seen;
                let task: Task = Box::new(move || seen.lock().unwrap().push(i));
                task
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(*seen.lock().unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn panic_in_task_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Task> = (0..6)
                .map(|i| {
                    let task: Task = Box::new(move || {
                        if i == 2 {
                            panic!("task {i} exploded");
                        }
                    });
                    task
                })
                .collect();
            pool.scope(tasks);
        }));
        assert!(caught.is_err(), "scope must re-throw the task panic");
        // Every worker must still be alive and working.
        let mut out = vec![0u32; 8];
        let tasks: Vec<Task> = out
            .chunks_mut(2)
            .enumerate()
            .map(|(s, c)| {
                let task: Task = Box::new(move || c.iter_mut().for_each(|v| *v = s as u32 + 1));
                task
            })
            .collect();
        pool.scope(tasks);
        assert!(out.iter().all(|&v| v > 0), "pool dead after panic: {out:?}");
        // And drop must join cleanly (a hang here times the suite out).
        drop(pool);
    }

    #[test]
    fn drop_with_no_work_is_clean() {
        drop(ThreadPool::new(8));
    }

    #[test]
    fn shard_mut_visits_every_element_once() {
        for threads in [1usize, 3, 8] {
            let pool = ThreadPool::new(threads);
            for rows in [0usize, 1, 7, 24] {
                let mut v = vec![0usize; rows];
                pool.shard_mut(&mut v, |start, chunk| {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot += start + j + 1; // global index, exactly once
                    }
                });
                for (i, &x) in v.iter().enumerate() {
                    assert_eq!(x, i + 1, "rows={rows} threads={threads} slot {i}");
                }
            }
        }
    }

    #[test]
    fn scope_fn_covers_every_index_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..67).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..5 {
            pool.scope_fn(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 5, "index {i}");
        }
    }

    #[test]
    fn scope_fn_panic_propagates_and_arena_recycles() {
        let pool = ThreadPool::new(3);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope_fn(6, &|i| {
                if i == 2 {
                    panic!("index {i} exploded");
                }
            });
        }));
        assert!(caught.is_err(), "scope_fn must re-throw the index panic");
        // The recycled batch state must come back clean: a follow-up
        // scope runs every index and rethrows nothing.
        let sum = AtomicUsize::new(0);
        pool.scope_fn(8, &|i| {
            sum.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 36);
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn parse_threads_accepts_positive_rejects_rest() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 2 "), Some(2));
        assert_eq!(parse_threads(""), None, "empty is unset, not an error");
        assert_eq!(parse_threads("0"), None, "zero threads is unusable");
        assert_eq!(parse_threads("fast"), None);
        assert_eq!(parse_threads("-1"), None);
    }

    #[test]
    fn pool_records_worker_gauge_when_enabled() {
        let _g = crate::telemetry::test_guard();
        let was = crate::telemetry::enabled();
        crate::telemetry::set_enabled(true);
        drop(ThreadPool::new(5));
        let recorded = crate::telemetry::global().gauge("pool.workers").get();
        crate::telemetry::set_enabled(was);
        // Another concurrently-constructed pool may have overwritten
        // the latest-wins gauge; it must at least hold a live value.
        assert!(recorded >= 1.0, "pool.workers gauge not recorded: {recorded}");
    }
}
