//! Fixed-size shard thread pool — the parallel substrate of the crate.
//!
//! Std-only (threads + channels-free: one shared injector deque behind
//! a `Mutex`/`Condvar` pair).  Design goals, in order:
//!
//! 1. **Determinism**: the pool never reorders *data*.  Callers submit
//!    a batch of shard tasks via [`ThreadPool::scope`]; each task
//!    writes to its own disjoint output, so results are bitwise
//!    identical for any worker count.  Scheduling order is free.
//! 2. **No idle caller**: the submitting thread drains the injector
//!    while it waits (it "steals" shards back), so a pool of size `t`
//!    really applies `t` threads — `t-1` workers plus the caller.
//! 3. **Panic containment**: a panicking task never takes a worker
//!    down or hangs the latch; the first payload is re-thrown on the
//!    submitting thread after every task of the batch has finished.
//!
//! Sizing comes from `SKI_TNN_THREADS` (env) or the machine's
//! available parallelism — see [`default_threads`] — with
//! `RunConfig.threads` / `--threads` overriding per run.  `threads: 1`
//! spawns no workers at all and runs shards inline on the caller: the
//! serial reference every determinism test compares against.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once, OnceLock};
use std::thread::JoinHandle;

/// Resolved pool parallelism, exported as a telemetry gauge whenever a
/// pool is built (latest pool wins — in practice the per-run pool).
static POOL_WORKERS: crate::telemetry::LazyGauge = crate::telemetry::LazyGauge::new("pool.workers");

/// A borrowed shard task, alive only for the duration of one
/// [`ThreadPool::scope`] call.
pub type Task<'a> = Box<dyn FnOnce() + Send + 'a>;

/// An owned job as stored in the injector (lifetime erased — sound
/// because `scope` blocks until its jobs have all run).
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    work: Condvar,
    shutdown: AtomicBool,
}

/// Completion latch for one `scope` batch.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

/// The fixed worker pool.  Dropping it joins every worker (pending
/// jobs finish first); the process-wide instance from [`global_pool`]
/// simply lives forever.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// A pool applying `threads` threads of parallelism: `threads - 1`
    /// spawned workers plus the calling thread (which participates in
    /// every `scope`).  `threads <= 1` spawns nothing and makes
    /// `scope` a plain serial loop.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        POOL_WORKERS.set(threads as f64);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (1..threads)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ski-tnn-pool-{i}"))
                    .spawn(move || worker_loop(&s))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { shared, workers, threads }
    }

    /// Configured parallelism (spawned workers + the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion, using the workers *and* the
    /// calling thread.  Returns once all tasks have finished.  If any
    /// task panicked, the first payload is re-thrown here — after the
    /// whole batch has drained, so no borrow escapes the scope.
    pub fn scope<'a>(&self, tasks: Vec<Task<'a>>) {
        if tasks.is_empty() {
            return;
        }
        if self.threads == 1 || tasks.len() == 1 {
            // Serial reference path: in order, on the caller.
            for t in tasks {
                t();
            }
            return;
        }
        let latch = Arc::new(Latch {
            remaining: Mutex::new(tasks.len()),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            for task in tasks {
                let l = Arc::clone(&latch);
                let job: Task<'a> = Box::new(move || {
                    if let Err(p) = catch_unwind(AssertUnwindSafe(task)) {
                        let mut slot = l.panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(p);
                        }
                    }
                    let mut rem = l.remaining.lock().unwrap();
                    *rem -= 1;
                    if *rem == 0 {
                        l.done.notify_all();
                    }
                });
                // SAFETY: the job's borrows (inside `task`) outlive the
                // injector's hold on it because this function does not
                // return until `remaining` hits zero, and the wrapper
                // only decrements after the task has been consumed.
                let job: Job = unsafe { std::mem::transmute::<Task<'a>, Task<'static>>(job) };
                q.push_back(job);
            }
            self.shared.work.notify_all();
        }
        // The caller works too: drain whatever is queued (usually its
        // own shards) instead of blocking immediately.
        loop {
            let job = self.shared.queue.lock().unwrap().pop_front();
            match job {
                Some(j) => j(),
                None => break,
            }
        }
        let mut rem = latch.remaining.lock().unwrap();
        while *rem > 0 {
            rem = latch.done.wait(rem).unwrap();
        }
        drop(rem);
        if let Some(p) = latch.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

impl ThreadPool {
    /// Shard `items` into fixed contiguous chunks of
    /// `ceil(len / threads)` and run `f(start_index, chunk)` for each
    /// on the pool — the one chunking policy every parallel path in
    /// the crate shares (batched applies, scheduler ticks, oracle
    /// channels).  With one thread (or one item) `f` runs once, inline
    /// on the caller, over the whole slice; either way each element is
    /// visited exactly once, so callers are bitwise worker-count-
    /// independent as long as `f` is element-wise.
    pub fn shard_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let rows = items.len();
        if rows == 0 {
            return;
        }
        let shards = self.threads().min(rows);
        if shards <= 1 {
            f(0, items);
            return;
        }
        let chunk = rows.div_ceil(shards);
        let f = &f;
        let tasks: Vec<Task> = items
            .chunks_mut(chunk)
            .enumerate()
            .map(|(s, c)| {
                let task: Task = Box::new(move || f(s * chunk, c));
                task
            })
            .collect();
        self.scope(tasks);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // The store + notify must happen under the queue mutex:
            // a worker checks `shutdown` while holding it, and an
            // unlocked store could land in the window between that
            // check and its `wait()`, losing the wakeup and hanging
            // the join below forever.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.work.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        // Scope wrappers already catch panics; this is defence so a
        // worker can never die and strand a latch.
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

/// Parse one `SKI_TNN_THREADS` value: `Some(t)` for a positive
/// integer, `None` for anything else (empty counts as unset and is
/// not an error; zero and garbage are).
fn parse_threads(v: &str) -> Option<usize> {
    let v = v.trim();
    if v.is_empty() {
        return None;
    }
    match v.parse::<usize>() {
        Ok(t) if t >= 1 => Some(t),
        _ => {
            warn_bad_threads(v);
            None
        }
    }
}

/// An unusable `SKI_TNN_THREADS` used to be silently ignored; warn
/// once per process so a typo'd CI matrix or shell export is visible.
fn warn_bad_threads(v: &str) {
    static WARNED: Once = Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "warning: ignoring SKI_TNN_THREADS={v:?} (want a positive integer); \
             falling back to available parallelism"
        );
    });
}

/// Parallelism the pool defaults to: `SKI_TNN_THREADS` when set to a
/// positive integer (anything else warns once to stderr and falls
/// through), else the machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Some(t) = std::env::var("SKI_TNN_THREADS").ok().and_then(|v| parse_threads(&v)) {
        return t;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a configured thread count: explicit values pass through,
/// `0` means "auto" ([`default_threads`]).
pub fn resolve_threads(configured: usize) -> usize {
    if configured >= 1 {
        configured
    } else {
        default_threads()
    }
}

/// The process-wide pool (sized once from [`default_threads`]); used
/// by call sites with no per-run thread configuration.
pub fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_write_disjoint_slots() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 32];
        let tasks: Vec<Task> = out
            .chunks_mut(5)
            .enumerate()
            .map(|(s, chunk)| {
                let task: Task = Box::new(move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = s * 100 + i;
                    }
                });
                task
            })
            .collect();
        pool.scope(tasks);
        for (j, &v) in out.iter().enumerate() {
            assert_eq!(v, (j / 5) * 100 + j % 5, "slot {j}");
        }
    }

    #[test]
    fn single_thread_pool_runs_inline_in_order() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let seen = Mutex::new(Vec::new());
        let tasks: Vec<Task> = (0..4)
            .map(|i| {
                let seen = &seen;
                let task: Task = Box::new(move || seen.lock().unwrap().push(i));
                task
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(*seen.lock().unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn panic_in_task_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Task> = (0..6)
                .map(|i| {
                    let task: Task = Box::new(move || {
                        if i == 2 {
                            panic!("task {i} exploded");
                        }
                    });
                    task
                })
                .collect();
            pool.scope(tasks);
        }));
        assert!(caught.is_err(), "scope must re-throw the task panic");
        // Every worker must still be alive and working.
        let mut out = vec![0u32; 8];
        let tasks: Vec<Task> = out
            .chunks_mut(2)
            .enumerate()
            .map(|(s, c)| {
                let task: Task = Box::new(move || c.iter_mut().for_each(|v| *v = s as u32 + 1));
                task
            })
            .collect();
        pool.scope(tasks);
        assert!(out.iter().all(|&v| v > 0), "pool dead after panic: {out:?}");
        // And drop must join cleanly (a hang here times the suite out).
        drop(pool);
    }

    #[test]
    fn drop_with_no_work_is_clean() {
        drop(ThreadPool::new(8));
    }

    #[test]
    fn shard_mut_visits_every_element_once() {
        for threads in [1usize, 3, 8] {
            let pool = ThreadPool::new(threads);
            for rows in [0usize, 1, 7, 24] {
                let mut v = vec![0usize; rows];
                pool.shard_mut(&mut v, |start, chunk| {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot += start + j + 1; // global index, exactly once
                    }
                });
                for (i, &x) in v.iter().enumerate() {
                    assert_eq!(x, i + 1, "rows={rows} threads={threads} slot {i}");
                }
            }
        }
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn parse_threads_accepts_positive_rejects_rest() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 2 "), Some(2));
        assert_eq!(parse_threads(""), None, "empty is unset, not an error");
        assert_eq!(parse_threads("0"), None, "zero threads is unusable");
        assert_eq!(parse_threads("fast"), None);
        assert_eq!(parse_threads("-1"), None);
    }

    #[test]
    fn pool_records_worker_gauge_when_enabled() {
        let _g = crate::telemetry::test_guard();
        let was = crate::telemetry::enabled();
        crate::telemetry::set_enabled(true);
        drop(ThreadPool::new(5));
        let recorded = crate::telemetry::global().gauge("pool.workers").get();
        crate::telemetry::set_enabled(was);
        // Another concurrently-constructed pool may have overwritten
        // the latest-wins gauge; it must at least hold a live value.
        assert!(recorded >= 1.0, "pool.workers gauge not recorded: {recorded}");
    }
}
