//! Model training state driven by the fused AOT `step` artifact.
//!
//! Holds the flat parameter vector plus Adam moments and the step
//! counter as XLA literals, in the manifest's jax-tree order.  One
//! [`ModelState::step`] call is one fused fwd+bwd+Adam execution — the
//! whole optimizer lives inside the artifact, Rust only shuttles
//! buffers.  Checkpoints serialize the full state (params, m, v, t) so
//! training resumes bit-exactly.

use std::io::{Read, Write};
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use super::engine::{Engine, Executable};
use super::manifest::{Dtype, ModelConfig};
use super::tensor::HostTensor;

/// Checkpoint file magic + version.
const CKPT_MAGIC: &[u8; 8] = b"SKITNN\x01\n";

/// Flat training state of one model config.
pub struct ModelState {
    pub config: ModelConfig,
    pub params: Vec<Literal>,
    pub m: Vec<Literal>,
    pub v: Vec<Literal>,
    /// f32 scalar step counter (the artifact's `t`).
    pub t: Literal,
    step_exe: Rc<Executable>,
}

impl ModelState {
    /// Initialize from the `init` artifact with the given seed; Adam
    /// moments start at zero, matching `train.adam_init`.
    pub fn init(engine: &Engine, config: &str, seed: u32) -> Result<ModelState> {
        let cfg = engine.config(config)?.clone();
        let init = engine.load(config, "init")?;
        let seed_lit = HostTensor::scalar_u32(seed).to_literal()?;
        let params = init.run(&[seed_lit])?;
        let zeros = |descs: &[super::manifest::IoDesc]| -> Result<Vec<Literal>> {
            descs
                .iter()
                .map(|d| {
                    if d.dtype != Dtype::F32 {
                        bail!("param {} is not f32", d.name);
                    }
                    HostTensor::f32(d.shape.clone(), vec![0.0; d.elem_count()]).to_literal()
                })
                .collect()
        };
        let m = zeros(&cfg.params)?;
        let v = zeros(&cfg.params)?;
        let t = HostTensor::scalar_f32(0.0).to_literal()?;
        let step_exe = engine.load(config, "step")?;
        Ok(ModelState { config: cfg, params, m, v, t, step_exe })
    }

    /// Current step count (reads the scalar back from the literal).
    pub fn step_count(&self) -> Result<f32> {
        self.t.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// One fused train step; `batch` literals must match
    /// [`ModelConfig::batch_inputs`].  Returns the loss.
    pub fn step(&mut self, batch: &[Literal]) -> Result<f32> {
        let p = self.params.len();
        let mut args: Vec<&Literal> = Vec::with_capacity(3 * p + 1 + batch.len());
        args.extend(self.params.iter());
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        args.push(&self.t);
        args.extend(batch.iter());
        let mut outs = self.step_exe.run_refs(&args)?;
        // outputs: params' m' v' t' loss
        let loss = outs
            .pop()
            .ok_or_else(|| anyhow!("step returned no loss"))?
            .get_first_element::<f32>()?;
        self.t = outs.pop().ok_or_else(|| anyhow!("step returned no t"))?;
        let vs: Vec<Literal> = outs.drain(2 * p..).collect();
        let ms: Vec<Literal> = outs.drain(p..).collect();
        self.params = outs;
        self.m = ms;
        self.v = vs;
        Ok(loss)
    }

    /// Run an eval-only entry (`fwd` or `fwd_n{L}`): returns `(loss, metric)`.
    pub fn fwd(&self, engine: &Engine, entry: &str, batch: &[Literal]) -> Result<(f32, f32)> {
        let exe = engine.load(&self.config.name, entry)?;
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.extend(batch.iter());
        let outs = exe.run_refs(&args)?;
        if outs.len() != 2 {
            bail!("{entry}: expected (loss, metric), got {} outputs", outs.len());
        }
        Ok((outs[0].get_first_element::<f32>()?, outs[1].get_first_element::<f32>()?))
    }

    /// Serving entry: class logits / last-position LM logits.
    pub fn logits(&self, engine: &Engine, ids: &Literal) -> Result<HostTensor> {
        let exe = engine.load(&self.config.name, "logits")?;
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(ids);
        let outs = exe.run_refs(&args)?;
        HostTensor::from_literal(&outs[0])
    }

    // ---------------------------------------------------------------
    // Checkpointing
    // ---------------------------------------------------------------

    /// Serialize full state (params, m, v, t) to `path`.
    ///
    /// Format: magic, u32 config-name length + bytes, f32 t, then for
    /// each of params/m/v in manifest order: raw little-endian f32.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
        );
        f.write_all(CKPT_MAGIC)?;
        let name = self.config.name.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&self.step_count()?.to_le_bytes())?;
        for group in [&self.params, &self.m, &self.v] {
            for (lit, desc) in group.iter().zip(self.config.params.iter()) {
                let data: Vec<f32> = lit.to_vec()?;
                if data.len() != desc.elem_count() {
                    bail!("checkpoint: {} has {} elems, want {}", desc.name, data.len(),
                        desc.elem_count());
                }
                for x in &data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
        Ok(())
    }

    /// Restore state saved by [`ModelState::save`]; the checkpoint's
    /// config name must match.
    pub fn load(engine: &Engine, path: &Path) -> Result<ModelState> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != CKPT_MAGIC {
            bail!("{}: not a ski-tnn checkpoint", path.display());
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let mut name = vec![0u8; u32::from_le_bytes(len4) as usize];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let cfg = engine.config(&name)?.clone();
        let mut t4 = [0u8; 4];
        f.read_exact(&mut t4)?;
        let t = f32::from_le_bytes(t4);

        let mut read_group = || -> Result<Vec<Literal>> {
            cfg.params
                .iter()
                .map(|desc| {
                    let mut buf = vec![0u8; 4 * desc.elem_count()];
                    f.read_exact(&mut buf)?;
                    let data: Vec<f32> = buf
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    HostTensor::f32(desc.shape.clone(), data).to_literal()
                })
                .collect()
        };
        let params = read_group()?;
        let m = read_group()?;
        let v = read_group()?;
        let step_exe = engine.load(&name, "step")?;
        Ok(ModelState {
            config: cfg,
            params,
            m,
            v,
            t: HostTensor::scalar_f32(t).to_literal()?,
            step_exe,
        })
    }
}

impl Executable {
    /// Like [`Executable::run`] but over borrowed literals (avoids
    /// cloning the parameter vector every step).
    pub fn run_refs(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "{}/{}: got {} args, entry wants {}",
                self.key.0,
                self.key.1,
                args.len(),
                self.entry.inputs.len()
            );
        }
        let bufs = self.exe.execute::<&Literal>(args)?;
        let mut tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        if outs.len() != self.entry.outputs.len() {
            bail!(
                "{}/{}: executable returned {} outputs, manifest declares {}",
                self.key.0,
                self.key.1,
                outs.len(),
                self.entry.outputs.len()
            );
        }
        Ok(outs)
    }
}
