//! Runtime — the Rust ⇄ XLA bridge (PJRT CPU client).
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (see `artifacts/manifest.json`), compiles them once per process on
//! the PJRT client, and exposes typed execution:
//!
//! * [`Manifest`] / [`ModelConfig`] — the artifact contract: per-config
//!   shapes, flat parameter order, and entrypoints.
//! * [`Engine`] — PJRT client + bounded (LRU, [`EXE_CACHE_CAP`])
//!   executable cache keyed by `(config, entry)`; all compiles happen
//!   through here.
//! * [`ModelState`] — the device-facing training state (`params`, Adam
//!   `m`/`v`, step counter) driven by the fused `step` artifact.
//! * [`HostTensor`] — dtype-tagged host arrays for batches and outputs.
//! * [`pool`] — the std-only shard thread pool every parallel path in
//!   the crate (batched Toeplitz applies, scheduler ticks) runs on;
//!   sized by `SKI_TNN_THREADS` / `RunConfig.threads`.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes
//! HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; `HloModuleProto::from_text_file` re-parses and reassigns
//! ids (see /opt/xla-example/README.md).  Python never runs here.

mod engine;
mod manifest;
pub mod pool;
mod state;
mod tensor;

pub use engine::{Engine, EXE_CACHE_CAP};
pub use manifest::{Dtype, Entry, IoDesc, Manifest, ModelConfig, Task, Variant};
pub use pool::{default_threads, global_pool, resolve_threads, ThreadPool};
pub use state::ModelState;
pub use tensor::HostTensor;
