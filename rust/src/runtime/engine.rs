//! PJRT engine: compile-once executable cache over the artifact dir.
//!
//! One [`Engine`] per process wraps the PJRT CPU client.  Artifacts are
//! HLO text (`HloModuleProto::from_text_file` reassigns instruction
//! ids, sidestepping the 64-bit-id protos jax ≥ 0.5 emits that
//! xla_extension 0.5.1 rejects).  Compiles are cached by
//! `(config, entry)` so a training run pays exactly one compile per
//! entrypoint regardless of step count.  The cache is **bounded**
//! ([`EXE_CACHE_CAP`], LRU eviction through the same
//! [`LruCore`] primitive as the execution-plan and FFT-plan caches) —
//! a long-lived process cycling through many configs re-compiles cold
//! entries instead of holding every executable ever built.

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use crate::plan::LruCore;

use super::manifest::{Entry, Manifest, ModelConfig};

/// Most compiled executables kept resident; past this the least
/// recently used entry drops (and recompiles if ever needed again).
pub const EXE_CACHE_CAP: usize = 32;

/// A compiled entrypoint plus its manifest signature.
pub struct Executable {
    pub exe: PjRtLoadedExecutable,
    pub entry: Entry,
    pub key: (String, String),
}

impl Executable {
    /// Execute with host literals; returns the flattened output tuple.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so PJRT
    /// hands back a single tuple buffer which we pull to host and
    /// decompose into one literal per declared output.
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        if args.len() != self.entry.inputs.len() {
            bail!(
                "{}/{}: got {} args, entry wants {}",
                self.key.0,
                self.key.1,
                args.len(),
                self.entry.inputs.len()
            );
        }
        let bufs = self.exe.execute::<Literal>(args)?;
        let mut tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        if outs.len() != self.entry.outputs.len() {
            bail!(
                "{}/{}: executable returned {} outputs, manifest declares {}",
                self.key.0,
                self.key.1,
                outs.len(),
                self.entry.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// PJRT client + artifact manifest + executable cache.
pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<LruCore<(String, String), Rc<Executable>>>,
    /// (key, compile seconds) log — surfaced by `stats()` for EXPERIMENTS.md.
    compile_log: RefCell<Vec<(String, f64)>>,
}

impl Engine {
    /// Create a CPU PJRT client and load `<dir>/manifest.json`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: RefCell::new(LruCore::new(EXE_CACHE_CAP)),
            compile_log: RefCell::new(Vec::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.manifest.config(name)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-once) an entrypoint of a config.
    pub fn load(&self, config: &str, entry: &str) -> Result<Rc<Executable>> {
        let key = (config.to_string(), entry.to_string());
        if let Some(exe) = self.cache.borrow_mut().get(&key) {
            return Ok(exe.clone());
        }
        let cfg = self.manifest.config(config)?;
        let ent = cfg.entry(entry)?.clone();
        let path = self.dir.join(&ent.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let secs = t0.elapsed().as_secs_f64();
        self.compile_log.borrow_mut().push((format!("{config}.{entry}"), secs));
        let exe = Rc::new(Executable { exe, entry: ent, key: key.clone() });
        // Past capacity the LRU executable drops here (its PJRT
        // resources free once no caller still holds the `Rc`).
        let _evicted = self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// (resident executables, capacity) of the compile cache.
    pub fn cache_stats(&self) -> (usize, usize) {
        let cache = self.cache.borrow();
        (cache.len(), cache.cap())
    }

    /// (entry, seconds) for every compile done so far.
    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.borrow().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    /// Engine over the AOT artifacts, or `None` when they haven't been
    /// built (`make artifacts` — these tests are artifact-gated, not
    /// failures of the Rust substrate).
    fn engine() -> Option<Engine> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping artifact-gated test: {} missing", dir.display());
            return None;
        }
        let eng = Engine::new(dir).expect("engine");
        if eng.platform().contains("shim") {
            eprintln!("skipping artifact-gated test: no native PJRT backend");
            return None;
        }
        Some(eng)
    }

    #[test]
    fn init_produces_declared_params() {
        let Some(eng) = engine() else { return };
        let name = "lm_fd_3l";
        let cfg = eng.config(name).unwrap().clone();
        let init = eng.load(name, "init").unwrap();
        let seed = HostTensor::scalar_u32(0).to_literal().unwrap();
        let outs = init.run(&[seed]).unwrap();
        assert_eq!(outs.len(), cfg.params.len());
        for (lit, desc) in outs.iter().zip(cfg.params.iter()) {
            let t = HostTensor::from_literal(lit).unwrap();
            t.check(desc).unwrap();
            // init'd params must be finite
            if let Ok(v) = t.as_f32() {
                assert!(v.iter().all(|x| x.is_finite()), "{}: non-finite init", desc.name);
            }
        }
    }

    #[test]
    fn executable_cache_hits() {
        let Some(eng) = engine() else { return };
        let a = eng.load("lm_fd_3l", "init").unwrap();
        let b = eng.load("lm_fd_3l", "init").unwrap();
        assert!(Rc::ptr_eq(&a, &b), "cache must return the same executable");
        assert_eq!(eng.compile_log().len(), 1);
    }

    #[test]
    fn executable_cache_is_bounded() {
        let Some(eng) = engine() else { return };
        let (len, cap) = eng.cache_stats();
        assert_eq!((len, cap), (0, EXE_CACHE_CAP));
        // Load every entrypoint the manifest declares — the cache must
        // never outgrow its capacity, however many configs exist.
        let names: Vec<(String, Vec<String>)> = eng
            .manifest()
            .configs
            .values()
            .map(|c| (c.name.clone(), c.entries.keys().cloned().collect()))
            .collect();
        for (config, entries) in &names {
            for entry in entries {
                let _ = eng.load(config, entry);
            }
        }
        let (len, cap) = eng.cache_stats();
        assert!(len <= cap, "{len} resident executables exceed cap {cap}");
    }

    #[test]
    fn run_rejects_wrong_arity() {
        let Some(eng) = engine() else { return };
        let init = eng.load("lm_fd_3l", "init").unwrap();
        assert!(init.run(&[]).is_err());
    }
}
