//! Synthetic byte corpus from a probabilistic grammar.
//!
//! Wikitext-103 stand-in (DESIGN.md §Substitutions): a deterministic
//! generator whose output has the statistical structure that makes LM
//! loss curves informative —
//!
//! * **n-gram structure**: words are built from a syllable inventory,
//!   drawn from a Zipf-ish unigram distribution, so local transitions
//!   are compressible;
//! * **long-range agreement**: each sentence opens with a singular or
//!   plural subject and the verb (several words later) must agree —
//!   exactly the relative-position signal a TNO can exploit;
//! * **bracket matching**: parenthetical clauses nest and must close;
//! * **topic coherence**: each paragraph commits to a topic that tilts
//!   the noun distribution for hundreds of bytes, so there is signal
//!   *beyond* any short conv window and perplexity keeps improving as
//!   the model learns longer-range structure.
//!
//! The grammar is tiny but none of it is learnable by a bigram model
//! alone, which is what separates the TNO variants in the Fig 7/8/9
//! reproductions.

use crate::util::rng::Rng;

/// Syllables composing the open-vocabulary nouns/verbs.
const SYLLABLES: &[&str] = &[
    "ta", "ri", "mo", "ka", "shi", "lu", "ven", "dor", "pel", "gra", "ne", "os", "ith", "ba",
    "qu", "zem",
];

/// Closed-class words. Determiners/conjunctions give high-frequency
/// anchors (Zipf head), mirroring natural text.
const DET_SG: &[&str] = &["the", "a", "this", "every"];
const DET_PL: &[&str] = &["the", "some", "these", "many"];
const VERB_SG: &[&str] = &["runs", "holds", "makes", "sees", "lifts"];
const VERB_PL: &[&str] = &["run", "hold", "make", "see", "lift"];
const ADVERBS: &[&str] = &["slowly", "often", "never", "boldly"];
const CONJ: &[&str] = &["and", "but", "while", "because"];

/// Number of topics; each topic owns a disjoint noun sub-inventory.
const TOPICS: usize = 8;
/// Nouns per topic.
const NOUNS_PER_TOPIC: usize = 24;

/// A deterministic synthetic corpus: one long byte string plus
/// generation metadata.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub bytes: Vec<u8>,
}

impl Corpus {
    /// Generate roughly `target_bytes` of text from `seed`.
    pub fn generate(seed: u64, target_bytes: usize) -> Corpus {
        let mut rng = Rng::new(seed ^ 0x5EED_C049);
        // Pre-build per-topic noun inventories (stems reused across the
        // corpus so unigram stats are stable).
        let nouns: Vec<Vec<String>> = (0..TOPICS)
            .map(|t| {
                let mut tr = rng.fork(t as u64);
                (0..NOUNS_PER_TOPIC).map(|_| Self::make_stem(&mut tr)).collect()
            })
            .collect();
        let mut out = Vec::with_capacity(target_bytes + 256);
        while out.len() < target_bytes {
            Self::paragraph(&mut rng, &nouns, &mut out);
            out.push(b'\n');
        }
        out.truncate(target_bytes);
        Corpus { bytes: out }
    }

    /// Token stream view (bytes as i32 ids; specials never occur).
    pub fn tokens(&self) -> Vec<i32> {
        self.bytes.iter().map(|&b| b as i32).collect()
    }

    fn make_stem(rng: &mut Rng) -> String {
        let k = 2 + rng.below(2); // 2-3 syllables
        (0..k).map(|_| SYLLABLES[rng.below(SYLLABLES.len())]).collect()
    }

    /// Zipf-biased choice: index drawn with P(i) ∝ 1/(i+1).
    fn zipf<'a>(rng: &mut Rng, items: &'a [String]) -> &'a str {
        let n = items.len();
        let weights: Vec<f64> = (0..n).map(|i| 1.0 / (i as f64 + 1.0)).collect();
        &items[rng.weighted(&weights)]
    }

    fn paragraph(rng: &mut Rng, nouns: &[Vec<String>], out: &mut Vec<u8>) {
        let topic = rng.below(TOPICS);
        let sentences = 3 + rng.below(5);
        for _ in 0..sentences {
            Self::sentence(rng, &nouns[topic], 0, out);
            out.push(b' ');
        }
    }

    /// One sentence with subject-verb agreement and optional nested
    /// parenthetical (depth-limited).
    fn sentence(rng: &mut Rng, nouns: &[String], depth: usize, out: &mut Vec<u8>) {
        let plural = rng.bool(0.5);
        let det = if plural {
            DET_PL[rng.below(DET_PL.len())]
        } else {
            DET_SG[rng.below(DET_SG.len())]
        };
        out.extend_from_slice(det.as_bytes());
        out.push(b' ');
        let mut noun = Self::zipf(rng, nouns).to_string();
        if plural {
            noun.push('s');
        }
        out.extend_from_slice(noun.as_bytes());
        out.push(b' ');
        // Optional parenthetical widens the subject→verb distance —
        // the long-range agreement signal.
        if depth < 2 && rng.bool(0.3) {
            out.push(b'(');
            Self::sentence(rng, nouns, depth + 1, out);
            out.push(b')');
            out.push(b' ');
        }
        if rng.bool(0.4) {
            out.extend_from_slice(ADVERBS[rng.below(ADVERBS.len())].as_bytes());
            out.push(b' ');
        }
        let verb = if plural {
            VERB_PL[rng.below(VERB_PL.len())]
        } else {
            VERB_SG[rng.below(VERB_SG.len())]
        };
        out.extend_from_slice(verb.as_bytes());
        out.push(b' ');
        out.extend_from_slice(Self::zipf(rng, nouns).as_bytes());
        if depth == 0 {
            if rng.bool(0.25) {
                out.push(b' ');
                out.extend_from_slice(CONJ[rng.below(CONJ.len())].as_bytes());
                out.push(b' ');
                Self::sentence(rng, nouns, depth + 1, out);
            } else {
                out.push(b'.');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = Corpus::generate(7, 10_000);
        let b = Corpus::generate(7, 10_000);
        assert_eq!(a.bytes, b.bytes);
        let c = Corpus::generate(8, 10_000);
        assert_ne!(a.bytes, c.bytes, "different seeds must differ");
    }

    #[test]
    fn exact_target_length_and_ascii() {
        let c = Corpus::generate(1, 4096);
        assert_eq!(c.bytes.len(), 4096);
        assert!(c.bytes.iter().all(|&b| b.is_ascii()), "corpus must be ascii bytes");
    }

    #[test]
    fn brackets_balance_before_truncation() {
        // Generate, then check nesting never goes negative and depth ≤ 3.
        let c = Corpus::generate(3, 200_000);
        let mut depth: i32 = 0;
        for &b in &c.bytes {
            if b == b'(' {
                depth += 1;
            }
            if b == b')' {
                depth -= 1;
            }
            assert!((-1..=3).contains(&depth)); // -1 possible only after truncation point
        }
    }

    #[test]
    fn agreement_holds() {
        // Every "these|some|many <noun>s" is followed (within the
        // sentence) by a plural verb form more often than singular.
        let c = Corpus::generate(5, 100_000);
        let text = String::from_utf8(c.bytes).unwrap();
        let mut sg_after_pl = 0;
        let mut pl_after_pl = 0;
        for sent in text.split('.') {
            let toks: Vec<&str> = sent.split_whitespace().collect();
            if toks.first().map(|w| ["these", "some", "many"].contains(w)) == Some(true) {
                for w in &toks {
                    if VERB_PL.contains(w) {
                        pl_after_pl += 1;
                        break;
                    }
                    if VERB_SG.contains(w) {
                        sg_after_pl += 1;
                        break;
                    }
                }
            }
        }
        assert!(pl_after_pl > 0);
        // nested clauses may flip number, so demand a strong majority,
        // not unanimity
        assert!(
            pl_after_pl as f64 > 2.0 * sg_after_pl as f64,
            "plural agreement too weak: {pl_after_pl} vs {sg_after_pl}"
        );
    }

    #[test]
    fn topical_coherence_is_measurable() {
        // Within a paragraph (line), noun stems repeat more than across
        // paragraphs — the long-range signal.
        let c = Corpus::generate(11, 200_000);
        let text = String::from_utf8(c.bytes).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| l.len() > 200).collect();
        assert!(lines.len() > 10);
        let word_set = |s: &str| {
            s.split_whitespace()
                .filter(|w| w.len() >= 4 && w.chars().all(|c| c.is_ascii_lowercase()))
                .map(|w| w.trim_end_matches('s').to_string())
                .collect::<std::collections::HashSet<_>>()
        };
        let mut within = 0.0;
        let mut across = 0.0;
        let mut cnt = 0;
        for w in lines.windows(2) {
            let (a, b) = (word_set(w[0]), word_set(w[1]));
            let half = |s: &str| {
                let mid = s.len() / 2;
                (word_set(&s[..mid]), word_set(&s[mid..]))
            };
            let (a1, a2) = half(w[0]);
            let j = |x: &std::collections::HashSet<String>,
                     y: &std::collections::HashSet<String>| {
                x.intersection(y).count() as f64 / x.union(y).count().max(1) as f64
            };
            within += j(&a1, &a2);
            across += j(&a, &b);
            cnt += 1;
        }
        within /= cnt as f64;
        across /= cnt as f64;
        assert!(
            within > across,
            "within-paragraph overlap {within:.3} should exceed across {across:.3}"
        );
    }
}
