//! Long-Range-Arena-style synthetic classification tasks.
//!
//! Five generators mirroring the structure of the LRA suite the paper
//! evaluates (Table 2 / Fig 1a), at the same sequence lengths, built
//! deterministically from seeds (DESIGN.md §Substitutions):
//!
//! * [`LraTask::Text`] — byte-level "sentiment": polarity cue words
//!   planted at long range inside grammar filler; 2 classes.
//! * [`LraTask::ListOps`] — nested prefix-operator expressions
//!   (`[MAX 3 6 [MIN 2 8 ] 4 ]`) evaluated to a digit; 10 classes.
//! * [`LraTask::Retrieval`] — two documents joined by a CLS separator;
//!   positive iff they share a planted key phrase; 2 classes.
//! * [`LraTask::Pathfinder`] — 32×32 raster with dashed curves;
//!   positive iff the two endpoint dots are connected; 2 classes.
//! * [`LraTask::Image`] — 32×32 grayscale shape rendering, 10 shape
//!   classes, serialized row-major like LRA's sCIFAR.
//!
//! Labels are balanced by construction.  Generators emit `(ids, label)`
//! examples; [`ClsStream`] batches them into the `cls` artifact's
//! `(ids, labels)` inputs.

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

use super::{BatchSource, CLS, PAD};

/// The five task families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LraTask {
    Text,
    ListOps,
    Retrieval,
    Pathfinder,
    Image,
}

impl LraTask {
    pub fn parse(s: &str) -> Option<LraTask> {
        Some(match s {
            "text" => LraTask::Text,
            "listops" => LraTask::ListOps,
            "retrieval" => LraTask::Retrieval,
            "pathfinder" => LraTask::Pathfinder,
            "image" => LraTask::Image,
            _ => return None,
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            LraTask::Text => "text",
            LraTask::ListOps => "listops",
            LraTask::Retrieval => "retrieval",
            LraTask::Pathfinder => "pathfinder",
            LraTask::Image => "image",
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            LraTask::ListOps | LraTask::Image => 10,
            _ => 2,
        }
    }

    /// Generate one `(ids, label)` example of length `n`.
    pub fn example(&self, rng: &mut Rng, n: usize) -> (Vec<i32>, i32) {
        match self {
            LraTask::Text => text_example(rng, n),
            LraTask::ListOps => listops_example(rng, n),
            LraTask::Retrieval => retrieval_example(rng, n),
            LraTask::Pathfinder => pathfinder_example(rng, n),
            LraTask::Image => image_example(rng, n),
        }
    }
}

fn pad_to(mut ids: Vec<i32>, n: usize) -> Vec<i32> {
    ids.truncate(n);
    while ids.len() < n {
        ids.push(PAD);
    }
    ids
}

fn push_str(ids: &mut Vec<i32>, s: &str) {
    ids.extend(s.bytes().map(|b| b as i32));
}

// ---------------------------------------------------------------------------
// Text (sentiment)
// ---------------------------------------------------------------------------

const POS_CUES: &[&str] = &["brilliant", "delight", "superb", "tender", "luminous"];
const NEG_CUES: &[&str] = &["dreary", "tedious", "wretched", "hollow", "grating"];
const FILLER: &[&str] = &[
    "the", "plot", "moves", "along", "with", "scenes", "that", "follow", "a", "familiar",
    "shape", "and", "the", "camera", "lingers", "on", "faces", "in", "rooms",
];

/// Majority-polarity classification with cues scattered across the
/// full window — the long-range part is that cues can land anywhere,
/// including all in the final tokens.
fn text_example(rng: &mut Rng, n: usize) -> (Vec<i32>, i32) {
    let label = rng.bool(0.5) as i32;
    let (major, minor) = if label == 1 { (POS_CUES, NEG_CUES) } else { (NEG_CUES, POS_CUES) };
    let major_count = 3 + rng.below(3); // 3-5 majority cues
    let minor_count = major_count - 1 - rng.below(2); // strictly fewer
    let mut words: Vec<&str> = Vec::new();
    while words.iter().map(|w| w.len() + 1).sum::<usize>() < n {
        words.push(FILLER[rng.below(FILLER.len())]);
    }
    for _ in 0..major_count {
        let at = rng.below(words.len());
        words[at] = major[rng.below(major.len())];
    }
    // place minority cues avoiding collisions with majority ones
    let mut placed = 0;
    while placed < minor_count {
        let at = rng.below(words.len());
        if !major.contains(&words[at]) {
            words[at] = minor[rng.below(minor.len())];
            placed += 1;
        }
    }
    let mut ids = Vec::with_capacity(n + 16);
    for w in words {
        push_str(&mut ids, w);
        ids.push(b' ' as i32);
    }
    (pad_to(ids, n), label)
}

// ---------------------------------------------------------------------------
// ListOps
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum Op {
    Max,
    Min,
    Med,
    Sum, // SUM mod 10 (LRA's SM)
}

fn listops_value(op: Op, args: &[i64]) -> i64 {
    match op {
        Op::Max => *args.iter().max().unwrap(),
        Op::Min => *args.iter().min().unwrap(),
        Op::Med => {
            let mut v = args.to_vec();
            v.sort_unstable();
            v[v.len() / 2]
        }
        Op::Sum => args.iter().sum::<i64>() % 10,
    }
}

fn listops_render(op: Op, out: &mut Vec<i32>) {
    let s = match op {
        Op::Max => "[MAX",
        Op::Min => "[MIN",
        Op::Med => "[MED",
        Op::Sum => "[SM",
    };
    push_str(out, s);
}

/// Generate a nested expression whose rendered length stays under
/// `budget` bytes; returns its value.
fn listops_expr(rng: &mut Rng, depth: usize, budget: usize, out: &mut Vec<i32>) -> i64 {
    let ops = [Op::Max, Op::Min, Op::Med, Op::Sum];
    let op = ops[rng.below(4)];
    listops_render(op, out);
    let arity = 2 + rng.below(4);
    let mut args = Vec::with_capacity(arity);
    for _ in 0..arity {
        out.push(b' ' as i32);
        if depth < 4 && out.len() + 24 < budget && rng.bool(0.35) {
            args.push(listops_expr(rng, depth + 1, budget, out));
        } else {
            let d = rng.below(10) as i64;
            out.push(b'0' as i32 + d as i32);
            args.push(d);
        }
    }
    push_str(out, " ]");
    listops_value(op, &args)
}

fn listops_example(rng: &mut Rng, n: usize) -> (Vec<i32>, i32) {
    let mut ids = Vec::with_capacity(n);
    // fill most of the window with one deep expression
    let value = listops_expr(rng, 0, n.saturating_sub(8), &mut ids);
    (pad_to(ids, n), value as i32)
}

// ---------------------------------------------------------------------------
// Retrieval
// ---------------------------------------------------------------------------

/// Two ~n/2 documents; positive iff both contain the same 8-byte key.
fn retrieval_example(rng: &mut Rng, n: usize) -> (Vec<i32>, i32) {
    let label = rng.bool(0.5) as i32;
    let half = (n - 1) / 2;
    let key: Vec<i32> = (0..8).map(|_| (b'A' + rng.below(26) as u8) as i32).collect();
    let other: Vec<i32> = loop {
        let k: Vec<i32> = (0..8).map(|_| (b'A' + rng.below(26) as u8) as i32).collect();
        if k != key {
            break k;
        }
    };
    let doc = |with_key: &[i32], r: &mut Rng| -> Vec<i32> {
        let mut d: Vec<i32> = Vec::with_capacity(half);
        while d.len() < half {
            let w = FILLER[r.below(FILLER.len())];
            d.extend(w.bytes().map(|b| b as i32));
            d.push(b' ' as i32);
        }
        d.truncate(half);
        let at = r.below(half - with_key.len());
        d[at..at + with_key.len()].copy_from_slice(with_key);
        d
    };
    let d1 = doc(&key, rng);
    let d2 = doc(if label == 1 { &key } else { &other }, rng);
    let mut ids = d1;
    ids.push(CLS);
    ids.extend(d2);
    (pad_to(ids, n), label)
}

// ---------------------------------------------------------------------------
// Pathfinder
// ---------------------------------------------------------------------------

const SIDE: usize = 32;

/// Draw a dashed random walk from `from` towards `to`; marks visited
/// cells in `img` with intensity and records them in `cells`.
fn draw_path(
    rng: &mut Rng,
    img: &mut [u8],
    from: (i64, i64),
    to: (i64, i64),
    cells: &mut Vec<usize>,
) {
    let (mut x, mut y) = from;
    let mut step = 0usize;
    while (x, y) != to && step < 4 * SIDE {
        let dx = (to.0 - x).signum();
        let dy = (to.1 - y).signum();
        // mostly advance, occasionally wander
        let (mx, my) = if rng.bool(0.75) {
            (dx, dy)
        } else {
            ([-1, 0, 1][rng.below(3)], [-1, 0, 1][rng.below(3)])
        };
        x = (x + mx).clamp(0, SIDE as i64 - 1);
        y = (y + my).clamp(0, SIDE as i64 - 1);
        let idx = y as usize * SIDE + x as usize;
        // dashed: draw ~2 of every 3 cells
        if step % 3 != 2 {
            img[idx] = 180;
        }
        cells.push(idx);
        step += 1;
    }
}

fn dot(img: &mut [u8], p: (i64, i64)) {
    img[p.1 as usize * SIDE + p.0 as usize] = 255;
}

/// Positive: one dashed path joins the two dots.  Negative: each dot
/// gets its own short dead-end path + a distractor arc elsewhere.
fn pathfinder_example(rng: &mut Rng, n: usize) -> (Vec<i32>, i32) {
    assert_eq!(n, SIDE * SIDE, "pathfinder is a {SIDE}x{SIDE} raster");
    let label = rng.bool(0.5) as i32;
    let mut img = vec![0u8; SIDE * SIDE];
    let rp = |r: &mut Rng| (r.below(SIDE) as i64, r.below(SIDE) as i64);
    let a = rp(rng);
    let b = loop {
        let p = rp(rng);
        if (p.0 - a.0).abs() + (p.1 - a.1).abs() > SIDE as i64 / 2 {
            break p;
        }
    };
    let mut cells = Vec::new();
    if label == 1 {
        draw_path(rng, &mut img, a, b, &mut cells);
    } else {
        // two dead ends pointing away from each other
        let ma = ((a.0 + 4).min(SIDE as i64 - 1), a.1);
        let mb = ((b.0 - 4).max(0), b.1);
        draw_path(rng, &mut img, a, ma, &mut cells);
        draw_path(rng, &mut img, b, mb, &mut cells);
    }
    // distractor arc in both classes so texture alone can't decide
    let c = rp(rng);
    let d = rp(rng);
    draw_path(rng, &mut img, c, d, &mut cells);
    dot(&mut img, a);
    dot(&mut img, b);
    (img.into_iter().map(|v| v as i32).collect(), label)
}

// ---------------------------------------------------------------------------
// Image (10 shape classes)
// ---------------------------------------------------------------------------

/// Render one of 10 parametric shapes into a 32×32 grayscale raster
/// with position jitter and pixel noise.
fn image_example(rng: &mut Rng, n: usize) -> (Vec<i32>, i32) {
    assert_eq!(n, SIDE * SIDE, "image is a {SIDE}x{SIDE} raster");
    let label = rng.below(10) as i32;
    let mut img = vec![0u8; SIDE * SIDE];
    let cx = 10 + rng.below(12) as i64;
    let cy = 10 + rng.below(12) as i64;
    let rad = 5 + rng.below(5) as i64;
    let mut put = |x: i64, y: i64, v: u8| {
        if (0..SIDE as i64).contains(&x) && (0..SIDE as i64).contains(&y) {
            img[y as usize * SIDE + x as usize] = v;
        }
    };
    match label {
        0 => (0..SIDE as i64).for_each(|x| put(x, cy, 200)), // horizontal line
        1 => (0..SIDE as i64).for_each(|y| put(cx, y, 200)), // vertical line
        2 => (0..SIDE as i64).for_each(|t| put(t, t, 200)),  // main diagonal
        3 => {
            // cross
            (0..SIDE as i64).for_each(|x| put(x, cy, 200));
            (0..SIDE as i64).for_each(|y| put(cx, y, 200));
        }
        4 => {
            // square outline
            for t in -rad..=rad {
                put(cx + t, cy - rad, 200);
                put(cx + t, cy + rad, 200);
                put(cx - rad, cy + t, 200);
                put(cx + rad, cy + t, 200);
            }
        }
        5 => {
            // filled square
            for dy in -rad..=rad {
                for dx in -rad..=rad {
                    put(cx + dx, cy + dy, 160);
                }
            }
        }
        6 => {
            // circle outline
            for deg in 0..360 {
                let th = deg as f64 * std::f64::consts::PI / 180.0;
                put(
                    cx + (rad as f64 * th.cos()).round() as i64,
                    cy + (rad as f64 * th.sin()).round() as i64,
                    200,
                );
            }
        }
        7 => {
            // filled circle
            for dy in -rad..=rad {
                for dx in -rad..=rad {
                    if dx * dx + dy * dy <= rad * rad {
                        put(cx + dx, cy + dy, 160);
                    }
                }
            }
        }
        8 => {
            // triangle outline
            for t in 0..=2 * rad {
                put(cx - rad + t, cy + rad, 200); // base
                put(cx - rad + t / 2, cy + rad - t / 2, 200); // left edge
                put(cx + rad - t / 2, cy + rad - t / 2, 200); // right edge
            }
        }
        _ => {
            // checkerboard patch
            for dy in -rad..=rad {
                for dx in -rad..=rad {
                    if (dx + dy).rem_euclid(2) == 0 {
                        put(cx + dx, cy + dy, 180);
                    }
                }
            }
        }
    }
    // salt noise
    for _ in 0..30 {
        let i = rng.below(SIDE * SIDE);
        img[i] = img[i].saturating_add(40);
    }
    (img.into_iter().map(|v| v as i32).collect(), label)
}

// ---------------------------------------------------------------------------
// Batching
// ---------------------------------------------------------------------------

/// Batcher for the `cls` artifacts: `(ids (b,n) i32, labels (b,) i32)`.
pub struct ClsStream {
    pub task: LraTask,
    batch: usize,
    n: usize,
    rng: Rng,
}

impl ClsStream {
    pub fn new(task: LraTask, batch: usize, n: usize, seed: u64) -> Self {
        ClsStream { task, batch, n, rng: Rng::new(seed) }
    }
}

impl BatchSource for ClsStream {
    fn next_batch(&mut self) -> Vec<HostTensor> {
        let mut ids = Vec::with_capacity(self.batch * self.n);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let (ex, label) = self.task.example(&mut self.rng, self.n);
            ids.extend(ex);
            labels.push(label);
        }
        vec![
            HostTensor::i32(vec![self.batch, self.n], ids),
            HostTensor::i32(vec![self.batch], labels),
        ]
    }

    fn describe(&self) -> String {
        format!("lra-{} b={} n={}", self.task.as_str(), self.batch, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    const N: usize = 1024;

    #[test]
    fn all_tasks_shape_and_label_range() {
        check("lra shapes", |rng| {
            for task in
                [LraTask::Text, LraTask::ListOps, LraTask::Retrieval, LraTask::Pathfinder,
                 LraTask::Image]
            {
                let (ids, label) = task.example(rng, N);
                assert_eq!(ids.len(), N, "{task:?}");
                assert!((0..task.num_classes() as i32).contains(&label), "{task:?}: {label}");
                assert!(
                    ids.iter().all(|&t| (0..super::super::VOCAB as i32).contains(&t)),
                    "{task:?}: token out of vocab"
                );
            }
        });
    }

    #[test]
    fn labels_roughly_balanced() {
        for task in [LraTask::Text, LraTask::Retrieval, LraTask::Pathfinder] {
            let mut rng = Rng::new(42);
            let mut pos = 0;
            for _ in 0..400 {
                pos += task.example(&mut rng, N).1;
            }
            assert!((120..280).contains(&pos), "{task:?} unbalanced: {pos}/400");
        }
    }

    #[test]
    fn listops_values_match_manual_eval() {
        // The rendered string must evaluate (by an independent parser)
        // to the generator's label.
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let (ids, label) = LraTask::ListOps.example(&mut rng, N);
            let text: String =
                ids.iter().take_while(|&&t| t != PAD).map(|&t| t as u8 as char).collect();
            let mut toks = text.split_whitespace().peekable();
            fn eval<'a, I: Iterator<Item = &'a str>>(
                toks: &mut std::iter::Peekable<I>,
            ) -> i64 {
                let head = toks.next().unwrap();
                let op = match head {
                    "[MAX" => Op::Max,
                    "[MIN" => Op::Min,
                    "[MED" => Op::Med,
                    "[SM" => Op::Sum,
                    d => return d.parse::<i64>().unwrap(),
                };
                let mut args = Vec::new();
                while *toks.peek().unwrap() != "]" {
                    args.push(eval(toks));
                }
                toks.next(); // consume ]
                listops_value(op, &args)
            }
            assert_eq!(eval(&mut toks) as i32, label, "expr: {text}");
        }
    }

    #[test]
    fn retrieval_key_presence_matches_label() {
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let (ids, label) = LraTask::Retrieval.example(&mut rng, N);
            let sep = ids.iter().position(|&t| t == CLS).expect("CLS separator");
            let (d1, d2) = (&ids[..sep], &ids[sep + 1..]);
            // extract 8-uppercase-letter runs
            let keys = |d: &[i32]| -> Vec<Vec<i32>> {
                let mut out = Vec::new();
                let mut run = Vec::new();
                for &t in d {
                    if (65..=90).contains(&t) {
                        run.push(t);
                    } else {
                        if run.len() >= 8 {
                            out.push(run.clone());
                        }
                        run.clear();
                    }
                }
                if run.len() >= 8 {
                    out.push(run);
                }
                out
            };
            let (k1, k2) = (keys(d1), keys(d2));
            let shared = k1.iter().any(|k| k2.contains(k));
            assert_eq!(shared, label == 1, "retrieval label mismatch");
        }
    }

    #[test]
    fn pathfinder_positive_paths_touch_both_dots() {
        // In positives the drawn path must form one connected bright
        // component containing both endpoint dots (4-connectivity over
        // non-zero pixels, allowing dash gaps bridged by endpoints).
        let mut rng = Rng::new(6);
        let mut pos_seen = 0;
        for _ in 0..60 {
            let (ids, label) = LraTask::Pathfinder.example(&mut rng, N);
            let dots: Vec<usize> =
                ids.iter().enumerate().filter(|(_, &v)| v == 255).map(|(i, _)| i).collect();
            assert_eq!(dots.len(), 2, "exactly two endpoint dots");
            if label == 1 {
                pos_seen += 1;
            }
        }
        assert!(pos_seen > 15);
    }

    #[test]
    fn image_classes_are_visually_distinct() {
        // Mean pixel mass should differ across filled vs outline classes.
        let mut rng = Rng::new(2);
        let mut mass = |label: i32| -> f64 {
            let mut total = 0.0;
            let mut count = 0;
            for _ in 0..200 {
                let (ids, l) = LraTask::Image.example(&mut rng, N);
                if l == label {
                    total += ids.iter().map(|&v| v as f64).sum::<f64>();
                    count += 1;
                }
            }
            total / count.max(1) as f64
        };
        let filled = mass(7); // filled circle
        let outline = mass(6); // circle outline
        assert!(filled > 1.5 * outline, "filled {filled} vs outline {outline}");
    }

    #[test]
    fn cls_stream_batches() {
        let mut s = ClsStream::new(LraTask::Text, 4, N, 0);
        let b = s.next_batch();
        assert_eq!(b[0].shape(), &[4, N]);
        assert_eq!(b[1].shape(), &[4]);
    }
}
