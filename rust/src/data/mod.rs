//! Data pipeline — synthetic stand-ins for the paper's corpora.
//!
//! The paper trains on Wikitext-103 and evaluates on the Long-Range
//! Arena; neither is available offline, so this module generates
//! deterministic synthetic equivalents that exercise the *same code
//! paths and learning dynamics* (documented in DESIGN.md
//! §Substitutions):
//!
//! * [`corpus`] — a probabilistic-grammar byte corpus with n-gram and
//!   long-range structure (agreement, bracket matching, topic words)
//!   standing in for Wikitext-103.
//! * [`lm`] — causal and masked LM batchers over a token stream, with
//!   deterministic train/val splits.
//! * [`lra`] — five LRA-style classification task generators (text,
//!   listops, retrieval, pathfinder, image) with the benchmark's
//!   structural challenges at the same sequence lengths.
//!
//! Everything is seeded and allocation-conscious; batch tensors are
//! plain [`HostTensor`]s so generation can run on a prefetch thread
//! (XLA handles are not `Send`; see `runtime::tensor`).

pub mod corpus;
pub mod lm;
pub mod lra;

pub use corpus::Corpus;
pub use lm::{CausalLmStream, MaskedLmStream, Split};
pub use lra::{ClsStream, LraTask};

use crate::runtime::HostTensor;

/// Special token ids shared with `python/compile/configs.py`.
pub const PAD: i32 = 256;
pub const MASK: i32 = 257;
pub const CLS: i32 = 258;
/// Vocabulary size (256 bytes + PAD + MASK + CLS).
pub const VOCAB: usize = 259;

/// A source of training batches, consumed by the coordinator.
///
/// Implementations must be deterministic functions of their seed so
/// runs are reproducible and the prefetch thread can be interleaved
/// freely.
pub trait BatchSource: Send {
    /// Produce the next batch, matching the manifest's batch inputs.
    fn next_batch(&mut self) -> Vec<HostTensor>;
    /// Human-readable description for logs.
    fn describe(&self) -> String;
}
