//! Language-model batchers over a token stream.
//!
//! * [`CausalLmStream`] — `(b, n+1)` windows for next-token prediction
//!   (the `lm_causal` artifact's single batch input).
//! * [`MaskedLmStream`] — `(ids, tgt, mask)` triples with BERT-style
//!   token masking (the `lm_bidir` artifact's inputs), mirroring
//!   `model.mask_batch_tokens` on the python side.
//!
//! Streams draw random windows from a disjoint train/val [`Split`] of
//! the corpus; every stream is a pure function of `(corpus seed,
//! stream seed)` so validation batches are identical across evals and
//! across runs.

use std::sync::Arc;

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

use super::{BatchSource, MASK};

/// Which contiguous region of the corpus a stream samples from.
/// The last 10% of tokens are validation; no window crosses the split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

fn split_bounds(len: usize, split: Split) -> (usize, usize) {
    let cut = len - len / 10;
    match split {
        Split::Train => (0, cut),
        Split::Val => (cut, len),
    }
}

/// Random fixed-length windows for causal LM training.
pub struct CausalLmStream {
    tokens: Arc<Vec<i32>>,
    lo: usize,
    hi: usize,
    batch: usize,
    n: usize,
    rng: Rng,
    split: Split,
}

impl CausalLmStream {
    /// `n` is the model context (the batch tensor is `(batch, n+1)`).
    pub fn new(tokens: Arc<Vec<i32>>, split: Split, batch: usize, n: usize, seed: u64) -> Self {
        let (lo, hi) = split_bounds(tokens.len(), split);
        assert!(hi - lo > n + 1, "split too small for window {n}");
        CausalLmStream { tokens, lo, hi, batch, n, rng: Rng::new(seed), split }
    }
}

impl BatchSource for CausalLmStream {
    fn next_batch(&mut self) -> Vec<HostTensor> {
        let w = self.n + 1;
        let mut data = Vec::with_capacity(self.batch * w);
        for _ in 0..self.batch {
            let start = self.lo + self.rng.below(self.hi - self.lo - w);
            data.extend_from_slice(&self.tokens[start..start + w]);
        }
        vec![HostTensor::i32(vec![self.batch, w], data)]
    }

    fn describe(&self) -> String {
        format!("causal-lm {:?} b={} n={}", self.split, self.batch, self.n)
    }
}

/// Masking rate for the bidirectional objective (matches the python
/// reference `mask_batch_tokens` default).
pub const MASK_RATE: f64 = 0.15;

/// BERT-style masked-LM batches: `(ids, tgt, mask)`.
pub struct MaskedLmStream {
    tokens: Arc<Vec<i32>>,
    lo: usize,
    hi: usize,
    batch: usize,
    n: usize,
    rng: Rng,
    split: Split,
}

impl MaskedLmStream {
    pub fn new(tokens: Arc<Vec<i32>>, split: Split, batch: usize, n: usize, seed: u64) -> Self {
        let (lo, hi) = split_bounds(tokens.len(), split);
        assert!(hi - lo > n, "split too small for window {n}");
        MaskedLmStream { tokens, lo, hi, batch, n, rng: Rng::new(seed), split }
    }
}

impl BatchSource for MaskedLmStream {
    fn next_batch(&mut self) -> Vec<HostTensor> {
        let (b, n) = (self.batch, self.n);
        let mut ids = Vec::with_capacity(b * n);
        let mut tgt = Vec::with_capacity(b * n);
        let mut mask = Vec::with_capacity(b * n);
        for _ in 0..b {
            let start = self.lo + self.rng.below(self.hi - self.lo - n);
            let window = &self.tokens[start..start + n];
            let mut any = false;
            for &tok in window {
                let m = self.rng.bool(MASK_RATE);
                any |= m;
                ids.push(if m { MASK } else { tok });
                tgt.push(tok);
                mask.push(if m { 1.0f32 } else { 0.0 });
            }
            // Guarantee ≥1 masked position per row so the loss
            // denominator (sum of mask) is never saturated by the
            // max(·, 1) guard.
            if !any {
                let j = ids.len() - n + self.rng.below(n);
                ids[j] = MASK;
                mask[j] = 1.0;
            }
        }
        vec![
            HostTensor::i32(vec![b, n], ids),
            HostTensor::i32(vec![b, n], tgt),
            HostTensor::f32(vec![b, n], mask),
        ]
    }

    fn describe(&self) -> String {
        format!("masked-lm {:?} b={} n={}", self.split, self.batch, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;

    fn toks() -> Arc<Vec<i32>> {
        Arc::new(Corpus::generate(0, 50_000).tokens())
    }

    #[test]
    fn causal_shapes_and_determinism() {
        let t = toks();
        let mut a = CausalLmStream::new(t.clone(), Split::Train, 4, 64, 9);
        let mut b = CausalLmStream::new(t, Split::Train, 4, 64, 9);
        let ba = a.next_batch();
        let bb = b.next_batch();
        assert_eq!(ba, bb, "same seed ⇒ same batches");
        assert_eq!(ba[0].shape(), &[4, 65]);
    }

    #[test]
    fn splits_are_disjoint() {
        let t = toks();
        let n = t.len();
        let (tl, th) = split_bounds(n, Split::Train);
        let (vl, vh) = split_bounds(n, Split::Val);
        assert_eq!(th, vl);
        assert_eq!(tl, 0);
        assert_eq!(vh, n);
        // windows stay inside their split
        let mut s = CausalLmStream::new(t.clone(), Split::Val, 8, 32, 1);
        for _ in 0..20 {
            let b = s.next_batch();
            let ids = b[0].as_i32().unwrap();
            // all val windows must match some suffix slice of the corpus
            assert!(ids.iter().all(|&x| (0..256).contains(&x)));
        }
    }

    #[test]
    fn masked_stream_invariants() {
        let t = toks();
        let mut s = MaskedLmStream::new(t, Split::Train, 4, 128, 3);
        for _ in 0..10 {
            let b = s.next_batch();
            let (ids, tgt, mask) =
                (b[0].as_i32().unwrap(), b[1].as_i32().unwrap(), b[2].as_f32().unwrap());
            let mut frac = 0.0;
            for i in 0..ids.len() {
                if mask[i] > 0.5 {
                    assert_eq!(ids[i], MASK, "masked position must carry MASK id");
                } else {
                    assert_eq!(ids[i], tgt[i], "unmasked position must be identity");
                }
                assert!((0..256).contains(&tgt[i]), "targets are raw bytes");
                frac += f64::from(mask[i]);
            }
            frac /= ids.len() as f64;
            assert!((0.05..0.3).contains(&frac), "mask rate {frac} out of band");
            // every row has at least one masked position
            for row in mask.chunks(128) {
                assert!(row.iter().any(|&m| m > 0.5));
            }
        }
    }
}
